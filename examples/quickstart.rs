//! Quickstart: the smallest end-to-end CaraServe run.
//!
//! Loads the AOT artifacts (run `make artifacts` first), stands up one
//! inference server with CPU-assisted cold-start handling, serves three
//! multi-tenant LoRA requests, and prints the generated tokens and
//! latency metrics.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use caraserve::model::LoraSpec;
use caraserve::runtime::ModelRuntime;
use caraserve::server::{ColdStartMode, EngineConfig, InferenceRequest, InferenceServer};

fn main() -> anyhow::Result<()> {
    // 1. Load the compiled model (HLO text → PJRT executables).
    let artifacts = std::path::Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let runtime = ModelRuntime::load(artifacts)?;
    println!(
        "loaded {} artifacts (hidden={}, layers={}, vocab={})",
        runtime.manifest.artifacts.len(),
        runtime.hidden,
        runtime.layers,
        runtime.vocab
    );

    // 2. Stand up a server with CaraServe's cold-start overlap.
    let mut server = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: ColdStartMode::CaraServe,
            ..Default::default()
        },
    )?;
    for id in 0..3 {
        server.install_adapter(LoraSpec::standard(id, 8, "tiny"));
    }

    // 3. Serve three requests against three different LoRA adapters.
    for (id, adapter) in [(0u64, 0u64), (1, 1), (2, 2)] {
        server.submit(InferenceRequest {
            id,
            adapter,
            prompt: (0..12).map(|i| (i * 83 + id as i32 * 17) % 1024).collect(),
            max_new_tokens: 8,
        })?;
    }
    server.run_until_idle()?;

    // 4. Inspect outputs + metrics.
    for out in server.outputs() {
        println!("request {} → tokens {:?}", out.id, out.tokens);
    }
    for metric in ["ttft", "tpt", "latency"] {
        if let Some(s) = server.metrics().summary(metric) {
            println!("{metric:>8}: mean {:.2} ms, p99 {:.2} ms", s.mean * 1e3, s.p99 * 1e3);
        }
    }
    Ok(())
}
