//! Quickstart: the smallest end-to-end CaraServe run, on the streaming
//! request-lifecycle API.
//!
//! Loads the AOT artifacts (run `make artifacts` first), stands up one
//! inference server with CPU-assisted cold-start handling, streams three
//! multi-tenant LoRA requests through [`RequestHandle`] event streams,
//! and prints the generated tokens and latency metrics.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use caraserve::model::LoraSpec;
use caraserve::runtime::ModelRuntime;
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, Priority, RequestEvent, ServeRequest,
    ServingFront,
};

fn main() -> anyhow::Result<()> {
    // 1. Load the compiled model (HLO text → PJRT executables).
    let artifacts = std::path::Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let runtime = ModelRuntime::load(artifacts)?;
    println!(
        "loaded {} artifacts (hidden={}, layers={}, vocab={})",
        runtime.manifest.artifacts.len(),
        runtime.hidden,
        runtime.layers,
        runtime.vocab
    );

    // 2. Stand up a server with CaraServe's cold-start overlap.
    let mut server = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: ColdStartMode::CaraServe,
            ..Default::default()
        },
    )?;
    for id in 0..3 {
        server.install_adapter(&LoraSpec::standard(id, 8, "tiny"))?;
    }

    // 3. Submit three requests against three different LoRA adapters.
    //    Each submit returns a handle streaming that request's lifecycle.
    let handles: Vec<_> = (0..3u64)
        .map(|adapter| {
            server.submit(
                ServeRequest::new(
                    adapter,
                    (0..12).map(|i| (i * 83 + adapter as i32 * 17) % 1024).collect(),
                )
                .max_new_tokens(8)
                .priority(Priority::Standard)
                .slo(200.0, 50.0),
            )
        })
        .collect();
    server.run_until_idle()?;

    // 4. Drain each handle's event stream and inspect metrics.
    for handle in &handles {
        print!("request {}:", handle.id());
        for event in handle.drain_events() {
            match event {
                RequestEvent::Admitted => print!(" admitted"),
                RequestEvent::FirstToken(t) => print!(" | first {t}"),
                RequestEvent::Token(t) => print!(" {t}"),
                RequestEvent::Finished(reason) => print!(" | finished ({reason:?})"),
                RequestEvent::Cancelled => print!(" | cancelled"),
                RequestEvent::Rejected(why) => print!(" | rejected: {why}"),
            }
        }
        println!(" → tokens {:?}", handle.tokens());
    }
    for metric in ["ttft", "tpot", "latency"] {
        if let Some(s) = server.metrics().summary(metric) {
            println!("{metric:>8}: mean {:.2} ms, p99 {:.2} ms", s.mean * 1e3, s.p99 * 1e3);
        }
    }
    if let Some(att) = server.metrics().slo_attainment() {
        println!("SLO attainment: {:.0}%", att * 100.0);
    }
    Ok(())
}
