//! Multi-tenant serving study (the paper's §7.2 scenario at simulator
//! scale): one Llama2-7B/A10 server multiplexing hundreds of LoRA
//! adapters under a skewed MAF-like workload, comparing all four
//! serving modes on the three user-facing metrics.
//!
//! ```sh
//! cargo run --release --example multi_tenant_serving
//! ```

use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::server::{ServeRequest, ServingFront};
use caraserve::sim::{
    GpuModel, MafTrace, ServingMode, SimFront, SimInstance, Simulation, SingleServer,
};
use caraserve::util::stats::Summary;

/// Per-token decode SLO used for the attainment column (≈ the §7.5
/// setting: 1.5× the unloaded decode latency).
const TPOT_SLO_S: f64 = 36e-3;

fn main() {
    let n_adapters = 512;
    let rps = MafTrace::scaled_rps(n_adapters); // 7.7 (paper §7.2)
    let trace = MafTrace::new(7, n_adapters, 1.0, &[64]);
    let reqs = trace.generate(11, rps, 300.0);
    println!(
        "workload: {} adapters (MAF-skewed), {:.1} rps, {} requests over 300s\n",
        n_adapters,
        rps,
        reqs.len()
    );

    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>12} {:>10}",
        "mode", "ttft (ms)", "tpt (ms)", "latency (ms)", "cold (%)", "slo (%)"
    );
    let mut cached_ttft = None;
    for mode in [
        ServingMode::Cached,
        ServingMode::OnDemand,
        ServingMode::SLora,
        ServingMode::CaraServe,
    ] {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut sim =
            Simulation::new(vec![SimInstance::new(0, model, mode, 64, 32, 128)]);
        let out = sim.run(&reqs, &mut SingleServer);
        let ttft = Summary::of(&out.column("ttft")).unwrap();
        let tpt = Summary::of(&out.column("tpt")).unwrap();
        let lat = Summary::of(&out.column("latency")).unwrap();
        let cold = Summary::of(&out.column("cold_frac")).unwrap();
        println!(
            "{:<10} {:>12.2} {:>12.2} {:>14.1} {:>12.2} {:>10.1}",
            mode.name(),
            ttft.mean * 1e3,
            tpt.mean * 1e3,
            lat.mean * 1e3,
            cold.mean * 1e2,
            out.slo_attainment(TPOT_SLO_S) * 1e2
        );
        if mode == ServingMode::Cached {
            cached_ttft = Some(ttft.mean);
        }
    }
    if let Some(base) = cached_ttft {
        println!(
            "\n(overheads are relative to the CACHED oracle, ttft {base_ms:.1} ms — \
             the paper's §7.2 comparison; slo = tpt ≤ {slo_ms:.0} ms)",
            base_ms = base * 1e3,
            slo_ms = TPOT_SLO_S * 1e3
        );
    }

    // The same simulator also speaks the streaming lifecycle API: one
    // request through a SimFront, event by event.
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let inst = SimInstance::new(0, model, ServingMode::CaraServe, 64, 32, 128);
    let mut front = SimFront::new(inst, 512);
    front.register_adapter(1, 64);
    let handle = front.submit(
        ServeRequest::new(1, vec![1; 32])
            .max_new_tokens(6)
            .slo(200.0, TPOT_SLO_S * 1e3),
    );
    front.run_until_idle().expect("sim front");
    println!(
        "\nstreaming demo (SimFront): request {} → events {:?}",
        handle.id(),
        handle.drain_events()
    );
}
