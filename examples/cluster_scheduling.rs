//! Cluster scheduling study (the paper's §7.5 scenario): heterogeneous
//! LoRA requests routed across 8 inference servers by four policies;
//! reports SLO attainment and mean time-per-token for both kernel
//! backends (BGMV and MBGMV).
//!
//! ```sh
//! cargo run --release --example cluster_scheduling
//! ```

use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{profiler, KernelKind};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::sim::{GpuModel, MafTrace, ServingMode, SimInstance, Simulation};
use caraserve::util::stats::{mean, percentile};

fn main() {
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let avg_ctx = 160;
    // SLO = 1.5× what the HF-PEFT-style (one request per model) setup
    // achieves (§7.5).
    let slo = 1.5 * gm.decode_iter(&[avg_ctx]);
    println!("SLO: time per token ≤ {:.1} ms\n", slo * 1e3);

    for kernel in [KernelKind::Bgmv, KernelKind::Mbgmv] {
        // Fit the §5 performance models by profiling.
        let plan = profiler::ProfilePlan::default();
        let g1 = gm.clone();
        let dec = profiler::calibrate(kernel, &plan, |ranks| {
            g1.decode_iter(&vec![avg_ctx; ranks.len()])
                + g1.lora_decode_overhead(kernel, ranks)
        })
        .unwrap();
        let g2 = gm.clone();
        let pre =
            profiler::calibrate(kernel, &plan, |ranks| g2.prefill(ranks.len() * 28)).unwrap();
        println!(
            "[{kernel:?}] perf model: alpha={:.2e}, beta={:.1} ms, R²={:.3}",
            dec.alpha,
            dec.beta * 1e3,
            dec.r2
        );

        let mode = match kernel {
            KernelKind::Bgmv => ServingMode::CaraServe,
            KernelKind::Mbgmv => ServingMode::SLora,
        };
        let trace = MafTrace::new(3, 2048, 1.0, &[8, 16, 32, 64]);
        let reqs = trace.generate(5, 45.0, 120.0);
        println!(
            "  workload: {} requests over 120 s across 8 instances",
            reqs.len()
        );
        println!(
            "  {:<12} {:>14} {:>16} {:>15}",
            "policy", "SLO attain", "mean tpt (ms)", "p99 tpt (ms)"
        );
        for policy_name in ["rank-aware", "most-idle", "first-fit", "random"] {
            let instances: Vec<SimInstance> = (0..8)
                .map(|i| SimInstance::new(i, gm.clone(), mode, 48, 32, 512))
                .collect();
            let mut policy = policy_by_name(
                policy_name,
                pre.clone(),
                dec.clone(),
                RankAwareConfig {
                    slo,
                    ..Default::default()
                },
                42,
            )
            .expect("known policy");
            let mut sim = Simulation::new(instances);
            let out = sim.run(&reqs, policy.as_mut());
            let tpt = out.column("tpt");
            println!(
                "  {:<12} {:>13.1}% {:>16.2} {:>15.2}",
                policy_name,
                out.slo_attainment(slo) * 100.0,
                mean(&tpt) * 1e3,
                percentile(&tpt, 99.0) * 1e3
            );
        }
        println!();
    }

    // The same policies also route *real* engines: ClusterFront puts the
    // scheduler in front of live native-runtime InferenceServers behind
    // the identical ServingFront surface (`caraserve cluster` is the
    // full driver; benches/cluster_slo.rs the measured comparison).
    use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
    let cfg = SyntheticConfig {
        instances: 2,
        requests: 12,
        adapters: 16,
        ..Default::default()
    };
    println!("live-engine cluster (2 native runtimes, 12 requests):");
    for policy in ["rank-aware", "random"] {
        let rep = synthetic::run(policy, &cfg).expect("cluster run");
        println!(
            "  {:<12} finished {:>2}/{:<2}  SLO {:>5.1}%  routed {:?} (rank sums {:?})",
            rep.policy,
            rep.finished,
            rep.requests,
            rep.slo_attainment.unwrap_or(1.0) * 100.0,
            rep.routed,
            rep.routed_rank_sum
        );
    }
}
