//! End-to-end driver (the EXPERIMENTS.md validation run): serve a
//! sustained multi-tenant batch of requests under each cold-start mode
//! through the streaming lifecycle API, reporting latency, throughput,
//! SLO attainment, and the TTFT cold-start decomposition — proving the
//! layers compose on a real workload.
//!
//! Uses the PJRT runtime when artifacts are built (`make artifacts`),
//! otherwise the native pure-Rust runtime — where `CaraServe` mode runs
//! the paper's *real* CPU-assisted path: prefill starts immediately with
//! shm-worker `xAB` deltas while the adapter load window runs
//! asynchronously, and decode hands off to the resident path when it
//! completes.
//!
//! ```sh
//! cargo run --release --example e2e_serving
//! ```

use std::path::Path;
use std::time::Instant;

use caraserve::model::LoraSpec;
use caraserve::runtime::{ModelRuntime, NativeConfig, NativeRuntime, Runtime};
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, LifecycleState, ServeRequest,
    ServingFront,
};
use caraserve::util::rng::Rng;

const N_REQUESTS: usize = 48;
const N_ADAPTERS: u64 = 64;

fn workload(seed: u64) -> Vec<ServeRequest> {
    let mut rng = Rng::new(seed);
    (0..N_REQUESTS)
        .map(|_| {
            // 64 adapters over 8 device slots → plenty of cold starts.
            let adapter = rng.range(0, N_ADAPTERS as usize) as u64;
            let prompt: Vec<i32> = (0..rng.range(8, 32))
                .map(|_| rng.range(0, 1024) as i32)
                .collect();
            ServeRequest::new(adapter, prompt)
                .max_new_tokens(rng.range(4, 12))
                .slo(250.0, 60.0)
        })
        .collect()
}

fn backend() -> anyhow::Result<Runtime> {
    if Path::new("artifacts/manifest.json").exists() {
        Ok(ModelRuntime::load(Path::new("artifacts"))?.into())
    } else {
        Ok(NativeRuntime::new(NativeConfig::tiny()).into())
    }
}

fn run_mode(mode: ColdStartMode) -> anyhow::Result<()> {
    let mut server = InferenceServer::new(
        backend()?,
        EngineConfig {
            cold_start: mode,
            ..Default::default()
        },
    )?;
    for id in 0..N_ADAPTERS {
        server.install_adapter(&LoraSpec::standard(id, 8, "tiny"))?;
    }
    // 4 shm CPU-LoRA workers: on the native backend this makes CaraServe
    // cold starts the real §4 mechanism rather than a modeled window.
    // Other modes/backends never plan an assist row — don't spawn a pool
    // they can't use.
    if mode == ColdStartMode::CaraServe && server.runtime.supports_cpu_assist() {
        server.enable_cpu_assist(4)?;
    }

    let reqs = workload(2024);
    let total_tokens: usize = reqs.iter().map(|r| r.sampling.max_new_tokens).sum();
    let t0 = Instant::now();
    let handles: Vec<_> = reqs.into_iter().map(|r| server.submit(r)).collect();
    server.run_until_idle()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n--- mode {mode:?} ---");
    for metric in ["ttft", "tpot", "latency"] {
        if let Some(s) = server.metrics().summary(metric) {
            println!(
                "{metric:>8}: mean {:8.2} ms   p50 {:8.2} ms   p99 {:8.2} ms",
                s.mean * 1e3,
                s.p50 * 1e3,
                s.p99 * 1e3
            );
        }
    }
    let cs = server.metrics().cold_start();
    println!(
        "cold starts: {} cold / {} warm, {} CPU-assisted, {} handoffs",
        cs.cold_admits, cs.warm_admits, cs.cpu_assisted, cs.handoffs
    );
    if let Some(att) = server.metrics().slo_attainment() {
        println!("SLO (250 ms ttft / 60 ms tpot): attainment {:5.1}%", att * 100.0);
    }
    let finished = handles
        .iter()
        .filter(|h| h.state() == LifecycleState::Finished)
        .count();
    let (rps, tps) = server.metrics().throughput(wall);
    println!(
        "completed {finished} requests / {total_tokens} tokens in {wall:.2}s → {rps:.1} req/s, {tps:.1} tok/s"
    );
    anyhow::ensure!(finished == N_REQUESTS, "request loss");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let backend_name = if Path::new("artifacts/manifest.json").exists() {
        "pjrt artifacts"
    } else {
        "native runtime"
    };
    println!(
        "e2e serving on {backend_name}: {N_REQUESTS} requests, {N_ADAPTERS} adapters over 8 device slots"
    );
    // Cached (oracle) vs OnDemand (cold-start serialized) vs CaraServe
    // (cold-start hidden by CPU assist): the §7.2 comparison.
    run_mode(ColdStartMode::Cached)?;
    run_mode(ColdStartMode::OnDemand)?;
    run_mode(ColdStartMode::CaraServe)?;
    println!("\nexpected shape: Cached ≤ CaraServe < OnDemand on TTFT (cold-start hiding)");
    Ok(())
}
