"""L2 model correctness: shapes, LoRA plumbing, and the decode/prefill
consistency invariant that the Rust serving path relies on."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import SLOT_RANKS, WEIGHTS_SEED


@pytest.fixture(scope="module")
def setup():
    w = M.init_weights(WEIGHTS_SEED)
    lora = M.init_lora(WEIGHTS_SEED, SLOT_RANKS)
    return w, lora


def prompts(rng, b, s):
    return jnp.asarray(rng.integers(0, M.TINY["vocab"], (b, s)), jnp.int32)


def test_prefill_shapes(setup):
    w, lora = setup
    rng = np.random.default_rng(0)
    tokens = prompts(rng, 2, 32)
    idx = jnp.asarray([0, 3], jnp.int32)
    lens = jnp.asarray([32, 20], jnp.int32)
    logits, kc, vc = M.prefill(w, lora, idx, tokens, lens)
    assert logits.shape == (2, M.TINY["vocab"])
    assert kc.shape == (M.TINY["layers"], 2, 32, M.TINY["hidden"])
    assert vc.shape == kc.shape
    assert bool(jnp.isfinite(logits).all())


def test_decode_shapes(setup):
    w, lora = setup
    l, h = M.TINY["layers"], M.TINY["hidden"]
    b, m = 4, 128
    kc = jnp.zeros((l, b, m, h), jnp.float32)
    vc = jnp.zeros((l, b, m, h), jnp.float32)
    idx = jnp.asarray([0, 1, 2, 3], jnp.int32)
    tokens = jnp.asarray([5, 6, 7, 8], jnp.int32)
    pos = jnp.asarray([0, 0, 0, 0], jnp.int32)
    logits, kn, vn = M.decode_step(w, lora, idx, tokens, pos, kc, vc)
    assert logits.shape == (b, M.TINY["vocab"])
    assert kn.shape == (l, b, h)
    assert vn.shape == (l, b, h)


def test_decode_consistent_with_prefill(setup):
    """Greedy-decoding one token via decode_step must equal prefilling
    the extended prompt — the invariant the continuous batcher relies on
    when a request transitions from prefill to decode."""
    w, lora = setup
    rng = np.random.default_rng(1)
    tokens = prompts(rng, 1, 16)
    idx = jnp.asarray([2], jnp.int32)
    lens = jnp.asarray([16], jnp.int32)
    logits, kc, vc = M.prefill(w, lora, idx, tokens, lens)
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)

    l, b, s, h = kc.shape
    m = 128
    kpad = jnp.zeros((l, b, m, h), jnp.float32).at[:, :, :s].set(kc)
    vpad = jnp.zeros((l, b, m, h), jnp.float32).at[:, :, :s].set(vc)
    logits_dec, _, _ = M.decode_step(w, lora, idx, next_tok, lens, kpad, vpad)

    ext = jnp.concatenate([tokens, next_tok[None]], axis=1)
    ext_pad = jnp.pad(ext, ((0, 0), (0, 15)))
    logits_pre, _, _ = M.prefill(w, lora, idx, ext_pad, jnp.asarray([17], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_pre), rtol=1e-3, atol=1e-4
    )


def test_padding_does_not_change_logits(setup):
    """The same prompt in a larger bucket must yield the same logits —
    the Rust router picks buckets freely."""
    w, lora = setup
    rng = np.random.default_rng(2)
    tokens16 = prompts(rng, 1, 16)
    idx = jnp.asarray([1], jnp.int32)
    lens = jnp.asarray([16], jnp.int32)
    lg16, _, _ = M.prefill(w, lora, idx, tokens16, lens)
    tokens32 = jnp.pad(tokens16, ((0, 0), (0, 16)))
    lg32, _, _ = M.prefill(w, lora, idx, tokens32, lens)
    np.testing.assert_allclose(
        np.asarray(lg16), np.asarray(lg32), rtol=1e-4, atol=1e-5
    )


def test_different_adapters_give_different_logits(setup):
    """LoRA must actually flow through the forward pass."""
    w, lora = setup
    rng = np.random.default_rng(3)
    tokens = prompts(rng, 1, 16)
    lens = jnp.asarray([16], jnp.int32)
    lg_a, _, _ = M.prefill(w, lora, jnp.asarray([0], jnp.int32), tokens, lens)
    lg_b, _, _ = M.prefill(w, lora, jnp.asarray([5], jnp.int32), tokens, lens)
    assert float(jnp.abs(lg_a - lg_b).max()) > 1e-3


def test_batch_order_invariance(setup):
    """Each request's output must not depend on its batch position —
    the invariant that lets the batcher reorder/join requests freely."""
    w, lora = setup
    rng = np.random.default_rng(4)
    t = prompts(rng, 2, 32)
    idx = jnp.asarray([0, 4], jnp.int32)
    lens = jnp.asarray([30, 22], jnp.int32)
    lg, _, _ = M.prefill(w, lora, idx, t, lens)
    lg_swap, _, _ = M.prefill(
        w, lora, idx[::-1], t[::-1], lens[::-1]
    )
    np.testing.assert_allclose(
        np.asarray(lg[0]), np.asarray(lg_swap[1]), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(lg[1]), np.asarray(lg_swap[0]), rtol=1e-4, atol=1e-5
    )


def test_lora_stacks_zero_padded_beyond_rank(setup):
    """init_lora must zero-pad so BGMV (padded) and MBGMV (masked) agree."""
    _, lora = setup
    ranks = np.asarray(SLOT_RANKS)
    a_q = np.asarray(lora["a_q"])  # [L, S, H, R]
    col = np.arange(M.LORA_MAX_RANK)
    for slot in range(M.LORA_SLOTS):
        dead = a_q[:, slot, :, :][:, :, col >= ranks[slot]]
        assert np.all(dead == 0.0), f"slot {slot} not zero-padded"


def test_bucket_specs_cover_manifest():
    pre, dec = M.bucket_specs()
    assert (1, 16) in pre and (4, 64) in pre
    assert all(m == 128 for _, m in dec)
    assert sorted(b for b, _ in dec) == [1, 2, 4, 8]
