"""L1 kernel correctness: Pallas BGMV/MBGMV vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; `numpy.testing.assert_allclose`
against `ref.py` is THE correctness signal for the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bgmv import bgmv, mbgmv

SETTINGS = dict(max_examples=25, deadline=None)


def make_case(rng, n, h, h2, s, r, dtype):
    x = rng.normal(size=(n, h)).astype(dtype)
    a = rng.normal(size=(s, h, r)).astype(dtype)
    b = rng.normal(size=(s, r, h2)).astype(dtype)
    idx = rng.integers(0, s, size=n).astype(np.int32)
    ranks = rng.integers(1, r + 1, size=s).astype(np.int32)
    return x, a, b, idx, ranks


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float16 else dict(
        rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(
    n=st.integers(1, 9),
    h=st.sampled_from([8, 16, 64]),
    h2=st.sampled_from([8, 32]),
    s=st.integers(1, 5),
    r=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bgmv_matches_ref(n, h, h2, s, r, seed):
    rng = np.random.default_rng(seed)
    x, a, b, idx, _ = make_case(rng, n, h, h2, s, r, np.float32)
    got = np.asarray(bgmv(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx)))
    want = np.asarray(ref.bgmv_ref(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx)))
    np.testing.assert_allclose(got, want, **tol(np.float32))


@settings(**SETTINGS)
@given(
    n=st.integers(1, 9),
    h=st.sampled_from([8, 16, 64]),
    s=st.integers(1, 5),
    r=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mbgmv_matches_ref(n, h, s, r, seed):
    rng = np.random.default_rng(seed)
    x, a, b, idx, ranks = make_case(rng, n, h, h, s, r, np.float32)
    got = np.asarray(
        mbgmv(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx), jnp.asarray(ranks))
    )
    want = np.asarray(
        ref.mbgmv_ref(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx), jnp.asarray(ranks))
    )
    np.testing.assert_allclose(got, want, **tol(np.float32))


@settings(**SETTINGS)
@given(
    n=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_bgmv_bf16(n, seed):
    """bfloat16 path (the deployment dtype on TPU)."""
    rng = np.random.default_rng(seed)
    x, a, b, idx, _ = make_case(rng, n, 16, 16, 3, 4, np.float32)
    xb, ab, bb = (jnp.asarray(v, jnp.bfloat16) for v in (x, a, b))
    got = np.asarray(bgmv(xb, ab, bb, jnp.asarray(idx)), np.float32)
    want = np.asarray(
        ref.bgmv_ref(xb, ab, bb, jnp.asarray(idx)), np.float32
    )
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_bgmv_equals_mbgmv_when_zero_padded():
    """With zero-padded stacks (what init_lora produces), the padded and
    padding-free kernels must agree — the numerical basis for comparing
    their perf models on the same workload."""
    rng = np.random.default_rng(7)
    s, h, r = 4, 32, 8
    ranks = np.asarray([2, 4, 8, 1], np.int32)
    a = rng.normal(size=(s, h, r)).astype(np.float32)
    b = rng.normal(size=(s, r, h)).astype(np.float32)
    col = np.arange(r)
    a *= (col[None, None, :] < ranks[:, None, None])
    b *= (col[None, :, None] < ranks[:, None, None])
    x = rng.normal(size=(6, h)).astype(np.float32)
    idx = rng.integers(0, s, size=6).astype(np.int32)
    y1 = np.asarray(bgmv(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx)))
    y2 = np.asarray(
        mbgmv(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx), jnp.asarray(ranks))
    )
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


def test_gather_selects_correct_adapter():
    """Adapters with distinguishable outputs: each token must use its own."""
    h = 8
    a = np.zeros((2, h, 1), np.float32)
    b = np.zeros((2, 1, h), np.float32)
    a[0, :, 0] = 1.0
    b[0, 0, :] = 1.0  # adapter 0: y = sum(x)
    a[1, :, 0] = 1.0
    b[1, 0, :] = -1.0  # adapter 1: y = -sum(x)
    x = np.ones((2, h), np.float32)
    idx = np.asarray([0, 1], np.int32)
    y = np.asarray(bgmv(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx)))
    np.testing.assert_allclose(y[0], np.full(h, 8.0), rtol=1e-6)
    np.testing.assert_allclose(y[1], np.full(h, -8.0), rtol=1e-6)


def test_single_token_batch():
    rng = np.random.default_rng(3)
    x, a, b, idx, _ = make_case(rng, 1, 16, 16, 1, 4, np.float32)
    got = np.asarray(bgmv(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx)))
    want = np.asarray(ref.bgmv_ref(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_zero_rank_mask_gives_zero_delta():
    """An adapter masked to rank 0 via MBGMV contributes nothing."""
    rng = np.random.default_rng(11)
    x, a, b, idx, _ = make_case(rng, 4, 16, 16, 2, 4, np.float32)
    ranks = np.zeros(2, np.int32)
    y = np.asarray(
        mbgmv(jnp.asarray(x), jnp.asarray(a), jnp.asarray(b), jnp.asarray(idx), jnp.asarray(ranks))
    )
    np.testing.assert_allclose(y, np.zeros_like(y), atol=1e-7)
