"""L1 Pallas kernels: BGMV (padded) and MBGMV (padding-free rank-masked).

These are the GPU-LoRA gather kernels of Punica / S-LoRA re-thought for
the TPU idiom (DESIGN.md §Hardware-Adaptation):

* one grid step per token (the CUDA version maps tokens to thread
  blocks); ``BlockSpec`` streams each token's activation row through
  VMEM while the (small) adapter stacks stay VMEM-resident;
* the per-token dynamic gather ``A[idx[n]]`` is a dynamic-slice on the
  leading axis — the Mosaic analogue of Punica's warp-level gather;
* BGMV does the full padded-rank matmul (cost ∝ max rank, Fig 4-Left);
  MBGMV masks the inactive columns so only the true rank contributes
  (cost ∝ Σ ranks on real hardware, Fig 4-Right).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
both the python tests and the Rust runtime can run. Real-TPU efficiency
is estimated analytically in DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bgmv_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref):
    """One token per grid step: o[n] = x[n] @ A[idx[n]] @ B[idx[n]]."""
    n = pl.program_id(0)
    j = idx_ref[n]
    x = x_ref[0, :]  # [H] — this token's activation row (VMEM block)
    a = a_ref[j]  # [H, R] dynamic gather from the adapter stack
    b = b_ref[j]  # [R, H2]
    t = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32))  # [R]
    y = jnp.dot(t, b.astype(jnp.float32))  # [H2]
    o_ref[0, :] = y.astype(o_ref.dtype)


def _mbgmv_kernel(idx_ref, ranks_ref, x_ref, a_ref, b_ref, o_ref):
    """Rank-masked variant: only the first ranks[idx[n]] columns count."""
    n = pl.program_id(0)
    j = idx_ref[n]
    x = x_ref[0, :]
    a = a_ref[j]
    b = b_ref[j]
    r = a.shape[-1]
    mask = (jnp.arange(r) < ranks_ref[j]).astype(jnp.float32)
    t = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32)) * mask
    y = jnp.dot(t, b.astype(jnp.float32))
    o_ref[0, :] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def bgmv(x, a_stack, b_stack, idx):
    """Padded BGMV: ``y[n] = x[n] @ A[idx[n]] @ B[idx[n]]``.

    Args:
      x: [N, H] activations.
      a_stack: [S, H, R] adapter A stack (zero-padded to max rank R).
      b_stack: [S, R, H2] adapter B stack.
      idx: [N] int32 adapter index per token.

    Returns:
      [N, H2] LoRA delta, dtype of x.
    """
    n, _h = x.shape
    h2 = b_stack.shape[-1]
    return pl.pallas_call(
        _bgmv_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # idx: whole array
            pl.BlockSpec((1, x.shape[1]), lambda i: (i, 0)),  # x row
            pl.BlockSpec(memory_space=pl.ANY),  # A stack resident
            pl.BlockSpec(memory_space=pl.ANY),  # B stack resident
        ],
        out_specs=pl.BlockSpec((1, h2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h2), x.dtype),
        interpret=True,
    )(idx, x, a_stack, b_stack)


@functools.partial(jax.jit, static_argnames=())
def mbgmv(x, a_stack, b_stack, idx, ranks):
    """Padding-free MBGMV: per-token true-rank masked gather matvec.

    Args:
      ranks: [S] int32 true rank of each adapter in the stack.
    """
    n, _h = x.shape
    h2 = b_stack.shape[-1]
    return pl.pallas_call(
        _mbgmv_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # idx
            pl.BlockSpec(memory_space=pl.ANY),  # ranks
            pl.BlockSpec((1, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, h2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h2), x.dtype),
        interpret=True,
    )(idx, ranks, x, a_stack, b_stack)
