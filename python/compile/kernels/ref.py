"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: `python/tests/test_kernel.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels match
these references to tolerance.

Semantics (matching Punica's BGMV / S-LoRA's MBGMV, paper §2.3):
a batch of N tokens, token n mapped by ``idx[n]`` to one of S adapters;
``y[n] = x[n] @ A[idx[n]] @ B[idx[n]]``.
"""

import jax.numpy as jnp


def bgmv_ref(x, a_stack, b_stack, idx):
    """Padded BGMV reference.

    Args:
      x: [N, H] token activations.
      a_stack: [S, H, R] per-adapter A matrices (padded to max rank R).
      b_stack: [S, R, H2] per-adapter B matrices.
      idx: [N] int32 adapter index per token.

    Returns:
      [N, H2] LoRA deltas x·A·B.
    """
    a = a_stack[idx]  # [N, H, R]
    b = b_stack[idx]  # [N, R, H2]
    t = jnp.einsum("nh,nhr->nr", x, a)
    return jnp.einsum("nr,nrk->nk", t, b).astype(x.dtype)


def mbgmv_ref(x, a_stack, b_stack, idx, ranks):
    """Padding-free MBGMV reference.

    Identical to ``bgmv_ref`` but each token only uses the first
    ``ranks[idx[n]]`` columns of its adapter (the true rank), matching
    S-LoRA's padding-free kernel. When the stacks are zero-padded beyond
    each adapter's true rank the result equals ``bgmv_ref``.

    Args:
      ranks: [S] int32 true rank per adapter.
    """
    a = a_stack[idx]  # [N, H, R]
    b = b_stack[idx]  # [N, R, H2]
    r = a_stack.shape[-1]
    mask = (jnp.arange(r)[None, :] < ranks[idx][:, None]).astype(x.dtype)  # [N, R]
    t = jnp.einsum("nh,nhr->nr", x, a) * mask
    return jnp.einsum("nr,nrk->nk", t, b).astype(x.dtype)
