"""L2: TinyLlama forward pass with LoRA adaptation (build-time JAX).

A scaled-down Llama-2-style transformer (RMSNorm, RoPE, SwiGLU, MHA)
whose W_Q/W_K/W_V projections are adapted by LoRA through the L1 Pallas
BGMV kernel — the same architecture/adaptation layout as the paper's
Llama2-7B deployment, at a size the CPU PJRT plugin executes quickly.

Two entry points are AOT-lowered per (batch, seq) bucket by ``aot.py``:

* ``prefill``: padded prompt batch → last-token logits + the KV cache
  rows for every prompt position.
* ``decode_step``: one token per running request + the (padded) KV cache
  → next-token logits + the new KV rows (the Rust KV-cache manager owns
  cache assembly; only the new rows cross the boundary back).

Shapes must stay in sync with ``rust/src/model/mod.rs::LlamaConfig::tiny``
and the manifest consumed by ``rust/src/runtime``.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.bgmv import bgmv

# Must match rust/src/model/mod.rs::LlamaConfig::tiny().
TINY = dict(
    vocab=1024,
    hidden=256,
    layers=4,
    heads=8,
    kv_heads=8,
    intermediate=688,
    max_seq=256,
)

# Number of device adapter slots and the padded max rank of the LoRA
# stacks baked into every artifact (must match manifest.json).
LORA_SLOTS = 8
LORA_MAX_RANK = 8

# Flat weight-argument order shared with aot.py / the Rust runtime.
WEIGHT_NAMES = [
    "embed",     # [V, H]
    "wq",        # [L, H, H]
    "wk",        # [L, H, H]
    "wv",        # [L, H, H]
    "wo",        # [L, H, H]
    "w_gate",    # [L, H, I]
    "w_up",      # [L, H, I]
    "w_down",    # [L, I, H]
    "ln_attn",   # [L, H]
    "ln_ffn",    # [L, H]
    "ln_final",  # [H]
    "lm_head",   # [H, V]
]

LORA_NAMES = [
    "a_q",  # [L, S, H, R]
    "b_q",  # [L, S, R, H]
    "a_k",
    "b_k",
    "a_v",
    "b_v",
]


def init_weights(seed: int, cfg=None):
    """Deterministic synthetic weights (paper uses dummy LoRA weights;
    the base weights just need to be numerically tame)."""
    cfg = cfg or TINY
    v, h, l, i = cfg["vocab"], cfg["hidden"], cfg["layers"], cfg["intermediate"]
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 12)

    def mk(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    s = 1.0 / (h ** 0.5)
    return {
        "embed": mk(keys[0], (v, h), 0.02),
        "wq": mk(keys[1], (l, h, h), s),
        "wk": mk(keys[2], (l, h, h), s),
        "wv": mk(keys[3], (l, h, h), s),
        "wo": mk(keys[4], (l, h, h), s),
        "w_gate": mk(keys[5], (l, h, i), s),
        "w_up": mk(keys[6], (l, h, i), s),
        "w_down": mk(keys[7], (l, i, h), 1.0 / (i ** 0.5)),
        "ln_attn": jnp.ones((l, h), jnp.float32),
        "ln_ffn": jnp.ones((l, h), jnp.float32),
        "ln_final": jnp.ones((h,), jnp.float32),
        "lm_head": mk(keys[8], (h, v), 0.02),
    }


def init_lora(seed: int, ranks, cfg=None):
    """LoRA stacks for ``LORA_SLOTS`` adapters with the given true ranks
    (zero-padded to LORA_MAX_RANK so BGMV and MBGMV agree numerically)."""
    cfg = cfg or TINY
    h, l = cfg["hidden"], cfg["layers"]
    assert len(ranks) == LORA_SLOTS
    key = jax.random.PRNGKey(seed + 1)
    out = {}
    for t, name in enumerate(["q", "k", "v"]):
        ka, kb = jax.random.split(jax.random.fold_in(key, t))
        a = jax.random.normal(ka, (l, LORA_SLOTS, h, LORA_MAX_RANK), jnp.float32)
        b = jax.random.normal(kb, (l, LORA_SLOTS, LORA_MAX_RANK, h), jnp.float32)
        # Zero-pad beyond each slot's true rank; scale like LoRA init.
        col = jnp.arange(LORA_MAX_RANK)
        mask = (col[None, :] < jnp.asarray(ranks)[:, None]).astype(jnp.float32)
        a = a * mask[None, :, None, :] * 0.05
        b = b * mask[None, :, :, None] * 0.05
        out[f"a_{name}"] = a
        out[f"b_{name}"] = b
    out["ranks"] = jnp.asarray(ranks, jnp.int32)
    return out


def _rmsnorm(x, g, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _rope(x, positions):
    """Rotary embedding. x: [..., T, heads, head_dim]; positions: [..., T]."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv_with_lora(x_flat, w, lora, layer, idx_flat):
    """Project tokens through Wq/Wk/Wv with LoRA deltas via the Pallas
    BGMV kernel. x_flat: [N, H]; idx_flat: [N] adapter slot per token."""
    outs = []
    for name, wmat in (("q", w["wq"]), ("k", w["wk"]), ("v", w["wv"])):
        base = x_flat @ wmat[layer]
        delta = bgmv(
            x_flat, lora[f"a_{name}"][layer], lora[f"b_{name}"][layer], idx_flat
        )
        outs.append(base + delta)
    return outs


def _attention(q, k, v, mask, cfg):
    """q: [B, Tq, heads, hd]; k/v: [B, Tk, heads, hd]; mask: [B, Tq, Tk]."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _ffn(x, w, layer):
    gate = jax.nn.silu(x @ w["w_gate"][layer])
    up = x @ w["w_up"][layer]
    return (gate * up) @ w["w_down"][layer]


def prefill(w, lora, idx, tokens, lens):
    """Prefill a padded prompt batch.

    Args:
      w: weight dict (WEIGHT_NAMES).
      lora: LoRA stacks (LORA_NAMES).
      idx: [B] int32 adapter slot per request.
      tokens: [B, S] int32 padded prompts.
      lens: [B] int32 true prompt lengths (≤ S).

    Returns:
      logits: [B, V] logits at each request's last real token.
      k_cache, v_cache: [L, B, S, H] per-layer KV rows for all positions.
    """
    cfg = TINY
    b, s = tokens.shape
    h, heads = cfg["hidden"], cfg["heads"]
    hd = h // heads
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    # Causal mask ∧ key-position < len (padded keys never attended).
    causal = jnp.tril(jnp.ones((s, s), bool))[None]
    valid = positions[:, None, :] < lens[:, None, None]
    mask = causal & valid

    x = w["embed"][tokens]  # [B, S, H]
    idx_flat = jnp.repeat(idx, s)  # token n belongs to request n // s
    ks, vs = [], []
    for layer in range(cfg["layers"]):
        xn = _rmsnorm(x, w["ln_attn"][layer])
        q, k, v = _qkv_with_lora(
            xn.reshape(b * s, h), w, lora, layer, idx_flat
        )
        q = _rope(q.reshape(b, s, heads, hd), positions)
        k = _rope(k.reshape(b, s, heads, hd), positions)
        v = v.reshape(b, s, heads, hd)
        attn = _attention(q, k, v, mask, cfg).reshape(b, s, h)
        x = x + attn @ w["wo"][layer]
        xf = _rmsnorm(x, w["ln_ffn"][layer])
        x = x + _ffn(xf, w, layer)
        ks.append(k.reshape(b, s, h))
        vs.append(v.reshape(b, s, h))

    x = _rmsnorm(x, w["ln_final"])
    logits_all = x @ w["lm_head"]  # [B, S, V]
    last = jnp.clip(lens - 1, 0, s - 1)
    logits = jnp.take_along_axis(
        logits_all, last[:, None, None], axis=1
    ).squeeze(1)
    k_cache = jnp.stack(ks)  # [L, B, S, H]
    v_cache = jnp.stack(vs)
    return logits, k_cache, v_cache


def decode_step(w, lora, idx, tokens, pos, k_cache, v_cache):
    """One decode iteration for a running batch.

    Args:
      idx: [B] adapter slot per request.
      tokens: [B] int32 current token per request.
      pos: [B] int32 current position (= tokens generated so far + prompt
        length); the new token sits at this position.
      k_cache, v_cache: [L, B, M, H] padded caches; rows ≥ pos[b] are
        garbage and masked out.

    Returns:
      logits: [B, V] next-token logits.
      k_new, v_new: [L, B, H] this token's KV rows (the Rust KV manager
        appends them; the big cache never round-trips as an output).
    """
    cfg = TINY
    l_, b, m, h = k_cache.shape
    heads = cfg["heads"]
    hd = h // heads
    x = w["embed"][tokens]  # [B, H]
    key_positions = jnp.arange(m)[None, :]  # [1, M]
    cache_mask = key_positions < pos[:, None]  # [B, M]

    k_news, v_news = [], []
    for layer in range(cfg["layers"]):
        xn = _rmsnorm(x, w["ln_attn"][layer])
        q, k, v = _qkv_with_lora(xn, w, lora, layer, idx)
        q = _rope(q.reshape(b, 1, heads, hd), pos[:, None])
        k = _rope(k.reshape(b, 1, heads, hd), pos[:, None])
        v = v.reshape(b, 1, heads, hd)
        # Keys = cache ∥ self; self always attended.
        k_all = jnp.concatenate(
            [k_cache[layer].reshape(b, m, heads, hd), k], axis=1
        )
        v_all = jnp.concatenate(
            [v_cache[layer].reshape(b, m, heads, hd), v], axis=1
        )
        mask = jnp.concatenate(
            [cache_mask, jnp.ones((b, 1), bool)], axis=1
        )[:, None, :]  # [B, 1, M+1]
        attn = _attention(q, k_all, v_all, mask, cfg).reshape(b, h)
        x = x + attn @ w["wo"][layer]
        xf = _rmsnorm(x, w["ln_ffn"][layer])
        x = x + _ffn(xf, w, layer)
        k_news.append(k.reshape(b, h))
        v_news.append(v.reshape(b, h))

    x = _rmsnorm(x, w["ln_final"])
    logits = x @ w["lm_head"]
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def _flat_weights(w):
    return [w[n] for n in WEIGHT_NAMES]


def _flat_lora(lora):
    return [lora[n] for n in LORA_NAMES]


def prefill_flat(*args):
    """Flat-argument prefill for AOT lowering. Argument order:
    WEIGHT_NAMES ++ LORA_NAMES ++ [idx, tokens, lens]."""
    nw, nl = len(WEIGHT_NAMES), len(LORA_NAMES)
    w = dict(zip(WEIGHT_NAMES, args[:nw]))
    lora = dict(zip(LORA_NAMES, args[nw : nw + nl]))
    idx, tokens, lens = args[nw + nl :]
    return prefill(w, lora, idx, tokens, lens)


def decode_flat(*args):
    """Flat-argument decode_step. Argument order:
    WEIGHT_NAMES ++ LORA_NAMES ++ [idx, tokens, pos, k_cache, v_cache]."""
    nw, nl = len(WEIGHT_NAMES), len(LORA_NAMES)
    w = dict(zip(WEIGHT_NAMES, args[:nw]))
    lora = dict(zip(LORA_NAMES, args[nw : nw + nl]))
    idx, tokens, pos, k_cache, v_cache = args[nw + nl :]
    return decode_step(w, lora, idx, tokens, pos, k_cache, v_cache)


@functools.lru_cache(maxsize=None)
def bucket_specs():
    """The (phase, batch, seq/cache) buckets lowered to artifacts.

    Prefill buckets are (B, S_prompt); decode buckets are (B, M_cache).
    Must match the Rust runtime's bucket table.
    """
    prefill_buckets = [(1, 16), (1, 32), (1, 64), (2, 32), (4, 32), (4, 64)]
    decode_buckets = [(1, 128), (2, 128), (4, 128), (8, 128)]
    return prefill_buckets, decode_buckets
