"""AOT compile path: lower the L2 model to HLO text artifacts.

Usage (from ``python/``)::

    python -m compile.aot --out ../artifacts

Emits, per (phase, bucket):

* ``artifacts/<name>.hlo.txt`` — HLO **text** (NOT a serialized proto:
  jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
  rejects; the text parser reassigns ids — see /opt/xla-example/README).
* ``artifacts/weights.npz`` — deterministic synthetic base weights +
  LoRA stacks (uncompressed npz; the Rust runtime reads it with
  ``Literal::read_npz``).
* ``artifacts/manifest.json`` — model config, bucket table, and the
  exact input ordering per artifact.

Python runs only here; the Rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_specs(cfg):
    v, h, l, i = cfg["vocab"], cfg["hidden"], cfg["layers"], cfg["intermediate"]
    s, r = M.LORA_SLOTS, M.LORA_MAX_RANK
    shapes = {
        "embed": (v, h),
        "wq": (l, h, h),
        "wk": (l, h, h),
        "wv": (l, h, h),
        "wo": (l, h, h),
        "w_gate": (l, h, i),
        "w_up": (l, h, i),
        "w_down": (l, i, h),
        "ln_attn": (l, h),
        "ln_ffn": (l, h),
        "ln_final": (h,),
        "lm_head": (h, v),
        "a_q": (l, s, h, r),
        "b_q": (l, s, r, h),
        "a_k": (l, s, h, r),
        "b_k": (l, s, r, h),
        "a_v": (l, s, h, r),
        "b_v": (l, s, r, h),
    }
    return shapes


def lower_prefill(b, s):
    cfg = M.TINY
    shapes = weight_specs(cfg)
    f32 = jnp.float32
    i32 = jnp.int32
    args = [
        jax.ShapeDtypeStruct(shapes[n], f32)
        for n in M.WEIGHT_NAMES + M.LORA_NAMES
    ]
    args += [
        jax.ShapeDtypeStruct((b,), i32),  # idx
        jax.ShapeDtypeStruct((b, s), i32),  # tokens
        jax.ShapeDtypeStruct((b,), i32),  # lens
    ]
    return jax.jit(M.prefill_flat).lower(*args)


def lower_decode(b, m):
    cfg = M.TINY
    shapes = weight_specs(cfg)
    f32 = jnp.float32
    i32 = jnp.int32
    h, l = cfg["hidden"], cfg["layers"]
    args = [
        jax.ShapeDtypeStruct(shapes[n], f32)
        for n in M.WEIGHT_NAMES + M.LORA_NAMES
    ]
    args += [
        jax.ShapeDtypeStruct((b,), i32),  # idx
        jax.ShapeDtypeStruct((b,), i32),  # tokens
        jax.ShapeDtypeStruct((b,), i32),  # pos
        jax.ShapeDtypeStruct((l, b, m, h), f32),  # k_cache
        jax.ShapeDtypeStruct((l, b, m, h), f32),  # v_cache
    ]
    return jax.jit(M.decode_flat).lower(*args)


# The adapter slot ranks baked into weights.npz (heterogeneous on purpose
# so MBGMV's rank mask is exercised end to end).
SLOT_RANKS = [8, 8, 4, 4, 8, 2, 8, 8]
WEIGHTS_SEED = 20240131


def build(out_dir: str, force: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    # Input fingerprint for the no-op fast path (make artifacts is
    # idempotent when sources are unchanged).
    src_dir = os.path.dirname(os.path.abspath(__file__))
    hasher = hashlib.sha256()
    for fname in sorted(
        os.path.join(dp, f)
        for dp, _, fs in os.walk(src_dir)
        for f in fs
        if f.endswith(".py")
    ):
        with open(fname, "rb") as fh:
            hasher.update(fh.read())
    fingerprint = hasher.hexdigest()
    stamp_path = os.path.join(out_dir, ".stamp")
    manifest_path = os.path.join(out_dir, "manifest.json")
    if not force and os.path.exists(stamp_path) and os.path.exists(manifest_path):
        with open(stamp_path) as fh:
            if fh.read().strip() == fingerprint:
                print(f"artifacts up to date in {out_dir} (stamp match)")
                return

    cfg = M.TINY
    prefill_buckets, decode_buckets = M.bucket_specs()

    # --- weights ---
    w = M.init_weights(WEIGHTS_SEED)
    lora = M.init_lora(WEIGHTS_SEED, SLOT_RANKS)
    arrays = {n: np.asarray(w[n]) for n in M.WEIGHT_NAMES}
    arrays.update({n: np.asarray(lora[n]) for n in M.LORA_NAMES})
    arrays["ranks"] = np.asarray(lora["ranks"])
    np.savez(os.path.join(out_dir, "weights.npz"), **arrays)
    print(f"wrote weights.npz ({len(arrays)} arrays)")

    artifacts = []

    # --- prefill buckets ---
    for b, s in prefill_buckets:
        name = f"prefill_b{b}_s{s}"
        text = to_hlo_text(lower_prefill(b, s))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        artifacts.append(
            {
                "name": name,
                "phase": "prefill",
                "batch": b,
                "seq": s,
                "path": f"{name}.hlo.txt",
                "inputs": M.WEIGHT_NAMES + M.LORA_NAMES + ["idx", "tokens", "lens"],
                "outputs": ["logits", "k_cache", "v_cache"],
            }
        )
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

    # --- decode buckets ---
    for b, m in decode_buckets:
        name = f"decode_b{b}_m{m}"
        text = to_hlo_text(lower_decode(b, m))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        artifacts.append(
            {
                "name": name,
                "phase": "decode",
                "batch": b,
                "seq": m,
                "path": f"{name}.hlo.txt",
                "inputs": M.WEIGHT_NAMES
                + M.LORA_NAMES
                + ["idx", "tokens", "pos", "k_cache", "v_cache"],
                "outputs": ["logits", "k_new", "v_new"],
            }
        )
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

    manifest = {
        "model": cfg,
        "lora": {
            "slots": M.LORA_SLOTS,
            "max_rank": M.LORA_MAX_RANK,
            "slot_ranks": SLOT_RANKS,
        },
        "weights": "weights.npz",
        "weight_names": M.WEIGHT_NAMES,
        "lora_names": M.LORA_NAMES,
        "artifacts": artifacts,
    }
    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=2)
    with open(stamp_path, "w") as fh:
        fh.write(fingerprint)
    print(f"wrote manifest.json ({len(artifacts)} artifacts)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--force", action="store_true", help="rebuild even if stamp matches"
    )
    args = parser.parse_args()
    build(args.out, force=args.force)


if __name__ == "__main__":
    sys.exit(main())
