//! Figs 10 & 11 reproduction: end-to-end serving on the synthetic
//! workload (Llama2-7B on A10, Poisson RPS = 9, rank = 64, every
//! request a distinct adapter, Alpaca lengths, 5 minutes).
//!
//! Fig 10: CDF summaries of TTFT / time-per-token / request latency for
//! CACHED, ONDMD, S-LoRA, CARASERVE. Paper: ONDMD/S-LoRA inflate TTFT
//! by 412%/451% over CACHED; CaraServe holds overheads to 22%/11%/9%.
//!
//! Fig 11: per-iteration prefill and decode latency by baseline —
//! CaraServe's prefill iterations shed the adapter-loading time.

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::sim::{GpuModel, ServingMode, SimInstance, Simulation, SingleServer};
use caraserve::util::stats::{mean, percentile, Ecdf};

fn main() {
    let reqs = caraserve::sim::workload::synthetic(1, 9.0, 64, 300.0);
    println!("workload: {} requests (rps=9, rank=64, 300 s)", reqs.len());

    let modes = [
        ServingMode::Cached,
        ServingMode::OnDemand,
        ServingMode::SLora,
        ServingMode::CaraServe,
    ];
    let mut outputs = Vec::new();
    for mode in modes {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut sim =
            Simulation::new(vec![SimInstance::new(0, model, mode, 64, 32, 1024)]);
        outputs.push((mode, sim.run(&reqs, &mut SingleServer)));
    }

    // --- Fig 10: metric summaries + overhead vs CACHED ---
    for metric in ["ttft", "tpt", "latency"] {
        let mut rep = Report::new(
            &format!("Fig 10: {metric} by baseline"),
            &["mode", "mean (ms)", "p50 (ms)", "p90 (ms)", "p99 (ms)", "vs cached"],
        );
        let base = mean(&outputs[0].1.column(metric));
        for (mode, out) in &outputs {
            let col = out.column(metric);
            let m = mean(&col);
            rep.row(vec![
                mode.name().to_string(),
                f(m * 1e3, 2),
                f(percentile(&col, 50.0) * 1e3, 2),
                f(percentile(&col, 90.0) * 1e3, 2),
                f(percentile(&col, 99.0) * 1e3, 2),
                format!("+{:.0}%", (m / base - 1.0) * 100.0),
            ]);
        }
        rep.note(match metric {
            "ttft" => "paper: ondmd +412%, s-lora +451%, caraserve +22%",
            "tpt" => "paper: ondmd +71%, s-lora +78%, caraserve +11%",
            _ => "paper: ondmd +50%, s-lora +50%, caraserve +9%",
        });
        rep.print();
        rep.save(&format!("fig10_{metric}")).ok();

        // CDF series (10 points) for plotting.
        let mut cdf = Report::new(
            &format!("Fig 10 CDF series: {metric} (ms at cumulative fraction)"),
            &["mode", "10%", "30%", "50%", "70%", "90%", "99%"],
        );
        for (mode, out) in &outputs {
            let e = Ecdf::new(&out.column(metric));
            let pts = e.points(100);
            let at = |q: f64| {
                let idx = ((q * 100.0) as usize).min(99);
                f(pts[idx].0 * 1e3, 1)
            };
            cdf.row(vec![
                mode.name().to_string(),
                at(0.10),
                at(0.30),
                at(0.50),
                at(0.70),
                at(0.90),
                at(0.99),
            ]);
        }
        cdf.print();
        cdf.save(&format!("fig10_cdf_{metric}")).ok();
    }

    // --- Fig 11: per-iteration latency by type ---
    let mut fig11 = Report::new(
        "Fig 11: per-iteration latency at the LLM inference server",
        &["mode", "prefill mean (ms)", "prefill p99 (ms)", "decode mean (ms)", "decode p99 (ms)"],
    );
    for (mode, out) in &outputs {
        let prefill: Vec<f64> = out.iterations[0]
            .iter()
            .filter(|i| i.is_prefill)
            .map(|i| i.duration)
            .collect();
        let decode: Vec<f64> = out.iterations[0]
            .iter()
            .filter(|i| !i.is_prefill)
            .map(|i| i.duration)
            .collect();
        fig11.row(vec![
            mode.name().to_string(),
            f(mean(&prefill) * 1e3, 2),
            f(percentile(&prefill, 99.0) * 1e3, 2),
            f(mean(&decode) * 1e3, 2),
            f(percentile(&decode, 99.0) * 1e3, 2),
        ]);
    }
    fig11.note("paper: decode similar across baselines; ondmd/s-lora prefill inflated by loading");
    fig11.print();
    fig11.save("fig11_iterations").ok();
}
