//! Fig 3 reproduction.
//!
//! Left: distribution of cold-start overhead as a fraction of each
//! request's total serving time, for aggregate loads 3/6/9 rps
//! (512 rank-64 adapters with MAF-skewed popularity, on-demand loading).
//! Paper: mean 10% / 16% / 20%.
//!
//! Right: cold-start latency of loading a single adapter of rank
//! 8..128 onto the device (Wq/Wk/Wv of Llama2-7B on A10).
//! Paper: a few to tens of ms, linear in rank.

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::{LlamaConfig, LoraSpec};
use caraserve::sim::{GpuModel, MafTrace, ServingMode, SimInstance, Simulation, SingleServer};
use caraserve::util::stats::{mean, percentile};

fn main() {
    // --- Left: cold-start share vs load ---
    let mut left = Report::new(
        "Fig 3-Left: cold-start fraction of request time (OnDemand, 512 adapters r=64)",
        &["rps", "mean %", "p50 %", "p90 %", "p99 %"],
    );
    for rps in [3.0, 6.0, 9.0] {
        let trace = MafTrace::new(7, 512, 1.0, &[64]);
        let reqs = trace.generate(11, rps, 300.0);
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        // Adapter cache = 32 residents (A10 memory budget; see fig14).
        let mut sim = Simulation::new(vec![SimInstance::new(
            0,
            model,
            ServingMode::OnDemand,
            64,
            32,
            32,
        )]);
        let out = sim.run(&reqs, &mut SingleServer);
        let frac = out.column("cold_frac");
        left.row(vec![
            f(rps, 0),
            f(mean(&frac) * 100.0, 1),
            f(percentile(&frac, 50.0) * 100.0, 1),
            f(percentile(&frac, 90.0) * 100.0, 1),
            f(percentile(&frac, 99.0) * 100.0, 1),
        ]);
    }
    left.note("paper: mean 10% / 16% / 20% at rps 3 / 6 / 9 — fraction must grow with load");
    left.print();
    left.save("fig03_left").ok();

    // --- Right: load latency vs rank ---
    let mut right = Report::new(
        "Fig 3-Right: adapter load latency vs rank (Llama2-7B Q/K/V on A10)",
        &["rank", "size (MiB)", "load (ms)"],
    );
    let cfg = LlamaConfig::llama2_7b();
    let model = GpuModel::new(cfg.clone(), GpuSpec::a10(), 1);
    for rank in [8usize, 16, 32, 64, 128] {
        let spec = LoraSpec::standard(1, rank, &cfg.name);
        right.row(vec![
            rank.to_string(),
            f(spec.weight_bytes(&cfg) / (1024.0 * 1024.0), 1),
            f(model.adapter_load(&spec) * 1e3, 1),
        ]);
    }
    right.note("paper: a few ms (rank 8) to tens of ms (rank 128), linear in rank");
    right.print();
    right.save("fig03_right").ok();
}
