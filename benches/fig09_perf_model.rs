//! Fig 9 reproduction: fit the §5 linear performance models from a
//! profiling sweep and report (α, β, R²) plus predicted-vs-measured
//! sample points. Paper: both fits reach R² = 0.96.

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{profiler, KernelKind};
use caraserve::sim::GpuModel;
use caraserve::util::rng::Rng;

fn main() {
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let ctx = 160usize;
    let plan = profiler::ProfilePlan::default();

    let mut report = Report::new(
        "Fig 9: performance-model fits (decode latency)",
        &["kernel", "alpha (s/feat)", "beta (ms)", "R^2"],
    );
    let mut models = Vec::new();
    for kernel in [KernelKind::Bgmv, KernelKind::Mbgmv] {
        let g = gm.clone();
        // Profile with mild measurement noise (real profiling jitters).
        let mut rng = Rng::new(13);
        let m = profiler::calibrate(kernel, &plan, |ranks| {
            g.decode_iter(&vec![ctx; ranks.len()])
                + g.lora_decode_overhead(kernel, ranks)
                + rng.normal_with(0.0, 1e-4)
        })
        .unwrap();
        report.row(vec![
            format!("{kernel:?}"),
            format!("{:.3e}", m.alpha),
            f(m.beta * 1e3, 2),
            f(m.r2, 4),
        ]);
        models.push((kernel, m));
    }
    report.note("paper: R^2 = 0.96 for both kernels");
    report.print();
    report.save("fig09_fits").ok();

    // Predicted vs measured on held-out batches.
    let mut check = Report::new(
        "Fig 9 (check): predicted vs measured on held-out batches",
        &["kernel", "batch", "feature", "measured (ms)", "predicted (ms)", "err %"],
    );
    let mut rng = Rng::new(99);
    for (kernel, m) in &models {
        for _ in 0..5 {
            let b = rng.range(3, 48);
            let ranks: Vec<usize> =
                (0..b).map(|_| *rng.choose(&[8, 16, 32, 64, 128])).collect();
            let measured = gm.decode_iter(&vec![ctx; b])
                + gm.lora_decode_overhead(*kernel, &ranks);
            let predicted = m.predict(&ranks);
            check.row(vec![
                format!("{kernel:?}"),
                b.to_string(),
                f(kernel.feature(&ranks), 0),
                f(measured * 1e3, 2),
                f(predicted * 1e3, 2),
                f((predicted / measured - 1.0) * 100.0, 1),
            ]);
        }
    }
    check.print();
    check.save("fig09_check").ok();
}
