//! Failover under injected faults: the ISSUE 8 tentpole measured end
//! to end on live native engines.
//!
//! Each scenario drives the shared synthetic workload through a
//! 3-engine cluster with a seeded fault plan wrapped around the victim
//! backends, then reconciles every completed stream against a no-fault
//! oracle run of the same config. The acceptance shape: **zero
//! diverged streams** in every scenario — a backend death mid-decode
//! either fails over bitwise-identically or terminates the request
//! with a typed rejection — plus nonzero shedding when the whole
//! cluster is down (graceful degradation, not queue collapse).
//!
//! Emits `BENCH_failover.json` in the working directory (plus the
//! standard `target/bench-reports/failover.json`); CI runs `--smoke`
//! to keep the file fresh.

use caraserve::server::cluster::synthetic::{self, ChaosConfig, SyntheticConfig};
use caraserve::server::{ColdStartMode, RetryPolicy};
use caraserve::testkit::faults::FaultPlan;
use caraserve::util::json::{self, Json};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CARA_BENCH_FAST").is_ok();
    let policy = "rank-aware";
    let requests = if smoke { 24 } else { 64 };
    let cfg = SyntheticConfig {
        instances: 3,
        requests,
        adapters: 12,
        seed: 11,
        threads: 1,
        cpu_workers: 0,
        // Cached admits keep the streams wall-clock-independent, which
        // is what the bitwise oracle comparison measures.
        cold_start: ColdStartMode::Cached,
        kv_pages: 256,
        polls_per_arrival: 2,
        skew: 0.0,
    };

    let kill = FaultPlan::seeded_mid_decode_kill(cfg.seed, 2, 10);
    let die = FaultPlan::parse("die@poll:1").map_err(|e| anyhow::anyhow!(e))?;
    let scenarios: Vec<(&str, ChaosConfig)> = vec![
        (
            "kill 1/3 mid-decode",
            ChaosConfig {
                faults: vec![(0, kill.clone())],
                retry: None,
            },
        ),
        (
            "transient poll errors",
            ChaosConfig {
                faults: vec![(
                    0,
                    FaultPlan::parse("error@poll:2,error@poll:4")
                        .map_err(|e| anyhow::anyhow!(e))?,
                )],
                retry: None,
            },
        ),
        (
            "kill 2/3 mid-decode",
            ChaosConfig {
                faults: vec![(0, kill.clone()), (1, kill)],
                retry: None,
            },
        ),
        (
            "all 3 dead at first poll",
            ChaosConfig {
                faults: vec![(0, die.clone()), (1, die.clone()), (2, die)],
                retry: Some(RetryPolicy {
                    down_after: 1,
                    ..Default::default()
                }),
            },
        ),
    ];

    let mut report = caraserve::bench::Report::new(
        "Failover under injected faults (3 native engines, bitwise oracle check)",
        &[
            "scenario",
            "done",
            "stable",
            "diverged",
            "failed",
            "failovers",
            "shed",
            "health",
            "wall s",
        ],
    );

    let mut runs = Vec::new();
    let mut total_diverged = 0usize;
    let mut dead_cluster_shed = 0usize;
    for (name, chaos) in &scenarios {
        let (rep, oracle) = synthetic::run_chaos(policy, &cfg, chaos)?;
        total_diverged += rep.diverged;
        if name.starts_with("all 3 dead") {
            dead_cluster_shed += rep.shed;
        }
        let health: Vec<String> = rep.health.iter().map(|h| format!("{h:?}")).collect();
        report.row(vec![
            name.to_string(),
            format!("{}/{}", rep.base.finished, rep.base.requests),
            rep.stable.to_string(),
            rep.diverged.to_string(),
            rep.failed.to_string(),
            rep.failovers.to_string(),
            rep.shed.to_string(),
            health.join("/"),
            format!("{:.2}", rep.base.wall_s),
        ]);
        runs.push(json::obj(vec![
            ("scenario", json::s(name)),
            ("requests", json::num(rep.base.requests as f64)),
            ("finished", json::num(rep.base.finished as f64)),
            ("rejected", json::num(rep.base.rejected as f64)),
            ("stable", json::num(rep.stable as f64)),
            ("diverged", json::num(rep.diverged as f64)),
            ("failed", json::num(rep.failed as f64)),
            ("failovers", json::num(rep.failovers as f64)),
            ("shed", json::num(rep.shed as f64)),
            (
                "health",
                Json::Arr(health.iter().map(|h| json::s(h)).collect()),
            ),
            ("wall_s", json::num(rep.base.wall_s)),
            ("oracle_finished", json::num(oracle.finished as f64)),
            ("oracle_wall_s", json::num(oracle.wall_s)),
        ]));
    }

    report.note(format!(
        "{total_diverged} diverged streams across all scenarios (acceptance: 0 — \
         every completed stream is bitwise-identical to its no-fault oracle); \
         {dead_cluster_shed} requests shed by the dead-cluster degradation gate \
         (acceptance: ≥ 1)"
    ));
    report.print();
    report.save("failover").ok();

    let top = json::obj(vec![
        ("bench", json::s("failover")),
        ("smoke", json::s(if smoke { "true" } else { "false" })),
        ("policy", json::s(policy)),
        ("requests", json::num(requests as f64)),
        ("instances", json::num(cfg.instances as f64)),
        ("total_diverged", json::num(total_diverged as f64)),
        ("dead_cluster_shed", json::num(dead_cluster_shed as f64)),
        ("runs", Json::Arr(runs)),
    ]);
    std::fs::write("BENCH_failover.json", top.to_string_pretty())
        .expect("write BENCH_failover.json");
    println!("\nwrote BENCH_failover.json");

    anyhow::ensure!(
        total_diverged == 0,
        "failover is not bitwise-stable: {total_diverged} diverged streams"
    );
    anyhow::ensure!(
        dead_cluster_shed >= 1,
        "dead-cluster degradation gate never shed"
    );
    Ok(())
}
