//! Fig 4 reproduction: decoding latency when batching heterogeneous
//! LoRA adapters.
//!
//! Left (Punica BGMV): latency is set by batch size × the *maximum*
//! rank in the batch — padding makes a single rank-64 straggler drag
//! the whole batch.
//! Right (S-LoRA MBGMV): latency tracks the *average* (i.e. sum of)
//! rank — no padding penalty.
//!
//! Both the calibrated analytical model (A10 timing) and the real Rust
//! CPU kernels (wall-clock, structure check) are exercised.

use caraserve::bench::{f, Bencher, Report};
use caraserve::config::GpuSpec;
use caraserve::kernels::{bgmv_padded, mbgmv, AdapterWeights};
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::KernelKind;
use caraserve::sim::GpuModel;

fn main() {
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let ctx = 160usize;

    // --- Left: BGMV, batch × max-rank ---
    let mut left = Report::new(
        "Fig 4-Left: BGMV decode latency (ms) vs batch size × max rank",
        &["batch", "r=8", "r=16", "r=32", "r=64", "r=128"],
    );
    for batch in [8usize, 16, 24, 32] {
        let mut row = vec![batch.to_string()];
        for max_rank in [8usize, 16, 32, 64, 128] {
            // Heterogeneous batch: half rank-8, half max_rank → BGMV pays
            // the max for everyone.
            let mut ranks = vec![8usize; batch / 2];
            ranks.extend(vec![max_rank; batch - batch / 2]);
            let t = model.decode_iter(&vec![ctx; batch])
                + model.lora_decode_overhead(KernelKind::Bgmv, &ranks);
            row.push(f(t * 1e3, 1));
        }
        left.row(row);
    }
    left.note("columns = max rank in a half/half mixed batch; latency grows with batch×max_rank");
    left.print();
    left.save("fig04_left").ok();

    // --- Right: MBGMV, batch × average rank ---
    let mut right = Report::new(
        "Fig 4-Right: MBGMV decode latency (ms) vs batch size × avg rank",
        &["batch", "avg=8", "avg=16", "avg=32", "avg=64", "avg=128"],
    );
    for batch in [8usize, 16, 24, 32] {
        let mut row = vec![batch.to_string()];
        for avg in [8usize, 16, 32, 64, 128] {
            let ranks = vec![avg; batch];
            let t = model.decode_iter(&vec![ctx; batch])
                + model.lora_decode_overhead(KernelKind::Mbgmv, &ranks);
            row.push(f(t * 1e3, 1));
        }
        right.row(row);
    }
    right.note("MBGMV pays Σrank: a single high-rank adapter does NOT penalize the batch");
    right.print();
    right.save("fig04_right").ok();

    // --- Cross-check the padding claim on the real CPU kernels ---
    let mut b = Bencher::new();
    b.header("real CPU kernels: padding cost (structure check)");
    let h = 256;
    // 15 rank-8 adapters + 1 rank-64: BGMV pads everyone to 64.
    let mut adapters: Vec<AdapterWeights> = (0..15)
        .map(|i| AdapterWeights::synthetic(i, h, h, 8))
        .collect();
    adapters.push(AdapterWeights::synthetic(99, h, h, 64));
    let indices: Vec<usize> = (0..16).collect();
    let x = vec![0.1f32; 16 * h];
    let mut y = vec![0.0f32; 16 * h];
    let r_pad = b
        .bench("bgmv_padded 15x r8 + 1x r64 (pays max)", || {
            y.fill(0.0);
            bgmv_padded(&adapters, &indices, h, h, &x, &mut y);
        })
        .mean;
    let mut y2 = vec![0.0f32; 16 * h];
    let r_nopad = b
        .bench("mbgmv      15x r8 + 1x r64 (pays sum)", || {
            y2.fill(0.0);
            mbgmv(&adapters, &indices, h, h, &x, &mut y2);
        })
        .mean;
    println!(
        "\npadding penalty (BGMV/MBGMV): {:.2}x  (theory: 16*64 / (15*8+64) = {:.2}x)",
        r_pad.as_secs_f64() / r_nopad.as_secs_f64(),
        (16.0 * 64.0) / (15.0 * 8.0 + 64.0)
    );
}
