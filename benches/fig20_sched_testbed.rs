//! Fig 20 reproduction [Testbed-scale]: 8 instances serving Llama2-7B
//! with the CACHED backend (as the paper does on its 8×A10 testbed),
//! 1200 requests sampled from the MAF trace at aggregate RPS ≈ 60,
//! SLO = 1.5× HF-PEFT time-per-token.
//!
//! Paper: CaraServe's rank-aware scheduler attains the highest SLO
//! compliance (80%) among MostIdle / FirstFit / Random.

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{profiler, KernelKind};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::sim::{GpuModel, MafTrace, ServingMode, SimInstance, Simulation};
use caraserve::util::stats::{mean, percentile};

fn main() {
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let avg_ctx = 160usize;
    let slo = 1.5 * gm.decode_iter(&[avg_ctx]);
    let kernel = KernelKind::Bgmv;

    let plan = profiler::ProfilePlan::default();
    let g1 = gm.clone();
    let dec = profiler::calibrate(kernel, &plan, |ranks| {
        g1.decode_iter(&vec![avg_ctx; ranks.len()])
            + g1.lora_decode_overhead(kernel, ranks)
    })
    .unwrap();
    let g2 = gm.clone();
    let pre =
        profiler::calibrate(kernel, &plan, |ranks| g2.prefill(ranks.len() * 28)).unwrap();

    // 1200 requests at ~60 rps ⇒ 20 s of trace.
    let trace = MafTrace::new(23, 4096, 1.0, &[8, 16, 32, 64]);
    let mut reqs = trace.generate(29, 60.0, 3600.0);
    reqs.truncate(1200);

    let mut rep = Report::new(
        &format!(
            "Fig 20: 8-instance testbed (CACHED backend, BGMV), {} requests, SLO {:.1} ms",
            reqs.len(),
            slo * 1e3
        ),
        &["policy", "SLO attain %", "tpt mean (ms)", "tpt p50", "tpt p99"],
    );
    for policy_name in ["rank-aware", "most-idle", "first-fit", "random"] {
        let instances: Vec<SimInstance> = (0..8)
            .map(|i| SimInstance::new(i, gm.clone(), ServingMode::Cached, 64, 32, 4096))
            .collect();
        let mut policy = policy_by_name(
            policy_name,
            pre.clone(),
            dec.clone(),
            RankAwareConfig {
                slo,
                ..Default::default()
            },
            7,
        )
        .expect("known policy");
        let mut sim = Simulation::new(instances);
        let out = sim.run(&reqs, policy.as_mut());
        let tpt = out.column("tpt");
        rep.row(vec![
            policy_name.to_string(),
            f(out.slo_attainment(slo) * 100.0, 1),
            f(mean(&tpt) * 1e3, 2),
            f(percentile(&tpt, 50.0) * 1e3, 2),
            f(percentile(&tpt, 99.0) * 1e3, 2),
        ]);
    }
    rep.note("paper: rank-aware achieves the highest attainment (80%) on the real 8xA10 testbed");
    rep.print();
    rep.save("fig20_testbed").ok();
}
