//! Ablations of CaraServe's design choices (DESIGN.md §6):
//!
//! A. **CPU-core budget** — how many host cores CPU-assisted prefill
//!    needs before the cold-start residual disappears (§4.2's
//!    profiling-guided allocation is the knob).
//! B. **SLO penalty term** — Algorithm 1 with and without the violation
//!    penalty (cost-only vs cost+penalty routing).
//! C. **Device adapter-cache size** — cold-start rate vs resident
//!    adapter budget under the MAF workload (why LRU + CPU-assist beats
//!    just buying cache).

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{profiler, KernelKind};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::sim::{
    GpuModel, MafTrace, ServingMode, SimInstance, Simulation, SingleServer,
};
use caraserve::util::stats::mean;

fn main() {
    ablation_cpu_cores();
    ablation_slo_penalty();
    ablation_cache_size();
}

/// A: sweep the host-core budget for CPU-assisted prefill.
fn ablation_cpu_cores() {
    let mut rep = Report::new(
        "Ablation A: CaraServe TTFT overhead vs host-core budget (rps=9, r=64)",
        &["cpu cores", "ttft mean (ms)", "vs cached +%", "cold %"],
    );
    let reqs = caraserve::sim::workload::synthetic(5, 9.0, 64, 180.0);
    let cached = {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut sim = Simulation::new(vec![SimInstance::new(
            0,
            model,
            ServingMode::Cached,
            64,
            1,
            1024,
        )]);
        mean(&sim.run(&reqs, &mut SingleServer).column("ttft"))
    };
    for cores in [1usize, 2, 4, 8, 16, 32, 64] {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut sim = Simulation::new(vec![SimInstance::new(
            0,
            model,
            ServingMode::CaraServe,
            64,
            cores,
            1024,
        )]);
        let out = sim.run(&reqs, &mut SingleServer);
        let ttft = mean(&out.column("ttft"));
        rep.row(vec![
            cores.to_string(),
            f(ttft * 1e3, 2),
            f((ttft / cached - 1.0) * 100.0, 1),
            f(mean(&out.column("cold_frac")) * 100.0, 2),
        ]);
    }
    rep.note("§4.2: the ⌈L/c⌉ allocation needs enough cores before CPU LoRA stops being the prefill bottleneck");
    rep.print();
    rep.save("ablation_cpu_cores").ok();
}

/// B: Algorithm 1 with penalty = 0 (pure marginal cost) vs default.
fn ablation_slo_penalty() {
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let avg_ctx = 160usize;
    let slo = 1.5 * gm.decode_iter(&[avg_ctx]);
    let kernel = KernelKind::Bgmv;
    let plan = profiler::ProfilePlan::default();
    let g1 = gm.clone();
    let dec = profiler::calibrate(kernel, &plan, |ranks| {
        g1.decode_iter(&vec![avg_ctx; ranks.len()]) + g1.lora_decode_overhead(kernel, ranks)
    })
    .unwrap();
    let g2 = gm.clone();
    let pre =
        profiler::calibrate(kernel, &plan, |ranks| g2.prefill(ranks.len() * 28)).unwrap();

    let trace = MafTrace::new(3, 2048, 1.0, &[8, 16, 32, 64]);
    let reqs = trace.generate(5, 55.0, 90.0);
    let mut rep = Report::new(
        "Ablation B: Algorithm 1 SLO-penalty term (8 instances, rps=55)",
        &["penalty", "SLO attain %", "tpt mean (ms)"],
    );
    for penalty in [0.0, 1.0] {
        let instances: Vec<SimInstance> = (0..8)
            .map(|i| SimInstance::new(i, gm.clone(), ServingMode::CaraServe, 48, 32, 512))
            .collect();
        let mut policy = policy_by_name(
            "rank-aware",
            pre.clone(),
            dec.clone(),
            RankAwareConfig {
                slo,
                penalty,
                ..Default::default()
            },
            42,
        )
        .expect("known policy");
        let mut sim = Simulation::new(instances);
        let out = sim.run(&reqs, policy.as_mut());
        rep.row(vec![
            format!("{penalty}"),
            f(out.slo_attainment(slo) * 100.0, 1),
            f(mean(&out.column("tpt")) * 1e3, 2),
        ]);
    }
    rep.note("the penalty steers marginal-cost routing away from servers already at the SLO edge");
    rep.print();
    rep.save("ablation_slo_penalty").ok();
}

/// C: adapter-cache budget vs cold-start rate (OnDemand vs CaraServe).
fn ablation_cache_size() {
    let trace = MafTrace::new(7, 512, 1.0, &[64]);
    let reqs = trace.generate(11, 7.7, 180.0);
    let mut rep = Report::new(
        "Ablation C: device adapter-cache size (512 MAF adapters, rps=7.7)",
        &["cache", "ondmd cold %", "ondmd ttft (ms)", "cara cold %", "cara ttft (ms)"],
    );
    for cache in [8usize, 16, 32, 64, 128, 256] {
        let run = |mode| {
            let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
            let mut sim =
                Simulation::new(vec![SimInstance::new(0, model, mode, 64, 32, cache)]);
            let out = sim.run(&reqs, &mut SingleServer);
            (
                mean(&out.column("cold_frac")) * 100.0,
                mean(&out.column("ttft")) * 1e3,
            )
        };
        let (oc, ot) = run(ServingMode::OnDemand);
        let (cc, ct) = run(ServingMode::CaraServe);
        rep.row(vec![
            cache.to_string(),
            f(oc, 2),
            f(ot, 2),
            f(cc, 2),
            f(ct, 2),
        ]);
    }
    rep.note("CPU assistance makes TTFT insensitive to the cache budget; on-demand loading needs ~1 GB-scale caches to catch up");
    rep.print();
    rep.save("ablation_cache_size").ok();
}
