//! Fig 18 reproduction: CPU LoRA computation scaling.
//!
//! Left: single-core xAB prefill time vs prompt length (real kernel
//! wall-clock on this host, Llama2-7B shapes, rank 64).
//!
//! Right: multi-core speedup for a 128-token prompt — CaraServe's
//! chunked worker-pool design vs a PyTorch-native-style single
//! sequential pass. On this 1-core testbed the wall-clock speedup is
//! bounded by physical parallelism, so the table reports both the
//! measured wall time and the calibrated multi-core model (paper:
//! 1.7× at 8 cores vs native threading).

use std::sync::Arc;
use std::time::Instant;

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::cpu_lora::{AdapterTable, CoreProfile, CpuLoraEngine};
use caraserve::kernels::{lora_apply, AdapterWeights};
use caraserve::model::{LlamaConfig, TargetMatrix};
use caraserve::sim::GpuModel;

const HIDDEN: usize = 4096;
const RANK: usize = 64;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    // --- Left: single-core time vs token count (real kernel) ---
    let ad = AdapterWeights::synthetic(1, HIDDEN, HIDDEN, RANK);
    let mut left = Report::new(
        "Fig 18-Left: single-core xAB time vs prompt length (H=4096, r=64, one target)",
        &["tokens", "time (ms)", "tokens/s"],
    );
    for tokens in [16usize, 32, 64, 128, 256, 512] {
        let x = vec![0.2f32; tokens * HIDDEN];
        let mut y = vec![0.0f32; tokens * HIDDEN];
        let mut scratch = vec![0.0f32; tokens * RANK];
        let t = median(
            (0..5)
                .map(|_| {
                    y.fill(0.0);
                    let t0 = Instant::now();
                    lora_apply(
                        tokens, HIDDEN, HIDDEN, RANK, &x, &ad.a, &ad.b, &mut y,
                        &mut scratch,
                    );
                    t0.elapsed().as_secs_f64()
                })
                .collect(),
        );
        left.row(vec![
            tokens.to_string(),
            f(t * 1e3, 2),
            f(tokens as f64 / t, 0),
        ]);
    }
    left.note("paper: single-CPU throughput saturates — the motivation for multi-core scaling");
    left.print();
    left.save("fig18_left").ok();

    // --- Right: worker-pool scatter/gather for 128 tokens ---
    let tokens = 128usize;
    let mut right = Report::new(
        "Fig 18-Right: 128-token prefill — CaraServe worker pool vs sequential",
        &["workers", "measured (ms)", "model (ms)", "model speedup"],
    );
    // Sequential (PyTorch-native-like single pass) baseline.
    let x = vec![0.2f32; tokens * HIDDEN];
    let mut y = vec![0.0f32; tokens * HIDDEN];
    let mut scratch = vec![0.0f32; tokens * RANK];
    let seq = median(
        (0..5)
            .map(|_| {
                y.fill(0.0);
                let t0 = Instant::now();
                lora_apply(
                    tokens, HIDDEN, HIDDEN, RANK, &x, &ad.a, &ad.b, &mut y, &mut scratch,
                );
                t0.elapsed().as_secs_f64()
            })
            .collect(),
    );
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let model_seq = gm.cpu_prefill(tokens, RANK, 1) / 3.0; // one target
    right.row(vec![
        "1 (seq)".into(),
        f(seq * 1e3, 2),
        f(model_seq * 1e3, 2),
        "1.00".into(),
    ]);
    for n_workers in [2usize, 4, 8] {
        let table = Arc::new(AdapterTable::new());
        table.install_synthetic(1, HIDDEN, RANK);
        let profile = CoreProfile::from_rate(HIDDEN, RANK, 1e9, 10.0); // split over all workers
        let engine = CpuLoraEngine::new(
            n_workers,
            HIDDEN,
            tokens,
            table,
            CoreProfile {
                tokens_per_core: tokens / n_workers,
                ..profile
            },
        )
        .unwrap();
        // Warm.
        let _ = engine.apply(1, TargetMatrix::Q, tokens, &x);
        let measured = median(
            (0..5)
                .map(|_| {
                    let t0 = Instant::now();
                    let out = engine.apply(1, TargetMatrix::Q, tokens, &x);
                    caraserve::bench::black_box(out);
                    t0.elapsed().as_secs_f64()
                })
                .collect(),
        );
        // Calibrated multi-core model (what an N-core host achieves).
        let model_t = gm.cpu_prefill(tokens, RANK, n_workers) / 3.0; // one target
        let model_speedup =
            gm.cpu_prefill(tokens, RANK, 1) / gm.cpu_prefill(tokens, RANK, n_workers);
        right.row(vec![
            n_workers.to_string(),
            f(measured * 1e3, 2),
            f(model_t * 1e3, 2),
            f(model_speedup, 2),
        ]);
    }
    right.note("paper: 1.7x speedup at 8 CPUs over PyTorch-native threading");
    right.note("this host has 1 physical core: 'measured' shows pool overhead; 'model' shows the calibrated N-core scaling");
    right.print();
    right.save("fig18_right").ok();
}
