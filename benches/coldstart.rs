//! Cold-start TTFT benchmark on the *real engine* (native runtime):
//! per-`ColdStartMode` TTFT p50/p99 with the CPU-assisted path live —
//! the serving-path counterpart of the simulator-based Fig 3 bench.
//!
//! Emits `BENCH_coldstart.json` in the working directory (plus the
//! standard `target/bench-reports/coldstart.json` report) so successive
//! PRs can track the cold-start trajectory.

use caraserve::bench::{f, Report};
use caraserve::model::LoraSpec;
use caraserve::runtime::{NativeConfig, NativeRuntime};
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, LifecycleState, ServeRequest,
    ServingFront,
};
use caraserve::util::json::{self, Json};
use caraserve::util::rng::Rng;
use caraserve::util::stats::Summary;

const N_REQUESTS: usize = 24;
const N_ADAPTERS: u64 = 16;
const CPU_WORKERS: usize = 2;
/// Scale the modeled load window to ~10 ms so cold-start behaviour
/// dominates scheduler noise but the bench stays quick.
const LOAD_SCALE: f64 = 2.0;

fn mode_name(mode: ColdStartMode) -> &'static str {
    match mode {
        ColdStartMode::Cached => "cached",
        ColdStartMode::OnDemand => "ondemand",
        ColdStartMode::CaraServe => "caraserve",
    }
}

fn run(mode: ColdStartMode, assist: bool) -> (Summary, Summary, usize) {
    let mut server = InferenceServer::new(
        NativeRuntime::new(NativeConfig::test_tiny()),
        EngineConfig {
            cold_start: mode,
            load_scale: LOAD_SCALE,
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..N_ADAPTERS {
        server
            .install_adapter(&LoraSpec::standard(id, 4, "tiny"))
            .expect("install");
    }
    if assist {
        server.enable_cpu_assist(CPU_WORKERS).expect("cpu assist");
    }

    // Waves of requests over 16 adapters and 4 device slots: plenty of
    // cold starts and re-colds, identical across modes (seeded).
    let mut rng = Rng::new(7);
    let mut handles = Vec::new();
    for _ in 0..N_REQUESTS {
        let adapter = rng.range(0, N_ADAPTERS as usize) as u64;
        let prompt: Vec<i32> = (0..rng.range(4, 12)).map(|_| rng.range(0, 64) as i32).collect();
        let req = ServeRequest::new(adapter, prompt).max_new_tokens(rng.range(2, 6));
        handles.push(server.submit(req));
        server.run_until_idle().expect("serve");
    }
    assert!(handles.iter().all(|h| h.state() == LifecycleState::Finished));

    let m = server.metrics();
    let ttft = m.summary("ttft").expect("ttft");
    let load = m.summary("ttft_load").expect("ttft_load");
    (ttft, load, m.cold_start().cold_admits)
}

fn main() {
    let mut report = Report::new(
        "Cold-start TTFT per mode (native engine, real CPU-assist path)",
        &["mode", "ttft p50 (ms)", "ttft p99 (ms)", "mean load window (ms)", "cold admits"],
    );
    let mut modes_json: Vec<(String, Json)> = Vec::new();
    for (mode, assist) in [
        (ColdStartMode::Cached, false),
        (ColdStartMode::OnDemand, false),
        (ColdStartMode::CaraServe, true),
    ] {
        let (ttft, load, cold) = run(mode, assist);
        report.row(vec![
            mode_name(mode).to_string(),
            f(ttft.p50 * 1e3, 2),
            f(ttft.p99 * 1e3, 2),
            f(load.mean * 1e3, 2),
            cold.to_string(),
        ]);
        modes_json.push((
            mode_name(mode).to_string(),
            json::obj(vec![
                ("ttft_p50_ms", json::num(ttft.p50 * 1e3)),
                ("ttft_p99_ms", json::num(ttft.p99 * 1e3)),
                ("ttft_mean_ms", json::num(ttft.mean * 1e3)),
                ("load_window_mean_ms", json::num(load.mean * 1e3)),
                ("cold_admits", json::num(cold as f64)),
            ]),
        ));
    }
    report.note(
        "expected: caraserve p99 ≈ cached p99 ≪ ondemand p99 (CPU assist hides the load window)",
    );
    report.print();
    report.save("coldstart").ok();

    let top = json::obj(vec![
        ("bench", json::s("coldstart")),
        ("requests", json::num(N_REQUESTS as f64)),
        ("adapters", json::num(N_ADAPTERS as f64)),
        ("cpu_workers", json::num(CPU_WORKERS as f64)),
        ("load_scale", json::num(LOAD_SCALE)),
        (
            "modes",
            Json::Obj(modes_json),
        ),
    ]);
    std::fs::write("BENCH_coldstart.json", top.to_string_pretty())
        .expect("write BENCH_coldstart.json");
    println!("\nwrote BENCH_coldstart.json");
}
