//! Unified-pool memory pressure: adapter catalogs vs pool size on one
//! live native engine (the ISSUE 7 tentpole measured end to end).
//!
//! Sweeps (catalog size × pool pages) over the shared synthetic
//! harness — Zipf-skewed traffic, one engine, rank-aware admission —
//! and reports completion, SLO attainment, TTFT percentiles, cold
//! admits, decode preemptions, and unified-pool adapter evictions.
//! The acceptance shape: tight pools finish the same workload with a
//! nonzero eviction count and no request loss, because adapter weights
//! page out under pressure instead of pinning the pool.
//!
//! Emits `BENCH_memory.json` in the working directory (plus the
//! standard `target/bench-reports/memory.json`); CI runs `--smoke` to
//! keep the file fresh.

use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::ColdStartMode;
use caraserve::util::json::{self, Json};
use caraserve::util::stats::{ms_or_dash as ms, Summary};

fn summary_json(s: &Option<Summary>) -> Json {
    match s {
        None => Json::Null,
        Some(s) => json::obj(vec![
            ("mean_ms", json::num(s.mean * 1e3)),
            ("p50_ms", json::num(s.p50 * 1e3)),
            ("p99_ms", json::num(s.p99 * 1e3)),
        ]),
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CARA_BENCH_FAST").is_ok();
    let policy = "rank-aware";
    // Catalog sizes cross the 1,000-adapter line the tentpole targets;
    // pool sizes span pressure (40 pages barely covers 8 resident
    // adapters plus a running batch) to roomy (4096 never evicts for
    // capacity).
    let catalogs: &[usize] = if smoke { &[64, 256] } else { &[64, 1024] };
    let pools: &[usize] = if smoke { &[40, 512] } else { &[40, 256, 4096] };
    let requests = if smoke { 24 } else { 64 };

    let mut report = caraserve::bench::Report::new(
        "Memory pressure: adapter catalog × unified pool size (one native engine)",
        &[
            "adapters",
            "pool pages",
            "done",
            "SLO %",
            "ttft p50",
            "ttft p99",
            "cold",
            "evictions",
            "preempt",
        ],
    );

    let mut runs = Vec::new();
    // First pool size is the tight one; its eviction counts are the
    // headline (roomy pools may legitimately report 0).
    let mut tight_evictions = 0usize;
    for &adapters in catalogs {
        for &kv_pages in pools {
            let cfg = SyntheticConfig {
                instances: 1,
                requests,
                adapters,
                seed: 11,
                threads: 1,
                cpu_workers: 0,
                // CaraServe cold starts: evictions compete with real
                // async load windows, the regime §6 measures.
                cold_start: ColdStartMode::CaraServe,
                kv_pages,
                polls_per_arrival: 1,
                skew: 1.2,
            };
            let rep = synthetic::run(policy, &cfg)?;
            if kv_pages == pools[0] {
                tight_evictions += rep.adapter_evictions;
            }
            report.row(vec![
                adapters.to_string(),
                kv_pages.to_string(),
                rep.finished.to_string(),
                format!("{:.1}", rep.slo_attainment.unwrap_or(1.0) * 100.0),
                ms(&rep.ttft, |s| s.p50),
                ms(&rep.ttft, |s| s.p99),
                rep.cold.cold_admits.to_string(),
                rep.adapter_evictions.to_string(),
                rep.preemptions.to_string(),
            ]);
            runs.push(json::obj(vec![
                ("adapters", json::num(adapters as f64)),
                ("pool_pages", json::num(kv_pages as f64)),
                ("requests", json::num(rep.requests as f64)),
                ("finished", json::num(rep.finished as f64)),
                ("rejected", json::num(rep.rejected as f64)),
                (
                    "slo_attainment",
                    rep.slo_attainment.map_or(Json::Null, json::num),
                ),
                ("ttft", summary_json(&rep.ttft)),
                ("tpot", summary_json(&rep.tpot)),
                ("cold_admits", json::num(rep.cold.cold_admits as f64)),
                ("adapter_evictions", json::num(rep.adapter_evictions as f64)),
                ("preemptions", json::num(rep.preemptions as f64)),
                ("wall_s", json::num(rep.wall_s)),
            ]));
        }
    }

    report.note(format!(
        "{tight_evictions} adapter evictions across tight-pool ({}-page) runs \
         (acceptance: ≥ 1 — weights page out under pressure, nothing is lost)",
        pools[0]
    ));
    report.print();
    report.save("memory").ok();

    let top = json::obj(vec![
        ("bench", json::s("memory")),
        ("smoke", json::s(if smoke { "true" } else { "false" })),
        ("policy", json::s(policy)),
        ("requests", json::num(requests as f64)),
        (
            "catalogs",
            Json::Arr(catalogs.iter().map(|&n| json::num(n as f64)).collect()),
        ),
        (
            "pools",
            Json::Arr(pools.iter().map(|&n| json::num(n as f64)).collect()),
        ),
        (
            "tight_pool_evictions",
            json::num(tight_evictions as f64),
        ),
        ("runs", Json::Arr(runs)),
    ]);
    std::fs::write("BENCH_memory.json", top.to_string_pretty())
        .expect("write BENCH_memory.json");
    println!("\nwrote BENCH_memory.json");
    Ok(())
}
