//! Cluster SLO attainment: the §5 scheduler in front of real engines.
//!
//! Drives the shared synthetic heterogeneous-rank workload
//! (`server::cluster::synthetic`) through a `ClusterFront` over N
//! native-runtime `InferenceServer`s, once per routing policy, and
//! reports the §7.5 headline comparison measured on live engines
//! instead of the discrete-event simulator: SLO attainment, TTFT/TPOT
//! percentiles, per-server load balance, cold-start counts, and
//! decode-growth preemptions.
//!
//! Emits `BENCH_cluster.json` in the working directory (plus the
//! standard `target/bench-reports/cluster_slo.json`); CI runs `--smoke`
//! (2 engines, small workload, rank-aware + random only) to keep the
//! file fresh. The acceptance shape is rank-aware ≥ random on SLO
//! attainment.

use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::ColdStartMode;
use caraserve::util::json::{self, Json};
use caraserve::util::stats::{ms_or_dash as ms, Summary};

fn summary_json(s: &Option<Summary>) -> Json {
    match s {
        None => Json::Null,
        Some(s) => json::obj(vec![
            ("mean_ms", json::num(s.mean * 1e3)),
            ("p50_ms", json::num(s.p50 * 1e3)),
            ("p99_ms", json::num(s.p99 * 1e3)),
        ]),
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CARA_BENCH_FAST").is_ok();
    let cfg = if smoke {
        SyntheticConfig {
            instances: 2,
            requests: 16,
            adapters: 16,
            seed: 1,
            threads: 1,
            cpu_workers: 2,
            cold_start: ColdStartMode::CaraServe,
            kv_pages: 256,
            polls_per_arrival: 2,
            skew: 0.0,
        }
    } else {
        SyntheticConfig {
            instances: 4,
            requests: 96,
            adapters: 24,
            seed: 1,
            threads: 2,
            cpu_workers: 2,
            cold_start: ColdStartMode::CaraServe,
            kv_pages: 256,
            polls_per_arrival: 2,
            skew: 0.0,
        }
    };
    let policies: Vec<&str> = if smoke {
        vec!["rank-aware", "random"]
    } else {
        vec!["rank-aware", "most-idle", "first-fit", "random"]
    };

    let mut report = caraserve::bench::Report::new(
        "Cluster SLO attainment: rank-aware routing over live native engines",
        &[
            "policy",
            "done",
            "SLO %",
            "ttft p50",
            "ttft p99",
            "tpot p50",
            "tpot p99",
            "cold",
            "preempt",
            "rank balance",
        ],
    );
    let mut runs_json: Vec<Json> = Vec::new();
    let mut attainment: Vec<(String, f64)> = Vec::new();

    for name in &policies {
        // run() itself reconciles finished + rejected == submitted.
        let rep = synthetic::run(name, &cfg)?;
        let att = rep.slo_attainment.unwrap_or(1.0);
        attainment.push((rep.policy.clone(), att));
        let balance = format!(
            "{}..{}",
            rep.routed_rank_sum.iter().min().unwrap(),
            rep.routed_rank_sum.iter().max().unwrap()
        );
        report.row(vec![
            rep.policy.clone(),
            rep.finished.to_string(),
            format!("{:.1}", att * 100.0),
            ms(&rep.ttft, |s| s.p50),
            ms(&rep.ttft, |s| s.p99),
            ms(&rep.tpot, |s| s.p50),
            ms(&rep.tpot, |s| s.p99),
            rep.cold.cold_admits.to_string(),
            rep.preemptions.to_string(),
            balance,
        ]);
        runs_json.push(json::obj(vec![
            ("policy", json::s(&rep.policy)),
            ("requests", json::num(rep.requests as f64)),
            ("finished", json::num(rep.finished as f64)),
            ("rejected", json::num(rep.rejected as f64)),
            ("slo_attainment", json::num(att)),
            ("ttft", summary_json(&rep.ttft)),
            ("tpot", summary_json(&rep.tpot)),
            (
                "routed",
                Json::Arr(rep.routed.iter().map(|&n| json::num(n as f64)).collect()),
            ),
            (
                "routed_rank_sum",
                Json::Arr(
                    rep.routed_rank_sum
                        .iter()
                        .map(|&n| json::num(n as f64))
                        .collect(),
                ),
            ),
            ("cold_admits", json::num(rep.cold.cold_admits as f64)),
            ("cpu_assisted", json::num(rep.cold.cpu_assisted as f64)),
            ("preemptions", json::num(rep.preemptions as f64)),
            ("wall_s", json::num(rep.wall_s)),
        ]));
    }

    let find = |n: &str| attainment.iter().find(|(p, _)| p == n).map(|&(_, a)| a);
    let headline = match (find("rank-aware"), find("random")) {
        (Some(ra), Some(rnd)) => {
            report.note(format!(
                "rank-aware {:.1}% vs random {:.1}% SLO attainment \
                 (acceptance: rank-aware ≥ random)",
                ra * 100.0,
                rnd * 100.0
            ));
            Some((ra, rnd))
        }
        _ => None,
    };
    report.print();
    report.save("cluster_slo").ok();

    let top = json::obj(vec![
        ("bench", json::s("cluster_slo")),
        ("smoke", json::s(if smoke { "true" } else { "false" })),
        ("instances", json::num(cfg.instances as f64)),
        ("requests", json::num(cfg.requests as f64)),
        ("adapters", json::num(cfg.adapters as f64)),
        (
            "ranks",
            Json::Arr(
                synthetic::RANKS
                    .iter()
                    .map(|&r| json::num(r as f64))
                    .collect(),
            ),
        ),
        (
            "slo_attainment_rank_aware",
            headline.map_or(Json::Null, |(ra, _)| json::num(ra)),
        ),
        (
            "slo_attainment_random",
            headline.map_or(Json::Null, |(_, rnd)| json::num(rnd)),
        ),
        ("runs", Json::Arr(runs_json)),
    ]);
    std::fs::write("BENCH_cluster.json", top.to_string_pretty())
        .expect("write BENCH_cluster.json");
    println!("\nwrote BENCH_cluster.json");
    Ok(())
}
