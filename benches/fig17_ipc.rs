//! Fig 17 reproduction: CPU LoRA invocation cost under shared-memory
//! vs Unix-domain-socket IPC as the number of receiver workers grows.
//!
//! Measures the full round trip: scatter 16 tokens of activation to
//! each worker, worker computes xAB with the real kernel, gather the
//! results. Paper: sockets degrade linearly with receivers
//! (serialization + per-connection overheads); shared memory stays
//! near-constant and the data-transfer share drops under 1 ms.

use std::sync::Arc;
use std::time::Instant;

use caraserve::bench::{f, Report};
use caraserve::cpu_lora::{AdapterTable, WorkerPool};
use caraserve::ipc::socket::SocketChannel;
use caraserve::kernels::lora_apply;
use caraserve::model::TargetMatrix;

const HIDDEN: usize = 4096;
const RANK: usize = 64;
const TOKENS_PER_WORKER: usize = 16;

/// Median-of-n wall time.
fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn shm_roundtrip(n_workers: usize) -> f64 {
    let table = Arc::new(AdapterTable::new());
    table.install_synthetic(1, HIDDEN, RANK);
    let pool = WorkerPool::spawn(n_workers, HIDDEN, TOKENS_PER_WORKER, table).unwrap();
    let x = vec![0.3f32; TOKENS_PER_WORKER * HIDDEN];
    let mut out = Vec::new();
    // Warm.
    for w in 0..n_workers {
        let t = pool.submit(w, 1, TargetMatrix::Q, TOKENS_PER_WORKER, HIDDEN, &x);
        pool.collect(w, t, &mut out);
    }
    median(
        (0..9)
            .map(|_| {
                let t0 = Instant::now();
                let tokens: Vec<(usize, u32)> = (0..n_workers)
                    .map(|w| {
                        (w, pool.submit(w, 1, TargetMatrix::Q, TOKENS_PER_WORKER, HIDDEN, &x))
                    })
                    .collect();
                for (w, t) in tokens {
                    pool.collect(w, t, &mut out);
                }
                t0.elapsed().as_secs_f64()
            })
            .collect(),
    )
}

fn socket_roundtrip(n_workers: usize) -> f64 {
    // One socket pair per worker; workers compute the same xAB.
    let mut mains = Vec::new();
    let mut handles = Vec::new();
    for w in 0..n_workers {
        let (main, mut worker) = SocketChannel::pair().unwrap();
        mains.push(main);
        handles.push(std::thread::spawn(move || {
            let ad = caraserve::kernels::AdapterWeights::synthetic(
                w as u64, HIDDEN, HIDDEN, RANK,
            );
            let mut buf = Vec::new();
            let mut y = vec![0.0f32; TOKENS_PER_WORKER * HIDDEN];
            let mut scratch = vec![0.0f32; TOKENS_PER_WORKER * RANK];
            // 1 warm + 9 measured rounds.
            for _ in 0..10 {
                if worker.recv(&mut buf).is_err() {
                    return;
                }
                y.fill(0.0);
                lora_apply(
                    TOKENS_PER_WORKER,
                    HIDDEN,
                    HIDDEN,
                    RANK,
                    &buf,
                    &ad.a,
                    &ad.b,
                    &mut y,
                    &mut scratch,
                );
                if worker.send(&y).is_err() {
                    return;
                }
            }
        }));
    }
    let x = vec![0.3f32; TOKENS_PER_WORKER * HIDDEN];
    let mut resp = Vec::new();
    // Warm round.
    for m in mains.iter_mut() {
        m.send(&x).unwrap();
    }
    for m in mains.iter_mut() {
        m.recv(&mut resp).unwrap();
    }
    let t = median(
        (0..9)
            .map(|_| {
                let t0 = Instant::now();
                for m in mains.iter_mut() {
                    m.send(&x).unwrap();
                }
                for m in mains.iter_mut() {
                    m.recv(&mut resp).unwrap();
                }
                t0.elapsed().as_secs_f64()
            })
            .collect(),
    );
    drop(mains);
    for h in handles {
        let _ = h.join();
    }
    t
}

fn main() {
    let mut rep = Report::new(
        "Fig 17: CPU LoRA round trip — shared memory vs domain socket (16 tokens/worker)",
        &["receivers", "shm (ms)", "socket (ms)", "socket/shm"],
    );
    for n in [1usize, 2, 4, 8] {
        let shm = shm_roundtrip(n);
        let sock = socket_roundtrip(n);
        rep.row(vec![
            n.to_string(),
            f(shm * 1e3, 3),
            f(sock * 1e3, 3),
            f(sock / shm, 2),
        ]);
    }
    rep.note("paper: socket IPC grows ~linearly with receivers; shm stays near-constant, <1 ms transfer");
    rep.note("note: this 1-core host serializes worker compute; the IPC delta is the signal");
    rep.print();
    rep.save("fig17_ipc").ok();
}
