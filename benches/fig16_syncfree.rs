//! Fig 16 reproduction: sync-free CPU LoRA invocation vs the native
//! (explicit host-synchronization) path, measured wall-clock on the
//! FIFO device-queue substrate.
//!
//! The native path blocks the submitting thread on a queue drain
//! between the memcpy and the worker signal at every attention layer;
//! the fused async copy+signal command never blocks. Paper: up to 16%
//! prefill-latency reduction, growing with token count.

use std::sync::Arc;
use std::time::{Duration, Instant};

use caraserve::bench::{f, Report};
use caraserve::cpu_lora::{DeviceQueue, InvokeMode};
use caraserve::ipc::Doorbell;

/// Run one full "prefill" of `layers` attention layers and return the
/// wall-clock time until both the submitter AND the device queue finish.
fn prefill_walltime(
    mode: InvokeMode,
    layers: usize,
    kernel: Duration,
    copy_bytes: usize,
) -> Duration {
    let q = DeviceQueue::spawn(25.0); // 25 GB/s activation copies
    let bell = Arc::new(Doorbell::new());
    let t0 = Instant::now();
    for _ in 0..layers {
        q.invoke_layer(mode, kernel, copy_bytes, &bell);
    }
    q.synchronize();
    t0.elapsed()
}

fn main() {
    let layers = 32; // Llama2-7B attention layers
    let mut rep = Report::new(
        "Fig 16: prefill latency — native sync vs CaraServe fused operator",
        &["tokens", "native (ms)", "sync-free (ms)", "reduction %"],
    );
    for tokens in [128usize, 256, 512, 1024, 2048] {
        // Per-layer kernel time and activation bytes scale with tokens.
        let kernel = Duration::from_micros(60 + (tokens / 8) as u64);
        let copy_bytes = tokens * 4096 * 2; // fp16 activations
        // Median of 5 runs each.
        let mut native: Vec<f64> = (0..5)
            .map(|_| {
                prefill_walltime(InvokeMode::NativeSync, layers, kernel, copy_bytes)
                    .as_secs_f64()
            })
            .collect();
        let mut fused: Vec<f64> = (0..5)
            .map(|_| {
                prefill_walltime(InvokeMode::SyncFree, layers, kernel, copy_bytes)
                    .as_secs_f64()
            })
            .collect();
        native.sort_by(|a, b| a.partial_cmp(b).unwrap());
        fused.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (n, s) = (native[2], fused[2]);
        rep.row(vec![
            tokens.to_string(),
            f(n * 1e3, 2),
            f(s * 1e3, 2),
            f((1.0 - s / n) * 100.0, 1),
        ]);
    }
    rep.note("paper: CaraServe's kernel gains up to 16% as prefill tokens increase");
    rep.print();
    rep.save("fig16_syncfree").ok();
}
