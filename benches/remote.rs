//! Remote fan-out cost: the ISSUE 9 distributed tier measured at the
//! router's hot control-plane call — `ClusterFront::stats()`, which
//! fans one Stats RPC out to every backend and aggregates the replies.
//!
//! Two compositions of the same 16-backend cluster are timed: all
//! backends in-process (the PR 7 baseline) and all backends behind
//! `RemoteFront`s over socketpairs, each served by its own host thread
//! speaking the `remote::wire` protocol. The aggregated snapshots must
//! be identical — the remote hop may cost time but never meaning. A
//! short end-to-end streaming phase through the remote composition
//! closes the loop (every request must finish).
//!
//! Emits `BENCH_remote.json` in the working directory (plus the
//! standard `target/bench-reports/remote.json`); CI runs `--smoke`.

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use caraserve::config::GpuSpec;
use caraserve::ipc::SocketChannel;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{KernelKind, PerfModel};
use caraserve::remote::client::DEFAULT_IO_TIMEOUT;
use caraserve::remote::{serve_connection, RemoteFront};
use caraserve::scheduler::registry::{AdapterMeta, GlobalRegistry};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::server::{ClusterFront, LifecycleState, ServeRequest, ServingFront};
use caraserve::sim::{GpuModel, ServingMode, SimFront, SimInstance};
use caraserve::util::json::{self, Json};

const BACKENDS: usize = 16;
const ADAPTERS: u64 = 8;

fn rank_of(id: u64) -> usize {
    [8usize, 16, 32, 64][(id % 4) as usize]
}

fn sim_front(s: usize) -> SimFront {
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let inst = SimInstance::new(s, model, ServingMode::CaraServe, 32, 8, 64);
    let mut f = SimFront::new(inst, 512);
    for id in 0..ADAPTERS {
        f.register_adapter(id, rank_of(id));
    }
    f
}

fn cluster(backends: Vec<Box<dyn ServingFront>>) -> ClusterFront {
    let registry = Arc::new(GlobalRegistry::new());
    for id in 0..ADAPTERS {
        registry.register(AdapterMeta {
            id,
            rank: rank_of(id),
            base_model: "sim".into(),
            weights_path: String::new(),
        });
        for s in 0..BACKENDS {
            registry.place(id, s);
        }
    }
    let pre = PerfModel::from_coefficients(KernelKind::Bgmv, 4e-5, 60e-3);
    let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
    let policy = policy_by_name("rank-aware", pre, dec, RankAwareConfig::default(), 7)
        .expect("policy");
    ClusterFront::new(backends, policy, registry)
}

fn local_cluster() -> ClusterFront {
    cluster(
        (0..BACKENDS)
            .map(|s| Box::new(sim_front(s)) as Box<dyn ServingFront>)
            .collect(),
    )
}

/// 16 socketpair-served hosts, one OS thread each; the threads exit
/// when the cluster (and with it every `RemoteFront`) drops.
fn remote_cluster() -> (ClusterFront, Vec<JoinHandle<()>>) {
    let mut backends: Vec<Box<dyn ServingFront>> = Vec::with_capacity(BACKENDS);
    let mut hosts = Vec::with_capacity(BACKENDS);
    for s in 0..BACKENDS {
        let mut front = sim_front(s);
        let (client, mut server) = SocketChannel::pair().expect("socketpair");
        hosts.push(std::thread::spawn(move || {
            let _ = serve_connection(&mut front, &mut server, "bench-host");
        }));
        let front =
            RemoteFront::from_channel(client, &format!("router#{s}"), DEFAULT_IO_TIMEOUT)
                .expect("handshake");
        backends.push(Box::new(front));
    }
    (cluster(backends), hosts)
}

/// Time `iters` aggregations; returns (mean µs per call, a checksum of
/// the last snapshot so the work cannot be optimized away).
fn measure_stats(cluster: &ClusterFront, iters: usize) -> (f64, usize) {
    let mut checksum = 0usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let agg = cluster.stats();
        checksum = agg.kv_free_tokens.wrapping_add(agg.total_requests());
    }
    (t0.elapsed().as_secs_f64() * 1e6 / iters as f64, checksum)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CARA_BENCH_FAST").is_ok();
    let iters = if smoke { 200 } else { 2000 };
    let e2e_requests = if smoke { 32 } else { 128 };

    let mut report = caraserve::bench::Report::new(
        "Remote control-plane fan-out (ClusterFront::stats over 16 backends)",
        &["composition", "backends", "iters", "mean µs/call", "calls/s"],
    );
    let mut runs = Vec::new();

    let local = local_cluster();
    let local_agg = local.stats();
    let (local_us, _) = measure_stats(&local, iters);

    let (remote, hosts) = remote_cluster();
    let remote_agg = remote.stats();
    anyhow::ensure!(
        remote_agg == local_agg,
        "remote aggregation changed meaning:\n  local  {local_agg:?}\n  remote {remote_agg:?}"
    );
    let (remote_us, _) = measure_stats(&remote, iters);

    for (name, us) in [("in-process", local_us), ("remote (wire RPC)", remote_us)] {
        report.row(vec![
            name.to_string(),
            BACKENDS.to_string(),
            iters.to_string(),
            format!("{us:.1}"),
            format!("{:.0}", 1e6 / us),
        ]);
        runs.push(json::obj(vec![
            ("composition", json::s(name)),
            ("backends", json::num(BACKENDS as f64)),
            ("iters", json::num(iters as f64)),
            ("mean_us_per_call", json::num(us)),
            ("calls_per_s", json::num(1e6 / us)),
        ]));
    }

    // End-to-end: stream a small workload through the remote
    // composition; every request must finish.
    let mut remote = remote;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..e2e_requests)
        .map(|i| {
            let req = ServeRequest::new(i as u64 % ADAPTERS, vec![1, 2, 3, 4])
                .max_new_tokens(8);
            remote.submit(req)
        })
        .collect();
    remote.run_until_idle()?;
    let e2e_wall = t0.elapsed().as_secs_f64();
    let finished = handles
        .iter()
        .filter(|h| h.state() == LifecycleState::Finished)
        .count();
    anyhow::ensure!(
        finished == e2e_requests,
        "remote e2e lost requests: {finished}/{e2e_requests} finished"
    );

    report.note(format!(
        "aggregated snapshots identical across compositions; remote hop costs \
         {:.1}x the in-process fan-out; e2e: {finished}/{e2e_requests} streams \
         finished over the wire in {e2e_wall:.2}s",
        remote_us / local_us.max(1e-9),
    ));
    report.print();
    report.save("remote").ok();

    let top = json::obj(vec![
        ("bench", json::s("remote")),
        ("smoke", json::s(if smoke { "true" } else { "false" })),
        ("backends", json::num(BACKENDS as f64)),
        ("adapters", json::num(ADAPTERS as f64)),
        ("stats_overhead_x", json::num(remote_us / local_us.max(1e-9))),
        (
            "e2e",
            json::obj(vec![
                ("requests", json::num(e2e_requests as f64)),
                ("finished", json::num(finished as f64)),
                ("wall_s", json::num(e2e_wall)),
            ]),
        ),
        ("runs", Json::Arr(runs)),
    ]);
    std::fs::write("BENCH_remote.json", top.to_string_pretty())
        .expect("write BENCH_remote.json");
    println!("\nwrote BENCH_remote.json");

    drop(remote);
    for h in hosts {
        h.join().expect("host thread");
    }
    Ok(())
}
