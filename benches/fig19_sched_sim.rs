//! Fig 19 reproduction [Simulation]: cluster scheduling at scale —
//! 60 instances, MAF trace with tens of thousands of functions,
//! aggregate RPS ≈ 340, SLO = 1.5× the HF-PEFT time-per-token.
//!
//! Top: S-LoRA's MBGMV backend; Bottom: Punica/CaraServe's BGMV.
//! Paper: CaraServe's rank-aware scheduler reaches 99% SLO attainment
//! and cuts mean time-per-token by up to 36.4% (MBGMV) / 36.0% (BGMV)
//! vs MostIdle/Random/FirstFit.

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{profiler, KernelKind};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::sim::{GpuModel, MafTrace, ServingMode, SimInstance, Simulation};
use caraserve::util::stats::{mean, percentile};

const INSTANCES: usize = 60;
const RPS: f64 = 340.0; // paper: aggregate ≈340
const DURATION_S: f64 = 120.0;
const N_FUNCTIONS: usize = 40_000;

fn main() {
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let avg_ctx = 160usize;
    let slo = 1.5 * gm.decode_iter(&[avg_ctx]);
    println!(
        "setup: {INSTANCES} instances, {N_FUNCTIONS} functions, rps≈{RPS}, SLO={:.1} ms",
        slo * 1e3
    );

    for kernel in [KernelKind::Mbgmv, KernelKind::Bgmv] {
        // §5 profiling → models.
        let plan = profiler::ProfilePlan::default();
        let g1 = gm.clone();
        let dec = profiler::calibrate(kernel, &plan, |ranks| {
            g1.decode_iter(&vec![avg_ctx; ranks.len()])
                + g1.lora_decode_overhead(kernel, ranks)
        })
        .unwrap();
        let g2 = gm.clone();
        let pre =
            profiler::calibrate(kernel, &plan, |ranks| g2.prefill(ranks.len() * 28)).unwrap();

        let mode = match kernel {
            KernelKind::Bgmv => ServingMode::CaraServe,
            KernelKind::Mbgmv => ServingMode::SLora,
        };
        let trace = MafTrace::new(17, N_FUNCTIONS, 1.0, &[8, 16, 32, 64]);
        let reqs = trace.generate(19, RPS, DURATION_S);

        let mut rep = Report::new(
            &format!("Fig 19 [{kernel:?}]: SLO attainment + time-per-token, {} requests", reqs.len()),
            &["policy", "SLO attain %", "tpt mean (ms)", "tpt p50", "tpt p90", "tpt p99"],
        );
        let mut ra_tpt = None;
        for policy_name in ["rank-aware", "most-idle", "first-fit", "random"] {
            let instances: Vec<SimInstance> = (0..INSTANCES)
                .map(|i| SimInstance::new(i, gm.clone(), mode, 64, 32, 1024))
                .collect();
            let mut policy = policy_by_name(
                policy_name,
                pre.clone(),
                dec.clone(),
                RankAwareConfig {
                    slo,
                    ..Default::default()
                },
                42,
            )
            .expect("known policy");
            let mut sim = Simulation::new(instances);
            let out = sim.run(&reqs, policy.as_mut());
            let tpt = out.column("tpt");
            let m = mean(&tpt);
            if policy_name == "rank-aware" {
                ra_tpt = Some(m);
            }
            rep.row(vec![
                policy_name.to_string(),
                f(out.slo_attainment(slo) * 100.0, 1),
                f(m * 1e3, 2),
                f(percentile(&tpt, 50.0) * 1e3, 2),
                f(percentile(&tpt, 90.0) * 1e3, 2),
                f(percentile(&tpt, 99.0) * 1e3, 2),
            ]);
        }
        if let Some(ra) = ra_tpt {
            rep.note(format!(
                "rank-aware mean tpt {:.2} ms; paper: 99% attainment, up to 36% tpt reduction",
                ra * 1e3
            ));
        }
        rep.print();
        rep.save(&format!("fig19_{kernel:?}")).ok();
    }
}
