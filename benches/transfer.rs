//! Artifact transfer-plane cost (ISSUE 10): chunked, digest-verified
//! blob streaming over the wire protocol, and the migration-TTFT win
//! from overlapping the transfer with serving instead of serializing
//! behind it.
//!
//! Three phases against socketpair-served hosts with attached
//! content-addressed stores:
//!
//! 1. **Throughput** — push a seeded adapter catalog (every chunk
//!    SHA-256-verified on both sides) and report MB/s.
//! 2. **Serialized migration** — drain the in-flight request, then
//!    transfer, then install + first token: wall = decode + transfer
//!    + TTFT, the naive ordering.
//! 3. **Overlapped migration** — pump `push_step` between `poll`s so
//!    the transfer rides inside the serving window: wall approaches
//!    max(transfer, decode) + TTFT. The report's `overlap_x` is the
//!    serialized/overlapped ratio.
//!
//! Emits `BENCH_transfer.json` in the working directory (plus the
//! standard `target/bench-reports/transfer.json`); CI runs `--smoke`.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use caraserve::artifacts::{synthetic_stack, ArtifactStore};
use caraserve::config::GpuSpec;
use caraserve::ipc::SocketChannel;
use caraserve::model::{LlamaConfig, LoraSpec};
use caraserve::remote::client::DEFAULT_IO_TIMEOUT;
use caraserve::remote::{serve_connection_with_store, RemoteFront};
use caraserve::server::{RequestHandle, ServeRequest, ServingFront};
use caraserve::sim::{GpuModel, ServingMode, SimFront, SimInstance};
use caraserve::util::json::{self, Json};

/// Store-side hidden size for the streamed weights. The host is a
/// simulator, so nothing loads these into an engine — sized for
/// meaningful transfer volume (rank-64 blob = 8·hidden·64 bytes).
const HIDDEN: usize = 1024;

fn rank_of(id: u64) -> usize {
    [8usize, 16, 32, 64][(id % 4) as usize]
}

/// Blob bytes one adapter's stack occupies (4 targets, f32 A+B pair).
fn stack_bytes(rank: usize) -> u64 {
    4 * (8 * HIDDEN * rank) as u64
}

struct Scratch(PathBuf);
impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// A sim-backed host over a socketpair with an empty attached store,
/// plus a router `RemoteFront` attached to the seeded source store.
fn host(
    tag: &str,
    scratch: &Scratch,
    source: &Arc<Mutex<ArtifactStore>>,
) -> (RemoteFront, JoinHandle<()>) {
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
    let mut front = SimFront::new(inst, 512);
    let target = Arc::new(Mutex::new(
        ArtifactStore::open(&scratch.0.join(format!("target-{tag}"))).expect("target store"),
    ));
    let (client, mut server) = SocketChannel::pair().expect("socketpair");
    let hosts_store = Arc::clone(&target);
    let handle = std::thread::spawn(move || {
        let _ =
            serve_connection_with_store(&mut front, &mut server, "bench-host", Some(&*hosts_store));
    });
    let mut front =
        RemoteFront::from_channel(client, "bench-router", DEFAULT_IO_TIMEOUT).expect("handshake");
    front.attach_store(Arc::clone(source));
    (front, handle)
}

/// Poll until the handle has produced its first token; returns polls.
fn poll_to_first_token(front: &mut RemoteFront, h: &RequestHandle) -> usize {
    for polls in 0..100_000 {
        if !h.tokens().is_empty() {
            return polls;
        }
        front.poll().expect("poll");
    }
    panic!("first token never arrived");
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CARA_BENCH_FAST").is_ok();
    let adapters: u64 = if smoke { 4 } else { 16 };
    let decode_tokens = if smoke { 32 } else { 128 };

    let scratch = Scratch(
        std::env::temp_dir().join(format!("caraserve-bench-transfer-{}", std::process::id())),
    );
    let _ = std::fs::remove_dir_all(&scratch.0);
    std::fs::create_dir_all(&scratch.0)?;
    let mut source = ArtifactStore::open(&scratch.0.join("source"))?;
    for a in 0..adapters {
        let rank = rank_of(a);
        source.publish(a, rank, "tiny", &synthetic_stack(a, HIDDEN, rank))?;
    }
    let source = Arc::new(Mutex::new(source));

    let mut report = caraserve::bench::Report::new(
        "Artifact transfer: digest-verified streaming + migration overlap",
        &["phase", "adapters", "bytes", "wall ms", "metric"],
    );
    let mut runs = Vec::new();

    // ---- Phase 1: raw push throughput over the whole catalog -------------
    let (front, h1) = host("throughput", &scratch, &source);
    let total_bytes: u64 = (0..adapters).map(|a| stack_bytes(rank_of(a))).sum();
    let t0 = Instant::now();
    for a in 0..adapters {
        front.push_adapter(a).expect("push");
    }
    let push_wall = t0.elapsed().as_secs_f64();
    let mb_s = total_bytes as f64 / 1e6 / push_wall.max(1e-9);
    // Re-push is pure dedup probing: no blob bytes move.
    let session = front.push_session(0).expect("re-session");
    anyhow::ensure!(session.total_bytes() == 0, "dedup probe saw missing blobs");
    report.row(vec![
        "push throughput".into(),
        adapters.to_string(),
        total_bytes.to_string(),
        format!("{:.2}", push_wall * 1e3),
        format!("{mb_s:.1} MB/s"),
    ]);
    runs.push(json::obj(vec![
        ("phase", json::s("throughput")),
        ("adapters", json::num(adapters as f64)),
        ("bytes", json::num(total_bytes as f64)),
        ("wall_ms", json::num(push_wall * 1e3)),
        ("mb_per_s", json::num(mb_s)),
    ]));
    front.shutdown().ok();
    h1.join().expect("host thread");

    // The migrated adapter: the largest rank in the catalog.
    let migrated = 3u64;
    let warm = 1u64;
    let migrate_bytes = stack_bytes(rank_of(migrated));

    // ---- Phase 2: serialized — decode, then transfer, then install ------
    let (mut front, h2) = host("serialized", &scratch, &source);
    front
        .install_adapter(&LoraSpec::standard(warm, rank_of(warm), "sim"))
        .expect("warm install");
    let t0 = Instant::now();
    let inflight = front.submit(
        ServeRequest::new(warm, vec![1, 2, 3, 4]).max_new_tokens(decode_tokens),
    );
    front.run_until_idle().expect("drain in-flight");
    front.push_adapter(migrated).expect("push");
    front
        .install_adapter(&LoraSpec::standard(migrated, rank_of(migrated), "sim"))
        .expect("migrated install");
    let h = front.submit(ServeRequest::new(migrated, vec![1, 2, 3, 4]).max_new_tokens(4));
    poll_to_first_token(&mut front, &h);
    let serial_wall = t0.elapsed().as_secs_f64();
    anyhow::ensure!(!inflight.tokens().is_empty(), "in-flight stream stalled");
    front.run_until_idle().expect("drain");
    front.shutdown().ok();
    h2.join().expect("host thread");

    // ---- Phase 3: overlapped — transfer rides the serving window --------
    let (mut front, h3) = host("overlapped", &scratch, &source);
    front
        .install_adapter(&LoraSpec::standard(warm, rank_of(warm), "sim"))
        .expect("warm install");
    let t0 = Instant::now();
    let inflight = front.submit(
        ServeRequest::new(warm, vec![1, 2, 3, 4]).max_new_tokens(decode_tokens),
    );
    let mut session = front.push_session(migrated).expect("session");
    let mut done = false;
    while !done || !inflight.is_terminal() {
        if !done {
            done = front.push_step(&mut session).expect("push step");
        }
        front.poll().expect("poll");
    }
    front
        .install_adapter(&LoraSpec::standard(migrated, rank_of(migrated), "sim"))
        .expect("migrated install");
    let h = front.submit(ServeRequest::new(migrated, vec![1, 2, 3, 4]).max_new_tokens(4));
    poll_to_first_token(&mut front, &h);
    let overlap_wall = t0.elapsed().as_secs_f64();
    anyhow::ensure!(!inflight.tokens().is_empty(), "in-flight stream stalled");
    front.run_until_idle().expect("drain");
    front.shutdown().ok();
    h3.join().expect("host thread");

    let overlap_x = serial_wall / overlap_wall.max(1e-9);
    for (name, wall) in [("serialized", serial_wall), ("overlapped", overlap_wall)] {
        report.row(vec![
            format!("migration ({name})"),
            "1".into(),
            migrate_bytes.to_string(),
            format!("{:.2}", wall * 1e3),
            format!("decode {decode_tokens} tok + transfer + TTFT"),
        ]);
        runs.push(json::obj(vec![
            ("phase", json::s(name)),
            ("adapters", json::num(1.0)),
            ("bytes", json::num(migrate_bytes as f64)),
            ("wall_ms", json::num(wall * 1e3)),
            ("decode_tokens", json::num(decode_tokens as f64)),
        ]));
    }

    report.note(format!(
        "push: {mb_s:.1} MB/s with per-chunk digests; migration wall \
         serialized {:.1} ms vs overlapped {:.1} ms ({overlap_x:.2}x) — the \
         transfer hides inside the serving window, so target TTFT trends to \
         max(transfer, prefill) instead of their sum",
        serial_wall * 1e3,
        overlap_wall * 1e3,
    ));
    report.print();
    report.save("transfer").ok();

    let top = json::obj(vec![
        ("bench", json::s("transfer")),
        ("smoke", json::s(if smoke { "true" } else { "false" })),
        ("adapters", json::num(adapters as f64)),
        ("hidden", json::num(HIDDEN as f64)),
        ("throughput_mb_s", json::num(mb_s)),
        ("migration_serialized_ms", json::num(serial_wall * 1e3)),
        ("migration_overlapped_ms", json::num(overlap_wall * 1e3)),
        ("overlap_x", json::num(overlap_x)),
        ("runs", Json::Arr(runs)),
    ]);
    std::fs::write("BENCH_transfer.json", top.to_string_pretty())
        .expect("write BENCH_transfer.json");
    println!("\nwrote BENCH_transfer.json");
    Ok(())
}
