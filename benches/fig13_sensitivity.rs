//! Fig 13 reproduction: sensitivity to LoRA rank and load.
//!
//! Top: RPS = 9, rank = 32 (smaller adapters ⇒ shorter loads).
//! Bottom: RPS = 6, rank = 64 (lighter traffic ⇒ fewer cold prefills).
//! Paper overheads vs CACHED —
//!   top: ondmd 88/28/25 %, s-lora 126/36/31 %, caraserve 36/5/6 %;
//!   bottom: ondmd 42/25/24 %, s-lora 41/25/20 %, caraserve 1/10/9 %.

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::sim::{GpuModel, ServingMode, SimInstance, Simulation, SingleServer};
use caraserve::util::stats::mean;

fn run_config(rps: f64, rank: usize, label: &str, paper: &str) {
    let reqs = caraserve::sim::workload::synthetic(2, rps, rank, 300.0);
    let modes = [
        ServingMode::Cached,
        ServingMode::OnDemand,
        ServingMode::SLora,
        ServingMode::CaraServe,
    ];
    let mut rep = Report::new(
        &format!("Fig 13 ({label}): overhead vs CACHED, rps={rps} rank={rank}"),
        &["mode", "ttft +%", "tpt +%", "latency +%"],
    );
    let mut base: Option<(f64, f64, f64)> = None;
    for mode in modes {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut sim =
            Simulation::new(vec![SimInstance::new(0, model, mode, 64, 32, 1024)]);
        let out = sim.run(&reqs, &mut SingleServer);
        let t = mean(&out.column("ttft"));
        let p = mean(&out.column("tpt"));
        let l = mean(&out.column("latency"));
        match base {
            None => {
                base = Some((t, p, l));
                rep.row(vec![
                    mode.name().to_string(),
                    "base".into(),
                    "base".into(),
                    "base".into(),
                ]);
            }
            Some((bt, bp, bl)) => {
                rep.row(vec![
                    mode.name().to_string(),
                    f((t / bt - 1.0) * 100.0, 0),
                    f((p / bp - 1.0) * 100.0, 0),
                    f((l / bl - 1.0) * 100.0, 0),
                ]);
            }
        }
    }
    rep.note(paper);
    rep.print();
    rep.save(&format!("fig13_{label}")).ok();
}

fn main() {
    run_config(
        9.0,
        32,
        "top",
        "paper: ondmd 88/28/25, s-lora 126/36/31, caraserve 36/5/6 (%)",
    );
    run_config(
        6.0,
        64,
        "bottom",
        "paper: ondmd 42/25/24, s-lora 41/25/20, caraserve 1/10/9 (%)",
    );
}
