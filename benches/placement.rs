//! Static vs coordinator placement on a skewed workload: the §3
//! global-coordinator payoff measured on live native engines.
//!
//! Runs the shared synthetic harness twice per seed — once with the
//! static id-hash placement baseline (`synthetic::build`), once with
//! registry-driven placement + pre-warming + live migration
//! (`synthetic::build_coordinated`) — and reports SLO attainment, TTFT
//! percentiles, cold starts, rank-balance spread, and the coordinator's
//! placement/migration counters.
//!
//! Emits `BENCH_placement.json` in the working directory (plus the
//! standard `target/bench-reports/placement.json`); CI runs `--smoke`
//! to keep the file fresh. The acceptance shape is coordinator ≥ static
//! on SLO attainment with fewer cold starts on the skewed head.

use caraserve::coordinator::CoordinatorConfig;
use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::ColdStartMode;
use caraserve::util::json::{self, Json};
use caraserve::util::stats::{ms_or_dash as ms, Summary};

fn summary_json(s: &Option<Summary>) -> Json {
    match s {
        None => Json::Null,
        Some(s) => json::obj(vec![
            ("mean_ms", json::num(s.mean * 1e3)),
            ("p50_ms", json::num(s.p50 * 1e3)),
            ("p99_ms", json::num(s.p99 * 1e3)),
        ]),
    }
}

fn spread(sums: &[usize]) -> usize {
    match (sums.iter().max(), sums.iter().min()) {
        (Some(hi), Some(lo)) => hi - lo,
        _ => 0,
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CARA_BENCH_FAST").is_ok();
    let cfg = SyntheticConfig {
        instances: if smoke { 2 } else { 3 },
        requests: if smoke { 20 } else { 64 },
        adapters: if smoke { 12 } else { 24 },
        seed: 7,
        threads: 1,
        cpu_workers: 0,
        // CaraServe cold starts: pre-warming's cold-admit savings are
        // visible, and migration decisions still steer on real load.
        cold_start: ColdStartMode::CaraServe,
        kv_pages: 256,
        polls_per_arrival: 1,
        skew: 1.2,
    };
    let ccfg = CoordinatorConfig {
        migrate_interval: 2,
        prewarm: if smoke { 3 } else { 6 },
        // Match the static baseline's replication factor (`hosts`
        // places each adapter on two servers, or all of them when
        // instances <= 2) so the headline isolates placement quality.
        replicas: 2,
        min_imbalance: 1,
        ..Default::default()
    };
    let policy = "rank-aware";

    let mut report = caraserve::bench::Report::new(
        "Placement: static id-hash vs coordinator (registry-driven + migration)",
        &[
            "placement",
            "done",
            "SLO %",
            "ttft p50",
            "ttft p99",
            "cold",
            "rank spread",
            "migrations",
        ],
    );

    let static_rep = synthetic::run(policy, &cfg)?;
    let (coord_rep, coord) = synthetic::run_coordinated(policy, &cfg, ccfg)?;
    let cs = coord.coordinator_stats().clone();

    for (label, rep, migrations) in [
        ("static", &static_rep, 0),
        ("coordinator", &coord_rep, cs.migrations),
    ] {
        report.row(vec![
            label.to_string(),
            rep.finished.to_string(),
            format!("{:.1}", rep.slo_attainment.unwrap_or(1.0) * 100.0),
            ms(&rep.ttft, |s| s.p50),
            ms(&rep.ttft, |s| s.p99),
            rep.cold.cold_admits.to_string(),
            spread(&rep.routed_rank_sum).to_string(),
            migrations.to_string(),
        ]);
    }
    let (sa, ca) = (
        static_rep.slo_attainment.unwrap_or(1.0),
        coord_rep.slo_attainment.unwrap_or(1.0),
    );
    report.note(format!(
        "coordinator {:.1}% vs static {:.1}% SLO attainment; cold admits {} vs {}; \
         {} migrations, {} retirements, {} prewarmed \
         (acceptance: coordinator ≥ static)",
        ca * 100.0,
        sa * 100.0,
        coord_rep.cold.cold_admits,
        static_rep.cold.cold_admits,
        cs.migrations,
        cs.retirements,
        cs.prewarmed
    ));
    report.print();
    report.save("placement").ok();

    let run_json = |label: &str, rep: &synthetic::RunReport| {
        json::obj(vec![
            ("placement", json::s(label)),
            ("requests", json::num(rep.requests as f64)),
            ("finished", json::num(rep.finished as f64)),
            ("rejected", json::num(rep.rejected as f64)),
            (
                "slo_attainment",
                rep.slo_attainment.map_or(Json::Null, json::num),
            ),
            ("ttft", summary_json(&rep.ttft)),
            ("tpot", summary_json(&rep.tpot)),
            ("cold_admits", json::num(rep.cold.cold_admits as f64)),
            (
                "routed",
                Json::Arr(rep.routed.iter().map(|&n| json::num(n as f64)).collect()),
            ),
            (
                "rank_spread",
                json::num(spread(&rep.routed_rank_sum) as f64),
            ),
            ("preemptions", json::num(rep.preemptions as f64)),
            ("wall_s", json::num(rep.wall_s)),
        ])
    };
    let top = json::obj(vec![
        ("bench", json::s("placement")),
        ("smoke", json::s(if smoke { "true" } else { "false" })),
        ("instances", json::num(cfg.instances as f64)),
        ("requests", json::num(cfg.requests as f64)),
        ("adapters", json::num(cfg.adapters as f64)),
        ("skew", json::num(cfg.skew)),
        ("policy", json::s(policy)),
        ("slo_attainment_static", json::num(sa)),
        ("slo_attainment_coordinator", json::num(ca)),
        (
            "coordinator",
            json::obj(vec![
                ("initial_placements", json::num(cs.initial_placements as f64)),
                ("prewarmed", json::num(cs.prewarmed as f64)),
                ("rebalance_ticks", json::num(cs.rebalance_ticks as f64)),
                ("migrations", json::num(cs.migrations as f64)),
                ("retirements", json::num(cs.retirements as f64)),
                (
                    "deferred_retirements",
                    json::num(cs.deferred_retirements as f64),
                ),
            ]),
        ),
        (
            "runs",
            Json::Arr(vec![
                run_json("static", &static_rep),
                run_json("coordinator", &coord_rep),
            ]),
        ),
    ]);
    std::fs::write("BENCH_placement.json", top.to_string_pretty())
        .expect("write BENCH_placement.json");
    println!("\nwrote BENCH_placement.json");
    Ok(())
}
