//! Steady-state decode throughput: paged-vs-dense KV × 1-vs-N threads.
//!
//! The tentpole measurement for the zero-copy paged decode path. For
//! each (batch, context) point the same token-generation loop runs
//! under three regimes:
//!
//! - `dense/1t`  — the pre-paged contract: every step re-materializes
//!   the whole KV history densely (`assemble_into`) and decodes it
//!   serially. This is the baseline the speedup is quoted against.
//! - `paged/1t`  — zero-copy block-table reads, still serial: isolates
//!   the assembly cost.
//! - `paged/Nt`  — zero-copy plus the forward pool fanning batch rows
//!   across cores: the shipped configuration.
//!
//! All three regimes produce bitwise-identical logits (asserted on the
//! first step of every point — the equivalence the integration suite
//! pins in depth). Emits `BENCH_decode.json` in the working directory
//! (plus the standard `target/bench-reports/decode_throughput.json`)
//! so successive PRs can track the decode trajectory; CI runs the
//! `--smoke` mode (tiny contexts, few steps) to keep the file fresh.

use std::time::Instant;

use caraserve::bench::{f, Report};
use caraserve::kernels::AdapterWeights;
use caraserve::runtime::{DenseKv, KvWrite, NativeConfig, NativeRuntime, RowLora};
use caraserve::server::KvCacheManager;
use caraserve::util::json::{self, Json};
use caraserve::util::rng::Rng;

const PAGE_SIZE: usize = 16;

fn bench_config(threads: usize, cache_m: usize) -> NativeConfig {
    NativeConfig {
        hidden: 256,
        layers: 4,
        heads: 8,
        vocab: 1024,
        intermediate: 688,
        max_seq: cache_m + 64,
        lora_slots: 8,
        max_prompt: 64,
        max_prefill_batch: 4,
        max_decode_batch: 8,
        cache_m,
        seed: 0xCA7A_5E27,
        threads,
    }
}

fn make_runtime(threads: usize, cache_m: usize) -> NativeRuntime {
    let mut rt = NativeRuntime::new(bench_config(threads, cache_m));
    // A resident rank-8 adapter so decode exercises the rank-grouped
    // LoRA kernel, as in real serving.
    let mk = |t: u64| AdapterWeights::synthetic(31 + t, 256, 256, 8);
    rt.install_slot(0, Some(std::sync::Arc::new([mk(0), mk(1), mk(2), mk(3)])));
    rt
}

/// Fabricate `ctx` tokens of deterministic history KV for `batch`
/// requests straight into a fresh paged pool (prompt content is
/// irrelevant to throughput; values are kept small so softmax stays
/// tame).
fn seeded_kv(batch: usize, ctx: usize, steps: usize, layers: usize, hidden: usize) -> KvCacheManager {
    let pages_per_req = (ctx + steps).div_ceil(PAGE_SIZE) + 1;
    let mut kv = KvCacheManager::new(
        layers,
        hidden,
        PAGE_SIZE,
        batch * pages_per_req,
        ctx + steps + 8,
    );
    let mut rng = Rng::new(0xBEEF);
    let mut krow = vec![0.0f32; hidden];
    let mut vrow = vec![0.0f32; hidden];
    for b in 0..batch {
        kv.reserve(b as u64, ctx).unwrap();
    }
    let ids: Vec<u64> = (0..batch as u64).collect();
    let mut writers = kv.writers(&ids).unwrap();
    for w in writers.iter_mut() {
        for layer in 0..layers {
            for t in 0..ctx {
                for d in 0..hidden {
                    krow[d] = (rng.f32() - 0.5) * 0.2;
                    vrow[d] = (rng.f32() - 0.5) * 0.2;
                }
                w.write_kv(layer, t, &krow, &vrow);
            }
        }
    }
    drop(writers);
    kv
}

struct RunOut {
    tokens_per_s: f64,
    us_per_step: f64,
    first_logits: Vec<f32>,
}

/// Decode `steps` tokens for the whole batch, feeding argmax tokens
/// back, and time the loop. `dense` selects the pre-paged assembly
/// contract.
fn run(rt: &NativeRuntime, batch: usize, ctx: usize, steps: usize, dense: bool) -> RunOut {
    let cfg = &rt.cfg;
    let (layers, hidden, m) = (cfg.layers, cfg.hidden, cfg.cache_m);
    let mut kv = seeded_kv(batch, ctx, steps, layers, hidden);
    let ids: Vec<u64> = (0..batch as u64).collect();
    let idx: Vec<i32> = vec![0; batch];
    let rows = vec![RowLora::Slot(0); batch];
    let mut last: Vec<i32> = (0..batch as i32).map(|b| (b * 97 + 13) % 1024).collect();
    let mut pos: Vec<i32> = vec![ctx as i32; batch];
    let (mut ks, mut vs) = (Vec::new(), Vec::new());
    let mut first_logits = Vec::new();

    let t0 = Instant::now();
    for step in 0..steps {
        let out = if dense {
            kv.assemble_into(&ids, batch, m, &mut ks, &mut vs).unwrap();
            let view = DenseKv::new(&ks, &vs, layers, batch, m, hidden);
            rt.decode(&idx, &last, &pos, &view, &rows).unwrap()
        } else {
            // The view drops with this block, before the appends below.
            let view = kv.paged_view(&ids).unwrap();
            rt.decode(&idx, &last, &pos, &view, &rows).unwrap()
        };
        for (b, id) in ids.iter().enumerate() {
            kv.append_token(*id, &out.k_new, &out.v_new, batch, b).unwrap();
            last[b] = rt.argmax_row(&out.logits, b);
            pos[b] += 1;
        }
        if step == 0 {
            first_logits = out.logits;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    RunOut {
        tokens_per_s: (batch * steps) as f64 / dt,
        us_per_step: dt / steps as f64 * 1e6,
        first_logits,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("CARA_BENCH_FAST").is_ok();
    let (batches, ctxs, steps): (Vec<usize>, Vec<usize>, usize) = if smoke {
        (vec![4], vec![64], 4)
    } else {
        (vec![1, 4, 8], vec![128, 512], 32)
    };
    let max_ctx = *ctxs.iter().max().unwrap();
    let cache_m = max_ctx + steps + 16;
    let threads = caraserve::runtime::native::default_threads().max(2);

    let serial = make_runtime(1, cache_m);
    let parallel = make_runtime(threads, cache_m);

    let mut report = Report::new(
        "Steady-state decode: paged-vs-dense KV × 1-vs-N threads (native runtime)",
        &["batch", "ctx", "mode", "tokens/s", "µs/step", "× vs dense/1t"],
    );
    let mut runs_json: Vec<Json> = Vec::new();
    let mut headline: Option<f64> = None;

    for &batch in &batches {
        for &ctx in &ctxs {
            let dense1 = run(&serial, batch, ctx, steps, true);
            let paged1 = run(&serial, batch, ctx, steps, false);
            let pagedn = run(&parallel, batch, ctx, steps, false);
            // The three regimes must agree bitwise — the whole point of
            // the refactor is that layout and threading are invisible.
            assert_eq!(
                dense1.first_logits, paged1.first_logits,
                "paged decode diverged from dense at batch {batch} ctx {ctx}"
            );
            assert_eq!(
                dense1.first_logits, pagedn.first_logits,
                "parallel decode diverged from serial at batch {batch} ctx {ctx}"
            );
            let mode_n = format!("paged/{threads}t");
            for (mode, threads_used, out) in [
                ("dense/1t", 1usize, &dense1),
                ("paged/1t", 1, &paged1),
                (mode_n.as_str(), threads, &pagedn),
            ] {
                let speedup = out.tokens_per_s / dense1.tokens_per_s;
                report.row(vec![
                    batch.to_string(),
                    ctx.to_string(),
                    mode.to_string(),
                    f(out.tokens_per_s, 1),
                    f(out.us_per_step, 1),
                    f(speedup, 2),
                ]);
                runs_json.push(json::obj(vec![
                    ("batch", json::num(batch as f64)),
                    ("ctx", json::num(ctx as f64)),
                    ("mode", json::s(mode)),
                    ("threads", json::num(threads_used as f64)),
                    ("steps", json::num(steps as f64)),
                    ("tokens_per_s", json::num(out.tokens_per_s)),
                    ("us_per_step", json::num(out.us_per_step)),
                    ("speedup_vs_dense_serial", json::num(speedup)),
                ]));
            }
            if batch == 8 && ctx == max_ctx {
                headline = Some(pagedn.tokens_per_s / dense1.tokens_per_s);
            }
        }
    }
    report.note(
        "dense/1t is the pre-paged contract (assemble_into per step, serial rows); \
         acceptance: paged/Nt ≥ 2× dense/1t at batch 8, ctx ≥ 512",
    );
    if let Some(hx) = headline {
        report.note(format!("headline speedup (batch 8, ctx {max_ctx}): {hx:.2}×"));
    }
    report.print();
    report.save("decode_throughput").ok();

    let top = json::obj(vec![
        ("bench", json::s("decode_throughput")),
        ("smoke", json::s(if smoke { "true" } else { "false" })),
        (
            "model",
            json::obj(vec![
                ("hidden", json::num(256.0)),
                ("layers", json::num(4.0)),
                ("vocab", json::num(1024.0)),
            ]),
        ),
        ("page_size", json::num(PAGE_SIZE as f64)),
        ("threads", json::num(threads as f64)),
        (
            "headline_speedup_paged_parallel_vs_dense_serial",
            headline.map_or(Json::Null, json::num),
        ),
        ("runs", Json::Arr(runs_json)),
    ]);
    std::fs::write("BENCH_decode.json", top.to_string_pretty())
        .expect("write BENCH_decode.json");
    println!("\nwrote BENCH_decode.json");
}
