//! Figs 12 & 14 reproduction: scaled production (MAF) workload.
//!
//! Fig 12: the skewed adapter-invocation probability mass function.
//! Fig 14: serving overhead vs CACHED as the number of hosted adapters
//! grows 128 → 256 → 512 (aggregate rps 1.5 / 3.6 / 7.7). Paper @512:
//! ondmd/s-lora/caraserve inflate TTFT 39/39/7 %, tpt 34/32/7 %,
//! latency 31/31/8 %.

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::sim::{GpuModel, MafTrace, ServingMode, SimInstance, Simulation, SingleServer};
use caraserve::util::stats::mean;

fn main() {
    // --- Fig 12: invocation PMF ---
    let trace = MafTrace::new(7, 512, 1.0, &[64]);
    let mut pmf = Report::new(
        "Fig 12: LoRA invocation probability mass (512 adapters, sorted)",
        &["adapter rank-order", "invocation prob"],
    );
    for k in [0usize, 1, 3, 7, 15, 31, 63, 127, 255, 511] {
        pmf.row(vec![format!("#{}", k + 1), format!("{:.5}", trace.popularity[k])]);
    }
    pmf.note("skewed head (Zipf-like), matching the MAF trace shape");
    pmf.print();
    pmf.save("fig12_pmf").ok();

    // --- Fig 14: overhead vs adapter count ---
    for n_adapters in [128usize, 256, 512] {
        let rps = MafTrace::scaled_rps(n_adapters);
        let trace = MafTrace::new(7, n_adapters, 1.0, &[64]);
        let reqs = trace.generate(11, rps, 300.0);
        let mut rep = Report::new(
            &format!("Fig 14: {n_adapters} adapters (rps={rps:.1}, {} reqs)", reqs.len()),
            &["mode", "ttft +%", "tpt +%", "latency +%", "cold %"],
        );
        let mut base: Option<(f64, f64, f64)> = None;
        for mode in [
            ServingMode::Cached,
            ServingMode::OnDemand,
            ServingMode::SLora,
            ServingMode::CaraServe,
        ] {
            let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
            // Device adapter cache bounded at 32 resident rank-64
            // adapters: A10 (24 GB) minus 7B fp16 weights (13.5 GB)
            // minus KV leaves ~3 GB ≈ 32 × 100 MiB.
            let mut sim =
                Simulation::new(vec![SimInstance::new(0, model, mode, 64, 32, 32)]);
            let out = sim.run(&reqs, &mut SingleServer);
            let t = mean(&out.column("ttft"));
            let p = mean(&out.column("tpt"));
            let l = mean(&out.column("latency"));
            let c = mean(&out.column("cold_frac"));
            match base {
                None => {
                    base = Some((t, p, l));
                    rep.row(vec![
                        mode.name().into(),
                        "base".into(),
                        "base".into(),
                        "base".into(),
                        f(c * 100.0, 1),
                    ]);
                }
                Some((bt, bp, bl)) => rep.row(vec![
                    mode.name().into(),
                    f((t / bt - 1.0) * 100.0, 0),
                    f((p / bp - 1.0) * 100.0, 0),
                    f((l / bl - 1.0) * 100.0, 0),
                    f(c * 100.0, 1),
                ]),
            }
        }
        rep.note("paper @512: ondmd 39/34/31, s-lora 39/32/31, caraserve 7/7/8 (%)");
        rep.print();
        rep.save(&format!("fig14_n{n_adapters}")).ok();
    }
}
