//! Fig 15 reproduction: tensor-parallel serving of Llama2-13B (2×A10)
//! and Llama2-70B (4×A100) at RPS = 6, rank = 64.
//!
//! Paper: CaraServe gains 20.2% / 18.5% mean request-latency speedup
//! over on-demand loading for 13B / 70B, cutting cold-start by >50%.
//! (S-LoRA is excluded: no multi-GPU release at paper time.)

use caraserve::bench::{f, Report};
use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::sim::{GpuModel, ServingMode, SimInstance, Simulation, SingleServer};
use caraserve::util::stats::mean;

fn run(cfg: LlamaConfig, gpu: GpuSpec, tp: usize, label: &str) {
    let reqs = caraserve::sim::workload::synthetic(3, 6.0, 64, 300.0);
    let mut rep = Report::new(
        &format!("Fig 15: {label} (tp={tp}, rps=6, rank=64)"),
        &["mode", "ttft (ms)", "tpt (ms)", "latency (ms)", "cold %"],
    );
    let mut lat = Vec::new();
    let mut cold = Vec::new();
    for mode in [
        ServingMode::Cached,
        ServingMode::OnDemand,
        ServingMode::CaraServe,
    ] {
        let model = GpuModel::new(cfg.clone(), gpu.clone(), tp);
        let mut sim =
            Simulation::new(vec![SimInstance::new(0, model, mode, 64, 32, 1024)]);
        let out = sim.run(&reqs, &mut SingleServer);
        let l = mean(&out.column("latency"));
        let c = mean(&out.column("cold_frac"));
        lat.push(l);
        cold.push(c);
        rep.row(vec![
            mode.name().into(),
            f(mean(&out.column("ttft")) * 1e3, 1),
            f(mean(&out.column("tpt")) * 1e3, 1),
            f(l * 1e3, 0),
            f(c * 100.0, 1),
        ]);
    }
    let speedup = (lat[1] / lat[2] - 1.0) * 100.0;
    let cold_cut = (1.0 - cold[2] / cold[1].max(1e-12)) * 100.0;
    rep.note(format!(
        "caraserve vs ondmd: {speedup:.1}% latency speedup, {cold_cut:.0}% cold-start cut \
         (paper: ~20%/18.5% speedup, >50% cold-start cut)"
    ));
    rep.print();
    rep.save(&format!("fig15_{label}")).ok();
}

fn main() {
    run(LlamaConfig::llama2_13b(), GpuSpec::a10(), 2, "llama2-13b_2xA10");
    run(LlamaConfig::llama2_70b(), GpuSpec::a100(), 4, "llama2-70b_4xA100");
}
