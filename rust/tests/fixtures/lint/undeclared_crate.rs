//! Fixture: a path root that is neither a declared crate, a module of
//! the tree, nor a `use` import — the `undeclared-crate` rule must
//! fire. This is the class of break that ships `libc::` calls with no
//! manifest entry and only surfaces at build time.

pub fn encode(x: u64) -> String {
    serde_json::to_string(&x)
}
