//! Seeded fixture: panicking constructs in what pretends to be the
//! wire codec's non-test code. Every construct here must fire
//! `wire-panic-free` when scanned as `remote/wire.rs` — and nothing
//! anywhere else (the decoder's typed-error contract is scoped to the
//! codec file, not the whole tree).

pub fn frame_len(bytes: &[u8]) -> usize {
    let head: [u8; 4] = bytes[..4].try_into().unwrap();
    u32::from_le_bytes(head) as usize
}

pub fn tag_of(frame: u8) -> u8 {
    assert!(frame < 128, "tag overflow");
    frame
}

pub fn reserved() -> u8 {
    unreachable!("decoder state machine escaped");
}
