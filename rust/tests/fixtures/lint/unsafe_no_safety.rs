//! Fixture: an `unsafe` block with no safety justification — the
//! `safety-comment` rule must fire on the block's line.

pub fn read_through(p: &u32) -> u32 {
    unsafe { core::ptr::read(p) }
}
