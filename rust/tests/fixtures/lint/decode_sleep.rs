//! Fixture: sleeping and busy-spinning on the decode path — the
//! `decode-sleep` rule must fire on both.

pub fn nap() {
    std::thread::sleep(std::time::Duration::from_millis(1));
}

pub fn spin() {
    std::hint::spin_loop();
}
