//! Fixture: satisfies every lint rule even when scanned as a hot,
//! decode-path module. Not compiled into any target — read by
//! `rust/tests/lint_analysis.rs` and fed to `lint_source`.

use std::sync::atomic::{AtomicU32, Ordering};

/// Read a monitoring counter.
pub fn peek(c: &AtomicU32) -> u32 {
    // ORDERING: progress statistic only; no data is published on it.
    c.load(Ordering::Relaxed)
}

/// Copy a value out of a reference via a raw read.
pub fn read_through(p: &u32) -> u32 {
    // SAFETY: `p` is a live shared reference, so the pointee is valid,
    // aligned, and initialized for the duration of the read.
    unsafe { core::ptr::read(p) }
}
