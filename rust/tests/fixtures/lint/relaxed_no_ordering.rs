//! Fixture: `Ordering::Relaxed` without a nearby justification note —
//! the `ordering-comment` rule must fire.

use std::sync::atomic::{AtomicU32, Ordering};

pub fn peek(c: &AtomicU32) -> u32 {
    c.load(Ordering::Relaxed)
}
