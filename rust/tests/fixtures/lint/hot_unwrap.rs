//! Fixture: `.unwrap()` on a hot path outside tests — the
//! `hot-unwrap` rule must fire on `first` but tolerate the
//! lock-poisoning idiom in `locked`.

use std::sync::Mutex;

pub fn first(v: &[u32]) -> u32 {
    *v.first().unwrap()
}

pub fn locked(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
