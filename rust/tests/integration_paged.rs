//! Integration: the zero-copy paged-KV decode path.
//!
//! Pins the tentpole equivalences of the paged refactor:
//!
//! 1. decoding over [`PagedKv`] block tables is **bitwise** identical to
//!    the old dense-assembly path (`assemble_into` + dense view),
//!    including after page-boundary crossings and request
//!    eviction/readmission;
//! 2. engine token streams are invariant to the page size (the paged
//!    layout is invisible to the model);
//! 3. engine token streams are invariant to the forward-pool width
//!    (1-thread == N-thread, the §Perf threading contract).

use caraserve::model::LoraSpec;
use caraserve::runtime::{DenseKv, KvWrite, NativeConfig, NativeRuntime, RowLora};
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, KvCacheManager, LifecycleState,
    ServeRequest, ServingFront,
};

fn runtime() -> NativeRuntime {
    NativeRuntime::new(NativeConfig::test_tiny())
}

#[test]
fn paged_decode_is_bitwise_identical_to_dense_assembly() {
    let rt = runtime();
    let cfg = rt.cfg.clone();
    let (l, h, m) = (cfg.layers, cfg.hidden, cfg.cache_m);
    // page_size 4: a 7-token prompt already spans two pages and the
    // decode loop below crosses several more boundaries.
    let mut kv = KvCacheManager::new(l, h, 4, 64, m);

    let prompts: Vec<Vec<i32>> = vec![
        (0..7).map(|i| i * 3 + 1).collect(),
        (0..5).map(|i| i * 11 + 2).collect(),
    ];
    let ids = [101u64, 202];
    for (i, p) in prompts.iter().enumerate() {
        kv.reserve(ids[i], p.len()).unwrap();
    }
    let lens: Vec<i32> = prompts.iter().map(|p| p.len() as i32).collect();
    let rows = vec![RowLora::Base; 2];
    let out = {
        let mut writers = kv.writers(&ids).unwrap();
        let mut writer_refs: Vec<&mut dyn KvWrite> = writers
            .iter_mut()
            .map(|w| w as &mut dyn KvWrite)
            .collect();
        rt.prefill(&[0, 1], &prompts, &lens, &rows, &mut writer_refs)
            .unwrap()
    };

    let mut last: Vec<i32> = (0..2).map(|b| rt.argmax_row(&out.logits, b)).collect();
    let mut ctx: Vec<i32> = lens.clone();
    let idx = [0i32, 1];
    let (mut ks, mut vs) = (Vec::new(), Vec::new());
    for step in 0..12 {
        // The pre-paged contract: materialize the whole history densely…
        kv.assemble_into(&ids, 2, m, &mut ks, &mut vs).unwrap();
        let dense_view = DenseKv::new(&ks, &vs, l, 2, m, h);
        let dense = rt.decode(&idx, &last, &ctx, &dense_view, &rows).unwrap();
        // …versus reading the pages in place.
        let paged = {
            let view = kv.paged_view(&ids).unwrap();
            rt.decode(&idx, &last, &ctx, &view, &rows).unwrap()
        };
        assert_eq!(dense.logits, paged.logits, "logits diverged at step {step}");
        assert_eq!(dense.k_new, paged.k_new, "k_new diverged at step {step}");
        assert_eq!(dense.v_new, paged.v_new, "v_new diverged at step {step}");
        for (b, id) in ids.iter().enumerate() {
            kv.append_token(*id, &paged.k_new, &paged.v_new, 2, b).unwrap();
            last[b] = rt.argmax_row(&paged.logits, b);
            ctx[b] += 1;
        }
    }
    // 12 appends from a 7-token prompt crossed the 8-, 12- and 16-token
    // page boundaries.
    assert_eq!(kv.len_of(101), Some(19));
}

#[test]
fn paged_decode_survives_eviction_and_readmission() {
    // Free one request mid-flight and admit a new one over the recycled
    // pages: the survivor's stream and the newcomer's stream must still
    // match the dense reference exactly (stale page contents are never
    // addressed).
    let rt = runtime();
    let cfg = rt.cfg.clone();
    let (l, h, m) = (cfg.layers, cfg.hidden, cfg.cache_m);
    let mut kv = KvCacheManager::new(l, h, 4, 16, m);

    let prefill_one = |kv: &mut KvCacheManager, id: u64, prompt: &Vec<i32>| -> i32 {
        kv.reserve(id, prompt.len()).unwrap();
        let mut writers = kv.writers(&[id]).unwrap();
        let mut writer_refs: Vec<&mut dyn KvWrite> = writers
            .iter_mut()
            .map(|w| w as &mut dyn KvWrite)
            .collect();
        let out = rt
            .prefill(
                &[0],
                std::slice::from_ref(prompt),
                &[prompt.len() as i32],
                &[RowLora::Base],
                &mut writer_refs,
            )
            .unwrap();
        rt.argmax_row(&out.logits, 0)
    };

    let p_a: Vec<i32> = (0..8).map(|i| i * 5 + 3).collect();
    let p_b: Vec<i32> = (0..6).map(|i| i * 9 + 1).collect();
    let first_a = prefill_one(&mut kv, 1, &p_a);
    let free_before = kv.free_pages();
    kv.free_request(1).unwrap();
    assert!(kv.free_pages() > free_before, "pages must return to the pool");

    // Readmit over the recycled pages and decode both ways.
    let first_b = prefill_one(&mut kv, 2, &p_b);
    let rows = [RowLora::Base];
    let (mut last, mut ctx) = (first_b, p_b.len() as i32);
    let (mut ks, mut vs) = (Vec::new(), Vec::new());
    for _ in 0..6 {
        kv.assemble_into(&[2], 1, m, &mut ks, &mut vs).unwrap();
        let dense_view = DenseKv::new(&ks, &vs, l, 1, m, h);
        let dense = rt.decode(&[0], &[last], &[ctx], &dense_view, &rows).unwrap();
        let paged = {
            let view = kv.paged_view(&[2]).unwrap();
            rt.decode(&[0], &[last], &[ctx], &view, &rows).unwrap()
        };
        assert_eq!(dense.logits, paged.logits, "recycled pages leaked state");
        kv.append_token(2, &paged.k_new, &paged.v_new, 1, 0).unwrap();
        last = rt.argmax_row(&paged.logits, 0);
        ctx += 1;
    }
    // The evicted request's first token is reproducible on a fresh pool
    // (nothing about eviction depended on the survivor).
    let mut fresh = KvCacheManager::new(l, h, 4, 16, m);
    assert_eq!(prefill_one(&mut fresh, 9, &p_a), first_a);
}

const N_ADAPTERS: u64 = 6;

fn engine(page_size: usize, threads: usize) -> InferenceServer {
    let runtime = NativeRuntime::new(NativeConfig::test_tiny().with_threads(threads));
    let mut s = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            page_size,
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..N_ADAPTERS {
        s.install_adapter(&LoraSpec::standard(id, 4, "tiny"))
            .expect("install");
    }
    s
}

/// Run a deterministic mixed workload and collect every token stream.
fn workload_tokens(s: &mut InferenceServer) -> Vec<Vec<i32>> {
    let mut handles = Vec::new();
    for i in 0..8u64 {
        let prompt: Vec<i32> = (0..(5 + i as i32 % 7))
            .map(|j| (j * 13 + i as i32 * 3) % 64)
            .collect();
        let req = ServeRequest::new(i % N_ADAPTERS, prompt)
            .max_new_tokens(4 + (i as usize % 9));
        handles.push(s.submit(req));
        if i % 3 == 2 {
            // Interleave admits with decode so batches overlap.
            s.run_until_idle().expect("serve");
        }
    }
    s.run_until_idle().expect("serve");
    handles
        .iter()
        .map(|h| {
            assert_eq!(h.state(), LifecycleState::Finished);
            h.tokens()
        })
        .collect()
}

#[test]
fn admission_trims_to_available_pages() {
    // Two prompts that individually pass the page check but jointly
    // exhaust the pool: the engine must admit them one at a time (the
    // cumulative accounting in step()), not abort the serving loop with
    // a mid-batch reservation failure that orphans both handles.
    let runtime = NativeRuntime::new(NativeConfig::test_tiny());
    let mut s = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            page_size: 4,
            kv_pages: 3, // each 8-token prompt needs 2 pages
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..2u64 {
        s.install_adapter(&LoraSpec::standard(id, 4, "tiny"))
            .expect("install");
    }
    let h1 = s.submit(
        ServeRequest::new(0, (0..8).map(|i| i % 64).collect()).max_new_tokens(2),
    );
    let h2 = s.submit(
        ServeRequest::new(1, (0..8).map(|i| (i * 3) % 64).collect()).max_new_tokens(2),
    );
    s.run_until_idle()
        .expect("joint over-admission must not abort the engine");
    assert_eq!(h1.state(), LifecycleState::Finished);
    assert_eq!(h2.state(), LifecycleState::Finished);
    assert_eq!(h1.tokens().len(), 2);
    assert_eq!(h2.tokens().len(), 2);
}

#[test]
fn engine_streams_are_invariant_to_page_size() {
    let baseline = workload_tokens(&mut engine(16, 1));
    for page_size in [2usize, 5, 64] {
        let got = workload_tokens(&mut engine(page_size, 1));
        assert_eq!(got, baseline, "page_size {page_size} changed token streams");
    }
}

#[test]
fn engine_streams_are_invariant_to_thread_count() {
    let baseline = workload_tokens(&mut engine(16, 1));
    for threads in [2usize, 4] {
        let got = workload_tokens(&mut engine(16, threads));
        assert_eq!(got, baseline, "threads {threads} changed token streams");
    }
}
