//! Property-based tests on coordinator invariants (routing, batching,
//! KV accounting, simulation conservation laws) using the in-repo
//! mini-proptest framework ([`caraserve::testkit`]).

use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{KernelKind, PerfModel};
use caraserve::scheduler::{
    AdapterSet, Policy, RankAwareConfig, RankAwareScheduler, SchedRequest, ServerStats,
};
use caraserve::server::kvcache::KvCacheManager;
use caraserve::sim::{
    GpuModel, ServingMode, SimInstance, Simulation, SingleServer, WorkloadRequest,
};
use caraserve::testkit::prop::{self, Config, Gen};
use caraserve::util::rng::Rng;

fn gen_ranks() -> Gen<Vec<usize>> {
    prop::vec_of(prop::one_of(vec![8usize, 16, 32, 64, 128]), 0, 40)
}

#[test]
fn prop_perf_models_monotone_in_added_request() {
    // Adding a request never decreases predicted latency for either
    // kernel — the property Algorithm 1's Δcost relies on.
    let cfg = Config::default();
    forall_ranks(&cfg, |ranks| {
        for kernel in [KernelKind::Bgmv, KernelKind::Mbgmv] {
            let m = PerfModel::from_coefficients(kernel, 1e-5, 25e-3);
            let before = m.predict(ranks);
            for add in [8usize, 64, 128] {
                let mut after = ranks.to_vec();
                after.push(add);
                if m.predict(&after) + 1e-12 < before {
                    return Err(format!(
                        "{kernel:?}: predict decreased when adding rank {add}"
                    ));
                }
            }
        }
        Ok(())
    });
}

fn forall_ranks(cfg: &Config, f: impl Fn(&Vec<usize>) -> Result<(), String>) {
    prop::forall(cfg, &gen_ranks(), f);
}

#[test]
fn prop_rank_aware_always_picks_an_eligible_server() {
    let cfg = Config {
        cases: 128,
        ..Default::default()
    };
    // Generate clusters: vec of (load, eligible) pairs encoded as usize
    // (load*2 + eligible); ineligible servers host a disjoint adapter
    // set, the real mechanism the old boolean stood in for.
    let gen = prop::vec_of(prop::usize_in(0, 80), 1, 12);
    prop::forall(&cfg, &gen, |encoded| {
        let stats: Vec<ServerStats> = encoded
            .iter()
            .map(|&e| ServerStats {
                running_ranks: vec![32; e / 2],
                adapters: if e % 2 == 1 {
                    AdapterSet::Any
                } else {
                    AdapterSet::only(vec![99])
                },
                ..Default::default()
            })
            .collect();
        let mut sched = RankAwareScheduler::new(
            PerfModel::from_coefficients(KernelKind::Bgmv, 4e-5, 60e-3),
            PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3),
            RankAwareConfig::default(),
        );
        let req = SchedRequest {
            id: 1,
            adapter: 1,
            rank: 32,
            prompt_len: 16,
        };
        let pick = sched.pick(&req, &stats);
        let any_eligible = stats.iter().any(|s| s.eligible_for(&req));
        match pick {
            Some(i) if !stats[i].eligible_for(&req) => {
                Err(format!("picked ineligible {i}"))
            }
            Some(_) if !any_eligible => Err("picked from empty".into()),
            None if any_eligible => Err("missed eligible server".into()),
            _ => Ok(()),
        }
    });
}

#[test]
fn prop_kv_manager_conserves_pages() {
    // Random admit/append/free sequences never leak or double-free pages.
    let cfg = Config {
        cases: 64,
        ..Default::default()
    };
    let gen = prop::vec_of(prop::usize_in(0, 100), 1, 60);
    prop::forall(&cfg, &gen, |ops| {
        let layers = 2;
        let hidden = 8;
        let mut kv = KvCacheManager::new(layers, hidden, 4, 16, 64);
        let total = kv.total_pages();
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        let k = vec![0.5f32; layers * 1 * 8 * hidden];
        for &op in ops {
            match op % 3 {
                0 => {
                    // Admit with prompt length 1..8.
                    let len = 1 + op / 13 % 8;
                    if kv.can_admit(len) {
                        kv.admit_from_prefill(next_id, &k, &k, 1, 8, 0, len)
                            .map_err(|e| format!("admit: {e}"))?;
                        live.push(next_id);
                        next_id += 1;
                    }
                }
                1 => {
                    if let Some(&id) = live.first() {
                        let row = vec![0.1f32; layers * hidden];
                        // Append may legitimately fail when out of pages
                        // or at capacity; must not corrupt state.
                        let _ = kv.append_token(id, &row, &row, 1, 0);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let id = live.remove(0);
                        kv.free_request(id).map_err(|e| format!("free: {e}"))?;
                    }
                }
            }
            let used: usize = total - kv.free_pages();
            if kv.live_requests() == 0 && used != 0 {
                return Err(format!("leak: {used} pages with no live requests"));
            }
        }
        for id in live {
            kv.free_request(id).map_err(|e| format!("final free: {e}"))?;
        }
        if kv.free_pages() != total {
            return Err(format!(
                "pages not conserved: {} != {total}",
                kv.free_pages()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_unified_pool_accounting_never_leaks() {
    // ISSUE 7 tentpole invariant: with adapter weights paging through
    // the same pool as KV blocks, every page is exactly one of free,
    // KV-held, or adapter-held after *every* operation — under random
    // interleavings of request admits/appends/frees with adapter
    // page-ins/page-outs, including legitimately failing ops
    // (out-of-pages, already-resident, unknown adapter).
    let cfg = Config {
        cases: 64,
        ..Default::default()
    };
    let gen = prop::vec_of(prop::usize_in(0, 1000), 1, 80);
    prop::forall(&cfg, &gen, |ops| {
        let layers = 2;
        let hidden = 8;
        let mut kv = KvCacheManager::new(layers, hidden, 4, 16, 64);
        let total = kv.total_pages();
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        let k = vec![0.5f32; layers * 1 * 8 * hidden];
        for &op in ops {
            match op % 5 {
                0 => {
                    let len = 1 + op / 13 % 8;
                    if kv.can_admit(len) {
                        kv.admit_from_prefill(next_id, &k, &k, 1, 8, 0, len)
                            .map_err(|e| format!("admit: {e}"))?;
                        live.push(next_id);
                        next_id += 1;
                    }
                }
                1 => {
                    if let Some(&id) = live.first() {
                        let row = vec![0.1f32; layers * hidden];
                        let _ = kv.append_token(id, &row, &row, 1, 0);
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let id = live.remove(0);
                        kv.free_request(id).map_err(|e| format!("free: {e}"))?;
                    }
                }
                3 => {
                    // Page an adapter in: ranks 2/4/8 → 1/2/4 pages on
                    // this geometry (page_elems = 128). AlreadyResident
                    // and OutOfPages are legal outcomes; neither may
                    // corrupt the accounting.
                    let adapter = (op / 7 % 6) as u64;
                    let rank = [2usize, 4, 8][op / 11 % 3];
                    let w = vec![0.25f32; 8 * hidden * rank];
                    let _ = kv.reserve_adapter(adapter, &w);
                }
                _ => {
                    let adapter = (op / 7 % 6) as u64;
                    let _ = kv.free_adapter(adapter);
                }
            }
            if !kv.accounting_balanced() {
                return Err(format!(
                    "accounting unbalanced after op {op}: free={} kv={} adapter={} total={total}",
                    kv.free_pages(),
                    kv.kv_held_pages(),
                    kv.adapter_held_pages()
                ));
            }
            let held = kv.kv_held_pages() + kv.adapter_held_pages();
            if kv.free_pages() + held != total {
                return Err(format!(
                    "pages leaked mid-stream: {} free + {held} held != {total}",
                    kv.free_pages()
                ));
            }
        }
        // Drain both kinds of residency; the pool must come back whole.
        for id in live {
            kv.free_request(id).map_err(|e| format!("final free: {e}"))?;
        }
        for a in kv.resident_adapters() {
            if kv.free_adapter(a).is_none() {
                return Err(format!("resident adapter {a} refused to free"));
            }
        }
        if kv.free_pages() != total {
            return Err(format!(
                "pages not conserved after drain: {} != {total}",
                kv.free_pages()
            ));
        }
        Ok(())
    });
}

#[test]
fn interleaved_request_and_adapter_paging_conserve_the_pool() {
    // Exhaustive schedule exploration of one request thread (admit →
    // append → free) against one adapter-paging thread (reserve →
    // free): the unified-pool conservation law must hold after every
    // atomic step, in every interleaving, and the pool must be whole
    // at the end of every schedule.
    use caraserve::testkit::interleave::{self, always, ScriptModel};
    let factory = || {
        let kv = KvCacheManager::new(2, 8, 4, 16, 16);
        ScriptModel::new(kv)
            .thread(vec![
                always(|kv: &mut KvCacheManager| {
                    let k = vec![0.5f32; 2 * 8 * 8];
                    let _ = kv.admit_from_prefill(1, &k, &k, 1, 8, 0, 6);
                }),
                always(|kv: &mut KvCacheManager| {
                    let row = vec![0.1f32; 2 * 8];
                    let _ = kv.append_token(1, &row, &row, 1, 0);
                }),
                always(|kv: &mut KvCacheManager| {
                    let _ = kv.free_request(1);
                }),
            ])
            .thread(vec![
                always(|kv: &mut KvCacheManager| {
                    // rank-4 adapter: 2 pages on this geometry.
                    let w = vec![0.25f32; 8 * 8 * 4];
                    let _ = kv.reserve_adapter(7, &w);
                }),
                always(|kv: &mut KvCacheManager| {
                    let _ = kv.free_adapter(7);
                }),
            ])
            .invariant(|kv: &KvCacheManager| {
                if !kv.accounting_balanced() {
                    return Err("accounting unbalanced".into());
                }
                let held = kv.kv_held_pages() + kv.adapter_held_pages();
                if kv.free_pages() + held != kv.total_pages() {
                    return Err(format!(
                        "leak: {} free + {held} held != {}",
                        kv.free_pages(),
                        kv.total_pages()
                    ));
                }
                Ok(())
            })
            .finally(|kv: &KvCacheManager| {
                if kv.free_pages() == kv.total_pages() {
                    Ok(())
                } else {
                    Err(format!(
                        "pool not whole at end: {} != {}",
                        kv.free_pages(),
                        kv.total_pages()
                    ))
                }
            })
    };
    let report = interleave::explore(factory, 10_000);
    assert!(report.ok(), "{report}");
    assert!(report.exhausted, "schedule space unexpectedly large");
}

#[test]
fn prop_simulation_conserves_requests_and_orders_tokens() {
    // Every generated request completes exactly once, with monotone
    // token times and ttft ≤ latency — under random workloads and modes.
    let cfg = Config {
        cases: 24,
        ..Default::default()
    };
    let gen = prop::usize_in(0, 10_000);
    prop::forall(&cfg, &gen, |&seed| {
        let mut rng = Rng::new(seed as u64);
        let mode = *rng.choose(&[
            ServingMode::Cached,
            ServingMode::OnDemand,
            ServingMode::SLora,
            ServingMode::CaraServe,
        ]);
        let rps = rng.uniform(1.0, 12.0);
        let reqs: Vec<WorkloadRequest> =
            caraserve::sim::workload::synthetic(seed as u64, rps, 64, 20.0);
        let n = reqs.len();
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut sim =
            Simulation::new(vec![SimInstance::new(0, model, mode, 32, 16, 256)]);
        let out = sim.run(&reqs, &mut SingleServer);
        if out.requests.len() != n {
            return Err(format!("{} of {n} requests completed", out.requests.len()));
        }
        for r in &out.requests {
            if r.ttft < 0.0 || r.latency + 1e-9 < r.ttft {
                return Err(format!("bad timing: ttft={} latency={}", r.ttft, r.latency));
            }
            if r.time_per_token <= 0.0 {
                return Err("nonpositive tpt".into());
            }
            if r.cold_start < 0.0 {
                return Err("negative cold".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_never_exceeds_max_batch() {
    use caraserve::server::api::{ActiveRequest, Priority, SamplingParams};
    use caraserve::server::batcher::{Batcher, NextAction, RunningReq};
    let cfg = Config {
        cases: 128,
        ..Default::default()
    };
    let gen = prop::vec_of(prop::usize_in(1, 20), 0, 30);
    prop::forall(&cfg, &gen, |prompts| {
        let mut b = Batcher::new(4, 2);
        for (i, &p) in prompts.iter().enumerate() {
            b.enqueue(ActiveRequest {
                id: i as u64,
                adapter: i as u64,
                prompt: vec![1; p],
                sampling: SamplingParams {
                    max_new_tokens: 2,
                    ..Default::default()
                },
                priority: match p % 3 {
                    0 => Priority::Batch,
                    1 => Priority::Standard,
                    _ => Priority::Interactive,
                },
                slo: None,
                resume: None,
            });
        }
        // Drain: alternate admissions and reaps.
        let mut guard = 0;
        loop {
            guard += 1;
            if guard > 1000 {
                return Err("did not drain".into());
            }
            match b.next_action(|_| true) {
                NextAction::Idle => break,
                NextAction::Prefill { admit } => {
                    let admits = b.take_admits(admit);
                    for q in admits {
                        b.start_running(RunningReq {
                            id: q.req.id,
                            adapter: q.req.adapter,
                            ctx: q.req.prompt.len(),
                            prompt: q.req.prompt,
                            generated: 1,
                            sampling: q.req.sampling,
                            priority: q.req.priority,
                            slo: q.req.slo,
                            last_token: 0,
                            stopped: false,
                        });
                    }
                    if b.running.len() > 4 {
                        return Err(format!("batch overflow: {}", b.running.len()));
                    }
                }
                NextAction::Decode => {
                    for r in b.running.iter_mut() {
                        r.generated += 1;
                    }
                    b.reap_finished();
                }
            }
        }
        if !b.running.is_empty() || !b.queue.is_empty() {
            return Err("work left after drain".into());
        }
        Ok(())
    });
}

#[test]
fn prop_every_submitted_request_terminates_in_exactly_one_terminal_event() {
    // The lifecycle API's core guarantee, under random workloads with
    // random priorities, stop tokens, rejections, and cancellations:
    // every handle ends in exactly one terminal event, token streams
    // respect stop tokens and budgets, and the backend drains clean.
    use caraserve::server::api::Priority;
    use caraserve::server::{LifecycleState, RequestEvent, ServeRequest, ServingFront};
    use caraserve::sim::{SimFront, SimInstance};

    let cfg = Config {
        cases: 48,
        ..Default::default()
    };
    let gen = prop::usize_in(0, 100_000);
    prop::forall(&cfg, &gen, |&seed| {
        let mut rng = Rng::new(seed as u64);
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let max_batch = rng.range(1, 9);
        let inst =
            SimInstance::new(0, model, ServingMode::CaraServe, max_batch, 8, 16);
        let mut front = SimFront::new(inst, 64);
        for id in 0..7 {
            front.register_adapter(id, *rng.choose(&[8, 16, 32, 64]));
        }

        let n = rng.range(1, 20);
        let mut handles = Vec::with_capacity(n);
        let mut cancels = Vec::new();
        for _ in 0..n {
            // ~1 in 8 requests targets an unregistered adapter → Rejected.
            let adapter = rng.range(0, 8) as u64;
            let mut req = ServeRequest::new(adapter, vec![1; rng.range(1, 64)])
                .max_new_tokens(rng.range(1, 12))
                .priority(*rng.choose(&[
                    Priority::Batch,
                    Priority::Standard,
                    Priority::Interactive,
                ]));
            if rng.chance(0.3) {
                // Stop token somewhere in (or beyond) the synthetic stream.
                req = req.stop_token(rng.range(0, 14) as i32);
            }
            let handle = front.submit(req);
            if rng.chance(0.25) {
                cancels.push(handle.clone());
            }
            handles.push(handle);
            // Interleave some progress so cancels hit queued *and*
            // running requests.
            if rng.chance(0.5) {
                let _ = front.poll().map_err(|e| e.to_string())?;
            }
            for h in &cancels {
                if rng.chance(0.5) {
                    h.cancel();
                }
            }
        }
        for h in &cancels {
            h.cancel();
        }
        front.run_until_idle().map_err(|e| e.to_string())?;

        for h in &handles {
            let state = h.state();
            if !state.is_terminal() {
                return Err(format!("request {} ended in {state:?}", h.id()));
            }
            let events = h.drain_events();
            let terminals = events.iter().filter(|e| e.is_terminal()).count();
            if terminals != 1 {
                return Err(format!(
                    "request {}: {terminals} terminal events in {events:?}",
                    h.id()
                ));
            }
            if !events.last().unwrap().is_terminal() {
                return Err(format!("request {}: events after terminal", h.id()));
            }
            // Token stream consistency with the terminal reason.
            let tokens = h.tokens();
            match events.last().unwrap() {
                RequestEvent::Rejected(_) => {
                    if !tokens.is_empty() || events.len() != 1 {
                        return Err("rejected request saw activity".into());
                    }
                }
                RequestEvent::Finished(_) => {
                    if tokens.is_empty() {
                        return Err("finished without tokens".into());
                    }
                }
                RequestEvent::Cancelled => {}
                other => return Err(format!("non-terminal last event {other:?}")),
            }
            if state == LifecycleState::Finished && tokens.is_empty() {
                return Err("finished with empty stream".into());
            }
        }
        if front.instance().queue.len() + front.instance().running.len() != 0 {
            return Err("backend left work behind".into());
        }
        Ok(())
    });
}

#[test]
fn prop_management_surface_interleaves_safely_with_traffic() {
    // PR 5's management surface (install/uninstall/prewarm) interleaved
    // with live traffic: uninstall refuses *exactly* when the adapter
    // has in-flight requests, prewarm succeeds exactly when installed,
    // and the lifecycle guarantee still holds for every handle.
    use caraserve::model::LoraSpec;
    use caraserve::server::{RequestHandle, ServeRequest, ServingFront};
    use caraserve::sim::SimFront;

    let cfg = Config {
        cases: 32,
        ..Default::default()
    };
    let gen = prop::usize_in(0, 100_000);
    prop::forall(&cfg, &gen, |&seed| {
        let mut rng = Rng::new(seed as u64);
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst =
            SimInstance::new(0, model, ServingMode::CaraServe, rng.range(1, 6), 8, 16);
        let mut front = SimFront::new(inst, 64);
        for id in 0..4 {
            front.register_adapter(id, *rng.choose(&[8, 16, 32, 64]));
        }

        let in_flight = |front: &SimFront, id: u64| {
            let inst = front.instance();
            inst.queue
                .iter()
                .chain(inst.running.iter())
                .filter(|r| r.req.adapter == id)
                .count()
        };

        let mut handles: Vec<RequestHandle> = Vec::new();
        for _ in 0..rng.range(10, 40) {
            match rng.range(0, 10) {
                0..=3 => {
                    let adapter = rng.range(0, 5) as u64;
                    let req = ServeRequest::new(adapter, vec![1; rng.range(1, 32)])
                        .max_new_tokens(rng.range(1, 8));
                    handles.push(front.submit(req));
                }
                4 | 5 => {
                    front.poll().map_err(|e| e.to_string())?;
                }
                6 => {
                    // Install (or re-install with a possibly new rank).
                    let id = rng.range(0, 5) as u64;
                    let rank = *rng.choose(&[8usize, 16, 32, 64]);
                    front
                        .install_adapter(&LoraSpec::standard(id, rank, "sim"))
                        .map_err(|e| format!("install: {e}"))?;
                }
                7 => {
                    // Uninstall must refuse exactly when requests on the
                    // adapter are queued or running.
                    let id = rng.range(0, 5) as u64;
                    let busy = in_flight(&front, id);
                    match front.uninstall_adapter(id) {
                        Ok(()) if busy != 0 => {
                            return Err(format!(
                                "uninstalled adapter {id} with {busy} in flight"
                            ));
                        }
                        Ok(()) => {}
                        Err(e) => {
                            let msg = e.to_string();
                            if msg.contains("busy") {
                                if busy == 0 {
                                    return Err(format!(
                                        "spurious busy refusal for idle adapter {id}"
                                    ));
                                }
                            } else if !msg.contains("not installed") {
                                return Err(format!("unexpected refusal: {msg}"));
                            }
                        }
                    }
                }
                8 => {
                    // Prewarm succeeds (and warms) exactly when installed.
                    let id = rng.range(0, 5) as u64;
                    match front.prewarm_adapter(id) {
                        Ok(warmed) => {
                            if !warmed {
                                return Err(format!("prewarm {id} warmed nothing"));
                            }
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            if !msg.contains("not installed") {
                                return Err(format!("unexpected prewarm error: {msg}"));
                            }
                        }
                    }
                }
                _ => {
                    if let Some(h) = handles.last() {
                        h.cancel();
                    }
                }
            }
        }
        front.run_until_idle().map_err(|e| e.to_string())?;

        for h in &handles {
            let state = h.state();
            if !state.is_terminal() {
                return Err(format!("request {} ended in {state:?}", h.id()));
            }
            let events = h.drain_events();
            let terminals = events.iter().filter(|e| e.is_terminal()).count();
            if terminals != 1 {
                return Err(format!(
                    "request {}: {terminals} terminal events in {events:?}",
                    h.id()
                ));
            }
            if !events.last().unwrap().is_terminal() {
                return Err(format!("request {}: events after terminal", h.id()));
            }
        }
        if front.instance().queue.len() + front.instance().running.len() != 0 {
            return Err("backend left work behind".into());
        }
        Ok(())
    });
}
