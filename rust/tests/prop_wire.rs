//! Property tests for the distributed tier's frame codec
//! (`caraserve::remote::wire`): seeded-random frames of every variant
//! round-trip bitwise, and no mutilation of the byte stream —
//! truncation, bit flips, random soup, oversized declared counts,
//! foreign versions — ever panics the decoder. Failures print the seed
//! so a counterexample replays deterministically.

use caraserve::model::{LoraSpec, TargetMatrix};
use caraserve::remote::wire::{decode, encode, Frame, WireError, MAGIC, MAX_CHUNK_BYTES, VERSION};
use caraserve::scheduler::{AdapterSet, ServerStats};
use caraserve::server::metrics::ColdStartStats;
use caraserve::server::{
    FinishReason, Priority, RejectReason, RequestEvent, ResumeState, ServeRequest,
};
use caraserve::util::rng::Rng;

// ---------------------------------------------------------------------------
// Frame generator
// ---------------------------------------------------------------------------

fn arb_string(rng: &mut Rng) -> String {
    let len = rng.range(0, 24);
    (0..len)
        .map(|_| {
            // Mix ASCII with multi-byte code points so string length
            // (bytes) and char count disagree.
            if rng.chance(0.2) {
                'é'
            } else {
                (b'a' + rng.range(0, 26) as u8) as char
            }
        })
        .collect()
}

fn arb_tokens(rng: &mut Rng) -> Vec<i32> {
    let len = rng.range(0, 12);
    (0..len).map(|_| rng.next_u64() as i32).collect()
}

fn arb_reason(rng: &mut Rng, depth: usize) -> RejectReason {
    // The recursive variant only below the honest-encoder depth.
    let top = if depth == 0 { 11 } else { 10 };
    match rng.range(0, top) {
        0 => RejectReason::PromptBounds {
            len: rng.range(0, 10_000),
            max_prompt: rng.range(0, 10_000),
        },
        1 => RejectReason::EmptyBudget,
        2 => RejectReason::KvCapacity {
            kv_capacity: rng.range(0, 1 << 20),
        },
        3 => RejectReason::AdapterNotInstalled {
            adapter: rng.next_u64(),
        },
        4 => RejectReason::AdapterNotRegistered {
            adapter: rng.next_u64(),
        },
        5 => RejectReason::PoolTooSmall {
            adapter: rng.next_u64(),
            pool_pages: rng.range(0, 4096),
        },
        6 => RejectReason::NoEligibleServer { last: None },
        7 => RejectReason::PolicyRepick {
            server: rng.range(0, 64),
        },
        8 => RejectReason::Overloaded {
            healthy: rng.range(0, 64),
            shed: arb_priority(rng),
        },
        9 => RejectReason::BackendFailed {
            server: rng.range(0, 64),
        },
        _ => RejectReason::NoEligibleServer {
            last: Some(Box::new(arb_reason(rng, depth + 1))),
        },
    }
}

fn arb_priority(rng: &mut Rng) -> Priority {
    match rng.range(0, 3) {
        0 => Priority::Batch,
        1 => Priority::Standard,
        _ => Priority::Interactive,
    }
}

fn arb_event(rng: &mut Rng) -> RequestEvent {
    match rng.range(0, 8) {
        0 => RequestEvent::Admitted,
        1 => RequestEvent::Routed {
            server: rng.range(0, 64),
        },
        2 => RequestEvent::FirstToken(rng.next_u64() as i32),
        3 => RequestEvent::Token(rng.next_u64() as i32),
        4 => RequestEvent::Finished(if rng.chance(0.5) {
            FinishReason::Length
        } else {
            FinishReason::Stop
        }),
        5 => RequestEvent::Rerouted {
            from: rng.range(0, 64),
            to: rng.range(0, 64),
        },
        6 => RequestEvent::Cancelled,
        _ => RequestEvent::Rejected(arb_reason(rng, 0)),
    }
}

fn arb_request(rng: &mut Rng) -> ServeRequest {
    let mut req = ServeRequest::new(rng.next_u64(), arb_tokens(rng))
        .max_new_tokens(rng.range(0, 64))
        .priority(arb_priority(rng));
    for _ in 0..rng.range(0, 3) {
        req = req.stop_token(rng.next_u64() as i32);
    }
    if rng.chance(0.5) {
        req = req.top_k(rng.range(0, 40), rng.next_u64());
    }
    if rng.chance(0.5) {
        req = req.slo(rng.uniform(1.0, 1000.0), rng.uniform(1.0, 200.0));
    }
    if rng.chance(0.3) {
        req.resume = Some(ResumeState {
            tokens: arb_tokens(rng),
        });
    }
    req
}

fn arb_adapter_set(rng: &mut Rng) -> AdapterSet {
    if rng.chance(0.3) {
        AdapterSet::Any
    } else {
        let n = rng.range(0, 8);
        AdapterSet::only((0..n).map(|_| rng.below(100)).collect())
    }
}

fn arb_usizes(rng: &mut Rng) -> Vec<usize> {
    let n = rng.range(0, 6);
    (0..n).map(|_| rng.range(0, 128)).collect()
}

fn arb_stats(rng: &mut Rng) -> ServerStats {
    ServerStats {
        running_ranks: arb_usizes(rng),
        queued_ranks: arb_usizes(rng),
        adapters: arb_adapter_set(rng),
        // usize::MAX is the "unbounded" sentinel both fields document —
        // keep it in the generated population.
        max_prompt_tokens: if rng.chance(0.2) {
            usize::MAX
        } else {
            rng.range(0, 1 << 16)
        },
        kv_free_tokens: if rng.chance(0.2) {
            usize::MAX
        } else {
            rng.range(0, 1 << 16)
        },
        tpot_slo: rng.chance(0.5).then(|| rng.uniform(0.001, 0.5)),
        preemptions: rng.range(0, 100),
        pool_pages: rng.range(0, 4096),
        kv_held_pages: rng.range(0, 4096),
        adapter_held_pages: rng.range(0, 4096),
        adapter_evictions: rng.range(0, 100),
        event_overflows: rng.range(0, 100),
    }
}

fn arb_spec(rng: &mut Rng) -> LoraSpec {
    let mut spec = LoraSpec::standard(
        rng.next_u64(),
        [8, 16, 32, 64][rng.range(0, 4)],
        &arb_string(rng),
    );
    if rng.chance(0.3) {
        spec.targets = vec![TargetMatrix::Q, TargetMatrix::K, TargetMatrix::V, TargetMatrix::O];
    }
    spec
}

/// A digest-shaped string: usually 64 lowercase hex chars, sometimes
/// arbitrary text (the codec carries digests opaquely; validation is
/// the store's job).
fn arb_digest(rng: &mut Rng) -> String {
    if rng.chance(0.3) {
        return arb_string(rng);
    }
    (0..64)
        .map(|_| {
            let d = rng.below(16) as u32;
            char::from_digit(d, 16).unwrap()
        })
        .collect()
}

/// A chunk payload within the decoder's cap (frames declaring more
/// than [`MAX_CHUNK_BYTES`] are refused by design — tested separately).
fn arb_chunk_bytes(rng: &mut Rng) -> Vec<u8> {
    let len = rng.range(0, 256);
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

/// One random frame, uniform over all 30 variants.
fn arb_frame(rng: &mut Rng) -> Frame {
    match rng.range(0, 30) {
        0 => Frame::Hello {
            client: arb_string(rng),
        },
        1 => Frame::Submit {
            client_id: rng.next_u64(),
            req: arb_request(rng),
        },
        2 => Frame::Poll,
        3 => Frame::Cancel {
            client_id: rng.next_u64(),
        },
        4 => Frame::Stats,
        5 => Frame::Install {
            spec: arb_spec(rng),
        },
        6 => Frame::Uninstall {
            adapter: rng.next_u64(),
        },
        7 => Frame::Prewarm {
            adapter: rng.next_u64(),
        },
        8 => Frame::ColdStart,
        9 => Frame::Heartbeat {
            nonce: rng.next_u64(),
        },
        10 => Frame::Shutdown,
        11 => Frame::Welcome {
            version: VERSION,
            server: arb_string(rng),
            resident: arb_adapter_set(rng),
        },
        12 => Frame::Submitted {
            client_id: rng.next_u64(),
            backend_id: rng.next_u64(),
            events: (0..rng.range(0, 4)).map(|_| arb_event(rng)).collect(),
        },
        13 => Frame::Events {
            events: (0..rng.range(0, 6))
                .map(|_| (rng.next_u64(), arb_event(rng)))
                .collect(),
            progressed: rng.chance(0.5),
        },
        14 => Frame::CancelResult {
            live: rng.chance(0.5),
        },
        15 => Frame::StatsReply {
            stats: arb_stats(rng),
        },
        16 => Frame::PrewarmResult {
            warmed: rng.chance(0.5),
        },
        17 => Frame::ColdStartReply {
            stats: rng.chance(0.5).then(|| ColdStartStats {
                cold_admits: rng.range(0, 100),
                warm_admits: rng.range(0, 100),
                cpu_assisted: rng.range(0, 100),
                handoffs: rng.range(0, 100),
                deferred_collisions: rng.range(0, 100),
                assist_decode_s: rng.uniform(0.0, 10.0),
            }),
        },
        18 => Frame::HeartbeatAck {
            nonce: rng.next_u64(),
        },
        19 => Frame::OkReply,
        20 => Frame::ErrReply {
            message: arb_string(rng),
        },
        21 => Frame::FetchManifest {
            adapter: rng.next_u64(),
        },
        22 => Frame::FetchChunk {
            digest: arb_digest(rng),
            offset: rng.next_u64(),
            len: rng.range(0, MAX_CHUNK_BYTES + 1) as u32,
        },
        23 => Frame::PushManifest {
            json: arb_string(rng),
            digest: arb_digest(rng),
        },
        24 => Frame::PushChunk {
            digest: arb_digest(rng),
            offset: rng.next_u64(),
            total: rng.next_u64(),
            bytes: arb_chunk_bytes(rng),
            chunk_digest: arb_digest(rng),
        },
        25 => Frame::ArtifactStat,
        26 => Frame::ManifestReply {
            found: rng.chance(0.5),
            json: arb_string(rng),
            digest: arb_digest(rng),
        },
        27 => Frame::ChunkReply {
            digest: arb_digest(rng),
            offset: rng.next_u64(),
            total: rng.next_u64(),
            bytes: arb_chunk_bytes(rng),
            chunk_digest: arb_digest(rng),
        },
        28 => Frame::PushAck {
            complete: rng.chance(0.5),
            have: rng.next_u64(),
        },
        _ => Frame::ArtifactStatReply {
            store_hits: rng.next_u64(),
            synthetic_seeds: rng.next_u64(),
            blobs: rng.next_u64(),
        },
    }
}

// ---------------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------------

#[test]
fn random_frames_roundtrip_bitwise() {
    let mut rng = Rng::new(0xCA5E);
    for case in 0..2000 {
        let frame = arb_frame(&mut rng);
        let bytes = encode(&frame);
        let back = decode(&bytes);
        assert_eq!(
            back,
            Ok(frame),
            "case {case}: decode(encode(f)) != f through {} bytes",
            bytes.len()
        );
    }
}

/// Every strict prefix of a valid frame is a typed error — the decoder
/// validates lengths before trusting them, so truncation can never
/// panic (or, worse, succeed).
#[test]
fn every_truncation_is_a_typed_error() {
    let mut rng = Rng::new(7);
    for _ in 0..300 {
        let bytes = encode(&arb_frame(&mut rng));
        for cut in 0..bytes.len() {
            let r = decode(&bytes[..cut]);
            assert!(
                r.is_err(),
                "prefix {cut}/{} decoded to {r:?}",
                bytes.len()
            );
        }
    }
}

/// Single-byte corruption of a valid frame either still decodes (the
/// byte was slack a different value also encodes to) or fails typed —
/// it never panics. This is the fuzz pass the panic-free lint rule is
/// the static twin of.
#[test]
fn single_byte_corruption_never_panics() {
    let mut rng = Rng::new(99);
    for _ in 0..400 {
        let bytes = encode(&arb_frame(&mut rng));
        if bytes.is_empty() {
            continue;
        }
        let mut mutated = bytes.clone();
        let at = rng.range(0, mutated.len());
        mutated[at] ^= (1 + rng.below(255)) as u8;
        let _ = decode(&mutated); // Ok or Err — both fine; no panic.
    }
}

/// Pure random byte soup never panics the decoder.
#[test]
fn random_soup_never_panics() {
    let mut rng = Rng::new(3);
    for _ in 0..2000 {
        let len = rng.range(0, 64);
        let soup: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = decode(&soup);
    }
    // Worst case: a valid header welded onto random payload bytes.
    for _ in 0..2000 {
        let mut bytes = vec![
            (MAGIC & 0xFF) as u8,
            (MAGIC >> 8) as u8,
            (VERSION & 0xFF) as u8,
            (VERSION >> 8) as u8,
            rng.below(80) as u8,
        ];
        bytes.extend((0..rng.range(0, 48)).map(|_| rng.next_u64() as u8));
        let _ = decode(&bytes);
    }
}

/// A declared element count far beyond the frame's actual bytes is
/// refused as `Oversized` before any allocation happens.
#[test]
fn oversized_declared_counts_are_refused() {
    // Events frame claiming u32::MAX entries in a 4-byte payload.
    let mut bytes = vec![
        (MAGIC & 0xFF) as u8,
        (MAGIC >> 8) as u8,
        (VERSION & 0xFF) as u8,
        (VERSION >> 8) as u8,
        66, // TAG_EVENTS
    ];
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(matches!(decode(&bytes), Err(WireError::Oversized { .. })));

    // Same for a string field (ErrReply message).
    let mut bytes = vec![
        (MAGIC & 0xFF) as u8,
        (MAGIC >> 8) as u8,
        (VERSION & 0xFF) as u8,
        (VERSION >> 8) as u8,
        73, // TAG_ERR
    ];
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    bytes.push(b'x');
    assert!(matches!(decode(&bytes), Err(WireError::Oversized { .. })));
}

/// A hostile chunk-length prefix — any declared size over the cap, on
/// either the push or the reply frame — is refused as `ChunkTooLarge`
/// before any allocation, regardless of how many payload bytes follow.
#[test]
fn hostile_chunk_lengths_are_capped() {
    let mut rng = Rng::new(0xB10B);
    for _ in 0..300 {
        let tag = if rng.chance(0.5) { 15 } else { 75 }; // PushChunk | ChunkReply
        let declared = MAX_CHUNK_BYTES + 1 + rng.range(0, 1 << 10);
        let mut bytes = vec![
            (MAGIC & 0xFF) as u8,
            (MAGIC >> 8) as u8,
            (VERSION & 0xFF) as u8,
            (VERSION >> 8) as u8,
            tag,
        ];
        // digest: empty string (u32 len = 0), then offset + total.
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&rng.next_u64().to_le_bytes());
        bytes.extend_from_slice(&rng.next_u64().to_le_bytes());
        // The hostile length prefix, backed by a few real bytes only.
        bytes.extend_from_slice(&(declared as u32).to_le_bytes());
        bytes.extend((0..rng.range(0, 16)).map(|_| rng.next_u64() as u8));
        assert_eq!(
            decode(&bytes),
            Err(WireError::ChunkTooLarge {
                declared,
                max: MAX_CHUNK_BYTES,
            }),
            "tag {tag} declaring {declared}"
        );
    }
}

/// Every version word other than [`VERSION`] is refused typed, and
/// every tag outside the defined ranges is `UnknownTag` — across the
/// whole u8 space, not just a sampled corner.
#[test]
fn foreign_versions_and_tags_are_typed() {
    let mut rng = Rng::new(11);
    for _ in 0..200 {
        let mut bytes = encode(&arb_frame(&mut rng));
        let v = (1 + rng.below(u16::MAX as u64 - 1)) as u16;
        let got = VERSION.wrapping_add(v);
        bytes[2] = (got & 0xFF) as u8;
        bytes[3] = (got >> 8) as u8;
        assert_eq!(decode(&bytes), Err(WireError::UnknownVersion { got }));
    }
    let valid = |t: u8| (1..=16).contains(&t) || (64..=77).contains(&t);
    for tag in 0..=u8::MAX {
        if valid(tag) {
            continue;
        }
        let bytes = vec![
            (MAGIC & 0xFF) as u8,
            (MAGIC >> 8) as u8,
            (VERSION & 0xFF) as u8,
            (VERSION >> 8) as u8,
            tag,
        ];
        assert!(
            matches!(decode(&bytes), Err(WireError::UnknownTag { tag: t, .. }) if t == tag),
            "tag {tag} not refused as UnknownTag"
        );
    }
}
