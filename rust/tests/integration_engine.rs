//! Integration: the full serving engine over the real PJRT runtime —
//! continuous batching + paged KV + device slot cache + cold-start
//! modes, end to end. Skips cleanly when artifacts aren't built.

use std::path::PathBuf;

use caraserve::model::LoraSpec;
use caraserve::runtime::ModelRuntime;
use caraserve::server::{ColdStartMode, EngineConfig, InferenceRequest, InferenceServer};
use caraserve::util::rng::Rng;

fn make_server(mode: ColdStartMode) -> Option<InferenceServer> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let runtime = ModelRuntime::load(&dir).expect("runtime");
    let mut server = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: mode,
            // Keep modeled loads small so the test runs fast but the
            // serialize-vs-overlap distinction is still visible.
            load_scale: 0.2,
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..32u64 {
        server.install_adapter(LoraSpec::standard(id, 8, "tiny"));
    }
    Some(server)
}

fn requests(n: usize, seed: u64) -> Vec<InferenceRequest> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| InferenceRequest {
            id,
            adapter: rng.range(0, 32) as u64,
            prompt: (0..rng.range(8, 30)).map(|_| rng.range(0, 1024) as i32).collect(),
            max_new_tokens: rng.range(2, 8),
        })
        .collect()
}

#[test]
fn serves_batch_to_completion_with_correct_outputs() {
    let Some(mut server) = make_server(ColdStartMode::CaraServe) else {
        return;
    };
    let reqs = requests(12, 7);
    let expect: Vec<(u64, usize)> =
        reqs.iter().map(|r| (r.id, r.max_new_tokens)).collect();
    for r in reqs {
        server.submit(r).unwrap();
    }
    server.run_until_idle().unwrap();

    assert_eq!(server.outputs().len(), 12);
    for (id, want_len) in expect {
        let out = server
            .outputs()
            .iter()
            .find(|o| o.id == id)
            .unwrap_or_else(|| panic!("missing output {id}"));
        assert_eq!(out.tokens.len(), want_len, "request {id}");
        assert!(out.tokens.iter().all(|&t| (0..1024).contains(&t)));
    }
    // Metrics recorded for all.
    assert_eq!(server.metrics().records().len(), 12);
    assert_eq!(server.metrics().inflight(), 0);
}

#[test]
fn greedy_output_independent_of_batching_and_mode() {
    // The same request must produce the same tokens whether served alone
    // (Cached) or batched with others under CaraServe — continuous
    // batching must not change results.
    let Some(mut solo) = make_server(ColdStartMode::Cached) else {
        return;
    };
    let probe = InferenceRequest {
        id: 1000,
        adapter: 3,
        prompt: (0..20).map(|i| (i * 31 + 5) % 1024).collect(),
        max_new_tokens: 6,
    };
    solo.submit(probe.clone()).unwrap();
    solo.run_until_idle().unwrap();
    let want = solo.outputs()[0].tokens.clone();

    let Some(mut busy) = make_server(ColdStartMode::CaraServe) else {
        return;
    };
    for r in requests(6, 9) {
        busy.submit(r).unwrap();
    }
    busy.submit(probe).unwrap();
    busy.run_until_idle().unwrap();
    let got = busy
        .outputs()
        .iter()
        .find(|o| o.id == 1000)
        .expect("probe output")
        .tokens
        .clone();
    assert_eq!(got, want, "batching changed greedy output");
}

#[test]
fn rejects_invalid_requests() {
    let Some(mut server) = make_server(ColdStartMode::Cached) else {
        return;
    };
    // Empty prompt.
    assert!(server
        .submit(InferenceRequest {
            id: 1,
            adapter: 0,
            prompt: vec![],
            max_new_tokens: 4
        })
        .is_err());
    // Prompt over the largest bucket.
    assert!(server
        .submit(InferenceRequest {
            id: 2,
            adapter: 0,
            prompt: vec![1; 65],
            max_new_tokens: 4
        })
        .is_err());
    // Zero generation budget.
    assert!(server
        .submit(InferenceRequest {
            id: 3,
            adapter: 0,
            prompt: vec![1; 8],
            max_new_tokens: 0
        })
        .is_err());
}

#[test]
fn kv_pages_are_reclaimed_across_waves() {
    let Some(mut server) = make_server(ColdStartMode::CaraServe) else {
        return;
    };
    // Three waves of requests; page leaks would exhaust the pool.
    for wave in 0..3 {
        for r in requests(8, 100 + wave) {
            let mut r = r;
            r.id += wave * 1000;
            server.submit(r).unwrap();
        }
        server.run_until_idle().unwrap();
    }
    assert_eq!(server.outputs().len(), 24);
}
