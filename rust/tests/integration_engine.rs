//! Integration: the full serving engine over the real PJRT runtime —
//! continuous batching + paged KV + device slot cache + cold-start
//! modes, driven through the streaming lifecycle API. Skips cleanly
//! when artifacts aren't built.

use std::path::PathBuf;

use caraserve::model::LoraSpec;
use caraserve::runtime::ModelRuntime;
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, LifecycleState, RequestEvent, RequestHandle,
    ServeRequest, ServingFront,
};
use caraserve::util::rng::Rng;

fn make_server(mode: ColdStartMode) -> Option<InferenceServer> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let runtime = ModelRuntime::load(&dir).expect("runtime");
    let mut server = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: mode,
            // Keep modeled loads small so the test runs fast but the
            // serialize-vs-overlap distinction is still visible.
            load_scale: 0.2,
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..32u64 {
        server
            .install_adapter(&LoraSpec::standard(id, 8, "tiny"))
            .expect("install");
    }
    Some(server)
}

fn requests(n: usize, seed: u64) -> Vec<ServeRequest> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let adapter = rng.range(0, 32) as u64;
            let prompt: Vec<i32> = (0..rng.range(8, 30))
                .map(|_| rng.range(0, 1024) as i32)
                .collect();
            ServeRequest::new(adapter, prompt).max_new_tokens(rng.range(2, 8))
        })
        .collect()
}

#[test]
fn serves_batch_to_completion_with_correct_outputs() {
    let Some(mut server) = make_server(ColdStartMode::CaraServe) else {
        return;
    };
    let reqs = requests(12, 7);
    let expect: Vec<usize> = reqs.iter().map(|r| r.sampling.max_new_tokens).collect();
    let handles: Vec<RequestHandle> = reqs.into_iter().map(|r| server.submit(r)).collect();
    server.run_until_idle().unwrap();

    for (handle, want_len) in handles.iter().zip(expect) {
        assert_eq!(handle.state(), LifecycleState::Finished, "request {}", handle.id());
        let tokens = handle.tokens();
        assert_eq!(tokens.len(), want_len, "request {}", handle.id());
        assert!(tokens.iter().all(|&t| (0..1024).contains(&t)));
        // Event stream shape: Admitted, FirstToken, Token*, Finished.
        let events = handle.drain_events();
        assert_eq!(events[0], RequestEvent::Admitted);
        assert!(matches!(events[1], RequestEvent::FirstToken(_)));
        assert!(events.last().unwrap().is_terminal());
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
    }
    // Metrics recorded for all.
    assert_eq!(server.metrics().records().len(), 12);
    assert_eq!(server.metrics().inflight(), 0);
}

#[test]
fn greedy_output_independent_of_batching_and_mode() {
    // The same request must produce the same tokens whether served alone
    // (Cached) or batched with others under CaraServe — continuous
    // batching must not change results.
    let Some(mut solo) = make_server(ColdStartMode::Cached) else {
        return;
    };
    let probe = || {
        ServeRequest::new(3, (0..20).map(|i| (i * 31 + 5) % 1024).collect())
            .max_new_tokens(6)
    };
    let h = solo.submit(probe());
    solo.run_until_idle().unwrap();
    let want = h.tokens();

    let Some(mut busy) = make_server(ColdStartMode::CaraServe) else {
        return;
    };
    for r in requests(6, 9) {
        busy.submit(r);
    }
    let h = busy.submit(probe());
    busy.run_until_idle().unwrap();
    assert_eq!(h.tokens(), want, "batching changed greedy output");
}

#[test]
fn invalid_requests_surface_as_rejected_events() {
    let Some(mut server) = make_server(ColdStartMode::Cached) else {
        return;
    };
    // Empty prompt.
    let h = server.submit(ServeRequest::new(0, vec![]).max_new_tokens(4));
    assert_eq!(h.state(), LifecycleState::Rejected);
    // Prompt over the largest bucket.
    let h = server.submit(ServeRequest::new(0, vec![1; 65]).max_new_tokens(4));
    assert_eq!(h.state(), LifecycleState::Rejected);
    // Zero generation budget.
    let h = server.submit(ServeRequest::new(0, vec![1; 8]).max_new_tokens(0));
    assert_eq!(h.state(), LifecycleState::Rejected);
    // Uninstalled adapter: no fabricated rank-8 spec, a Rejected event.
    let h = server.submit(ServeRequest::new(999, vec![1; 8]).max_new_tokens(4));
    match h.drain_events().as_slice() {
        [RequestEvent::Rejected(reason)] => {
            assert!(reason.to_string().contains("adapter 999"), "{reason}");
        }
        other => panic!("expected lone Rejected event, got {other:?}"),
    }
    // Rejected requests never enter the queue.
    assert!(!server.has_work());
    server.run_until_idle().unwrap();
    assert!(server.metrics().records().is_empty());
}

#[test]
fn cancellation_queued_and_mid_decode() {
    let Some(mut server) = make_server(ColdStartMode::CaraServe) else {
        return;
    };
    // Cancel while queued: terminal Cancelled, no tokens.
    let queued = server.submit(ServeRequest::new(1, vec![1; 10]).max_new_tokens(8));
    assert!(server.cancel(queued.id()));
    // Cancel mid-decode: submit a long request, run a few steps.
    let long = server.submit(ServeRequest::new(2, vec![2; 10]).max_new_tokens(40));
    for _ in 0..3 {
        assert!(server.step().unwrap());
    }
    assert_eq!(queued.state(), LifecycleState::Cancelled);
    assert!(queued.tokens().is_empty());
    assert_eq!(long.state(), LifecycleState::Running);
    long.cancel(); // handle-side cancel
    server.run_until_idle().unwrap();
    assert_eq!(long.state(), LifecycleState::Cancelled);
    let n = long.tokens().len();
    assert!((1..40).contains(&n), "tokens after cancel: {n}");
    assert_eq!(server.metrics().cancelled_count(), 2);

    // The engine stays serviceable: a fresh request completes.
    let after = server.submit(ServeRequest::new(3, vec![3; 10]).max_new_tokens(4));
    server.run_until_idle().unwrap();
    assert_eq!(after.state(), LifecycleState::Finished);
    assert_eq!(after.tokens().len(), 4);
}

#[test]
fn stop_tokens_terminate_generation_early() {
    let Some(mut server) = make_server(ColdStartMode::Cached) else {
        return;
    };
    // Learn the greedy stream first, then stop on its third token.
    let probe = server.submit(ServeRequest::new(5, vec![7; 12]).max_new_tokens(8));
    server.run_until_idle().unwrap();
    let stream = probe.tokens();
    assert_eq!(stream.len(), 8);
    let stop = stream[2];
    let cut = stream.iter().position(|&t| t == stop).unwrap() + 1;

    let Some(mut server) = make_server(ColdStartMode::Cached) else {
        return;
    };
    let h = server.submit(
        ServeRequest::new(5, vec![7; 12])
            .max_new_tokens(8)
            .stop_token(stop),
    );
    server.run_until_idle().unwrap();
    assert_eq!(h.tokens(), stream[..cut].to_vec());
    assert_eq!(
        h.drain_events().last(),
        Some(&RequestEvent::Finished(
            caraserve::server::FinishReason::Stop
        ))
    );
}

#[test]
fn kv_pages_are_reclaimed_across_waves() {
    let Some(mut server) = make_server(ColdStartMode::CaraServe) else {
        return;
    };
    // Three waves of requests; page leaks would exhaust the pool.
    let mut finished = 0;
    for wave in 0..3 {
        let handles: Vec<_> = requests(8, 100 + wave)
            .into_iter()
            .map(|r| server.submit(r))
            .collect();
        server.run_until_idle().unwrap();
        finished += handles
            .iter()
            .filter(|h| h.state() == LifecycleState::Finished)
            .count();
    }
    assert_eq!(finished, 24);
}

#[test]
fn stats_track_live_requests_and_slo() {
    let Some(mut server) = make_server(ColdStartMode::Cached) else {
        return;
    };
    let s = server.stats();
    assert!(s.running_ranks.is_empty() && s.queued_ranks.is_empty());
    assert!(s.tpot_slo.is_none());
    let _h1 = server.submit(
        ServeRequest::new(1, vec![1; 8])
            .max_new_tokens(6)
            .slo(200.0, 50.0),
    );
    let _h2 = server.submit(ServeRequest::new(2, vec![2; 8]).max_new_tokens(6));
    let s = server.stats();
    assert_eq!(s.queued_ranks, vec![8, 8]);
    assert!((s.tpot_slo.unwrap() - 0.050).abs() < 1e-12);
    server.step().unwrap(); // prefill
    let s = server.stats();
    assert_eq!(s.running_ranks.len(), 2);
    assert!(s.queued_ranks.is_empty());
    server.run_until_idle().unwrap();
    assert!(server.stats().tpot_slo.is_none());
}
