//! Integration: fault-tolerant cluster serving over *real* engines.
//!
//! The ISSUE 8 acceptance drills:
//!
//! 1. Kill 1 of 3 native backends mid-decode (seeded injected panic).
//!    Every in-flight request must either complete with a token stream
//!    bitwise-identical to a no-fault oracle run, or terminate with
//!    exactly one typed terminal event. The panic must not escape the
//!    `ClusterFront` poll boundary.
//! 2. Kill the coordinator and restart it from `GlobalRegistry::save`/
//!    `load` over fresh, empty engines: the restored placements must be
//!    identical, and the migration engine must keep migrating.
//! 3. Kill *every* backend: in-flight requests end with typed
//!    `BackendFailed` rejections, and new submissions shed with typed
//!    `Overloaded` instead of queueing into a dead cluster.

use caraserve::coordinator::{Coordinator, CoordinatorConfig};
use caraserve::runtime::{NativeConfig, NativeRuntime};
use caraserve::server::cluster::synthetic::{self, ChaosConfig, SyntheticConfig};
use caraserve::server::{
    ColdStartMode, EngineConfig, Health, InferenceServer, LifecycleState, RetryPolicy,
    ServeRequest, ServingFront,
};
use caraserve::testkit::faults::FaultPlan;

fn base_cfg() -> SyntheticConfig {
    SyntheticConfig {
        instances: 3,
        requests: 24,
        adapters: 12,
        seed: 7,
        threads: 1,
        cpu_workers: 0,
        // Cached admits keep both runs free of wall-clock-dependent
        // load windows: the streams are deterministic, which is what
        // the bitwise oracle comparison needs.
        cold_start: ColdStartMode::Cached,
        kv_pages: 256,
        polls_per_arrival: 2,
        skew: 0.0,
    }
}

#[test]
fn backend_death_mid_decode_is_bitwise_stable() {
    let cfg = base_cfg();
    let chaos = ChaosConfig {
        faults: vec![(0, FaultPlan::seeded_mid_decode_kill(cfg.seed, 2, 8))],
        retry: None,
    };
    // run_chaos returning Ok at all proves the injected panic never
    // escaped ClusterFront::poll.
    let (rep, oracle) = synthetic::run_chaos("rank-aware", &cfg, &chaos).expect("chaos run");
    assert_eq!(oracle.finished, cfg.requests, "oracle run lost requests");
    // The §failover acceptance criterion: no completed stream may
    // differ from the no-fault oracle — resumed requests regenerate
    // their undelivered suffix deterministically on the survivor.
    assert_eq!(rep.diverged, 0, "failover is not bitwise-stable");
    assert_eq!(
        rep.stable + rep.failed,
        cfg.requests,
        "request accounting: {rep:?}"
    );
    // The victim died mid-decode, so it had running requests: at least
    // one was re-placed onto a survivor (or typed-failed if its adapter
    // had no second copy).
    assert!(
        rep.failovers + rep.failed >= 1,
        "the kill touched nothing: {rep:?}"
    );
    assert_eq!(rep.health[0], Health::Down, "panicked backend not quarantined");
    assert!(
        rep.health[1..].iter().all(|h| *h == Health::Healthy),
        "survivors must stay healthy: {:?}",
        rep.health
    );
    // Both runs fully reconcile: nothing hangs, nothing double-counts.
    assert_eq!(rep.base.finished + rep.base.rejected, cfg.requests);
}

#[test]
fn every_backend_dead_degrades_with_typed_shedding() {
    let cfg = SyntheticConfig {
        instances: 2,
        requests: 8,
        ..base_cfg()
    };
    let die = FaultPlan::parse("die@poll:1").expect("plan");
    let chaos = ChaosConfig {
        faults: vec![(0, die.clone()), (1, die)],
        retry: Some(RetryPolicy {
            down_after: 1,
            ..Default::default()
        }),
    };
    let (rep, oracle) = synthetic::run_chaos("most-idle", &cfg, &chaos).expect("chaos run");
    assert_eq!(oracle.finished, cfg.requests);
    // Nothing can finish on a dead cluster, but everything terminates:
    // routed requests get typed BackendFailed, later submissions are
    // shed with typed Overloaded rather than queueing forever.
    assert_eq!(rep.base.finished, 0);
    assert_eq!(rep.base.rejected, cfg.requests);
    assert!(rep.shed >= 1, "degradation gate never shed: {rep:?}");
    assert!(
        rep.health.iter().all(|h| *h == Health::Down),
        "all backends must be down: {:?}",
        rep.health
    );
}

fn bare_engine() -> InferenceServer {
    InferenceServer::new(
        NativeRuntime::new(NativeConfig::tiny()),
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            kv_pages: 256,
            ..Default::default()
        },
    )
    .expect("server")
}

fn placements_of(coord: &Coordinator) -> Vec<(u64, Vec<usize>)> {
    let registry = coord.cluster().registry();
    registry
        .ids()
        .into_iter()
        .map(|id| (id, registry.servers_for(id)))
        .collect()
}

#[test]
fn coordinator_restart_restores_placements_and_keeps_migrating() {
    let cfg = SyntheticConfig {
        instances: 2,
        requests: 16,
        adapters: 8,
        seed: 3,
        skew: 1.0,
        polls_per_arrival: 1,
        ..base_cfg()
    };
    let ccfg = CoordinatorConfig {
        migrate_interval: 4,
        prewarm: 2,
        replicas: 1,
        min_imbalance: 2,
        ..Default::default()
    };
    let (rep, coord) =
        synthetic::run_coordinated("rank-aware", &cfg, ccfg.clone()).expect("coordinated run");
    assert_eq!(rep.finished + rep.rejected, cfg.requests);
    let before = placements_of(&coord);
    let dir = std::env::temp_dir().join("caraserve-failover-test");
    std::fs::create_dir_all(&dir).expect("tempdir");
    let path = dir.join("coordinator_state.json");
    coord.save_state(&path).expect("save");
    drop(coord); // crash: the control plane's memory is gone

    // Restart over fresh, empty native engines from the snapshot.
    let backends: Vec<Box<dyn ServingFront>> = (0..cfg.instances)
        .map(|_| Box::new(bare_engine()) as Box<dyn ServingFront>)
        .collect();
    let mut coord = Coordinator::load_state(
        &path,
        backends,
        synthetic::policy("rank-aware", cfg.seed).expect("policy"),
        ccfg,
    )
    .expect("restart");
    assert_eq!(placements_of(&coord), before, "restart changed placements");

    // The restarted control plane still serves and still migrates:
    // pile load onto a single-host adapter, then rebalance.
    let hot = before
        .iter()
        .find(|(_, servers)| servers.len() == 1)
        .map(|&(id, _)| id)
        .expect("replicas = 1 ⇒ single-host adapters exist");
    let handles: Vec<_> = (0..6)
        .map(|_| coord.submit(ServeRequest::new(hot, vec![1; 8]).max_new_tokens(3)))
        .collect();
    coord.tick().expect("tick");
    assert!(
        coord.coordinator_stats().migrations >= 1,
        "restarted coordinator stopped migrating: {:?}",
        coord.coordinator_stats()
    );
    coord.run_until_idle().expect("drain");
    for h in &handles {
        assert_eq!(h.state(), LifecycleState::Finished);
    }
    std::fs::remove_file(&path).ok();
}
