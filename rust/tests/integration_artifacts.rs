//! The artifact pipeline end to end, across real OS processes (PR 10):
//!
//! 1. A coordinator-driven migration between two `caraserve backend`
//!    processes moves an adapter by streaming digest-verified blobs
//!    from the router's content-addressed store — the target installs
//!    with **zero** synthetic re-seeding (asserted via the wire's
//!    install-provenance counters) and every in-flight token stream
//!    stays bitwise identical to the no-migration in-process oracle.
//! 2. The `caraserve artifacts` CLI round-trips: seed → push to a live
//!    backend → pull into a fresh store → verify → gc, with pulled
//!    weights bitwise identical to the seeded generator's.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use caraserve::artifacts::{synthetic_stack, ArtifactStore};
use caraserve::model::LoraSpec;
use caraserve::coordinator::{Coordinator, CoordinatorConfig};
use caraserve::remote::RemoteFront;
use caraserve::scheduler::registry::{AdapterMeta, GlobalRegistry};
use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::{ClusterFront, ColdStartMode, LifecycleState, RequestHandle, ServingFront};

/// `NativeConfig::tiny()`'s hidden size — the backends the children run.
const HIDDEN: usize = 256;

fn base_cfg() -> SyntheticConfig {
    SyntheticConfig {
        instances: 2,
        requests: 16,
        adapters: 8,
        seed: 7,
        threads: 1,
        cpu_workers: 0,
        cold_start: ColdStartMode::Cached,
        kv_pages: 256,
        polls_per_arrival: 2,
        skew: 0.0,
    }
}

/// Kill-and-reap children and remove scratch state on every exit path.
struct Fleet {
    children: Vec<Child>,
    socks: Vec<PathBuf>,
    dir: PathBuf,
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
        for s in &self.socks {
            let _ = std::fs::remove_file(s);
        }
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Publish the synthetic catalog into a store directory — what
/// `caraserve artifacts seed` does, via the same `synthetic_stack`
/// generator the engines' fallback seeding uses.
fn seed_store(dir: &Path, adapters: usize) {
    let mut store = ArtifactStore::open(dir).expect("open store");
    for a in 0..adapters as u64 {
        let rank = synthetic::rank_of(a);
        store
            .publish(a, rank, "tiny", &synthetic_stack(a, HIDDEN, rank))
            .expect("publish");
    }
}

fn spawn_backend(sock: &Path, adapters: usize, store: Option<&Path>, name: &str) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_caraserve"));
    cmd.arg("backend")
        .arg("--socket")
        .arg(sock)
        .args(["--name", name])
        .args(["--adapters", &adapters.to_string()])
        .args(["--mode", "cached"])
        .args(["--threads", "1"])
        .args(["--kv-pages", "256"]);
    if let Some(dir) = store {
        cmd.arg("--store").arg(dir);
    }
    cmd.stdout(Stdio::null()).spawn().expect("spawn caraserve backend")
}

fn connect_retry(path: &Path, name: &str) -> RemoteFront {
    let mut last = String::new();
    for _ in 0..750 {
        match RemoteFront::connect(path, name) {
            Ok(front) => return front,
            Err(e) => last = format!("{e:#}"),
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("backend at {} never came up: {last}", path.display());
}

/// Coordinator-driven migration across process boundaries: the source
/// backend installs its whole catalog from its own store (store hits),
/// the target starts empty, and `install_on` — the exact call the
/// rebalance tick makes — streams the adapter's blobs to the target
/// before its install frame lands. Provenance counters prove no
/// synthetic weights were fabricated anywhere, and streams match the
/// in-process no-migration oracle bit for bit.
#[test]
fn coordinator_migration_streams_weights_with_zero_synthetic_reseeds() {
    let cfg = base_cfg();
    let oracle = synthetic::run("rank-aware", &cfg).expect("in-process oracle");
    assert_eq!(oracle.rejected, 0, "oracle must finish everything");

    let dir = std::env::temp_dir().join(format!(
        "caraserve-artifacts-migration-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    // Three stores: the source backend's (full catalog), the target's
    // (empty), the router's (full catalog — migration source of truth).
    seed_store(&dir.join("store-b0"), cfg.adapters);
    seed_store(&dir.join("store-router"), cfg.adapters);
    let router_store = Arc::new(std::sync::Mutex::new(
        ArtifactStore::open(&dir.join("store-router")).expect("router store"),
    ));

    let socks = vec![dir.join("b0.sock"), dir.join("b1.sock")];
    let children = vec![
        spawn_backend(&socks[0], cfg.adapters, Some(&dir.join("store-b0")), "b0"),
        spawn_backend(&socks[1], 0, Some(&dir.join("store-b1")), "b1"),
    ];
    let fleet = Fleet {
        children,
        socks,
        dir: dir.clone(),
    };

    // Router: every adapter placed on backend 0 only; the registry
    // carries the content address (`cas:<digest>`) as its weights path.
    let registry = Arc::new(GlobalRegistry::new());
    for a in 0..cfg.adapters as u64 {
        let weights_path = {
            let s = router_store.lock().unwrap();
            let (d, _) = s.manifest_of(a).expect("seeded");
            format!("cas:{d}")
        };
        registry.register(AdapterMeta {
            id: a,
            rank: synthetic::rank_of(a),
            base_model: "tiny".into(),
            weights_path,
        });
        registry.place(a, 0);
    }
    let backends: Vec<Box<dyn ServingFront>> = fleet
        .socks
        .iter()
        .enumerate()
        .map(|(s, p)| {
            let mut front = connect_retry(p, &format!("router#{s}"));
            front.attach_store(Arc::clone(&router_store));
            Box::new(front) as Box<dyn ServingFront>
        })
        .collect();
    let policy = synthetic::policy("rank-aware", cfg.seed).expect("policy");
    let cluster = ClusterFront::new(backends, policy, registry);
    let mut coord = Coordinator::new(
        cluster,
        CoordinatorConfig {
            migrate_interval: 0, // migrations driven explicitly below
            ..Default::default()
        },
    );

    // Everything the fleet has installed so far came from a store.
    let before = coord.install_source_stats();
    assert_eq!(
        (before.store_hits, before.synthetic_seeds),
        (cfg.adapters as u64, 0),
        "source backend must have installed its catalog from its store"
    );

    // First half of the workload in flight…
    let reqs = synthetic::workload(&cfg);
    let (first, rest) = reqs.split_at(cfg.requests / 2);
    let mut handles: Vec<RequestHandle> = Vec::with_capacity(cfg.requests);
    for req in first {
        handles.push(coord.submit(req.clone()));
        for _ in 0..cfg.polls_per_arrival {
            coord.poll().expect("poll");
        }
    }
    let live = handles.iter().filter(|h| !h.is_terminal()).count();
    assert!(live > 0, "pacing left nothing in flight at migration time");

    // …then the coordinator migrates an adapter to the empty target:
    // the same `install_on` its rebalance tick issues. The router
    // streams blobs by digest first, so the target's engine install is
    // a store hit, not a synthetic seed.
    let migrated = 3u64;
    let spec = LoraSpec::standard(migrated, synthetic::rank_of(migrated), "tiny");
    coord.cluster_mut().install_on(1, &spec).expect("migration install");

    for req in rest {
        handles.push(coord.submit(req.clone()));
        for _ in 0..cfg.polls_per_arrival {
            coord.poll().expect("poll");
        }
    }
    coord.run_until_idle().expect("drain");

    // Acceptance: the target holds the adapter, installed from
    // streamed digest-verified blobs — zero synthetic re-seeding
    // anywhere in the fleet.
    let after = coord.install_source_stats();
    assert_eq!(
        after.synthetic_seeds, 0,
        "a migration target must never fabricate weights"
    );
    assert_eq!(
        after.store_hits,
        cfg.adapters as u64 + 1,
        "the migrated install must be a store hit on the target"
    );
    {
        let target = ArtifactStore::open(&dir.join("store-b1")).expect("target store");
        let (rank, stack) = target.load_stack(migrated, HIDDEN).expect("migrated blobs");
        assert_eq!(rank, synthetic::rank_of(migrated));
        let want = synthetic_stack(migrated, HIDDEN, rank);
        for (g, w) in stack.iter().zip(want.iter()) {
            assert_eq!(g.a, w.a, "streamed A matrix diverged");
            assert_eq!(g.b, w.b, "streamed B matrix diverged");
        }
        // Exactly one adapter's worth of blobs: 4 tensors + 1 manifest.
        assert_eq!(target.blob_count().expect("count"), 5);
    }

    // In-flight and post-migration streams are bitwise identical to
    // the no-migration oracle.
    assert_eq!(handles.len(), oracle.streams.len());
    for (i, h) in handles.iter().enumerate() {
        assert_eq!(
            h.state(),
            LifecycleState::Finished,
            "request {i} ended {:?} across the migration",
            h.state()
        );
        assert_eq!(
            h.tokens(),
            oracle.streams[i],
            "request {i}: stream diverged across the migration"
        );
    }
    drop(coord);
    drop(fleet);
}

/// The CLI pipeline: `seed → push → pull → verify → gc` against a live
/// backend process, with pulled weights bitwise identical to seeded.
#[test]
fn artifacts_cli_seed_push_pull_verify_gc_round_trip() {
    let dir = std::env::temp_dir().join(format!("caraserve-artifacts-cli-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let bin = env!("CARGO_BIN_EXE_caraserve");
    let run = |args: &[&str]| {
        let out = Command::new(bin).args(args).output().expect("run caraserve");
        assert!(
            out.status.success(),
            "caraserve {args:?} failed:\n{}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    };
    let seed_dir = dir.join("seeded");
    let seed_dir_s = seed_dir.to_str().unwrap().to_string();
    // Small hidden keeps the CLI round-trip quick; the generator is
    // hidden-agnostic, bitwise equality below pins it.
    run(&["artifacts", "seed", "--store", &seed_dir_s, "--adapters", "4", "--hidden", "64"]);
    run(&["artifacts", "verify", "--store", &seed_dir_s]);

    // A sim backend with an (empty) attached store to push into.
    let sock = dir.join("b.sock");
    let backend_store = dir.join("store-backend");
    let mut child = Command::new(bin)
        .arg("backend")
        .arg("--socket")
        .arg(&sock)
        .args(["--name", "cli-host", "--adapters", "0", "--sim"])
        .arg("--store")
        .arg(&backend_store)
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn backend");
    for _ in 0..750 {
        if std::os::unix::net::UnixStream::connect(&sock).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    let sock_s = sock.to_str().unwrap().to_string();
    run(&["artifacts", "push", "--store", &seed_dir_s, "--socket", &sock_s, "--adapter", "2"]);
    let fresh = dir.join("fresh");
    let fresh_s = fresh.to_str().unwrap().to_string();
    run(&["artifacts", "pull", "--store", &fresh_s, "--socket", &sock_s, "--adapter", "2"]);
    run(&["artifacts", "verify", "--store", &fresh_s]);

    // Pulled weights are bitwise what the generator seeds.
    let store = ArtifactStore::open(&fresh).expect("open pulled store");
    let rank = synthetic::rank_of(2);
    let (got_rank, stack) = store.load_stack(2, 64).expect("load pulled");
    assert_eq!(got_rank, rank);
    let want = synthetic_stack(2, 64, rank);
    for (g, w) in stack.iter().zip(want.iter()) {
        assert_eq!(g.a, w.a, "pulled A matrix diverged from seeded");
        assert_eq!(g.b, w.b, "pulled B matrix diverged from seeded");
    }
    drop(store);

    // gc on a store with no dangling blobs collects nothing and exits 0.
    run(&["artifacts", "gc", "--store", &fresh_s]);
    let store = ArtifactStore::open(&fresh).expect("reopen");
    assert_eq!(store.len(), 1);
    assert_eq!(store.blob_count().expect("count"), 5);

    let _ = child.kill();
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
