//! The distributed serving tier end to end (PR 9):
//!
//! 1. Interleave schedules (`testkit::interleave`) driving the full
//!    submit/cancel/poll/install/uninstall/prewarm surface through a
//!    `RemoteFront` over a socketpair — alone and as a `ClusterFront`
//!    of two remote backends — with the exactly-one-terminal oracle
//!    from `interleave_lifecycle`.
//! 2. Two real `caraserve backend` OS processes hosting native engines
//!    behind a routed `ClusterFront`: token streams must be bitwise
//!    identical to the in-process composition (`synthetic::run`), both
//!    on the clean path and through a SIGKILL of one backend mid-run
//!    followed by a state-less respawn — which must be readmitted only
//!    after registry-driven re-installation (`restore_placements`).

use std::cell::{Cell, RefCell};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use caraserve::config::GpuSpec;
use caraserve::ipc::SocketChannel;
use caraserve::model::{LlamaConfig, LoraSpec};
use caraserve::perfmodel::{KernelKind, PerfModel};
use caraserve::remote::client::DEFAULT_IO_TIMEOUT;
use caraserve::remote::{serve_connection, RemoteFront};
use caraserve::scheduler::registry::{AdapterMeta, GlobalRegistry};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::{
    ClusterFront, ColdStartMode, Health, LifecycleState, RequestEvent, RequestHandle,
    ServeRequest, ServingFront,
};
use caraserve::sim::{GpuModel, ServingMode, SimFront, SimInstance};
use caraserve::testkit::interleave::{always, explore_random, when, ScriptModel, Step};
use caraserve::util::rng::Rng;

// ---------------------------------------------------------------------------
// Part 1: lifecycle schedules over a socketpair (same Op machinery as
// interleave_lifecycle — the remote hop must be invisible to it).
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Op {
    Submit {
        adapter: u64,
        prompt: usize,
        max_new: usize,
        stop: Option<i32>,
    },
    Cancel(usize),
    Poll,
    Install(u64, usize),
    Uninstall(u64),
    Prewarm(u64),
}

fn random_op(rng: &mut Rng) -> Op {
    match rng.range(0, 10) {
        0..=3 => Op::Submit {
            // Ids 4–5 start unregistered → Rejected unless installed
            // by an earlier Install op in the same schedule.
            adapter: rng.range(0, 6) as u64,
            prompt: rng.range(1, 32),
            max_new: rng.range(1, 8),
            stop: if rng.chance(0.25) {
                Some(rng.range(0, 10) as i32)
            } else {
                None
            },
        },
        4 => Op::Cancel(rng.range(0, 16)),
        5 | 6 => Op::Poll,
        7 => Op::Install(rng.range(0, 6) as u64, *rng.choose(&[8usize, 16, 32, 64])),
        8 => Op::Uninstall(rng.range(0, 6) as u64),
        _ => Op::Prewarm(rng.range(0, 6) as u64),
    }
}

struct Lifecycle<F: ServingFront> {
    front: F,
    handles: Vec<RequestHandle>,
    steps_done: usize,
    drained: bool,
}

/// Apply one op. Management-surface refusals must be the documented
/// ones — over the wire they arrive wrapped ("remote … failed: remote
/// backend error: …"), but the original text must survive inside.
fn apply_op<F: ServingFront>(s: &mut Lifecycle<F>, op: &Op) {
    s.steps_done += 1;
    match op {
        Op::Submit {
            adapter,
            prompt,
            max_new,
            stop,
        } => {
            let mut req =
                ServeRequest::new(*adapter, vec![1; *prompt]).max_new_tokens(*max_new);
            if let Some(t) = stop {
                req = req.stop_token(*t);
            }
            let h = s.front.submit(req);
            s.handles.push(h);
        }
        Op::Cancel(i) => {
            if !s.handles.is_empty() {
                let id = s.handles[i % s.handles.len()].id();
                let _ = s.front.cancel(id);
            }
        }
        Op::Poll => {
            s.front.poll().expect("poll must not fail");
        }
        Op::Install(id, rank) => {
            s.front
                .install_adapter(&LoraSpec::standard(*id, *rank, "sim"))
                .expect("install must not fail");
        }
        Op::Uninstall(id) => {
            if let Err(e) = s.front.uninstall_adapter(*id) {
                let msg = e.to_string();
                assert!(
                    msg.contains("busy") || msg.contains("not installed"),
                    "unexpected uninstall refusal: {msg}"
                );
            }
        }
        Op::Prewarm(id) => {
            if let Err(e) = s.front.prewarm_adapter(*id) {
                let msg = e.to_string();
                assert!(
                    msg.contains("not installed"),
                    "unexpected prewarm refusal: {msg}"
                );
            }
        }
    }
}

/// The exactly-one-terminal oracle: every submission ends terminal,
/// with exactly one terminal event and nothing after it, and the token
/// stream is consistent with the terminal reason.
fn lifecycle_oracle<F: ServingFront>(s: &Lifecycle<F>) -> Result<(), String> {
    if !s.drained {
        return Err("drainer thread never ran".into());
    }
    for h in &s.handles {
        let state = h.state();
        if !state.is_terminal() {
            return Err(format!("request {} ended in {state:?}", h.id()));
        }
        let events = h.drain_events();
        let terminals = events.iter().filter(|e| e.is_terminal()).count();
        if terminals != 1 {
            return Err(format!(
                "request {}: {terminals} terminal events in {events:?}",
                h.id()
            ));
        }
        let last = events.last().expect("terminal implies ≥ 1 event");
        if !last.is_terminal() {
            return Err(format!("request {}: events after terminal", h.id()));
        }
        let tokens = h.tokens();
        match last {
            RequestEvent::Rejected(_) => {
                if !tokens.is_empty() || events.len() != 1 {
                    return Err(format!("request {}: rejected saw activity", h.id()));
                }
            }
            RequestEvent::Finished(_) => {
                if tokens.is_empty() {
                    return Err(format!("request {}: finished without tokens", h.id()));
                }
            }
            RequestEvent::Cancelled => {}
            other => return Err(format!("non-terminal last event {other:?}")),
        }
    }
    Ok(())
}

fn lifecycle_model<F: ServingFront + 'static>(
    front: F,
    ops: Vec<Vec<Op>>,
) -> ScriptModel<Lifecycle<F>> {
    let total: usize = ops.iter().map(Vec::len).sum();
    let mut m = ScriptModel::new(Lifecycle {
        front,
        handles: Vec::new(),
        steps_done: 0,
        drained: false,
    });
    for script in ops {
        let steps: Vec<Step<Lifecycle<F>>> = script
            .into_iter()
            .map(|op| always(move |s: &mut Lifecycle<F>| apply_op(s, &op)))
            .collect();
        m = m.thread(steps);
    }
    m.thread(vec![when(
        move |s: &Lifecycle<F>| s.steps_done == total,
        |s| {
            s.front.run_until_idle().expect("drain must not fail");
            s.drained = true;
        },
    )])
    .finally(|s| lifecycle_oracle(s))
}

fn random_scripts(rng: &mut Rng) -> Vec<Vec<Op>> {
    (0..3)
        .map(|_| (0..rng.range(3, 9)).map(|_| random_op(rng)).collect())
        .collect()
}

/// One simulator backend served over a socketpair on its own OS
/// thread; the returned `RemoteFront` is the schedule's front. The
/// host thread exits when the front drops (recv error → quiesce).
fn remote_sim_front(rng: &mut Rng, hosts: &RefCell<Vec<JoinHandle<()>>>) -> RemoteFront {
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let inst = SimInstance::new(0, model, ServingMode::CaraServe, rng.range(1, 6), 8, 16);
    let mut front = SimFront::new(inst, 64);
    for id in 0..4 {
        front.register_adapter(id, *rng.choose(&[8, 16, 32, 64]));
    }
    let (client, mut server) = SocketChannel::pair().expect("socketpair");
    hosts.borrow_mut().push(std::thread::spawn(move || {
        let _ = serve_connection(&mut front, &mut server, "sim-host");
    }));
    RemoteFront::from_channel(client, "sched-router", DEFAULT_IO_TIMEOUT).expect("handshake")
}

/// ≥150 seeded random schedules of mixed traffic + management ops
/// through one `RemoteFront` over a socketpair.
#[test]
fn lifecycle_schedules_hold_over_a_remote_socketpair() {
    let hosts = RefCell::new(Vec::new());
    let next = Cell::new(0u64);
    let report = explore_random(
        || {
            let seed = 0x9E_0001 + next.get();
            next.set(next.get() + 1);
            let mut rng = Rng::new(seed);
            let front = remote_sim_front(&mut rng, &hosts);
            lifecycle_model(front, random_scripts(&mut rng))
        },
        150,
        0x9E40_5EED,
    );
    assert!(report.ok(), "{report}");
    assert_eq!(report.schedules, 150);
    for h in hosts.into_inner() {
        h.join().expect("host thread");
    }
}

/// A routed `ClusterFront` whose two backends are both `RemoteFront`s
/// over socketpairs — the "unchanged router across processes" claim,
/// exercised at schedule granularity.
fn remote_cluster_pair(rng: &mut Rng, hosts: &RefCell<Vec<JoinHandle<()>>>) -> ClusterFront {
    let rank_of = |id: u64| [8usize, 16, 32, 64][(id % 4) as usize];
    let mut backends: Vec<Box<dyn ServingFront>> = Vec::new();
    for s in 0..2usize {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(s, model, ServingMode::CaraServe, 4, 8, 16);
        let mut f = SimFront::new(inst, 64);
        for id in 0..4u64 {
            f.register_adapter(id, rank_of(id));
        }
        let (client, mut server) = SocketChannel::pair().expect("socketpair");
        hosts.borrow_mut().push(std::thread::spawn(move || {
            let _ = serve_connection(&mut f, &mut server, "sim-host");
        }));
        let front = RemoteFront::from_channel(client, &format!("router#{s}"), DEFAULT_IO_TIMEOUT)
            .expect("handshake");
        backends.push(Box::new(front));
    }
    let registry = Arc::new(GlobalRegistry::new());
    for id in 0..4u64 {
        registry.register(AdapterMeta {
            id,
            rank: rank_of(id),
            base_model: "sim".into(),
            weights_path: String::new(),
        });
    }
    let pre = PerfModel::from_coefficients(KernelKind::Bgmv, 4e-5, 60e-3);
    let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
    let name = *rng.choose(&["rank-aware", "most-idle", "first-fit", "random"]);
    let policy = policy_by_name(name, pre, dec, RankAwareConfig::default(), 7).unwrap();
    ClusterFront::new(backends, policy, registry)
}

/// ≥80 schedules against the cluster-of-remotes pair, with the
/// registry-placement serveability invariant checked after every step
/// (each check round-trips Stats frames to both hosts).
#[test]
fn lifecycle_schedules_hold_on_a_cluster_of_remote_fronts() {
    let hosts = RefCell::new(Vec::new());
    let next = Cell::new(0u64);
    let report = explore_random(
        || {
            let seed = 0x9E_1001 + next.get();
            next.set(next.get() + 1);
            let mut rng = Rng::new(seed);
            let front = remote_cluster_pair(&mut rng, &hosts);
            lifecycle_model(front, random_scripts(&mut rng)).invariant(|s| {
                let stats = s.front.per_server_stats();
                for id in s.front.registry().ids() {
                    for srv in s.front.registry().servers_for(id) {
                        if srv >= stats.len() || !stats[srv].can_serve(id) {
                            return Err(format!(
                                "adapter {id} placed on server {srv} which cannot serve it"
                            ));
                        }
                    }
                }
                Ok(())
            })
        },
        80,
        0x9E80_5EED,
    );
    assert!(report.ok(), "{report}");
    assert_eq!(report.schedules, 80);
    for h in hosts.into_inner() {
        h.join().expect("host thread");
    }
}

// ---------------------------------------------------------------------------
// Part 2: real OS processes — `caraserve backend` children hosting
// native engines, routed by an in-test `ClusterFront` of `RemoteFront`s.
// ---------------------------------------------------------------------------

/// The proven bitwise-oracle configuration (integration_failover):
/// `Cached` admits keep both runs free of wall-clock-dependent load
/// windows, so streams are deterministic and comparable bit for bit.
fn base_cfg() -> SyntheticConfig {
    SyntheticConfig {
        instances: 2,
        requests: 24,
        adapters: 12,
        seed: 7,
        threads: 1,
        cpu_workers: 0,
        cold_start: ColdStartMode::Cached,
        kv_pages: 256,
        polls_per_arrival: 2,
        skew: 0.0,
    }
}

/// Kill-and-reap children and remove their socket files on every exit
/// path (including assertion panics).
struct Fleet {
    children: Vec<Child>,
    socks: Vec<PathBuf>,
    dir: PathBuf,
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
        for s in &self.socks {
            let _ = std::fs::remove_file(s);
        }
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

fn spawn_backend(sock: &Path, cfg: &SyntheticConfig, adapters: usize, name: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_caraserve"))
        .arg("backend")
        .arg("--socket")
        .arg(sock)
        .args(["--name", name])
        .args(["--adapters", &adapters.to_string()])
        .args(["--mode", "cached"])
        .args(["--threads", &cfg.threads.to_string()])
        .args(["--kv-pages", &cfg.kv_pages.to_string()])
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn caraserve backend")
}

/// Two backend processes on fresh sockets, each pre-installing the
/// synthetic catalog — the process-boundary twin of `synthetic::build`.
fn spawn_fleet(tag: &str, cfg: &SyntheticConfig) -> Fleet {
    let dir = std::env::temp_dir().join(format!("caraserve-remote-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let socks: Vec<PathBuf> = (0..cfg.instances)
        .map(|s| dir.join(format!("b{s}.sock")))
        .collect();
    let children = socks
        .iter()
        .enumerate()
        .map(|(s, p)| spawn_backend(p, cfg, cfg.adapters, &format!("backend#{s}")))
        .collect();
    Fleet {
        children,
        socks,
        dir,
    }
}

/// Connect with retries: the child needs time to build its engine and
/// install the catalog before it binds the socket.
fn connect_retry(path: &Path, name: &str) -> RemoteFront {
    let mut last = String::new();
    for _ in 0..750 {
        match RemoteFront::connect(path, name) {
            Ok(front) => return front,
            Err(e) => last = format!("{e:#}"),
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("backend at {} never came up: {last}", path.display());
}

/// Wait for a (re)spawned backend to accept connections; the probe
/// connection is dropped immediately, which the host treats as a
/// normal disconnect.
fn wait_ready(path: &Path) {
    for _ in 0..750 {
        if std::os::unix::net::UnixStream::connect(path).is_ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("backend at {} never bound its socket", path.display());
}

/// The router half: a `ClusterFront` of connected `RemoteFront`s with
/// the same registry contents `synthetic::build` would install.
fn remote_cluster(fleet: &Fleet, cfg: &SyntheticConfig) -> ClusterFront {
    let registry = Arc::new(GlobalRegistry::new());
    for a in 0..cfg.adapters as u64 {
        registry.register(AdapterMeta {
            id: a,
            rank: synthetic::rank_of(a),
            base_model: "tiny".into(),
            weights_path: String::new(),
        });
        for s in 0..cfg.instances {
            registry.place(a, s);
        }
    }
    let backends: Vec<Box<dyn ServingFront>> = fleet
        .socks
        .iter()
        .enumerate()
        .map(|(s, p)| {
            Box::new(connect_retry(p, &format!("router#{s}"))) as Box<dyn ServingFront>
        })
        .collect();
    let policy = synthetic::policy("rank-aware", cfg.seed).expect("policy");
    ClusterFront::new(backends, policy, registry)
}

/// `synthetic::drive`'s pacing, inlined so the process-backed run
/// drives the exact same submit/poll sequence as the in-process oracle.
fn drive_paced(
    cluster: &mut ClusterFront,
    reqs: &[ServeRequest],
    pace: usize,
    handles: &mut Vec<RequestHandle>,
) {
    for req in reqs {
        handles.push(cluster.submit(req.clone()));
        for _ in 0..pace {
            cluster.poll().expect("cluster poll");
        }
    }
}

fn assert_streams_match(handles: &[RequestHandle], oracle: &[Vec<i32>]) {
    assert_eq!(handles.len(), oracle.len());
    for (i, h) in handles.iter().enumerate() {
        assert_eq!(
            h.state(),
            LifecycleState::Finished,
            "request {i} ended {:?} across the process boundary",
            h.state()
        );
        assert_eq!(
            h.tokens(),
            oracle[i],
            "request {i}: stream diverged across the process boundary"
        );
    }
}

/// Acceptance: a `ClusterFront` over two `RemoteFront`s backed by live
/// native engines in separate OS processes produces token streams
/// bitwise identical to the in-process composition.
#[test]
fn two_process_native_cluster_is_bitwise_identical_to_in_process() {
    let cfg = base_cfg();
    let oracle = synthetic::run("rank-aware", &cfg).expect("in-process oracle run");
    assert_eq!(
        oracle.rejected, 0,
        "oracle config must finish everything for a stream-by-stream comparison"
    );

    let fleet = spawn_fleet("bitwise", &cfg);
    let mut cluster = remote_cluster(&fleet, &cfg);
    let mut handles = Vec::with_capacity(cfg.requests);
    drive_paced(
        &mut cluster,
        &synthetic::workload(&cfg),
        cfg.polls_per_arrival,
        &mut handles,
    );
    cluster.run_until_idle().expect("drain");

    assert_streams_match(&handles, &oracle.streams);
    assert_eq!(
        cluster.routed().iter().sum::<usize>(),
        cfg.requests,
        "every request must have been routed exactly once"
    );
    assert_eq!(cluster.stats().event_overflows, 0);
}

/// Acceptance: the same comparison through a SIGKILL of backend 0
/// mid-run. In-flight streams fail over to the survivor and continue
/// bitwise identically; the respawned process — deliberately started
/// with an *empty* adapter catalog — is readmitted only after the
/// router re-installs every registry placement (`restore_placements`),
/// and then serves post-rejoin traffic.
#[test]
fn backend_kill_and_stateless_rejoin_keeps_streams_bitwise_identical() {
    let cfg = base_cfg();
    let oracle = synthetic::run("rank-aware", &cfg).expect("in-process oracle run");
    assert_eq!(oracle.rejected, 0);

    let mut fleet = spawn_fleet("rejoin", &cfg);
    let mut cluster = remote_cluster(&fleet, &cfg);
    let reqs = synthetic::workload(&cfg);
    let (first, rest) = reqs.split_at(cfg.requests / 2);
    let mut handles = Vec::with_capacity(cfg.requests);
    drive_paced(&mut cluster, first, cfg.polls_per_arrival, &mut handles);
    let live_at_kill = handles.iter().filter(|h| !h.is_terminal()).count();
    assert!(
        live_at_kill > 0,
        "pacing left nothing in flight — the kill would exercise no failover"
    );

    // SIGKILL one backend with streams in flight.
    fleet.children[0].kill().expect("kill backend 0");
    fleet.children[0].wait().expect("reap backend 0");
    // Let the health machine count consecutive errors all the way to
    // Down *before* the replacement appears: a respawn racing the
    // Suspect window would be readmitted without the Probation
    // re-install gate this test is about.
    for _ in 0..64 {
        if cluster.health_of(0) == Health::Down {
            break;
        }
        cluster.poll().expect("cluster poll");
    }
    assert_eq!(cluster.health_of(0), Health::Down);

    // Respawn on the same socket with NO adapters: rejoin without
    // state, the case registry-driven re-install exists for.
    fleet.children[0] = spawn_backend(&fleet.socks[0], &cfg, 0, "backend#0-respawn");
    wait_ready(&fleet.socks[0]);

    drive_paced(&mut cluster, rest, cfg.polls_per_arrival, &mut handles);
    cluster.run_until_idle().expect("drain");
    // Keep ticking until the probation probe reconnects, re-installs,
    // and readmits the backend (backoff doubles per failed probe, so
    // give it room).
    for _ in 0..2048 {
        if cluster.health_of(0) == Health::Healthy {
            break;
        }
        cluster.poll().expect("cluster poll");
    }
    assert_eq!(
        cluster.health_of(0),
        Health::Healthy,
        "rejoined backend was never readmitted"
    );
    assert_eq!(
        cluster.rejoin_reinstalls(),
        cfg.adapters,
        "readmission must re-install every registry placement on the stateless rejoiner"
    );

    assert_streams_match(&handles, &oracle.streams);

    // Post-rejoin traffic must land cleanly on the restored cluster.
    let extra: Vec<RequestHandle> = (0..4)
        .map(|a| cluster.submit(ServeRequest::new(a as u64, vec![1, 2, 3]).max_new_tokens(4)))
        .collect();
    cluster.run_until_idle().expect("post-rejoin drain");
    for (i, h) in extra.iter().enumerate() {
        assert_eq!(
            h.state(),
            LifecycleState::Finished,
            "post-rejoin request {i} ended {:?}",
            h.state()
        );
    }
}
