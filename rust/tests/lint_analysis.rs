//! Integration tests for `caraserve::analysis` — the engine behind the
//! `caraserve lint` subcommand. Seeded-violation fixtures check that
//! every rule fires; the committed tree must scan clean (the same gate
//! CI enforces); and a miniature on-disk repo exercises the end-to-end
//! tree walk, allowlist handling, and JSON report shape.

use std::path::{Path, PathBuf};

use caraserve::analysis::{lint_source, lint_tree, LintContext, RULES};

fn ctx() -> LintContext {
    let mut c = LintContext::default();
    c.crates.extend(["anyhow".to_string(), "libc".to_string()]);
    c.modules.extend(["util".to_string(), "ipc".to_string()]);
    c
}

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/lint")
        .join(name);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("read fixture {}: {e}", p.display()))
}

/// Each seeded fixture fires exactly its target rule. Scanned under
/// `runtime/` so every path-scoped rule is armed (hot + decode path).
#[test]
fn seeded_fixtures_fire_their_rule() {
    let cases = [
        ("unsafe_no_safety.rs", "safety-comment"),
        ("relaxed_no_ordering.rs", "ordering-comment"),
        ("hot_unwrap.rs", "hot-unwrap"),
        ("decode_sleep.rs", "decode-sleep"),
        ("undeclared_crate.rs", "undeclared-crate"),
    ];
    for (file, rule) in cases {
        assert!(RULES.contains(&rule), "{rule} missing from RULES");
        let v = lint_source(&format!("runtime/{file}"), &fixture(file), &ctx());
        assert!(
            v.iter().any(|v| v.rule == rule),
            "{file}: expected a {rule} violation, got {v:?}"
        );
        assert!(
            v.iter().all(|v| v.rule == rule),
            "{file}: unexpected extra rules in {v:?}"
        );
    }
}

/// The wire codec's panic-free contract (PR 9): panicking constructs
/// in non-test code fire `wire-panic-free` when the file is the codec
/// itself, and are left to the other rules everywhere else.
#[test]
fn wire_panic_fixture_scoped_to_the_wire_codec() {
    assert!(RULES.contains(&"wire-panic-free"));
    let src = fixture("wire_panic.rs");
    let v = lint_source("remote/wire.rs", &src, &ctx());
    assert!(
        v.iter().filter(|v| v.rule == "wire-panic-free").count() >= 3,
        "expected unwrap/assert/unreachable to fire, got {v:?}"
    );
    assert!(
        v.iter().all(|v| v.rule == "wire-panic-free"),
        "unexpected extra rules in {v:?}"
    );
    // Identical source under any other path is this rule's business
    // nowhere else — and remote/ is not a hot path, so nothing fires.
    assert!(lint_source("remote/client.rs", &src, &ctx()).is_empty());
    // Codec test code may assert freely.
    let in_test = format!("#[cfg(test)]\nmod tests {{\n{src}}}\n");
    assert!(lint_source("remote/wire.rs", &in_test, &ctx()).is_empty());
}

#[test]
fn clean_fixture_passes_every_rule() {
    let v = lint_source("runtime/clean.rs", &fixture("clean.rs"), &ctx());
    assert!(v.is_empty(), "clean fixture flagged: {v:?}");
}

/// Path scoping: identical hot-path/decode-path violations are ignored
/// outside the modules the rules target.
#[test]
fn path_scoped_rules_ignore_cold_modules() {
    for file in ["hot_unwrap.rs", "decode_sleep.rs"] {
        let v = lint_source(&format!("sim/{file}"), &fixture(file), &ctx());
        assert!(v.is_empty(), "{file} flagged outside hot paths: {v:?}");
    }
}

/// The committed tree must be clean — the check `cargo run -- lint`
/// gates CI on, run here so `cargo test` catches regressions first.
#[test]
fn committed_tree_is_clean() {
    let report = lint_tree(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap();
    assert!(
        report.is_clean(),
        "committed tree has lint violations:\n{}",
        report.render_table()
    );
    assert!(
        report.files_scanned >= 50,
        "only {} files scanned — wrong root?",
        report.files_scanned
    );
    assert!(report.allowed > 0, "allowlist not exercised");
    assert!(
        report.unused_allow.is_empty(),
        "unused allowlist entries: {:?}",
        report.unused_allow
    );
    assert!(report.render_table().trim_end().ends_with("clean"));
}

/// Build a throwaway one-file repo under `target/` (kept inside the
/// workspace so scratch space is cleaned with it).
fn mini_repo(name: &str, lib: &str, allow: Option<&str>) -> PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("target")
        .join("lint-test-scratch")
        .join(name);
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(root.join("rust/src")).unwrap();
    std::fs::write(
        root.join("Cargo.toml"),
        "[package]\nname = \"mini\"\n\n[dependencies]\nanyhow = \"1\"\n",
    )
    .unwrap();
    std::fs::write(root.join("rust/src/lib.rs"), lib).unwrap();
    if let Some(text) = allow {
        std::fs::write(root.join("rust/lint-allow.txt"), text).unwrap();
    }
    root
}

const DENY: &str = "#![deny(unsafe_op_in_unsafe_fn)]\n";

fn unsafe_lib() -> String {
    format!("{DENY}pub fn f(p: &u32) -> u32 {{\n    unsafe {{ core::ptr::read(p) }}\n}}\n")
}

#[test]
fn tree_scan_reports_violations_and_json_shape() {
    let root = mini_repo("dirty", &unsafe_lib(), None);
    let report = lint_tree(&root).unwrap();
    assert!(!report.is_clean());
    assert_eq!(report.files_scanned, 1);
    assert_eq!(report.violations.len(), 1);
    let v = &report.violations[0];
    assert_eq!(v.rule, "safety-comment");
    assert_eq!(v.file, "lib.rs");
    assert_eq!(v.line, 3);

    let json = report.to_json();
    assert_eq!(json.get("clean").and_then(|j| j.as_bool()), Some(false));
    assert_eq!(
        json.get("violation_count").and_then(|j| j.as_usize()),
        Some(1)
    );
    let rules = json.get("rules").unwrap().as_arr().unwrap();
    assert_eq!(rules.len(), RULES.len());
    let arr = json.get("violations").unwrap().as_arr().unwrap();
    assert_eq!(
        arr[0].get("rule").and_then(|j| j.as_str()),
        Some("safety-comment")
    );
    assert_eq!(arr[0].get("line").and_then(|j| j.as_usize()), Some(3));
    assert!(report.render_table().contains("FAIL"));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn missing_crate_root_policy_is_reported() {
    let root = mini_repo("nodeny", "pub fn f() {}\n", None);
    let report = lint_tree(&root).unwrap();
    assert_eq!(report.violations.len(), 1);
    assert_eq!(report.violations[0].rule, "unsafe-op-deny");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn allowlist_suppresses_and_unused_entries_warn() {
    let allow = "\
# justified for the test
safety-comment :: lib.rs :: core::ptr::read
hot-unwrap :: nonexistent.rs :: .unwrap()
";
    let root = mini_repo("allow", &unsafe_lib(), Some(allow));
    let report = lint_tree(&root).unwrap();
    assert!(report.is_clean(), "{}", report.render_table());
    assert_eq!(report.allowed, 1);
    assert_eq!(report.unused_allow.len(), 1);
    assert!(report.unused_allow[0].contains("nonexistent.rs"));
    assert!(report.render_table().contains("unused allowlist entry"));
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn malformed_allowlist_is_an_error() {
    let root = mini_repo(
        "badallow",
        &format!("{DENY}pub fn f() {{}}\n"),
        Some("not a valid entry\n"),
    );
    assert!(lint_tree(&root).is_err());
    std::fs::remove_dir_all(&root).unwrap();
}
