//! Integration: the real CPU-assisted cold-start path on the native
//! runtime — always runs (no artifacts needed).
//!
//! Pins the paper's §4 correctness contract: with the shm worker pool
//! attached, `ColdStartMode::CaraServe` must produce exactly the token
//! streams of the `Cached` oracle (the CPU `xAB` deltas agree with the
//! resident `bgmv` path) across cold, warm, and mid-load-handoff
//! requests, while TTFT absorbs only the prefill compute — bounded by
//! `max(load, prefill)` — instead of OnDemand's `load + prefill`.

use std::time::Duration;

use caraserve::model::LoraSpec;
use caraserve::runtime::{NativeConfig, NativeRuntime};
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, LifecycleState, RequestEvent,
    ServeRequest, ServingFront,
};

const N_ADAPTERS: u64 = 8;

fn server(mode: ColdStartMode, cpu_workers: usize, load_scale: f64) -> InferenceServer {
    // CPU-assisted servers run a multi-threaded forward pool while the
    // oracle stays serial: every token-equality assertion below then
    // also pins the §Perf threading contract (N-thread forward ==
    // 1-thread forward, bitwise).
    let threads = if cpu_workers > 0 { 3 } else { 1 };
    let runtime = NativeRuntime::new(NativeConfig::test_tiny().with_threads(threads));
    let mut s = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: mode,
            load_scale,
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..N_ADAPTERS {
        s.install_adapter(&LoraSpec::standard(id, 4, "tiny"))
            .expect("install");
    }
    if cpu_workers > 0 {
        s.enable_cpu_assist(cpu_workers).expect("cpu assist");
    }
    s
}

fn probe(adapter: u64, salt: i32, max_new: usize) -> ServeRequest {
    let prompt: Vec<i32> = (0..8).map(|i| (i * 7 + salt) % 64).collect();
    ServeRequest::new(adapter, prompt).max_new_tokens(max_new)
}

/// Run one request to completion on a fresh server of the given mode and
/// return its token stream.
fn solo_tokens(mode: ColdStartMode, cpu: usize, req: ServeRequest) -> Vec<i32> {
    let mut s = server(mode, cpu, 1.0);
    let h = s.submit(req);
    s.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Finished);
    h.tokens()
}

#[test]
fn caraserve_matches_cached_oracle_on_cold_warm_and_handoff() {
    // Oracle: every adapter pre-resident.
    let mut oracle = server(ColdStartMode::Cached, 0, 1.0);
    // CaraServe with the real CPU-assisted path.
    let mut cara = server(ColdStartMode::CaraServe, 2, 1.0);
    assert!(cara.cpu_assist_active());

    // Wave 1 — cold admits on two adapters.
    let reqs = || vec![probe(0, 1, 6), probe(1, 2, 6)];
    let oh: Vec<_> = reqs().into_iter().map(|r| oracle.submit(r)).collect();
    oracle.run_until_idle().unwrap();
    let ch: Vec<_> = reqs().into_iter().map(|r| cara.submit(r)).collect();
    cara.run_until_idle().unwrap();
    for (o, c) in oh.iter().zip(&ch) {
        assert_eq!(c.state(), LifecycleState::Finished);
        assert_eq!(o.tokens(), c.tokens(), "cold-start CPU-assist changed tokens");
    }
    assert!(cara.metrics().cold_start().cold_admits >= 2);
    assert!(cara.metrics().cold_start().cpu_assisted >= 2);

    // Wave 2 — warm admit (adapter 0 resident by now on both servers).
    let o = oracle.submit(probe(0, 3, 6));
    oracle.run_until_idle().unwrap();
    let c = cara.submit(probe(0, 3, 6));
    cara.run_until_idle().unwrap();
    assert_eq!(o.tokens(), c.tokens(), "warm-path tokens diverged");

    // Wave 3 — mid-load handoff: admit a cold adapter, prefill through
    // the CPU path, then let the load window elapse while the request is
    // still decoding. The §4.3 switch to the resident path must be
    // invisible in the token stream.
    let o = oracle.submit(probe(5, 4, 24));
    oracle.run_until_idle().unwrap();
    let c = cara.submit(probe(5, 4, 24));
    // Step until the prefill lands (earlier adapters' in-flight load
    // windows can defer the admit — adapter 5 shares slot 1 with
    // adapter 1), then let the ~5 ms load window elapse while the
    // request still has 23 tokens to decode.
    while c.state() != LifecycleState::Running {
        assert!(cara.step().unwrap(), "engine stalled before prefill");
    }
    std::thread::sleep(Duration::from_millis(12));
    cara.run_until_idle().unwrap();
    assert_eq!(c.state(), LifecycleState::Finished);
    assert_eq!(o.tokens(), c.tokens(), "handoff perturbed the token stream");
    assert!(
        cara.metrics().cold_start().handoffs >= 1,
        "expected a mid-load decode handoff: {:?}",
        cara.metrics().cold_start()
    );

    // The CPU-assisted prefill was recorded as such.
    let assisted: Vec<_> = cara
        .metrics()
        .records()
        .iter()
        .filter(|r| r.breakdown.is_some_and(|b| b.cold))
        .collect();
    assert!(!assisted.is_empty());

    // And OnDemand (serialized loads) also agrees on values — the three
    // modes differ in timing only.
    let od = solo_tokens(ColdStartMode::OnDemand, 0, probe(0, 1, 6));
    assert_eq!(od, solo_tokens(ColdStartMode::Cached, 0, probe(0, 1, 6)));
}

#[test]
fn caraserve_ttft_absorbs_max_not_sum() {
    // Scale the modeled window to ~50 ms so it dominates wall noise.
    let scale = 10.0;

    let mut on = server(ColdStartMode::OnDemand, 0, scale);
    let h = on.submit(probe(0, 9, 2));
    on.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Finished);
    let r_on = &on.metrics().records()[0];
    let b_on = r_on.breakdown.unwrap();
    assert!(b_on.cold);
    assert!(b_on.load >= 0.045, "load window {}", b_on.load);
    // Serialized: TTFT pays load + prefill.
    assert!(
        r_on.ttft >= b_on.load,
        "OnDemand ttft {} < load {}",
        r_on.ttft,
        b_on.load
    );

    let mut cara = server(ColdStartMode::CaraServe, 2, scale);
    let h = cara.submit(probe(0, 9, 2));
    cara.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Finished);
    let r_cara = &cara.metrics().records()[0];
    let b_cara = r_cara.breakdown.unwrap();
    assert!(b_cara.cold);
    assert!(b_cara.load >= 0.045);
    // The real CPU-assisted path: prefill is not blocked by the load, so
    // TTFT stays far under the window — and certainly under
    // max(load, prefill), where OnDemand pays the sum.
    let max_bound = b_cara.load.max(b_cara.prefill);
    // Small absolute slack so scheduler noise on a loaded CI host can't
    // flip the bound; the window is 50 ms, the prefill is sub-ms.
    assert!(
        r_cara.ttft <= max_bound + 0.02,
        "CaraServe ttft {} exceeded max(load, prefill) {}",
        r_cara.ttft,
        max_bound
    );
    assert!(
        r_cara.ttft < 0.5 * r_on.ttft,
        "CaraServe ttft {} not ≪ OnDemand {}",
        r_cara.ttft,
        r_on.ttft
    );

    // Without a worker pool the mode degrades to the modeled overlap:
    // the iteration spans max(load, prefill).
    let mut modeled = server(ColdStartMode::CaraServe, 0, scale);
    assert!(!modeled.cpu_assist_active());
    let h = modeled.submit(probe(0, 9, 2));
    modeled.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Finished);
    let r_mod = &modeled.metrics().records()[0];
    assert!(
        r_mod.ttft >= 0.045,
        "modeled overlap should span the window, got {}",
        r_mod.ttft
    );
    assert_eq!(modeled.metrics().cold_start().cpu_assisted, 0);
}

#[test]
fn intra_batch_slot_collision_defers_instead_of_corrupting() {
    // Adapters 1 and 5 collide on fixed slot 1 (4 slots in test_tiny).
    // Submitted in one admit batch, the old engine let the second
    // acquire evict the first's weights before the prefill executed.
    let want1 = solo_tokens(ColdStartMode::Cached, 0, probe(1, 11, 5));
    let want5 = solo_tokens(ColdStartMode::Cached, 0, probe(5, 13, 5));

    let mut s = server(ColdStartMode::Cached, 0, 1.0);
    let h1 = s.submit(probe(1, 11, 5));
    let h5 = s.submit(probe(5, 13, 5));
    s.run_until_idle().unwrap();
    assert_eq!(h1.state(), LifecycleState::Finished);
    assert_eq!(h5.state(), LifecycleState::Finished);
    assert_eq!(h1.tokens(), want1, "first collider ran with wrong weights");
    assert_eq!(h5.tokens(), want5, "deferred collider ran with wrong weights");
    assert!(
        s.metrics().cold_start().deferred_collisions >= 1,
        "collision was not detected"
    );

    // Same batch under the real CPU-assisted path (the deferred admit
    // must also wait out the first adapter's in-flight load window).
    let mut s = server(ColdStartMode::CaraServe, 2, 1.0);
    let h1 = s.submit(probe(1, 11, 5));
    let h5 = s.submit(probe(5, 13, 5));
    s.run_until_idle().unwrap();
    assert_eq!(h1.tokens(), want1);
    assert_eq!(h5.tokens(), want5);
}

#[test]
fn native_backend_full_lifecycle_and_events() {
    let mut s = server(ColdStartMode::CaraServe, 2, 1.0);
    let handles: Vec<_> = (0..6)
        .map(|i| s.submit(probe(i % N_ADAPTERS, i as i32, 3 + i as usize % 4)))
        .collect();
    s.run_until_idle().unwrap();
    for h in &handles {
        assert_eq!(h.state(), LifecycleState::Finished);
        let events = h.drain_events();
        assert_eq!(events[0], RequestEvent::Admitted);
        assert!(matches!(events[1], RequestEvent::FirstToken(_)));
        assert!(events.last().unwrap().is_terminal());
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
        assert!(h.tokens().iter().all(|&t| (0..64).contains(&t)));
    }
    assert_eq!(s.metrics().records().len(), 6);
    assert_eq!(s.metrics().inflight(), 0);

    // Cancellation mid-decode stays serviceable with CPU assist on.
    let long = s.submit(probe(2, 40, 30));
    assert!(s.step().unwrap());
    long.cancel();
    s.run_until_idle().unwrap();
    assert_eq!(long.state(), LifecycleState::Cancelled);
    let after = s.submit(probe(3, 41, 4));
    s.run_until_idle().unwrap();
    assert_eq!(after.state(), LifecycleState::Finished);
    assert_eq!(after.tokens().len(), 4);
}

#[test]
fn zero_slot_backend_is_rejected_at_construction() {
    let cfg = NativeConfig {
        lora_slots: 0,
        ..NativeConfig::test_tiny()
    };
    let err = InferenceServer::new(NativeRuntime::new(cfg), EngineConfig::default())
        .err()
        .expect("zero slots must fail construction");
    assert!(err.to_string().contains("slot"), "{err}");
}
