//! Integration: the §3 global coordinator over *live* native engines
//! (always runs; no artifacts needed).
//!
//! The acceptance run drives a skewed (Zipf) synthetic workload over
//! three real `InferenceServer`s twice — once with the static
//! id-hash placement baseline, once with registry-driven placement +
//! pre-warming + live migration — and asserts the ISSUE 5 criteria:
//! coordinator SLO attainment keeps up with static, at least one
//! runtime migration happens (visible in `CoordinatorStats` and the
//! registry placements), and every token stream is bitwise identical
//! to a single-engine oracle, migrations included.
//!
//! The engine-level management surface (runtime install / uninstall /
//! prewarm) is exercised directly on one live engine below.

use caraserve::coordinator::{CoordinatorConfig, MigrationMode};
use caraserve::model::LoraSpec;
use caraserve::runtime::{NativeConfig, NativeRuntime};
use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, LifecycleState, ServeRequest,
    ServingFront,
};

/// The skewed-demand configuration: Cached cold starts keep every
/// routing and migration decision wall-clock independent (and therefore
/// deterministic); `skew: 1.2` concentrates ~40% of traffic on the
/// hottest adapter, the regime where placement matters.
fn skewed_cfg() -> SyntheticConfig {
    SyntheticConfig {
        instances: 3,
        requests: 36,
        adapters: 12,
        seed: 7,
        threads: 1,
        cpu_workers: 0,
        cold_start: ColdStartMode::Cached,
        kv_pages: 256,
        polls_per_arrival: 1,
        skew: 1.2,
    }
}

/// Token streams of the whole workload served by one roomy engine —
/// the content oracle: token values depend only on (adapter weights,
/// prompt, sampling), never on which server decodes, so any placement
/// or migration must reproduce these bitwise.
fn oracle_streams(cfg: &SyntheticConfig) -> Vec<Vec<i32>> {
    let mut server = InferenceServer::new(
        NativeRuntime::new(NativeConfig::tiny()),
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            kv_pages: 512,
            ..Default::default()
        },
    )
    .expect("oracle server");
    for a in 0..cfg.adapters as u64 {
        server
            .install_adapter(&LoraSpec::standard(a, synthetic::rank_of(a), "tiny"))
            .expect("install");
    }
    let handles: Vec<_> = synthetic::workload(cfg)
        .into_iter()
        .map(|r| server.submit(r))
        .collect();
    server.run_until_idle().expect("oracle run");
    handles
        .iter()
        .map(|h| {
            assert_eq!(h.state(), LifecycleState::Finished);
            h.tokens()
        })
        .collect()
}

#[test]
fn coordinator_beats_or_matches_static_with_live_migration() {
    let cfg = skewed_cfg();
    let ccfg = CoordinatorConfig {
        migrate_interval: 2,
        prewarm: 3,
        // Two replicas match the static baseline's replication factor
        // (`hosts` puts each adapter on two of the three servers), so
        // the comparison isolates *where* adapters live, not how many
        // copies exist.
        replicas: 2,
        slots_per_server: 8,
        // Any instantaneous load gap triggers relief, guaranteeing the
        // migration path runs within the 36-request window.
        min_imbalance: 1,
        mode: MigrationMode::Move,
        ..Default::default()
    };
    let static_rep = synthetic::run("rank-aware", &cfg).expect("static run");
    let (coord_rep, coord) =
        synthetic::run_coordinated("rank-aware", &cfg, ccfg).expect("coordinated run");

    for rep in [&static_rep, &coord_rep] {
        assert_eq!(rep.finished, rep.requests, "{}: request loss", rep.policy);
        assert_eq!(rep.rejected, 0, "{}: spurious rejection", rep.policy);
    }

    // The control plane actually ran: every adapter placed twice
    // (replicas = 2), the hot head pre-warmed, and at least one
    // runtime migration — visible in the counters *and* in the
    // registry's placement table (the migrated adapter is hosted by the
    // relief server the migration log names).
    let cs = coord.coordinator_stats();
    assert_eq!(cs.initial_placements, cfg.adapters * 2, "{cs:?}");
    assert!(cs.prewarmed >= 1, "{cs:?}");
    assert!(cs.migrations >= 1, "no migration on a skewed workload: {cs:?}");
    let ev = *coord.migration_log().last().expect("migrations ≥ 1");
    let placed = coord.cluster().registry().servers_for(ev.adapter);
    assert!(
        placed.contains(&ev.to),
        "migration of adapter {} to server {} not reflected in registry: {placed:?}",
        ev.adapter,
        ev.to
    );

    // Bitwise stream equivalence: no request — including those in
    // flight on a migrated adapter — may see a different token stream
    // than the single-engine oracle.
    let oracle = oracle_streams(&cfg);
    assert_eq!(coord_rep.streams.len(), oracle.len());
    for (i, (got, want)) in coord_rep.streams.iter().zip(&oracle).enumerate() {
        assert!(!want.is_empty(), "oracle stream {i} empty");
        assert_eq!(got, want, "request {i}: coordination changed the stream");
    }
    for (i, (got, want)) in static_rep.streams.iter().zip(&oracle).enumerate() {
        assert_eq!(got, want, "request {i}: static cluster changed the stream");
    }

    // SLO attainment: the coordinator must keep up with (and usually
    // beat) static placement; the tolerance absorbs wall-clock noise in
    // the measured latencies (routing itself is deterministic).
    let sa = static_rep.slo_attainment.expect("slo-carrying workload");
    let ca = coord_rep.slo_attainment.expect("slo-carrying workload");
    assert!(ca >= sa - 0.15, "coordinator attainment {ca} ≪ static {sa}");
}

#[test]
fn runtime_uninstall_refuses_until_inflight_drains() {
    let mut server = InferenceServer::new(
        NativeRuntime::new(NativeConfig::tiny()),
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            ..Default::default()
        },
    )
    .expect("server");
    server
        .install_adapter(&LoraSpec::standard(1, 8, "tiny"))
        .expect("install");

    // First pass: record the reference stream.
    let prompt: Vec<i32> = (0..10).map(|i| i * 3 + 2).collect();
    let h = server.submit(ServeRequest::new(1, prompt.clone()).max_new_tokens(8));
    // Admitted and decoding: a runtime uninstall must refuse.
    server.poll().unwrap();
    let err = ServingFront::uninstall_adapter(&mut server, 1).unwrap_err();
    assert!(err.to_string().contains("busy"), "{err}");
    server.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Finished);
    let want = h.tokens();
    assert_eq!(want.len(), 8);

    // Drained: the uninstall goes through; new submissions reject.
    ServingFront::uninstall_adapter(&mut server, 1).unwrap();
    let rejected = server.submit(ServeRequest::new(1, prompt.clone()).max_new_tokens(4));
    assert_eq!(rejected.state(), LifecycleState::Rejected);
    let err = ServingFront::uninstall_adapter(&mut server, 1).unwrap_err();
    assert!(err.to_string().contains("not installed"), "{err}");

    // Reinstall restores service with the identical (seeded) weights:
    // the stream matches the pre-uninstall run bitwise.
    server
        .install_adapter(&LoraSpec::standard(1, 8, "tiny"))
        .expect("reinstall");
    let h2 = server.submit(ServeRequest::new(1, prompt).max_new_tokens(8));
    server.run_until_idle().unwrap();
    assert_eq!(h2.state(), LifecycleState::Finished);
    assert_eq!(h2.tokens(), want, "reinstall changed the weights");
}

#[test]
fn prewarm_turns_the_first_admit_warm() {
    let engine = || {
        let mut s = InferenceServer::new(
            NativeRuntime::new(NativeConfig::tiny()),
            EngineConfig {
                cold_start: ColdStartMode::CaraServe,
                load_scale: 0.05,
                ..Default::default()
            },
        )
        .expect("server");
        s.install_adapter(&LoraSpec::standard(5, 8, "tiny"))
            .expect("install");
        s
    };
    let req = || ServeRequest::new(5, vec![1; 8]).max_new_tokens(3);

    let mut cold = engine();
    let hc = cold.submit(req());
    cold.run_until_idle().unwrap();
    assert_eq!(cold.metrics().cold_start().cold_admits, 1);

    let mut warmed = engine();
    assert!(warmed.prewarm_adapter(5).unwrap());
    assert!(warmed.prewarm_adapter(5).unwrap(), "idempotent");
    let hw = warmed.submit(req());
    warmed.run_until_idle().unwrap();
    let cs = warmed.metrics().cold_start().clone();
    assert_eq!(cs.cold_admits, 0, "prewarmed adapter cold-started: {cs:?}");
    assert_eq!(cs.warm_admits, 1);
    // Warm vs cold is a latency property only — content is identical.
    assert_eq!(hc.tokens(), hw.tokens());
    // Prewarming something never installed is an error.
    assert!(warmed.prewarm_adapter(99).is_err());
}
