//! Property tests for the content-addressed artifact store
//! (`caraserve::artifacts`): digest stability across re-saves, dedup
//! refcounting, GC safety under random publish/remove interleavings,
//! typed rejection of corrupted blobs, chunking-independence of
//! streamed ingest, and the engine's install-provenance counters when
//! a store is attached. Seeded RNG throughout so failures replay.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use caraserve::artifacts::{synthetic_stack, ArtifactStore, StoreError};
use caraserve::util::rng::Rng;

/// Fresh per-test store root under the system temp dir.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("caraserve-prop-artifacts")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

const HIDDEN: usize = 32;

/// Random (adapter, rank, stack-seed) population. Distinct adapters
/// sometimes share a stack seed, so dedup paths get exercised.
fn arb_catalog(rng: &mut Rng, n: usize) -> Vec<(u64, usize, u64)> {
    (0..n as u64)
        .map(|a| {
            let rank = [8usize, 16, 32, 64][rng.range(0, 4)];
            let seed = rng.below(4) as u64; // few seeds → forced sharing
            (a, rank, seed)
        })
        .collect()
}

#[test]
fn digests_are_stable_across_resaves_and_reopens() {
    let dir = tmp("stable");
    let mut rng = Rng::new(0xD16E57);
    let catalog = arb_catalog(&mut rng, 12);

    let mut store = ArtifactStore::open(&dir).expect("open");
    let mut digests = Vec::new();
    for (a, rank, seed) in &catalog {
        let stack = synthetic_stack(*seed, HIDDEN, *rank);
        digests.push(store.publish(*a, *rank, "tiny", &stack).expect("publish"));
    }
    let index_bytes = std::fs::read(dir.join("index.json")).expect("index");
    drop(store);

    // Ten reopen cycles: the index re-save is byte-stable and every
    // manifest digest is unchanged (content addressing means any drift
    // would be a broken canonical form).
    for cycle in 0..10 {
        let store = ArtifactStore::open(&dir).expect("reopen");
        for ((a, _, _), want) in catalog.iter().zip(&digests) {
            let (got, _) = store.manifest_of(*a).expect("indexed");
            assert_eq!(got, want, "cycle {cycle}: adapter {a} digest drifted");
        }
        drop(store);
        let again = std::fs::read(dir.join("index.json")).expect("index");
        assert_eq!(again, index_bytes, "cycle {cycle}: index re-save not byte-stable");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dedup_refcounts_match_manifest_references() {
    let dir = tmp("refcount");
    let mut rng = Rng::new(0x5EED);
    let catalog = arb_catalog(&mut rng, 16);

    let mut store = ArtifactStore::open(&dir).expect("open");
    for (a, rank, seed) in &catalog {
        let stack = synthetic_stack(*seed, HIDDEN, *rank);
        store.publish(*a, *rank, "tiny", &stack).expect("publish");
    }
    // Distinct (seed, rank) pairs give 4 tensor blobs each; identical
    // pairs share all four. blob files = 4·distinct + one manifest per
    // distinct manifest digest.
    let mut distinct_stacks = std::collections::BTreeSet::new();
    let mut distinct_manifests = std::collections::BTreeSet::new();
    for (a, rank, seed) in &catalog {
        distinct_stacks.insert((*seed, *rank));
        distinct_manifests.insert(store.manifest_of(*a).expect("indexed").0.to_string());
    }
    assert_eq!(
        store.blob_count().expect("count"),
        4 * distinct_stacks.len() + distinct_manifests.len(),
        "shared stacks must store each tensor blob exactly once"
    );
    // Every tensor blob's refcount equals the number of indexed
    // manifests that reference it.
    for (a, _, _) in &catalog {
        let blobs: Vec<_> = {
            let (_, m) = store.manifest_of(*a).expect("indexed");
            m.blobs.iter().map(|b| b.digest.clone()).collect()
        };
        for digest in blobs {
            let want = catalog
                .iter()
                .filter(|(other, _, _)| {
                    let (_, m) = store.manifest_of(*other).expect("indexed");
                    m.blobs.iter().any(|b| b.digest == digest)
                })
                .count();
            assert_eq!(store.refcount(&digest), want, "blob {digest}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// GC safety as a property: under random publish/remove/gc
/// interleavings, an indexed ("placed") adapter always survives GC
/// with every blob intact, and GC'd bytes never resurface.
#[test]
fn gc_never_collects_a_placed_adapter() {
    let dir = tmp("gc-safety");
    let mut rng = Rng::new(0x6C);
    let mut store = ArtifactStore::open(&dir).expect("open");
    let mut placed: Vec<(u64, usize, u64)> = Vec::new();
    let mut next_adapter = 0u64;

    for step in 0..120 {
        match rng.range(0, 3) {
            0 => {
                let rank = [8usize, 16, 32, 64][rng.range(0, 4)];
                let seed = rng.below(6) as u64;
                let stack = synthetic_stack(seed, HIDDEN, rank);
                store
                    .publish(next_adapter, rank, "tiny", &stack)
                    .expect("publish");
                placed.push((next_adapter, rank, seed));
                next_adapter += 1;
            }
            1 if !placed.is_empty() => {
                let at = rng.range(0, placed.len());
                let (a, _, _) = placed.swap_remove(at);
                assert!(store.remove(a).expect("remove"));
            }
            _ => {
                store.gc().expect("gc");
                // Every placed adapter must still load, bitwise.
                for (a, rank, seed) in &placed {
                    let (r, stack) = store
                        .load_stack(*a, HIDDEN)
                        .unwrap_or_else(|e| panic!("step {step}: adapter {a} lost to gc: {e}"));
                    assert_eq!(r, *rank);
                    let want = synthetic_stack(*seed, HIDDEN, *rank);
                    for (g, w) in stack.iter().zip(want.iter()) {
                        assert_eq!(g.a, w.a, "step {step}: adapter {a} A matrix diverged");
                        assert_eq!(g.b, w.b, "step {step}: adapter {a} B matrix diverged");
                    }
                }
            }
        }
    }
    // Final drain: removing everything and GC'ing empties the blob dir.
    for (a, _, _) in placed.drain(..) {
        store.remove(a).expect("remove");
    }
    store.gc().expect("final gc");
    assert_eq!(store.blob_count().expect("count"), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_blobs_are_typed_rejections_not_panics() {
    let dir = tmp("corrupt");
    let mut store = ArtifactStore::open(&dir).expect("open");
    let stack = synthetic_stack(3, HIDDEN, 16);
    store.publish(3, 16, "tiny", &stack).expect("publish");
    let first_blob = {
        let (_, m) = store.manifest_of(3).expect("indexed");
        m.blobs[0].digest.clone()
    };

    // Flip one byte of the blob on disk. Install must refuse with the
    // typed Corrupt error naming the digest — never serve wrong bytes.
    let path = dir.join("blobs").join(&first_blob);
    let mut bytes = std::fs::read(&path).expect("read blob");
    bytes[0] ^= 0x01;
    std::fs::write(&path, &bytes).expect("rewrite blob");

    match store.load_stack(3, HIDDEN) {
        Err(StoreError::Corrupt { digest, .. }) => assert_eq!(digest, first_blob),
        other => panic!("corrupted blob gave {other:?}, wanted StoreError::Corrupt"),
    }
    // verify_all sees it too.
    assert!(matches!(
        store.verify_all(),
        Err(StoreError::Corrupt { .. })
    ));
    // store_hits never advanced: the corruption was caught pre-serve.
    assert_eq!(store.store_hits(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Streamed ingest is chunking-independent: any random split of a blob
/// commits bytes identical to a direct `put_blob`, out-of-order chunks
/// are typed rejections that reset staging, and nothing commits early.
#[test]
fn ingest_is_chunking_independent_and_strictly_sequential() {
    let dir = tmp("ingest");
    let mut rng = Rng::new(0x1157);
    let mut store = ArtifactStore::open(&dir).expect("open");

    for case in 0..40 {
        let len = 1 + rng.range(0, 4096);
        let blob: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let digest = caraserve::artifacts::hex_digest(&blob);

        let mut offset = 0usize;
        while offset < blob.len() {
            let take = (1 + rng.range(0, 512)).min(blob.len() - offset);
            let done = store
                .ingest_chunk(&digest, offset as u64, blob.len() as u64, &blob[offset..offset + take])
                .expect("ingest");
            offset += take;
            assert_eq!(
                done,
                offset == blob.len(),
                "case {case}: commit signal at wrong offset {offset}"
            );
            assert_eq!(store.has_blob(&digest), offset == blob.len());
        }
        assert_eq!(store.read_blob(&digest).expect("read"), blob, "case {case}");
    }

    // Out-of-order offset: typed rejection, staging reset to zero.
    let blob = vec![7u8; 1024];
    let digest = caraserve::artifacts::hex_digest(&blob);
    store
        .ingest_chunk(&digest, 0, 1024, &blob[..256])
        .expect("first chunk");
    assert_eq!(store.staged_len(&digest), 256);
    match store.ingest_chunk(&digest, 512, 1024, &blob[512..768]) {
        Err(StoreError::ChunkOutOfOrder { expected, got, .. }) => {
            assert_eq!((expected, got), (256, 512));
        }
        other => panic!("out-of-order chunk gave {other:?}"),
    }
    assert_eq!(store.staged_len(&digest), 0, "violation must drop staging");
    assert!(!store.has_blob(&digest), "nothing may commit early");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The engine's install provenance: with a store attached, an adapter
/// the store holds installs as a store hit; one it lacks falls back to
/// synthetic seeding; a rank mismatch between manifest and spec is a
/// refusal, not a silent re-seed.
#[test]
fn engine_install_counts_store_hits_and_synthetic_seeds() {
    use caraserve::model::LoraSpec;
    use caraserve::runtime::{NativeConfig, NativeRuntime};
    use caraserve::server::{EngineConfig, InferenceServer, ServingFront};

    let dir = tmp("engine-counters");
    let cfg = NativeConfig::tiny();
    let hidden = cfg.hidden;
    let mut store = ArtifactStore::open(&dir).expect("open");
    store
        .publish(1, 8, "tiny", &synthetic_stack(1, hidden, 8))
        .expect("publish 1");
    store
        .publish(2, 16, "tiny", &synthetic_stack(2, hidden, 16))
        .expect("publish 2");
    let store = Arc::new(Mutex::new(store));

    let mut engine = InferenceServer::new(
        NativeRuntime::new(cfg),
        EngineConfig::default(),
    )
    .expect("engine");
    engine.attach_store(Arc::clone(&store));

    engine
        .install_adapter(&LoraSpec::standard(1, 8, "tiny"))
        .expect("store-backed install");
    engine
        .install_adapter(&LoraSpec::standard(9, 8, "tiny"))
        .expect("synthetic fallback install");
    let stats = engine.install_source_stats();
    assert_eq!(
        (stats.store_hits, stats.synthetic_seeds),
        (1, 1),
        "one install from the store, one seeded"
    );
    assert_eq!(store.lock().unwrap().store_hits(), 1);

    // Manifest says rank 16; the spec claims 8. Refusal, not re-seed.
    let err = engine
        .install_adapter(&LoraSpec::standard(2, 8, "tiny"))
        .expect_err("rank mismatch must refuse");
    assert!(
        err.to_string().contains("rank"),
        "error should name the rank conflict: {err}"
    );
    let stats = engine.install_source_stats();
    assert_eq!((stats.store_hits, stats.synthetic_seeds), (1, 1));
    let _ = std::fs::remove_dir_all(&dir);
}
