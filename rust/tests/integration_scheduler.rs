//! Integration: scheduler policies over the cluster simulator — the
//! §7.5 pipeline (profile → fit → route → measure SLO attainment) at
//! test scale.

use caraserve::config::GpuSpec;
use caraserve::model::LlamaConfig;
use caraserve::perfmodel::{profiler, KernelKind};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::sim::{GpuModel, MafTrace, ServingMode, SimInstance, Simulation};

struct Setup {
    gm: GpuModel,
    slo: f64,
}

fn setup() -> Setup {
    let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    // SLO = 1.5× the single-request (HF-PEFT-like) decode latency (§7.5).
    let slo = 1.5 * gm.decode_iter(&[160]);
    Setup { gm, slo }
}

fn run_policy(s: &Setup, policy_name: &str, kernel: KernelKind, seed: u64) -> (f64, f64) {
    let plan = profiler::ProfilePlan::default();
    let gm = s.gm.clone();
    let dec = profiler::calibrate(kernel, &plan, |ranks| {
        gm.decode_iter(&vec![160; ranks.len()]) + gm.lora_decode_overhead(kernel, ranks)
    })
    .unwrap();
    let pre = profiler::calibrate(kernel, &plan, |ranks| gm.prefill(ranks.len() * 28)).unwrap();

    let mode = match kernel {
        KernelKind::Bgmv => ServingMode::CaraServe,
        KernelKind::Mbgmv => ServingMode::SLora,
    };
    let instances: Vec<SimInstance> = (0..6)
        .map(|i| SimInstance::new(i, s.gm.clone(), mode, 48, 32, 512))
        .collect();
    // ~7.5 rps/instance creates enough contention that policies separate.
    let trace = MafTrace::new(seed, 512, 1.0, &[8, 16, 32, 64]);
    let reqs = trace.generate(seed + 1, 45.0, 60.0);
    let mut policy = policy_by_name(
        policy_name,
        pre,
        dec,
        RankAwareConfig {
            slo: s.slo,
            ..Default::default()
        },
        seed,
    )
    .expect("known policy");
    let mut sim = Simulation::new(instances);
    let out = sim.run(&reqs, policy.as_mut());
    (
        out.slo_attainment(s.slo),
        caraserve::util::stats::mean(&out.column("tpt")),
    )
}

#[test]
fn rank_aware_beats_baselines_on_slo_attainment() {
    let s = setup();
    let (ra, ra_tpt) = run_policy(&s, "rank-aware", KernelKind::Bgmv, 42);
    let (ff, _) = run_policy(&s, "first-fit", KernelKind::Bgmv, 42);
    let (rnd, _) = run_policy(&s, "random", KernelKind::Bgmv, 42);
    // §7.5: rank-aware achieves the highest attainment. First-fit packs
    // and must be clearly beaten; random may tie within noise when the
    // cluster is underloaded, so allow a small tolerance there.
    assert!(ra > ff, "rank-aware {ra} ≤ first-fit {ff}");
    assert!(ra >= rnd - 0.02, "rank-aware {ra} ≪ random {rnd}");
    assert!(ra > 0.5, "attainment collapsed: {ra}");
    assert!(ra_tpt > 0.0);
}

#[test]
fn rank_aware_works_with_mbgmv_backend_too() {
    let s = setup();
    let (ra, _) = run_policy(&s, "rank-aware", KernelKind::Mbgmv, 7);
    let (ff, _) = run_policy(&s, "first-fit", KernelKind::Mbgmv, 7);
    assert!(ra >= ff, "rank-aware {ra} < first-fit {ff} (mbgmv)");
}

#[test]
fn all_policies_complete_all_requests() {
    let s = setup();
    for name in ["rank-aware", "most-idle", "first-fit", "random"] {
        let (att, tpt) = run_policy(&s, name, KernelKind::Bgmv, 99);
        assert!((0.0..=1.0).contains(&att), "{name}: {att}");
        assert!(tpt > 0.0, "{name}");
    }
}

#[test]
fn perf_model_fit_quality_matches_paper() {
    // Fig 9: linear fits reach R² ≈ 0.96 on profiled data.
    let s = setup();
    let plan = profiler::ProfilePlan::default();
    let gm = s.gm.clone();
    let bgmv = profiler::calibrate(KernelKind::Bgmv, &plan, |ranks| {
        gm.decode_iter(&vec![160; ranks.len()])
            + gm.lora_decode_overhead(KernelKind::Bgmv, ranks)
    })
    .unwrap();
    assert!(bgmv.r2 > 0.9, "BGMV R² = {}", bgmv.r2);
    let gm2 = s.gm.clone();
    let mbgmv = profiler::calibrate(KernelKind::Mbgmv, &plan, |ranks| {
        gm2.decode_iter(&vec![160; ranks.len()])
            + gm2.lora_decode_overhead(KernelKind::Mbgmv, ranks)
    })
    .unwrap();
    assert!(mbgmv.r2 > 0.8, "MBGMV R² = {}", mbgmv.r2);
}
