//! Integration: the CPU-assisted LoRA engine across its real substrates
//! (shared-memory IPC + worker pool + profiling-guided split) and the
//! Rust↔Pallas kernel semantic equivalence.

use std::sync::Arc;

use caraserve::cpu_lora::{AdapterTable, CoreProfile, CpuLoraEngine};
use caraserve::ipc::{Doorbell, SlotChannel};
use caraserve::kernels::{bgmv_padded, mbgmv, AdapterWeights};
use caraserve::model::TargetMatrix;
use caraserve::util::rng::Rng;

#[test]
fn engine_matches_direct_kernel_over_many_shapes() {
    let hidden = 64;
    let table = Arc::new(AdapterTable::new());
    for id in 0..4 {
        table.install_synthetic(id, hidden, 4 + (id as usize % 3) * 2);
    }
    let profile = CoreProfile::from_rate(hidden, 8, 1600.0, 10.0); // c = 16
    let engine = CpuLoraEngine::new(4, hidden, 512, table.clone(), profile).unwrap();

    let mut rng = Rng::new(11);
    for &n_tok in &[1usize, 7, 16, 33, 64, 127] {
        for adapter in 0..4u64 {
            let x: Vec<f32> = (0..n_tok * hidden).map(|_| rng.f32() - 0.5).collect();
            let got = engine.apply(adapter, TargetMatrix::Q, n_tok, &x);
            // Direct single-shot reference.
            let weights = table.get(adapter).unwrap();
            let ad = &weights[0];
            let mut want = vec![0.0f32; n_tok * hidden];
            let mut scratch = vec![0.0f32; n_tok * ad.rank];
            caraserve::kernels::lora_apply(
                n_tok, hidden, hidden, ad.rank, &x, &ad.a, &ad.b, &mut want,
                &mut scratch,
            );
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "n={n_tok} adapter={adapter}");
            }
        }
    }
}

#[test]
fn rust_bgmv_and_mbgmv_agree_on_zero_padded_stacks() {
    // Mirrors python/tests/test_kernel.py::test_bgmv_equals_mbgmv: the
    // padded and padding-free kernels agree when stacks are zero-padded
    // beyond true rank — the numerical basis for the Fig 4 cost split.
    let h = 48;
    let ranks = [2usize, 8, 5, 1];
    let adapters: Vec<AdapterWeights> = ranks
        .iter()
        .enumerate()
        .map(|(i, &r)| {
            let mut a = AdapterWeights::synthetic(i as u64, h, h, 8);
            // Zero beyond true rank r.
            for row in 0..h {
                for c in r..8 {
                    a.a[row * 8 + c] = 0.0;
                }
            }
            for rr in r..8 {
                for c in 0..h {
                    a.b[rr * h + c] = 0.0;
                }
            }
            a
        })
        .collect();
    let mut rng = Rng::new(3);
    let indices: Vec<usize> = (0..12).map(|_| rng.range(0, 4)).collect();
    let x: Vec<f32> = (0..indices.len() * h).map(|_| rng.f32() - 0.5).collect();
    let mut y1 = vec![0.0f32; indices.len() * h];
    let mut y2 = vec![0.0f32; indices.len() * h];
    bgmv_padded(&adapters, &indices, h, h, &x, &mut y1);
    mbgmv(&adapters, &indices, h, h, &x, &mut y2);
    for (a, b) in y1.iter().zip(&y2) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn shm_slot_survives_sustained_bidirectional_traffic() {
    let (region, mut slots) = caraserve::ipc::shm::slot_channels(1, 1024).unwrap();
    let region = Arc::new(region);
    let ch = Arc::new(slots.remove(0));
    let (ch2, keep) = (ch.clone(), region.clone());
    let h = std::thread::spawn(move || {
        let _k = keep;
        let mut seen = 0u32;
        let mut buf = Vec::new();
        for _ in 0..2_000 {
            seen = ch2.recv_request(seen, &mut buf);
            let sum: f32 = buf.iter().sum();
            ch2.send_response(&[sum]);
        }
    });
    let mut resp = Vec::new();
    let mut rng = Rng::new(5);
    for i in 0..2_000 {
        let n = rng.range(1, 1024);
        let payload: Vec<f32> = vec![1.0; n];
        let token = ch.send_request(&payload);
        ch.recv_response(token, &mut resp);
        assert_eq!(resp.len(), 1, "round {i}");
        assert_eq!(resp[0], n as f32, "round {i}");
    }
    h.join().unwrap();
}

#[test]
fn doorbell_fan_out_to_many_waiters() {
    let bell = Arc::new(Doorbell::new());
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let b = bell.clone();
            std::thread::spawn(move || b.wait_past(0))
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(20));
    bell.ring();
    for h in handles {
        assert_eq!(h.join().unwrap(), 1);
    }
}

#[test]
fn slot_channel_capacity_bytes_accounting() {
    // bytes_needed must cover header + both payload directions.
    let need = SlotChannel::bytes_needed(100);
    assert!(need >= 2 * 100 * 4);
    let region = caraserve::ipc::ShmRegion::new(need + 8).unwrap();
    assert!(SlotChannel::at(&region, 0, 100).is_ok());
    assert!(SlotChannel::at(&region, 8, 100).is_ok()); // exactly fits
    assert!(SlotChannel::at(&region, 16, 100).is_err()); // off end
}
