//! Interleaving tests built on [`caraserve::testkit::interleave`]:
//!
//! 1. A faithful shadow model of the `ipc::shm` SlotChannel/Doorbell
//!    SPSC protocol, exhaustively verified (2 threads × 6 steps each),
//!    plus a 3-thread overlap model that re-catches the PR 2
//!    shared-length regression in a seeded known-bad variant while the
//!    committed split-length protocol passes exhaustively.
//! 2. The request-lifecycle state machine: the real `SimFront` and
//!    `ClusterFront` driven through ≥1,200 seeded random schedules of
//!    submit/cancel/poll/install/uninstall/prewarm, with oracles for
//!    terminal-event uniqueness and registry-placement serveability.
//! 3. Crash schedules: the same lifecycle traffic with one cluster
//!    backend killed (injected panic) at a seeded random decode step,
//!    a different step per schedule. Oracles: every request still ends
//!    with exactly one terminal event, and every registry placement on
//!    a live backend stays serveable.

use std::cell::Cell;
use std::sync::Arc;

use caraserve::config::GpuSpec;
use caraserve::model::{LlamaConfig, LoraSpec};
use caraserve::perfmodel::{KernelKind, PerfModel};
use caraserve::scheduler::registry::{AdapterMeta, GlobalRegistry};
use caraserve::scheduler::{policy_by_name, RankAwareConfig};
use caraserve::server::{
    ClusterFront, Health, RequestEvent, RequestHandle, ServeRequest, ServingFront,
};
use caraserve::sim::{GpuModel, ServingMode, SimFront, SimInstance};
use caraserve::testkit::faults::{ChaosFront, FaultPlan};
use caraserve::testkit::interleave::{
    always, explore, explore_random, explore_random_indexed, when, ScriptModel, Step,
};
use caraserve::util::rng::Rng;

// ---------------------------------------------------------------------------
// Part 1a: full request/response roundtrip, step-for-step with the real
// SlotChannel protocol (send_request / recv_request / send_response /
// recv_response), verified over every interleaving.
// ---------------------------------------------------------------------------

const CAP: usize = 8;

/// Shadow of one slot's shared memory plus each side's locals. Fixed
/// `CAP`-element buffers mirror the slot's fixed capacity; `*_seq`
/// mirror the doorbells; `*_len` mirror the header length words.
#[derive(Default)]
struct Spsc {
    req_buf: [f32; CAP],
    req_len: usize,
    req_seq: u32,
    resp_buf: [f32; CAP],
    resp_len: usize,
    resp_seq: u32,
    // Producer locals.
    p_resp_seen: u32,
    p_len: usize,
    got: Vec<f32>,
    // Consumer locals.
    c_len: usize,
    c_got: Vec<f32>,
}

/// One full exchange: producer sends [1,2,3], consumer echoes it
/// doubled. Each step is one shared-memory access of the real
/// protocol, so the interleaving granularity matches `ipc::shm`.
fn spsc_roundtrip() -> ScriptModel<Spsc> {
    ScriptModel::new(Spsc::default())
        // Producer: send_request, then recv_response.
        .thread(vec![
            always(|s: &mut Spsc| s.req_buf[..3].copy_from_slice(&[1.0, 2.0, 3.0])),
            always(|s: &mut Spsc| s.req_len = 3),
            always(|s: &mut Spsc| {
                // Capture the response sequence, then ring the request
                // doorbell — send_request's return value.
                s.p_resp_seen = s.resp_seq;
                s.req_seq += 1;
            }),
            when(|s: &Spsc| s.resp_seq != s.p_resp_seen, |_| {}),
            always(|s: &mut Spsc| s.p_len = s.resp_len.min(CAP)),
            always(|s: &mut Spsc| s.got = s.resp_buf[..s.p_len].to_vec()),
        ])
        // Consumer: recv_request, then send_response.
        .thread(vec![
            when(|s: &Spsc| s.req_seq > 0, |_| {}),
            always(|s: &mut Spsc| s.c_len = s.req_len.min(CAP)),
            always(|s: &mut Spsc| s.c_got = s.req_buf[..s.c_len].to_vec()),
            always(|s: &mut Spsc| {
                for (i, v) in s.c_got.clone().iter().enumerate() {
                    s.resp_buf[i] = v * 2.0;
                }
            }),
            always(|s: &mut Spsc| s.resp_len = s.c_len),
            always(|s: &mut Spsc| s.resp_seq += 1),
        ])
        .finally(|s| {
            if s.c_got != vec![1.0, 2.0, 3.0] {
                return Err(format!("consumer read {:?}", s.c_got));
            }
            if s.got != vec![2.0, 4.0, 6.0] {
                return Err(format!("producer read {:?}", s.got));
            }
            Ok(())
        })
}

#[test]
fn spsc_roundtrip_verified_exhaustively() {
    let report = explore(spsc_roundtrip, 100_000);
    assert!(report.ok(), "{report}");
    assert!(report.exhausted, "schedule space not covered: {report}");
    assert!(report.schedules >= 1);
}

// ---------------------------------------------------------------------------
// Part 1b: the PR 2 shared-length regression. A response is published
// while the producer concurrently publishes its next request (the
// overlap `ipc::shm`'s SlotHeader docs call out — e.g. a shutdown
// poison message racing an in-flight job). With one shared length word
// the request's length clobbers the response's; with the committed
// split req_len/resp_len design it cannot.
// ---------------------------------------------------------------------------

struct Overlap {
    /// Known-bad variant: both directions share one length word.
    shared: bool,
    req_buf: [f32; CAP],
    resp_buf: [f32; CAP],
    req_len: usize,
    resp_len: usize,
    /// The single length word of the known-bad variant.
    len: usize,
    resp_seq: u32,
    r_len: usize,
    out: Option<Vec<f32>>,
}

fn overlap_model(shared: bool) -> ScriptModel<Overlap> {
    let state = Overlap {
        shared,
        req_buf: [0.0; CAP],
        resp_buf: [0.0; CAP],
        req_len: 0,
        resp_len: 0,
        len: 0,
        resp_seq: 0,
        r_len: 0,
        out: None,
    };
    ScriptModel::new(state)
        // Worker: publish the 3-element response [7,7,7] and ring.
        .thread(vec![
            always(|s: &mut Overlap| s.resp_buf[..3].copy_from_slice(&[7.0; 3])),
            always(|s: &mut Overlap| {
                if s.shared {
                    s.len = 3;
                } else {
                    s.resp_len = 3;
                }
            }),
            always(|s: &mut Overlap| s.resp_seq += 1),
        ])
        // Producer: concurrently publish the next 5-element request.
        .thread(vec![
            always(|s: &mut Overlap| s.req_buf[..5].copy_from_slice(&[9.0; 5])),
            always(|s: &mut Overlap| {
                if s.shared {
                    s.len = 5;
                } else {
                    s.req_len = 5;
                }
            }),
        ])
        // Reader: wait for the response doorbell, then read length and
        // payload exactly like recv_response (clamped to capacity).
        .thread(vec![
            when(
                |s: &Overlap| s.resp_seq > 0,
                |s| {
                    let len = if s.shared { s.len } else { s.resp_len };
                    s.r_len = len.min(CAP);
                },
            ),
            always(|s: &mut Overlap| s.out = Some(s.resp_buf[..s.r_len].to_vec())),
        ])
        .finally(|s| match &s.out {
            Some(v) if v == &vec![7.0; 3] => Ok(()),
            other => Err(format!("response clobbered: read {other:?}")),
        })
}

#[test]
fn split_length_words_survive_overlap_exhaustively() {
    let report = explore(|| overlap_model(false), 100_000);
    assert!(report.ok(), "{report}");
    assert!(report.exhausted);
    // Three concurrent threads: genuinely many interleavings.
    assert!(report.schedules > 10, "only {} schedules", report.schedules);
}

#[test]
fn shared_length_word_regression_is_caught() {
    let report = explore(|| overlap_model(true), 100_000);
    let v = report.violation.expect("known-bad variant not caught");
    assert!(v.message.contains("clobbered"), "{}", v.message);
}

// ---------------------------------------------------------------------------
// Part 2: request-lifecycle schedules against the real fronts.
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Op {
    Submit {
        adapter: u64,
        prompt: usize,
        max_new: usize,
        stop: Option<i32>,
    },
    Cancel(usize),
    Poll,
    Install(u64, usize),
    Uninstall(u64),
    Prewarm(u64),
}

fn random_op(rng: &mut Rng) -> Op {
    match rng.range(0, 10) {
        0..=3 => Op::Submit {
            // Ids 4–5 start unregistered → Rejected unless installed
            // by an earlier Install op in the same schedule.
            adapter: rng.range(0, 6) as u64,
            prompt: rng.range(1, 32),
            max_new: rng.range(1, 8),
            stop: if rng.chance(0.25) {
                Some(rng.range(0, 10) as i32)
            } else {
                None
            },
        },
        4 => Op::Cancel(rng.range(0, 16)),
        5 | 6 => Op::Poll,
        7 => Op::Install(rng.range(0, 6) as u64, *rng.choose(&[8usize, 16, 32, 64])),
        8 => Op::Uninstall(rng.range(0, 6) as u64),
        _ => Op::Prewarm(rng.range(0, 6) as u64),
    }
}

/// Shared state of one lifecycle schedule: the front under test plus
/// every handle it ever returned, and the progress/drain bookkeeping
/// the drainer thread keys off.
struct Lifecycle<F: ServingFront> {
    front: F,
    handles: Vec<RequestHandle>,
    steps_done: usize,
    drained: bool,
}

/// Apply one op to the front. Management-surface refusals must be the
/// *documented* ones (busy / not installed) — anything else is a bug.
fn apply_op<F: ServingFront>(s: &mut Lifecycle<F>, op: &Op) {
    s.steps_done += 1;
    match op {
        Op::Submit {
            adapter,
            prompt,
            max_new,
            stop,
        } => {
            let mut req =
                ServeRequest::new(*adapter, vec![1; *prompt]).max_new_tokens(*max_new);
            if let Some(t) = stop {
                req = req.stop_token(*t);
            }
            let h = s.front.submit(req);
            s.handles.push(h);
        }
        Op::Cancel(i) => {
            if !s.handles.is_empty() {
                let id = s.handles[i % s.handles.len()].id();
                let _ = s.front.cancel(id);
            }
        }
        Op::Poll => {
            s.front.poll().expect("poll must not fail");
        }
        Op::Install(id, rank) => {
            s.front
                .install_adapter(&LoraSpec::standard(*id, *rank, "sim"))
                .expect("install must not fail");
        }
        Op::Uninstall(id) => {
            if let Err(e) = s.front.uninstall_adapter(*id) {
                let msg = e.to_string();
                assert!(
                    msg.contains("busy") || msg.contains("not installed"),
                    "unexpected uninstall refusal: {msg}"
                );
            }
        }
        Op::Prewarm(id) => {
            if let Err(e) = s.front.prewarm_adapter(*id) {
                let msg = e.to_string();
                assert!(
                    msg.contains("not installed"),
                    "unexpected prewarm refusal: {msg}"
                );
            }
        }
    }
}

/// The end-of-schedule oracle: every submitted request reached exactly
/// one terminal event, with no events after it, and token streams are
/// consistent with the terminal reason.
fn lifecycle_oracle<F: ServingFront>(s: &Lifecycle<F>) -> Result<(), String> {
    if !s.drained {
        return Err("drainer thread never ran".into());
    }
    for h in &s.handles {
        let state = h.state();
        if !state.is_terminal() {
            return Err(format!("request {} ended in {state:?}", h.id()));
        }
        let events = h.drain_events();
        let terminals = events.iter().filter(|e| e.is_terminal()).count();
        if terminals != 1 {
            return Err(format!(
                "request {}: {terminals} terminal events in {events:?}",
                h.id()
            ));
        }
        let last = events.last().expect("terminal implies ≥ 1 event");
        if !last.is_terminal() {
            return Err(format!("request {}: events after terminal", h.id()));
        }
        let tokens = h.tokens();
        match last {
            RequestEvent::Rejected(_) => {
                if !tokens.is_empty() || events.len() != 1 {
                    return Err(format!("request {}: rejected saw activity", h.id()));
                }
            }
            RequestEvent::Finished(_) => {
                if tokens.is_empty() {
                    return Err(format!("request {}: finished without tokens", h.id()));
                }
            }
            RequestEvent::Cancelled => {}
            other => return Err(format!("non-terminal last event {other:?}")),
        }
    }
    Ok(())
}

/// Assemble the client threads + drainer for a front. `ops` holds one
/// script per client thread; the drainer waits until every client step
/// has run, then drains the front so the oracle sees a quiesced system.
fn lifecycle_model<F: ServingFront + 'static>(
    front: F,
    ops: Vec<Vec<Op>>,
) -> ScriptModel<Lifecycle<F>> {
    let total: usize = ops.iter().map(Vec::len).sum();
    let mut m = ScriptModel::new(Lifecycle {
        front,
        handles: Vec::new(),
        steps_done: 0,
        drained: false,
    });
    for script in ops {
        let steps: Vec<Step<Lifecycle<F>>> = script
            .into_iter()
            .map(|op| always(move |s: &mut Lifecycle<F>| apply_op(s, &op)))
            .collect();
        m = m.thread(steps);
    }
    m.thread(vec![when(
        move |s: &Lifecycle<F>| s.steps_done == total,
        |s| {
            s.front.run_until_idle().expect("drain must not fail");
            s.drained = true;
        },
    )])
    .finally(|s| lifecycle_oracle(s))
}

fn random_scripts(rng: &mut Rng) -> Vec<Vec<Op>> {
    (0..3)
        .map(|_| (0..rng.range(3, 9)).map(|_| random_op(rng)).collect())
        .collect()
}

fn sim_front(rng: &mut Rng) -> SimFront {
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let inst = SimInstance::new(0, model, ServingMode::CaraServe, rng.range(1, 6), 8, 16);
    let mut front = SimFront::new(inst, 64);
    for id in 0..4 {
        front.register_adapter(id, *rng.choose(&[8, 16, 32, 64]));
    }
    front
}

/// ≥600 seeded random schedules of mixed traffic + management ops
/// against the single-instance `SimFront`.
#[test]
fn lifecycle_schedules_hold_on_sim_front() {
    let next = Cell::new(0u64);
    let report = explore_random(
        || {
            let seed = 0x51D0 + next.get();
            next.set(next.get() + 1);
            let mut rng = Rng::new(seed);
            let front = sim_front(&mut rng);
            lifecycle_model(front, random_scripts(&mut rng))
        },
        600,
        0xCA7A_5EED,
    );
    assert!(report.ok(), "{report}");
    assert_eq!(report.schedules, 600);
}

fn cluster_front(rng: &mut Rng) -> ClusterFront {
    let n = rng.range(2, 4);
    let rank_of = |id: u64| [8usize, 16, 32, 64][(id % 4) as usize];
    let mut backends: Vec<Box<dyn ServingFront>> = Vec::new();
    for s in 0..n {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(s, model, ServingMode::CaraServe, 4, 8, 16);
        let mut f = SimFront::new(inst, 64);
        for id in 0..4u64 {
            // Each adapter starts on two of the backends.
            if (id as usize) % n == s || (id as usize + 1) % n == s {
                f.register_adapter(id, rank_of(id));
            }
        }
        backends.push(Box::new(f));
    }
    let registry = Arc::new(GlobalRegistry::new());
    for id in 0..4u64 {
        registry.register(AdapterMeta {
            id,
            rank: rank_of(id),
            base_model: "sim".into(),
            weights_path: String::new(),
        });
    }
    let pre = PerfModel::from_coefficients(KernelKind::Bgmv, 4e-5, 60e-3);
    let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
    let name = *rng.choose(&["rank-aware", "most-idle", "first-fit", "random"]);
    let policy = policy_by_name(name, pre, dec, RankAwareConfig::default(), 7).unwrap();
    ClusterFront::new(backends, policy, registry)
}

/// ≥600 seeded random schedules against the routed `ClusterFront`,
/// with a per-step invariant: every placement the registry records
/// must point at a server that can actually serve the adapter (the
/// PR 5 coordinator's core consistency guarantee).
#[test]
fn lifecycle_schedules_hold_on_cluster_front() {
    let next = Cell::new(0u64);
    let report = explore_random(
        || {
            let seed = 0xC1_0570 + next.get();
            next.set(next.get() + 1);
            let mut rng = Rng::new(seed);
            let front = cluster_front(&mut rng);
            lifecycle_model(front, random_scripts(&mut rng)).invariant(|s| {
                let stats = s.front.per_server_stats();
                for id in s.front.registry().ids() {
                    for srv in s.front.registry().servers_for(id) {
                        if srv >= stats.len() || !stats[srv].can_serve(id) {
                            return Err(format!(
                                "adapter {id} placed on server {srv} which cannot serve it"
                            ));
                        }
                    }
                }
                Ok(())
            })
        },
        600,
        0xD00D_FEED,
    );
    assert!(report.ok(), "{report}");
    assert_eq!(report.schedules, 600);
}

// ---------------------------------------------------------------------------
// Part 3: crash schedules — one backend dies at a random step.
// ---------------------------------------------------------------------------

/// Like [`cluster_front`], but one randomly chosen backend is wrapped
/// in a [`ChaosFront`] executing `plan` (a seeded panic kill).
fn chaos_cluster_front(rng: &mut Rng, plan: &FaultPlan) -> ClusterFront {
    let n = rng.range(2, 4);
    let victim = rng.range(0, n);
    let rank_of = |id: u64| [8usize, 16, 32, 64][(id % 4) as usize];
    let mut backends: Vec<Box<dyn ServingFront>> = Vec::new();
    for s in 0..n {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(s, model, ServingMode::CaraServe, 4, 8, 16);
        let mut f = SimFront::new(inst, 64);
        for id in 0..4u64 {
            if (id as usize) % n == s || (id as usize + 1) % n == s {
                f.register_adapter(id, rank_of(id));
            }
        }
        let boxed: Box<dyn ServingFront> = Box::new(f);
        backends.push(if s == victim {
            Box::new(ChaosFront::new(boxed, plan.clone()))
        } else {
            boxed
        });
    }
    let registry = Arc::new(GlobalRegistry::new());
    for id in 0..4u64 {
        registry.register(AdapterMeta {
            id,
            rank: rank_of(id),
            base_model: "sim".into(),
            weights_path: String::new(),
        });
    }
    let pre = PerfModel::from_coefficients(KernelKind::Bgmv, 4e-5, 60e-3);
    let dec = PerfModel::from_coefficients(KernelKind::Bgmv, 1.3e-5, 24.8e-3);
    let name = *rng.choose(&["rank-aware", "most-idle", "first-fit", "random"]);
    let policy = policy_by_name(name, pre, dec, RankAwareConfig::default(), 7).unwrap();
    ClusterFront::new(backends, policy, registry)
}

/// [`lifecycle_oracle`] relaxed for schedules with an injected crash: a
/// request may stream tokens and *then* terminate with a typed
/// rejection (its backend died with no survivor for its adapter), so
/// the "rejected saw no activity" clause is dropped. What must still
/// hold under faults: a terminal state, exactly one terminal event,
/// nothing after it, and a finished stream is non-empty.
fn crash_oracle<F: ServingFront>(s: &Lifecycle<F>) -> Result<(), String> {
    if !s.drained {
        return Err("drainer thread never ran".into());
    }
    for h in &s.handles {
        let state = h.state();
        if !state.is_terminal() {
            return Err(format!("request {} ended in {state:?}", h.id()));
        }
        let events = h.drain_events();
        let terminals = events.iter().filter(|e| e.is_terminal()).count();
        if terminals != 1 {
            return Err(format!(
                "request {}: {terminals} terminal events in {events:?}",
                h.id()
            ));
        }
        let last = events.last().expect("terminal implies ≥ 1 event");
        if !last.is_terminal() {
            return Err(format!("request {}: events after terminal", h.id()));
        }
        if matches!(last, RequestEvent::Finished(_)) && h.tokens().is_empty() {
            return Err(format!("request {}: finished without tokens", h.id()));
        }
    }
    Ok(())
}

/// ≥300 crash schedules: lifecycle traffic with one backend panicking
/// at a seeded decode step that varies per schedule. No panic may
/// escape the cluster; terminal-event uniqueness must survive the
/// failover; registry placements on *live* backends stay serveable (a
/// placement on the dead backend is tolerated — its copy died with it,
/// which is exactly what the coordinator's restore path repairs).
#[test]
fn crash_schedules_keep_terminals_unique_and_registry_consistent() {
    let report = explore_random_indexed(
        |i| {
            let seed = 0xFA_1717 + i as u64;
            let mut rng = Rng::new(seed);
            let plan = FaultPlan::seeded_mid_decode_kill(seed, 1, 12);
            let front = chaos_cluster_front(&mut rng, &plan);
            let mut m = lifecycle_model(front, random_scripts(&mut rng));
            m = m.invariant(|s| {
                let stats = s.front.per_server_stats();
                for id in s.front.registry().ids() {
                    for srv in s.front.registry().servers_for(id) {
                        if srv >= stats.len() {
                            return Err(format!("adapter {id} placed on ghost server {srv}"));
                        }
                        if matches!(s.front.health_of(srv), Health::Healthy | Health::Suspect)
                            && !stats[srv].can_serve(id)
                        {
                            return Err(format!(
                                "adapter {id} placed on live server {srv} which cannot serve it"
                            ));
                        }
                    }
                }
                Ok(())
            });
            // Overrides the strict lifecycle oracle set by the builder.
            m.finally(|s| crash_oracle(s))
        },
        300,
        0xFA17_5EED,
    );
    assert!(report.ok(), "{report}");
    assert_eq!(report.schedules, 300);
}
