//! Integration: ISSUE 7's unified paged device memory (always runs; no
//! artifacts needed).
//!
//! The acceptance run drives a 1,024-adapter Zipf catalog over **one**
//! native engine with a deliberately tight page pool, so adapter
//! weights and request KV genuinely compete: the 8 residency slots and
//! the 40-page pool together force idle-adapter evictions under load.
//! The same workload over a roomy pool is the content oracle — paging
//! adapters in and out may change *when* requests run, never *what*
//! they generate, so every token stream must match bitwise.
//!
//! A direct engine-level test below pins the mechanism itself:
//! pre-warmed weights hold pool pages, a request on a third adapter
//! evicts the coldest idle one, and the page accounting stays balanced
//! throughout.

use caraserve::model::LoraSpec;
use caraserve::runtime::{NativeConfig, NativeRuntime};
use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, LifecycleState, ServeRequest,
    ServingFront,
};

/// The 1,000+ adapter catalog on one engine. `skew: 1.2` gives the
/// classic hot-head/long-tail mix, so the run touches far more distinct
/// adapters than the 8 residency slots (let alone the 40-page pool)
/// can hold at once. Cached cold starts keep every admission decision
/// wall-clock independent, hence deterministic.
fn catalog_cfg(kv_pages: usize) -> SyntheticConfig {
    SyntheticConfig {
        instances: 1,
        requests: 64,
        adapters: 1024,
        seed: 11,
        threads: 1,
        cpu_workers: 0,
        cold_start: ColdStartMode::Cached,
        kv_pages,
        polls_per_arrival: 1,
        skew: 1.2,
    }
}

#[test]
fn thousand_adapter_catalog_pages_under_pressure_without_changing_streams() {
    // Tight: 40 pages shared by KV (≤ 4 pages/request) and adapter
    // weights (1–4 pages each across ranks 8..64). Roomy: effectively
    // unbounded, the oracle.
    let tight = synthetic::run("rank-aware", &catalog_cfg(40)).expect("tight run");
    let roomy = synthetic::run("rank-aware", &catalog_cfg(4096)).expect("roomy run");

    for rep in [&tight, &roomy] {
        assert_eq!(rep.finished, rep.requests, "{}: request loss", rep.policy);
        assert_eq!(rep.rejected, 0, "{}: spurious rejection", rep.policy);
    }

    // Pressure actually materialised: the tight pool paged at least one
    // idle adapter's weights back out to make room.
    assert!(
        tight.adapter_evictions >= 1,
        "no adapter eviction under a 40-page pool: {tight:?}"
    );

    // Bitwise equivalence: memory pressure reorders work, never content.
    assert_eq!(tight.streams.len(), roomy.streams.len());
    for (i, (got, want)) in tight.streams.iter().zip(&roomy.streams).enumerate() {
        assert!(!want.is_empty(), "oracle stream {i} empty");
        assert_eq!(got, want, "request {i}: pool pressure changed the stream");
    }
}

#[test]
fn prewarmed_weights_hold_pages_and_yield_to_live_traffic() {
    // 12-page pool; rank-64 adapters cost 4 pages each on the tiny
    // geometry, so two pre-warmed adapters (8 pages) plus one live
    // request's KV leave no room for a third adapter without eviction.
    let mut server = InferenceServer::new(
        NativeRuntime::new(NativeConfig::tiny()),
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            kv_pages: 12,
            ..Default::default()
        },
    )
    .expect("server");
    for a in 0..3u64 {
        server
            .install_adapter(&LoraSpec::standard(a, 64, "tiny"))
            .expect("install");
    }
    assert!(server.prewarm_adapter(0).expect("prewarm 0"));
    assert!(server.prewarm_adapter(1).expect("prewarm 1"));
    let before = server.stats();
    assert_eq!(before.adapter_held_pages, 8, "{before:?}");
    assert_eq!(before.adapter_evictions, 0, "{before:?}");

    // A request on the un-warmed adapter 2 must evict an idle resident
    // adapter to page its own weights in — and still finish.
    let h = server.submit(ServeRequest::new(2, vec![3; 8]).max_new_tokens(4));
    server.run_until_idle().expect("run");
    assert_eq!(h.state(), LifecycleState::Finished);
    assert_eq!(h.tokens().len(), 4);

    let after = server.stats();
    assert!(
        after.adapter_evictions >= 1,
        "no eviction despite 12-page pool: {after:?}"
    );
    // Accounting: everything held fits the pool, and the drained
    // request returned its KV pages.
    assert_eq!(after.kv_held_pages, 0, "{after:?}");
    assert!(
        after.adapter_held_pages <= after.pool_pages,
        "{after:?}"
    );

    // The evicted adapter still serves — it pages back in on demand,
    // with identical (seeded) weights, so a fresh roomy engine agrees
    // on the stream.
    let h0 = server.submit(ServeRequest::new(0, vec![3; 8]).max_new_tokens(4));
    server.run_until_idle().expect("re-page run");
    assert_eq!(h0.state(), LifecycleState::Finished);

    let mut roomy = InferenceServer::new(
        NativeRuntime::new(NativeConfig::tiny()),
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            kv_pages: 512,
            ..Default::default()
        },
    )
    .expect("roomy server");
    roomy
        .install_adapter(&LoraSpec::standard(0, 64, "tiny"))
        .expect("install");
    let hr = roomy.submit(ServeRequest::new(0, vec![3; 8]).max_new_tokens(4));
    roomy.run_until_idle().expect("roomy run");
    assert_eq!(h0.tokens(), hr.tokens(), "re-paging changed the weights");
}
