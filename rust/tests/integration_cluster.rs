//! Integration: the rank-aware scheduler in front of *real* engines —
//! `ClusterFront` over native-runtime `InferenceServer`s (always runs;
//! no artifacts needed), plus the decode-growth preemption path the
//! cluster router steers on.

use caraserve::model::LoraSpec;
use caraserve::runtime::{NativeConfig, NativeRuntime};
use caraserve::server::cluster::synthetic::{self, SyntheticConfig};
use caraserve::server::{
    ColdStartMode, EngineConfig, InferenceServer, LifecycleState, ServeRequest,
    ServingFront,
};

/// A native engine with a deliberately small KV pool (or a roomy one).
fn engine_with_pool(kv_pages: usize, page_size: usize) -> InferenceServer {
    let runtime = NativeRuntime::new(NativeConfig::tiny());
    let mut s = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: ColdStartMode::Cached,
            kv_pages,
            page_size,
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..4u64 {
        s.install_adapter(&LoraSpec::standard(id, 8, "tiny"))
            .expect("install");
    }
    s
}

#[test]
fn decode_growth_preempts_instead_of_erroring() {
    // Two requests that jointly outgrow a 10-page pool mid-decode: the
    // old engine surfaced OutOfPages as a fatal error; now the youngest
    // is preempted, re-queued, and resumed — with a client-visible
    // stream bitwise identical to a run with a roomy pool.
    let reqs = || {
        vec![
            ServeRequest::new(0, (0..8).map(|i| i * 3 + 1).collect()).max_new_tokens(24),
            ServeRequest::new(1, (0..8).map(|i| i * 5 + 2).collect()).max_new_tokens(24),
        ]
    };

    let mut roomy = engine_with_pool(64, 4);
    let want: Vec<_> = reqs().into_iter().map(|r| roomy.submit(r)).collect();
    roomy.run_until_idle().unwrap();
    assert_eq!(roomy.metrics().preemptions(), 0);

    let mut tight = engine_with_pool(10, 4);
    let got: Vec<_> = reqs().into_iter().map(|r| tight.submit(r)).collect();
    tight.run_until_idle().unwrap();

    for (w, g) in want.iter().zip(&got) {
        assert_eq!(g.state(), LifecycleState::Finished);
        assert_eq!(g.tokens().len(), 24);
        assert_eq!(w.tokens(), g.tokens(), "preemption changed the stream");
        let events = g.drain_events();
        assert_eq!(
            events.iter().filter(|e| e.is_terminal()).count(),
            1,
            "exactly one terminal event: {events:?}"
        );
    }
    assert!(
        tight.metrics().preemptions() >= 1,
        "pool of 10 pages must have preempted"
    );
    // The preemption is visible to the cluster router via ServerStats.
    assert!(tight.stats().preemptions >= 1);
    assert_eq!(tight.metrics().inflight(), 0);
}

#[test]
fn rank_aware_matches_or_beats_random_on_heterogeneous_ranks() {
    // Fig 5-style heterogeneous-rank workload over three real engines
    // with partial adapter placement. Cached cold starts keep the run
    // free of wall-clock-dependent load windows, so routing decisions
    // are deterministic; only the measured latencies carry timing noise.
    let cfg = SyntheticConfig {
        instances: 3,
        requests: 36,
        adapters: 12,
        seed: 5,
        threads: 1,
        cpu_workers: 0,
        cold_start: ColdStartMode::Cached,
        kv_pages: 256,
        polls_per_arrival: 1,
        skew: 0.0,
    };
    let ra = synthetic::run("rank-aware", &cfg).expect("rank-aware run");
    let rnd = synthetic::run("random", &cfg).expect("random run");

    for rep in [&ra, &rnd] {
        assert_eq!(rep.finished, rep.requests, "{}: request loss", rep.policy);
        assert_eq!(rep.rejected, 0, "{}: spurious rejection", rep.policy);
        assert_eq!(rep.routed.iter().sum::<usize>(), rep.requests);
    }

    // Rank balance is deterministic (routing doesn't depend on wall
    // clock in Cached mode): the rank-aware policy must spread rank-sum
    // at least as evenly as random, within one max-rank adapter.
    let spread = |sums: &[usize]| {
        sums.iter().max().unwrap() - sums.iter().min().unwrap()
    };
    let ra_spread = spread(&ra.routed_rank_sum);
    let rnd_spread = spread(&rnd.routed_rank_sum);
    assert!(
        ra_spread <= rnd_spread + *synthetic::RANKS.iter().max().unwrap(),
        "rank-aware spread {ra_spread} ≫ random spread {rnd_spread} \
         (rank sums {:?} vs {:?})",
        ra.routed_rank_sum,
        rnd.routed_rank_sum
    );

    // SLO attainment: rank-aware must not lose to random beyond
    // wall-clock measurement noise.
    let ra_att = ra.slo_attainment.expect("slo-carrying workload");
    let rnd_att = rnd.slo_attainment.expect("slo-carrying workload");
    assert!(
        ra_att >= rnd_att - 0.15,
        "rank-aware attainment {ra_att} ≪ random {rnd_att}"
    );
    assert!(ra_att > 0.2, "attainment collapsed: {ra_att}");
}

#[test]
fn nested_cluster_tree_matches_flat_cluster() {
    // "Cluster front as a server": a two-level tree — an outer
    // ClusterFront routing over { inner ClusterFront over 2 engines,
    // 1 bare engine } — must serve the same workload as a flat
    // 3-engine cluster with bitwise-identical token streams (every
    // engine holds identical per-adapter weights, so placement cannot
    // change content) and aggregate stats coherently across levels.
    use caraserve::scheduler::baselines::MostIdle;
    use caraserve::scheduler::registry::{AdapterMeta, GlobalRegistry};
    use caraserve::server::ClusterFront;
    use std::sync::Arc;

    let registry = || {
        let reg = GlobalRegistry::new();
        for id in 0..4u64 {
            reg.register(AdapterMeta {
                id,
                rank: 8,
                base_model: "tiny".into(),
                weights_path: String::new(),
            });
        }
        Arc::new(reg)
    };
    let engines = || -> Vec<Box<dyn ServingFront>> {
        (0..3)
            .map(|_| Box::new(engine_with_pool(64, 4)) as Box<dyn ServingFront>)
            .collect()
    };
    let reqs = || {
        (0..9u64).map(|i| {
            ServeRequest::new(i % 4, (0..8).map(|t| (t * 7 + i as i32) % 999).collect())
                .max_new_tokens(4 + (i as usize % 3))
        })
    };

    let mut flat = ClusterFront::new(engines(), Box::new(MostIdle), registry());
    let flat_handles: Vec<_> = reqs().map(|r| flat.submit(r)).collect();
    flat.run_until_idle().unwrap();

    let mut backends = engines();
    let rack_b = backends.pop().unwrap();
    let inner = ClusterFront::new(backends, Box::new(MostIdle), registry());
    let mut outer = ClusterFront::new(
        vec![Box::new(inner), rack_b],
        Box::new(MostIdle),
        registry(),
    );
    let nested_handles: Vec<_> = reqs().map(|r| outer.submit(r)).collect();
    // Mid-flight, the tree aggregates its levels into one stats view.
    let s = outer.stats();
    assert_eq!(s.total_requests(), 9);
    for id in 0..4 {
        assert!(s.can_serve(id));
    }
    assert!(!s.can_serve(99));
    outer.run_until_idle().unwrap();

    for (i, (f, n)) in flat_handles.iter().zip(&nested_handles).enumerate() {
        assert_eq!(f.state(), LifecycleState::Finished, "flat request {i}");
        assert_eq!(n.state(), LifecycleState::Finished, "nested request {i}");
        assert_eq!(
            f.tokens(),
            n.tokens(),
            "request {i}: nesting changed the stream"
        );
        // The outer level relays the inner level's Routed events, so a
        // request through the tree observes ≥ 1 placement event and
        // still exactly one terminal.
        let events = n.drain_events();
        assert!(events
            .iter()
            .any(|e| matches!(e, caraserve::server::RequestEvent::Routed { .. })));
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
    }
    // All three engines drained; the tree reports idle at every level.
    assert_eq!(outer.stats().total_requests(), 0);
    assert_eq!(outer.metrics().records().len(), 9);
}

#[test]
fn cluster_smoke_with_cold_starts_and_cpu_assist() {
    // The CaraServe cold-start machinery (async loads, CPU-assisted
    // prefill, handoffs) running behind the cluster front: everything
    // terminates and cold admits are observed through the aggregated
    // counters.
    let cfg = SyntheticConfig {
        instances: 2,
        requests: 12,
        adapters: 16,
        seed: 3,
        threads: 1,
        cpu_workers: 2,
        cold_start: ColdStartMode::CaraServe,
        kv_pages: 256,
        polls_per_arrival: 2,
        skew: 0.0,
    };
    let rep = synthetic::run("most-idle", &cfg).expect("cluster run");
    assert_eq!(rep.finished, rep.requests);
    assert_eq!(rep.rejected, 0);
    assert!(
        rep.cold.cold_admits > 0,
        "16 adapters over 8 slots must cold-start: {:?}",
        rep.cold
    );
    assert!(rep.cold.cpu_assisted > 0, "{:?}", rep.cold);
}
