//! Integration: the AOT artifacts → PJRT runtime path.
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise so
//! `cargo test` works on a fresh checkout).

use std::path::PathBuf;

use caraserve::runtime::ModelRuntime;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

// PJRT handles are thread-bound (Rc inside the xla crate), so each test
// loads its own runtime; the test binary runs them on one thread anyway.
fn load() -> Option<ModelRuntime> {
    artifacts_dir().map(|d| ModelRuntime::load(&d).expect("runtime load"))
}

#[test]
fn loads_and_compiles_all_artifacts() {
    let Some(rt) = load() else { return };
    assert_eq!(rt.hidden, 256);
    assert_eq!(rt.layers, 4);
    assert_eq!(rt.vocab, 1024);
    assert!(!rt.manifest.prefill_buckets().is_empty());
    assert!(!rt.manifest.decode_buckets().is_empty());
}

#[test]
fn prefill_produces_finite_logits_and_kv() {
    let Some(rt) = load() else { return };
    let prompt: Vec<i32> = (0..16).map(|i| (i * 37) % 1024).collect();
    let out = rt
        .prefill(&[2], &[prompt], &[16])
        .expect("prefill");
    let (bb, bs) = out.bucket;
    assert!(bb >= 1 && bs >= 16);
    assert_eq!(out.logits.len(), bb * rt.vocab);
    assert_eq!(out.k_cache.len(), rt.layers * bb * bs * rt.hidden);
    assert!(out.logits.iter().all(|v| v.is_finite()));
    assert!(out.k_cache.iter().all(|v| v.is_finite()));
}

#[test]
fn decode_step_consistent_with_prefill_extension() {
    // THE cross-layer correctness check: greedy-decoding one token via
    // the decode artifact must match prefilling the extended prompt via
    // the prefill artifact (mirrors python/tests/test_model.py).
    let Some(rt) = load() else { return };
    let prompt: Vec<i32> = (0..16).map(|i| (i * 13 + 7) % 1024).collect();
    let pre = rt.prefill(&[1], &[prompt.clone()], &[16]).expect("prefill");
    let first = rt.argmax_row(&pre.logits, 0);

    // Assemble the decode cache: pad prefill KV [L,1,16,H] → [L,B,M,H].
    let (bb, m) = rt.manifest.pick_decode_bucket(1).unwrap();
    let (pb, ps) = pre.bucket;
    let mut k = vec![0.0f32; rt.layers * bb * m * rt.hidden];
    let mut v = vec![0.0f32; rt.layers * bb * m * rt.hidden];
    for layer in 0..rt.layers {
        for t in 0..16 {
            let src = ((layer * pb) * ps + t) * rt.hidden;
            let dst = ((layer * bb) * m + t) * rt.hidden;
            k[dst..dst + rt.hidden]
                .copy_from_slice(&pre.k_cache[src..src + rt.hidden]);
            v[dst..dst + rt.hidden]
                .copy_from_slice(&pre.v_cache[src..src + rt.hidden]);
        }
    }
    let dec = rt.decode(&[1], &[first], &[16], &k, &v).expect("decode");
    let dec_next = rt.argmax_row(&dec.logits, 0);

    // Reference: prefill the 17-token prompt.
    let mut ext = prompt;
    ext.push(first);
    let pre2 = rt.prefill(&[1], &[ext], &[17]).expect("prefill ext");
    let ref_next = rt.argmax_row(&pre2.logits, 0);
    assert_eq!(dec_next, ref_next, "decode vs prefill-extension mismatch");

    // Logits agree numerically, not just argmax.
    let mut max_err = 0.0f32;
    for i in 0..rt.vocab {
        let a = dec.logits[i];
        let b = pre2.logits[i];
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-2, "logits diverge: {max_err}");
}

#[test]
fn different_adapter_slots_change_logits() {
    let Some(rt) = load() else { return };
    let prompt: Vec<i32> = (0..16).map(|i| (i * 5) % 1024).collect();
    let a = rt.prefill(&[0], &[prompt.clone()], &[16]).unwrap();
    let b = rt.prefill(&[5], &[prompt], &[16]).unwrap();
    let diff: f32 = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max);
    assert!(diff > 1e-3, "LoRA slot must affect logits (diff={diff})");
}

#[test]
fn batch_prefill_rows_match_single_requests() {
    // Batch-order invariance across the runtime path.
    let Some(rt) = load() else { return };
    let p1: Vec<i32> = (0..20).map(|i| (i * 11) % 1024).collect();
    let p2: Vec<i32> = (0..28).map(|i| (i * 3 + 1) % 1024).collect();
    let batch = rt
        .prefill(&[1, 4], &[p1.clone(), p2.clone()], &[20, 28])
        .unwrap();
    let solo1 = rt.prefill(&[1], &[p1], &[20]).unwrap();
    let solo2 = rt.prefill(&[4], &[p2], &[28]).unwrap();
    let row = |out: &caraserve::runtime::PrefillOut, r: usize| {
        out.logits[r * rt.vocab..(r + 1) * rt.vocab].to_vec()
    };
    let err1: f32 = row(&batch, 0)
        .iter()
        .zip(row(&solo1, 0).iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    let err2: f32 = row(&batch, 1)
        .iter()
        .zip(row(&solo2, 0).iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(err1 < 1e-3, "row0 err {err1}");
    assert!(err2 < 1e-3, "row1 err {err2}");
}

#[test]
fn prompt_too_long_is_an_error() {
    let Some(rt) = load() else { return };
    let long: Vec<i32> = vec![1; 500];
    assert!(rt.prefill(&[0], &[long], &[500]).is_err());
}
