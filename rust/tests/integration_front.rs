//! One contract, many fronts: identical request-lifecycle assertions
//! driven through the [`ServingFront`] trait against (a) the simulator
//! front — always, (b) the native-runtime engine — always, (c) the real
//! PJRT engine — when artifacts are built, and (d) `ClusterFront`
//! compositions of the above (cluster-of-1 must behave identically to
//! the bare backend; multi-backend clusters add routing). Covers
//! first-token event ordering (with the cluster's non-terminal `Routed`
//! placement event), cancellation (queued and mid-decode), stop tokens,
//! and the exactly-one-terminal-event guarantee.

use std::path::PathBuf;
use std::sync::Arc;

use caraserve::config::GpuSpec;
use caraserve::model::{LlamaConfig, LoraSpec};
use caraserve::runtime::{ModelRuntime, NativeConfig, NativeRuntime};
use caraserve::scheduler::registry::{AdapterMeta, GlobalRegistry};
use caraserve::server::cluster::synthetic;
use caraserve::server::{
    ClusterFront, ColdStartMode, EngineConfig, FinishReason, InferenceServer,
    LifecycleState, RequestEvent, ServeRequest, ServingFront,
};
use caraserve::sim::{GpuModel, ServingMode, SimFront, SimInstance};

/// Adapters every backend has installed before the contract runs.
const ADAPTERS: u64 = 8;

fn sim_front_with_batch(max_batch: usize) -> SimFront {
    let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
    let inst = SimInstance::new(0, model, ServingMode::CaraServe, max_batch, 8, 64);
    let mut front = SimFront::new(inst, 64);
    for id in 0..ADAPTERS {
        front.register_adapter(id, 64);
    }
    front
}

fn sim_front() -> SimFront {
    sim_front_with_batch(32)
}

/// A native-runtime engine with the contract adapters — always runs.
fn native_front() -> InferenceServer {
    let runtime = NativeRuntime::new(NativeConfig::test_tiny());
    let mut server = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: ColdStartMode::CaraServe,
            load_scale: 0.2,
            ..Default::default()
        },
    )
    .expect("native server");
    for id in 0..ADAPTERS {
        server
            .install_adapter(&LoraSpec::standard(id, 4, "tiny"))
            .expect("install");
    }
    server
}

/// The contract registry: every adapter, rank as installed.
fn registry(rank: usize) -> Arc<GlobalRegistry> {
    let reg = GlobalRegistry::new();
    for id in 0..ADAPTERS {
        reg.register(AdapterMeta {
            id,
            rank,
            base_model: "contract".into(),
            weights_path: String::new(),
        });
    }
    Arc::new(reg)
}

fn cluster_over(backends: Vec<Box<dyn ServingFront>>, rank: usize) -> ClusterFront {
    let policy = synthetic::policy("rank-aware", 7).expect("policy");
    ClusterFront::new(backends, policy, registry(rank))
}

fn engine_front() -> Option<InferenceServer> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping engine backend: artifacts not built");
        return None;
    }
    let runtime = ModelRuntime::load(&dir).expect("runtime");
    let mut server = InferenceServer::new(
        runtime,
        EngineConfig {
            cold_start: ColdStartMode::CaraServe,
            load_scale: 0.2,
            ..Default::default()
        },
    )
    .expect("server");
    for id in 0..ADAPTERS {
        server
            .install_adapter(&LoraSpec::standard(id, 8, "tiny"))
            .expect("install");
    }
    Some(server)
}

/// Assert the canonical event shape of a completed request:
/// `Admitted, Routed*, FirstToken, Token*, <terminal>` with exactly one
/// terminal (bare backends emit no `Routed`; routing fronts emit it
/// between `Admitted` and `FirstToken`).
fn assert_stream_shape(events: &[RequestEvent], expect_tokens: usize) {
    assert!(events.len() >= 2, "{events:?}");
    assert_eq!(events[0], RequestEvent::Admitted);
    let mut tokens = 0;
    let mut terminal_at = None;
    for (i, ev) in events[1..].iter().enumerate() {
        match ev {
            RequestEvent::Routed { .. } => {
                assert_eq!(tokens, 0, "Routed after tokens began: {events:?}");
            }
            RequestEvent::FirstToken(_) => {
                assert_eq!(tokens, 0, "duplicate FirstToken: {events:?}");
                tokens += 1;
            }
            RequestEvent::Token(_) => {
                assert!(tokens >= 1, "Token before FirstToken: {events:?}");
                tokens += 1;
            }
            ev if ev.is_terminal() => terminal_at = Some(i),
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert_eq!(
        terminal_at,
        Some(events.len() - 2),
        "terminal event not last: {events:?}"
    );
    assert_eq!(tokens, expect_tokens, "{events:?}");
    assert_eq!(
        events.iter().filter(|e| e.is_terminal()).count(),
        1,
        "exactly one terminal event: {events:?}"
    );
}

/// Events with routing placement stripped — what a client comparing a
/// bare backend against a cluster-of-1 should see identically.
fn without_routing(events: Vec<RequestEvent>) -> Vec<RequestEvent> {
    events
        .into_iter()
        .filter(|e| !matches!(e, RequestEvent::Routed { .. }))
        .collect()
}

/// The shared lifecycle contract, driven purely through `ServingFront`.
fn drive_contract<F: ServingFront>(front: &mut F) {
    // 1. Plain completion: ordered event stream, all tokens delivered.
    let h = front.submit(ServeRequest::new(1, vec![1; 12]).max_new_tokens(5));
    front.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Finished);
    assert_eq!(h.tokens().len(), 5);
    assert_stream_shape(&h.drain_events(), 5);

    // 2. Rejection: unknown adapter → lone terminal Rejected event.
    let h = front.submit(ServeRequest::new(ADAPTERS + 50, vec![1; 8]).max_new_tokens(2));
    assert_eq!(h.state(), LifecycleState::Rejected);
    match h.drain_events().as_slice() {
        [RequestEvent::Rejected(_)] => {}
        other => panic!("expected lone Rejected, got {other:?}"),
    }

    // 3. Cancel while queued: never runs, one Cancelled terminal.
    let victim = front.submit(ServeRequest::new(2, vec![1; 12]).max_new_tokens(30));
    assert!(front.cancel(victim.id()));
    front.run_until_idle().unwrap();
    assert_eq!(victim.state(), LifecycleState::Cancelled);
    assert!(victim.tokens().is_empty());
    let events = without_routing(victim.drain_events());
    assert_eq!(events, vec![RequestEvent::Admitted, RequestEvent::Cancelled]);
    // Dead ids report false.
    assert!(!front.cancel(victim.id()));

    // 4. Cancel mid-decode: stream truncates with a Cancelled terminal.
    let h = front.submit(ServeRequest::new(3, vec![1; 12]).max_new_tokens(30));
    for _ in 0..3 {
        assert!(front.poll().unwrap());
    }
    assert_eq!(h.state(), LifecycleState::Running);
    assert!(front.cancel(h.id()));
    front.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Cancelled);
    let n = h.tokens().len();
    assert!((1..30).contains(&n), "tokens after cancel: {n}");
    let events = h.drain_events();
    assert_eq!(events.last(), Some(&RequestEvent::Cancelled));
    assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);

    // 5. Stop token: learn the stream, then stop on its third token.
    let probe = front.submit(ServeRequest::new(4, vec![2; 12]).max_new_tokens(6));
    front.run_until_idle().unwrap();
    let stream = probe.tokens();
    assert_eq!(stream.len(), 6);
    let stop = stream[2];
    let cut = stream.iter().position(|&t| t == stop).unwrap() + 1;
    let h = front.submit(
        ServeRequest::new(4, vec![2; 12])
            .max_new_tokens(6)
            .stop_token(stop),
    );
    front.run_until_idle().unwrap();
    assert_eq!(h.state(), LifecycleState::Finished);
    assert_eq!(h.tokens(), stream[..cut].to_vec());
    let events = h.drain_events();
    assert_eq!(
        events.last(),
        Some(&RequestEvent::Finished(FinishReason::Stop))
    );
    assert_stream_shape(&events, cut);

    // 6. Stats through the trait: queued before poll, empty after drain.
    let _a = front.submit(
        ServeRequest::new(5, vec![3; 10])
            .max_new_tokens(4)
            .slo(300.0, 60.0),
    );
    let _b = front.submit(ServeRequest::new(6, vec![3; 10]).max_new_tokens(4));
    let stats = front.stats();
    assert_eq!(stats.total_requests(), 2);
    assert_eq!(stats.queued_ranks.len(), 2);
    assert!((stats.tpot_slo.unwrap() - 0.060).abs() < 1e-12);
    assert!(stats.can_serve(5), "installed adapter must be servable");
    assert!(!stats.can_serve(ADAPTERS + 50));
    front.run_until_idle().unwrap();
    let stats = front.stats();
    assert_eq!(stats.total_requests(), 0);
    assert!(stats.tpot_slo.is_none());
}

#[test]
fn lifecycle_contract_holds_on_simulator_front() {
    drive_contract(&mut sim_front());
}

#[test]
fn lifecycle_contract_holds_on_native_engine_front() {
    drive_contract(&mut native_front());
}

#[test]
fn lifecycle_contract_holds_on_engine_front() {
    let Some(mut server) = engine_front() else {
        return;
    };
    drive_contract(&mut server);
}

#[test]
fn lifecycle_contract_holds_on_cluster_of_one_sim() {
    drive_contract(&mut cluster_over(vec![Box::new(sim_front())], 64));
}

#[test]
fn lifecycle_contract_holds_on_cluster_of_native_engines() {
    drive_contract(&mut cluster_over(
        vec![Box::new(native_front()), Box::new(native_front())],
        4,
    ));
}

#[test]
fn cluster_of_one_matches_bare_native_backend() {
    // The same submissions through a bare engine and a cluster-of-1 over
    // an identically configured engine must yield identical token
    // streams and identical terminal events — routing is invisible.
    let reqs = || {
        (0..6u64).map(|i| {
            ServeRequest::new(i % ADAPTERS, vec![(i as i32 % 5) + 1; 10])
                .max_new_tokens(4 + i as usize % 3)
        })
    };
    let mut bare = native_front();
    let bare_handles: Vec<_> = reqs().map(|r| bare.submit(r)).collect();
    bare.run_until_idle().unwrap();

    let mut cluster = cluster_over(vec![Box::new(native_front())], 4);
    let cluster_handles: Vec<_> = reqs().map(|r| cluster.submit(r)).collect();
    cluster.run_until_idle().unwrap();

    for (b, c) in bare_handles.iter().zip(&cluster_handles) {
        assert_eq!(b.state(), LifecycleState::Finished);
        assert_eq!(c.state(), LifecycleState::Finished);
        assert_eq!(b.tokens(), c.tokens(), "cluster-of-1 changed the stream");
        assert_eq!(
            without_routing(b.drain_events()),
            without_routing(c.drain_events()),
            "cluster-of-1 changed the event stream"
        );
    }
}

#[test]
fn priority_orders_admission_on_simulator_front() {
    // A batch-capacity-1 instance serializes admission: the Interactive
    // request submitted *after* a Batch one still runs first.
    use caraserve::server::Priority;
    let mut front = sim_front_with_batch(1);
    let slow = front.submit(
        ServeRequest::new(1, vec![1; 12])
            .max_new_tokens(3)
            .priority(Priority::Batch),
    );
    let fast = front.submit(
        ServeRequest::new(2, vec![1; 12])
            .max_new_tokens(3)
            .priority(Priority::Interactive),
    );
    front.poll().unwrap(); // first prefill admits the queue head only
    assert_eq!(fast.state(), LifecycleState::Running);
    assert_eq!(slow.state(), LifecycleState::Queued);
    front.run_until_idle().unwrap();
    assert_eq!(slow.state(), LifecycleState::Finished);
    assert_eq!(fast.state(), LifecycleState::Finished);
}
