//! Vendored offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this crate provides
//! the subset of `anyhow` the workspace actually uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait on `Result` and `Option`. Error values
//! carry a flattened message chain (no downcasting / backtraces).

use std::fmt;

/// A string-backed error type, API-compatible with `anyhow::Error` for
/// the operations used in this workspace.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// (and therefore `?` on foreign error types) coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    /// Wrap the error/none case with a fixed message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap the error/none case with a lazily built message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad {} of {}", 3, 7);
        assert_eq!(e.to_string(), "bad 3 of 7");
    }

    #[test]
    fn question_mark_converts_foreign_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(check(7).unwrap_err().to_string(), "unlucky");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        assert_eq!(
            r.context("reading manifest").unwrap_err().to_string(),
            "reading manifest: gone"
        );
        let o: Option<usize> = None;
        assert_eq!(o.context("missing key").unwrap_err().to_string(), "missing key");
        assert_eq!(Some(4usize).context("x").unwrap(), 4);
    }
}
