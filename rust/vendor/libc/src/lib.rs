//! Vendored offline stand-in for the slice of the `libc` crate this
//! repository actually uses (the build environment has no registry or
//! network access, so the real crate cannot be fetched).
//!
//! Only the shared-memory data plane ([`mmap`]/[`munmap`], used by
//! `ipc::shm`) and the futex doorbells (the variadic [`syscall`] entry
//! plus its constants, used by `ipc::signal`) are declared. These bind
//! the *real* symbols from the platform C library — this crate is a
//! declaration subset, not a reimplementation — so the semantics are
//! identical to the upstream `libc` crate for the covered surface.
//!
//! Constants are the Linux userspace ABI values (x86_64/aarch64 share
//! them for everything here except the futex syscall number, which is
//! per-architecture). Non-Linux targets only ever reach [`mmap`]/
//! [`munmap`] — `ipc::signal` compiles its futex path under
//! `cfg(target_os = "linux")` — and those two are POSIX-portable.

#![no_std]
#![allow(non_camel_case_types)]

pub use core::ffi::c_void;

pub type c_int = i32;
pub type c_uint = u32;
pub type c_long = i64;
pub type size_t = usize;
pub type off_t = i64;
pub type time_t = i64;

/// `struct timespec` as the kernel expects it on 64-bit Linux.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct timespec {
    pub tv_sec: time_t,
    pub tv_nsec: c_long,
}

pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;
pub const MAP_SHARED: c_int = 0x0001;
pub const MAP_ANONYMOUS: c_int = 0x0020;
/// `mmap`'s error sentinel, `(void *)-1`.
pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

/// `futex(2)` syscall number (per-architecture; 98 is the asm-generic
/// table shared by aarch64, riscv64, and other modern ports).
#[cfg(target_arch = "x86_64")]
pub const SYS_futex: c_long = 202;
#[cfg(not(target_arch = "x86_64"))]
pub const SYS_futex: c_long = 98;

pub const FUTEX_WAIT: c_int = 0;
pub const FUTEX_WAKE: c_int = 1;

extern "C" {
    pub fn mmap(
        addr: *mut c_void,
        len: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, len: size_t) -> c_int;
    pub fn syscall(num: c_long, ...) -> c_long;
}
