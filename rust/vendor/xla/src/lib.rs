//! Offline API stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links the native XLA/PJRT C library, which is not
//! present in this offline build environment. This stub mirrors the API
//! surface `caraserve::runtime` uses so the workspace compiles anywhere;
//! every entry point returns an "unavailable" error at runtime. The
//! serving stack already degrades cleanly: integration tests and
//! examples check for built artifacts before touching PJRT, and the
//! simulator backend (`caraserve::sim::front::SimFront`) never needs it.
//!
//! Swap this path dependency for the real `xla` crate to run the
//! functional PJRT path.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (Display-able, std error).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT unavailable: built with the vendored xla stub (no native XLA \
         runtime); use the real xla crate to execute compiled artifacts"
            .to_string(),
    )
}

/// Host-side tensor literal.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Deserialization of literals from on-disk formats (`.npz` here).
pub trait FromRawBytes: Sized {
    /// Read every named array from an `.npz` archive.
    fn read_npz<P: AsRef<Path>>(path: P, ctx: &()) -> Result<Vec<(String, Self)>, Error>;
}

impl FromRawBytes for Literal {
    fn read_npz<P: AsRef<Path>>(_path: P, _ctx: &()) -> Result<Vec<(String, Literal)>, Error> {
        Err(unavailable())
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy device → host.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute over borrowed input buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// The PJRT client.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    /// Upload a literal to the device.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable())
    }

    /// Upload a typed host slice to the device.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable())
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::read_npz("w.npz", &()).is_err());
    }
}
