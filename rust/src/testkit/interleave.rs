//! Bounded interleaving explorer (a mini-loom): systematic schedule
//! exploration for small concurrent protocol models.
//!
//! A [`Model`] presents N logical "threads", each a fixed script of
//! atomic steps over shared state. The explorer runs every step at the
//! granularity the model chose — one step is one indivisible action, so
//! the model's step boundaries define the memory model being checked —
//! and explores thread interleavings:
//!
//! - [`explore`] — exhaustive DFS over all schedules up to a cap,
//!   discovering enabled/blocked steps as it goes. Because models
//!   need not be `Clone`, branching works by *replay*: a fresh model
//!   from the factory re-executes the schedule prefix. Factories must
//!   therefore be deterministic.
//! - [`explore_random`] — seeded random schedules for state spaces too
//!   large to exhaust (driving real components rather than models).
//! - [`explore_random_indexed`] — the same, with the schedule index
//!   passed to the factory, so each schedule can vary the model itself
//!   deterministically (crash schedules: a different fault site per
//!   schedule, fixed oracles).
//!
//! Oracles: [`Model::invariant`] is checked after every step,
//! [`Model::finally`] once all threads finish. A step may return
//! [`StepOutcome::Blocked`] to model waiting (futex, full queue) —
//! **a blocked step must not mutate state** (that contract is what
//! lets the explorer probe blocked threads for free, and what
//! [`when`] enforces by construction). If every unfinished thread is
//! blocked, the schedule is reported as a deadlock.
//!
//! Used by `rust/tests/interleave_lifecycle.rs` to verify a faithful
//! model of the `ipc` SlotChannel/Doorbell protocol exhaustively
//! (including re-catching the PR 2 shared-length regression in a
//! known-bad variant) and to drive randomized request-lifecycle
//! schedules against the real `SimFront`/`ClusterFront`.

use crate::util::rng::Rng;

/// What a step attempt did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// The step executed; the thread's program counter advances.
    Ran,
    /// The step cannot run in the current state and did not mutate
    /// anything; the thread stays at the same step.
    Blocked,
}

/// A concurrent protocol model: fixed thread scripts over shared state.
pub trait Model {
    /// Number of logical threads.
    fn threads(&self) -> usize;
    /// Number of steps in `thread`'s script.
    fn steps(&self, thread: usize) -> usize;
    /// Attempt step `index` of `thread`. Returning
    /// [`StepOutcome::Blocked`] promises no state was mutated.
    fn step(&mut self, thread: usize, index: usize) -> StepOutcome;
    /// Safety oracle, checked after every executed step.
    fn invariant(&self) -> Result<(), String> {
        Ok(())
    }
    /// End-of-schedule oracle, checked when every thread has finished.
    fn finally(&self) -> Result<(), String> {
        Ok(())
    }
}

/// A failing schedule: the executed thread sequence and the oracle's
/// message (replayable against a fresh model from the same factory).
#[derive(Debug, Clone)]
pub struct Violation {
    /// Thread index of each executed step, in order.
    pub schedule: Vec<usize>,
    /// Oracle error (invariant, finally, or deadlock).
    pub message: String,
}

/// Exploration outcome.
#[derive(Debug, Clone)]
pub struct Report {
    /// Complete schedules executed.
    pub schedules: usize,
    /// True if the full schedule space was covered (exhaustive mode
    /// within the cap; random mode always reports `false`).
    pub exhausted: bool,
    /// First violation found, if any.
    pub violation: Option<Violation>,
}

impl Report {
    /// True when no violation was found.
    pub fn ok(&self) -> bool {
        self.violation.is_none()
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.violation {
            None => write!(
                f,
                "{} schedule(s), no violation{}",
                self.schedules,
                if self.exhausted { " (exhaustive)" } else { "" }
            ),
            Some(v) => write!(
                f,
                "violation after {} schedule(s): {} [schedule {:?}]",
                self.schedules, v.message, v.schedule
            ),
        }
    }
}

fn replay<M: Model>(factory: &impl Fn() -> M, prefix: &[usize]) -> (M, Vec<usize>) {
    let mut m = factory();
    let mut pcs = vec![0usize; m.threads()];
    for &t in prefix {
        match m.step(t, pcs[t]) {
            StepOutcome::Ran => pcs[t] += 1,
            StepOutcome::Blocked => unreachable!(
                "nondeterministic factory: step {t}:{} blocked on replay",
                pcs[t]
            ),
        }
    }
    (m, pcs)
}

/// Exhaustively explore all schedules of `factory`'s model, up to
/// `max_schedules` complete schedules. The factory must build an
/// identical model each call (replay-based branching). Returns on the
/// first violation.
pub fn explore<M: Model>(factory: impl Fn() -> M, max_schedules: usize) -> Report {
    let mut report = Report {
        schedules: 0,
        exhausted: true,
        violation: None,
    };
    let mut prefix = Vec::new();
    dfs(&factory, &mut prefix, &mut report, max_schedules);
    report
}

fn dfs<M: Model>(
    factory: &impl Fn() -> M,
    prefix: &mut Vec<usize>,
    report: &mut Report,
    max_schedules: usize,
) {
    if report.violation.is_some() {
        return;
    }
    if report.schedules >= max_schedules {
        report.exhausted = false;
        return;
    }
    let (m, pcs) = replay(factory, prefix);
    let unfinished: Vec<usize> = (0..m.threads())
        .filter(|&t| pcs[t] < m.steps(t))
        .collect();
    if unfinished.is_empty() {
        report.schedules += 1;
        if let Err(msg) = m.finally() {
            report.violation = Some(Violation {
                schedule: prefix.clone(),
                message: format!("at end of schedule: {msg}"),
            });
        }
        return;
    }
    drop(m);
    let mut any_ran = false;
    for &t in &unfinished {
        if report.violation.is_some() {
            return;
        }
        if report.schedules >= max_schedules {
            report.exhausted = false;
            return;
        }
        let (mut m, pcs) = replay(factory, prefix);
        match m.step(t, pcs[t]) {
            StepOutcome::Blocked => continue,
            StepOutcome::Ran => {
                any_ran = true;
                prefix.push(t);
                if let Err(msg) = m.invariant() {
                    report.violation = Some(Violation {
                        schedule: prefix.clone(),
                        message: msg,
                    });
                    prefix.pop();
                    return;
                }
                drop(m);
                dfs(factory, prefix, report, max_schedules);
                prefix.pop();
            }
        }
    }
    if !any_ran {
        // Every unfinished thread is blocked: no schedule can proceed.
        report.schedules += 1;
        report.violation = Some(Violation {
            schedule: prefix.clone(),
            message: format!("deadlock: threads {unfinished:?} all blocked"),
        });
    }
}

/// Run `schedules` seeded-random schedules. At each point a runnable
/// thread is picked uniformly among the non-blocked ones. The factory
/// may vary the model between schedules (e.g. re-seed a workload) —
/// random mode never replays. Returns on the first violation.
pub fn explore_random<M: Model>(
    factory: impl Fn() -> M,
    schedules: usize,
    seed: u64,
) -> Report {
    let mut rng = Rng::new(seed);
    let mut report = Report {
        schedules: 0,
        exhausted: false,
        violation: None,
    };
    for _ in 0..schedules {
        let mut m = factory();
        let mut pcs = vec![0usize; m.threads()];
        let mut trace = Vec::new();
        loop {
            let mut candidates: Vec<usize> = (0..m.threads())
                .filter(|&t| pcs[t] < m.steps(t))
                .collect();
            if candidates.is_empty() {
                report.schedules += 1;
                if let Err(msg) = m.finally() {
                    report.violation = Some(Violation {
                        schedule: trace,
                        message: format!("at end of schedule: {msg}"),
                    });
                    return report;
                }
                break;
            }
            rng.shuffle(&mut candidates);
            let mut ran = false;
            for &t in &candidates {
                match m.step(t, pcs[t]) {
                    StepOutcome::Blocked => continue,
                    StepOutcome::Ran => {
                        pcs[t] += 1;
                        trace.push(t);
                        if let Err(msg) = m.invariant() {
                            report.schedules += 1;
                            report.violation = Some(Violation {
                                schedule: trace,
                                message: msg,
                            });
                            return report;
                        }
                        ran = true;
                        break;
                    }
                }
            }
            if !ran {
                report.schedules += 1;
                report.violation = Some(Violation {
                    schedule: trace,
                    message: format!("deadlock: threads {candidates:?} all blocked"),
                });
                return report;
            }
        }
    }
    report
}

/// [`explore_random`] with the schedule index passed to the factory, so
/// each schedule can build a *different* model deterministically —
/// the crash-schedule pattern: schedule `i` derives a fault plan from
/// `(seed, i)` and kills a modeled backend at a different step each
/// time, while the oracles stay fixed.
pub fn explore_random_indexed<M: Model>(
    factory: impl Fn(usize) -> M,
    schedules: usize,
    seed: u64,
) -> Report {
    let mut rng = Rng::new(seed);
    let mut report = Report {
        schedules: 0,
        exhausted: false,
        violation: None,
    };
    for i in 0..schedules {
        let mut m = factory(i);
        let mut pcs = vec![0usize; m.threads()];
        let mut trace = Vec::new();
        loop {
            let mut candidates: Vec<usize> = (0..m.threads())
                .filter(|&t| pcs[t] < m.steps(t))
                .collect();
            if candidates.is_empty() {
                report.schedules += 1;
                if let Err(msg) = m.finally() {
                    report.violation = Some(Violation {
                        schedule: trace,
                        message: format!("at end of schedule {i}: {msg}"),
                    });
                    return report;
                }
                break;
            }
            rng.shuffle(&mut candidates);
            let mut ran = false;
            for &t in &candidates {
                match m.step(t, pcs[t]) {
                    StepOutcome::Blocked => continue,
                    StepOutcome::Ran => {
                        pcs[t] += 1;
                        trace.push(t);
                        if let Err(msg) = m.invariant() {
                            report.schedules += 1;
                            report.violation = Some(Violation {
                                schedule: trace,
                                message: format!("schedule {i}: {msg}"),
                            });
                            return report;
                        }
                        ran = true;
                        break;
                    }
                }
            }
            if !ran {
                report.schedules += 1;
                report.violation = Some(Violation {
                    schedule: trace,
                    message: format!("schedule {i} deadlock: threads {candidates:?} all blocked"),
                });
                return report;
            }
        }
    }
    report
}

/// Boxed step closure over shared state `S`.
pub type Step<S> = Box<dyn Fn(&mut S) -> StepOutcome>;

/// An unconditional step: always runs.
pub fn always<S>(f: impl Fn(&mut S) + 'static) -> Step<S> {
    Box::new(move |s| {
        f(s);
        StepOutcome::Ran
    })
}

/// A guarded step: blocks (without mutating — the guard only reads)
/// until `guard` holds, then runs `f`.
pub fn when<S>(guard: impl Fn(&S) -> bool + 'static, f: impl Fn(&mut S) + 'static) -> Step<S> {
    Box::new(move |s| {
        if guard(s) {
            f(s);
            StepOutcome::Ran
        } else {
            StepOutcome::Blocked
        }
    })
}

/// A [`Model`] assembled from closures: shared state plus per-thread
/// step scripts, with optional invariant/finally oracles. The
/// convenient way to write models in tests:
///
/// ```ignore
/// let factory = || {
///     ScriptModel::new(MyState::default())
///         .thread(vec![always(|s| s.x += 1)])
///         .thread(vec![when(|s| s.x > 0, |s| s.y = s.x)])
///         .finally(|s| if s.y == 1 { Ok(()) } else { Err("lost".into()) })
/// };
/// assert!(explore(factory, 10_000).ok());
/// ```
pub struct ScriptModel<S> {
    /// The shared state the step closures mutate.
    pub state: S,
    scripts: Vec<Vec<Step<S>>>,
    invariant: Option<Box<dyn Fn(&S) -> Result<(), String>>>,
    finally_: Option<Box<dyn Fn(&S) -> Result<(), String>>>,
}

impl<S> ScriptModel<S> {
    /// A model over `state` with no threads yet.
    pub fn new(state: S) -> Self {
        ScriptModel {
            state,
            scripts: Vec::new(),
            invariant: None,
            finally_: None,
        }
    }

    /// Append a thread with the given step script.
    pub fn thread(mut self, steps: Vec<Step<S>>) -> Self {
        self.scripts.push(steps);
        self
    }

    /// Set the per-step invariant oracle.
    pub fn invariant(mut self, f: impl Fn(&S) -> Result<(), String> + 'static) -> Self {
        self.invariant = Some(Box::new(f));
        self
    }

    /// Set the end-of-schedule oracle.
    pub fn finally(mut self, f: impl Fn(&S) -> Result<(), String> + 'static) -> Self {
        self.finally_ = Some(Box::new(f));
        self
    }
}

impl<S> Model for ScriptModel<S> {
    fn threads(&self) -> usize {
        self.scripts.len()
    }

    fn steps(&self, thread: usize) -> usize {
        self.scripts[thread].len()
    }

    fn step(&mut self, thread: usize, index: usize) -> StepOutcome {
        (self.scripts[thread][index])(&mut self.state)
    }

    fn invariant(&self) -> Result<(), String> {
        match &self.invariant {
            Some(f) => f(&self.state),
            None => Ok(()),
        }
    }

    fn finally(&self) -> Result<(), String> {
        match &self.finally_ {
            Some(f) => f(&self.state),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct Counter {
        x: i64,
        tmp: [i64; 2],
    }

    /// Two threads doing a non-atomic read-modify-write: the classic
    /// lost update. The explorer must find it.
    fn racy_counter() -> ScriptModel<Counter> {
        ScriptModel::new(Counter::default())
            .thread(vec![
                always(|s: &mut Counter| s.tmp[0] = s.x),
                always(|s: &mut Counter| s.x = s.tmp[0] + 1),
            ])
            .thread(vec![
                always(|s: &mut Counter| s.tmp[1] = s.x),
                always(|s: &mut Counter| s.x = s.tmp[1] + 1),
            ])
            .finally(|s| {
                if s.x == 2 {
                    Ok(())
                } else {
                    Err(format!("lost update: x = {}", s.x))
                }
            })
    }

    #[test]
    fn exhaustive_catches_lost_update() {
        let report = explore(racy_counter, 10_000);
        let v = report.violation.expect("lost update not found");
        assert!(v.message.contains("lost update"));
        // The canonical bad schedule: both reads before both writes.
        assert_eq!(v.schedule.len(), 4);
    }

    #[test]
    fn exhaustive_passes_atomic_counter_and_counts_schedules() {
        // Single-step increments are atomic at model granularity.
        let factory = || {
            ScriptModel::new(Counter::default())
                .thread(vec![always(|s: &mut Counter| s.x += 1)])
                .thread(vec![always(|s: &mut Counter| s.x += 1)])
                .finally(|s| {
                    if s.x == 2 {
                        Ok(())
                    } else {
                        Err(format!("x = {}", s.x))
                    }
                })
        };
        let report = explore(factory, 10_000);
        assert!(report.ok(), "{report}");
        assert!(report.exhausted);
        // Two threads, one step each: exactly 2 interleavings.
        assert_eq!(report.schedules, 2);
    }

    #[test]
    fn schedule_cap_is_respected() {
        let report = explore(racy_counter, 1);
        assert!(report.schedules <= 1);
        assert!(!report.exhausted || report.violation.is_some());
    }

    #[test]
    fn blocked_steps_wait_and_deadlock_is_reported() {
        // Consumer blocks until the producer publishes; never deadlocks
        // because the producer is always runnable.
        let ok = || {
            ScriptModel::new((0i64, 0i64))
                .thread(vec![always(|s: &mut (i64, i64)| s.0 = 7)])
                .thread(vec![when(|s: &(i64, i64)| s.0 != 0, |s| s.1 = s.0)])
                .finally(|s| {
                    if s.1 == 7 {
                        Ok(())
                    } else {
                        Err(format!("consumer read {}", s.1))
                    }
                })
        };
        let report = explore(ok, 10_000);
        assert!(report.ok(), "{report}");
        assert!(report.exhausted);

        // A guard that can never become true must be reported as
        // deadlock, not silently skipped.
        let stuck = || {
            ScriptModel::new(0i64)
                .thread(vec![when(|_: &i64| false, |_| {})])
        };
        let report = explore(stuck, 10_000);
        let v = report.violation.expect("deadlock not reported");
        assert!(v.message.contains("deadlock"), "{}", v.message);
    }

    #[test]
    fn random_mode_catches_lost_update() {
        let report = explore_random(racy_counter, 256, 0xCA7A);
        assert!(report.violation.is_some(), "random missed the race");
    }

    #[test]
    fn indexed_mode_varies_the_model_per_schedule() {
        // Schedule i's model writes i; the finally oracle accepts any
        // value < 8, so all 8 indexed schedules must run (proving the
        // factory saw every index), then index 8 trips the oracle.
        let factory = |i: usize| {
            ScriptModel::new(0usize)
                .thread(vec![always(move |s: &mut usize| *s = i)])
                .finally(|s| {
                    if *s < 8 {
                        Ok(())
                    } else {
                        Err(format!("model saw index {s}"))
                    }
                })
        };
        let report = explore_random_indexed(factory, 8, 1);
        assert!(report.ok(), "{report}");
        assert_eq!(report.schedules, 8);
        let report = explore_random_indexed(factory, 9, 1);
        let v = report.violation.expect("index 8 not reached");
        assert!(v.message.contains("schedule 8"), "{}", v.message);
    }

    #[test]
    fn invariant_checked_after_every_step() {
        // x must never exceed 1 mid-run — violated as soon as the
        // second thread increments.
        let factory = || {
            ScriptModel::new(Counter::default())
                .thread(vec![always(|s: &mut Counter| s.x += 1)])
                .thread(vec![always(|s: &mut Counter| s.x += 1)])
                .invariant(|s| {
                    if s.x <= 1 {
                        Ok(())
                    } else {
                        Err(format!("x hit {}", s.x))
                    }
                })
        };
        let report = explore(factory, 10_000);
        let v = report.violation.expect("invariant breach not found");
        assert_eq!(v.schedule.len(), 2);
    }
}
