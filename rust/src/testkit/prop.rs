//! Property-based testing: generators over a seeded PRNG + greedy
//! shrinking. A property is a `Fn(&T) -> Result<(), String>`; on failure
//! the framework shrinks the input via `Shrink` candidates and panics
//! with the minimal counterexample.

use crate::util::rng::Rng;

/// A value generator: produces a `T` from the PRNG.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    /// Wrap a closure as a generator.
    pub fn new(f: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Self { f: Box::new(f) }
    }

    /// Generate one value.
    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| g(self.sample(rng)))
    }
}

/// Generator for usize in `[lo, hi)`.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(move |rng| rng.range(lo, hi))
}

/// Generator for f64 in `[lo, hi)`.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |rng| rng.uniform(lo, hi))
}

/// Generator for a Vec of `n_lo..n_hi` elements from `elem`.
pub fn vec_of<T: 'static>(elem: Gen<T>, n_lo: usize, n_hi: usize) -> Gen<Vec<T>> {
    Gen::new(move |rng| {
        let n = rng.range(n_lo, n_hi);
        (0..n).map(|_| elem.sample(rng)).collect()
    })
}

/// Generator picking uniformly from a fixed set.
pub fn one_of<T: Clone + 'static>(items: Vec<T>) -> Gen<T> {
    assert!(!items.is_empty());
    Gen::new(move |rng| items[rng.range(0, items.len())].clone())
}

/// Types that can propose smaller candidate values for shrinking.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-"smaller" values, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<u64> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<f64> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink each element.
        out.push(Vec::new());
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 16 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for cand in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = cand;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// PRNG seed (change to explore a different corner of the space).
    pub seed: u64,
    /// Max shrink steps.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 256,
            seed: 0xCA7A_5E7E,
            max_shrink: 2_000,
        }
    }
}

/// Run `prop` against `cases` random values from `gen`; on failure,
/// greedily shrink and panic with the minimal counterexample.
pub fn forall<T: Shrink + std::fmt::Debug + 'static>(
    cfg: &Config,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.sample(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: loop {
                for cand in best.shrink() {
                    steps += 1;
                    if steps > cfg.max_shrink {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}):\n  input: {best:?}\n  error: {best_msg}",
                seed = cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = Config {
            cases: 100,
            ..Default::default()
        };
        forall(&cfg, &usize_in(0, 1000), |&x| {
            if x < 1000 {
                Ok(())
            } else {
                Err("oob".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let cfg = Config::default();
        let gen = usize_in(0, 10_000);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forall(&cfg, &gen, |&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 50"))
                }
            });
        }));
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrinking should land on exactly 50.
        assert!(msg.contains("input: 50"), "{msg}");
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let cfg = Config::default();
        let gen = vec_of(usize_in(0, 100), 0, 20);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forall(&cfg, &gen, |v: &Vec<usize>| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            });
        }));
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Minimal failing vec has exactly 3 elements, all shrunk to 0.
        assert!(msg.contains("input: [0, 0, 0]"), "{msg}");
    }

    #[test]
    fn generators_are_deterministic_given_seed() {
        let gen = vec_of(usize_in(0, 100), 1, 10);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(gen.sample(&mut r1), gen.sample(&mut r2));
    }

    #[test]
    fn one_of_and_map() {
        let gen = one_of(vec![8usize, 16, 32, 64]).map(|r| r * 2);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let v = gen.sample(&mut rng);
            assert!([16, 32, 64, 128].contains(&v));
        }
    }
}
