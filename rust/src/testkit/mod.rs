//! In-repo testing frameworks (the offline vendor set has no
//! `proptest` or `loom`):
//!
//! - [`prop`] — mini property testing: random-input generation with
//!   automatic shrinking on failure. Used by `rust/tests/prop_*.rs`
//!   to check coordinator invariants (routing, batching, KV-cache
//!   accounting, request lifecycle).
//! - [`interleave`] — bounded interleaving explorer (mini-loom):
//!   exhaustive or seeded-random schedule exploration of modeled
//!   concurrent protocols with shadow-state oracles. Used by
//!   `rust/tests/interleave_lifecycle.rs` on the shm SPSC/doorbell
//!   protocol model and the request-lifecycle state machine.
//! - [`faults`] — deterministic fault injection: a seeded
//!   [`faults::FaultPlan`] (panic/error/die/stall/slow at submit, poll
//!   step N, mid-decode, adapter-load sites) executed by the
//!   [`faults::ChaosFront`] decorator around any `ServingFront`
//!   backend. Drives the cluster failover suite
//!   (`rust/tests/integration_failover.rs`) and `caraserve chaos`.

pub mod faults;
pub mod interleave;
pub mod prop;

pub use prop::{forall, Config, Gen};
