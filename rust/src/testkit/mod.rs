//! Mini property-testing framework (the offline vendor set has no
//! `proptest`): random-input generation with automatic shrinking on
//! failure. Used by `rust/tests/prop_*.rs` to check coordinator
//! invariants (routing, batching, KV-cache accounting).

pub mod prop;

pub use prop::{forall, Config, Gen};
