//! Deterministic fault injection for the serving stack.
//!
//! Two pieces:
//!
//! - [`FaultPlan`] — a schedule of faults at named sites, written in a
//!   compact text syntax (`kind@site:n[:arg]`, comma-separated) so CLI
//!   flags, tests, and logs share one representation:
//!
//!   | spec                | effect                                             |
//!   |---------------------|----------------------------------------------------|
//!   | `panic@poll:12`     | panic inside the 12th `poll()` call                |
//!   | `error@poll:12`     | the 12th `poll()` returns `Err` (fires once)       |
//!   | `die@poll:12`       | from the 12th `poll()` on, every call errors       |
//!   | `panic@decode:3`    | panic on the 3rd poll with a request mid-decode    |
//!   | `error@submit:2`    | the 2nd `submit()` is rejected as a backend fault  |
//!   | `panic@submit:2`    | panic inside the 2nd `submit()`                    |
//!   | `error@load:1`      | the 1st install/prewarm call fails                 |
//!   | `stall@poll:5`      | from the 5th poll on, claim progress but make none |
//!   | `stall@poll:5:20`   | …for 20 polls, then recover                        |
//!   | `slow@poll:5:4`     | from the 5th poll, forward only every 4th poll     |
//!
//! - [`ChaosFront`] — a decorator implementing
//!   [`ServingFront`] around any boxed backend (sim or native engine),
//!   executing the plan at the matching call sites. Counters are
//!   per-front and deterministic, so a seeded plan reproduces the same
//!   failure on every run.
//!
//! A backend that panicked or `die`d stays failed: every later call
//! errors (or panics again), which is what drives the cluster's
//! Healthy→Suspect→Down health machine. A plain `error` fault is
//! transient — the next probe succeeds — exercising the
//! Down→Probation→Healthy recovery path.

use anyhow::anyhow;

use crate::model::LoraSpec;
use crate::scheduler::ServerStats;
use crate::server::api::{RejectReason, RequestEvent, RequestHandle, ServeRequest, ServingFront};
use crate::util::rng::Rng;

/// Where in the serving surface a fault fires. Counts are 1-based
/// occurrence indices of the site, not global call numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// The n-th `submit()` call.
    Submit(usize),
    /// The n-th `poll()` call.
    Poll(usize),
    /// The n-th `poll()` at which some request is mid-decode (running,
    /// past prefill).
    Decode(usize),
    /// The n-th adapter-load management call
    /// (`install_adapter` / `prewarm_adapter`).
    Load(usize),
}

/// What happens when a fault's site is reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic at the site; the backend stays dead (later calls panic
    /// too). Exercises catch-unwind containment.
    Panic,
    /// Fail the one call at the site, then behave normally — a
    /// transient fault the health machine should recover from.
    Error,
    /// Fail the call at the site and every call after it — a hard
    /// death without unwinding.
    Die,
    /// From the site on, `poll()` claims progress (`Ok(true)`) while
    /// doing nothing, for `polls` polls (`0` = forever) — a wedged
    /// backend only a stall watchdog can catch.
    Stall {
        /// Wedge duration in polls; `0` wedges forever.
        polls: usize,
    },
    /// From the site on, forward only every `factor`-th `poll()`,
    /// claiming empty progress for the rest — a degraded backend.
    Slow {
        /// Forward one poll in `factor`.
        factor: usize,
    },
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// What fires.
    pub kind: FaultKind,
    /// Where it fires.
    pub site: FaultSite,
}

impl std::fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (kind, arg) = match self.kind {
            FaultKind::Panic => ("panic", None),
            FaultKind::Error => ("error", None),
            FaultKind::Die => ("die", None),
            FaultKind::Stall { polls: 0 } => ("stall", None),
            FaultKind::Stall { polls } => ("stall", Some(polls)),
            FaultKind::Slow { factor } => ("slow", Some(factor)),
        };
        let (site, n) = match self.site {
            FaultSite::Submit(n) => ("submit", n),
            FaultSite::Poll(n) => ("poll", n),
            FaultSite::Decode(n) => ("decode", n),
            FaultSite::Load(n) => ("load", n),
        };
        write!(f, "{kind}@{site}:{n}")?;
        if let Some(a) = arg {
            write!(f, ":{a}")?;
        }
        Ok(())
    }
}

/// A deterministic schedule of faults for one backend.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The scheduled faults (order irrelevant; sites are absolute).
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// The empty plan: a `ChaosFront` with it is a transparent proxy.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// A plan with one fault.
    pub fn one(kind: FaultKind, site: FaultSite) -> FaultPlan {
        FaultPlan {
            faults: vec![FaultSpec { kind, site }],
        }
    }

    /// Parse the comma-separated `kind@site:n[:arg]` syntax (see the
    /// module table). Whitespace around entries is ignored.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for entry in s.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind_s, rest) = entry
                .split_once('@')
                .ok_or_else(|| format!("fault `{entry}`: expected kind@site:n"))?;
            let mut parts = rest.split(':');
            let site_s = parts.next().unwrap_or_default();
            let n: usize = parts
                .next()
                .ok_or_else(|| format!("fault `{entry}`: missing occurrence count"))?
                .parse()
                .map_err(|e| format!("fault `{entry}`: bad count ({e})"))?;
            let arg: Option<usize> = match parts.next() {
                None => None,
                Some(a) => Some(
                    a.parse()
                        .map_err(|e| format!("fault `{entry}`: bad argument ({e})"))?,
                ),
            };
            if parts.next().is_some() {
                return Err(format!("fault `{entry}`: too many fields"));
            }
            let kind = match (kind_s, arg) {
                ("panic", None) => FaultKind::Panic,
                ("error", None) => FaultKind::Error,
                ("die", None) => FaultKind::Die,
                ("stall", arg) => FaultKind::Stall {
                    polls: arg.unwrap_or(0),
                },
                ("slow", Some(factor)) if factor >= 1 => FaultKind::Slow { factor },
                ("slow", _) => {
                    return Err(format!("fault `{entry}`: slow needs a factor ≥ 1"))
                }
                _ => return Err(format!("fault `{entry}`: unknown kind `{kind_s}`")),
            };
            let site = match site_s {
                "submit" => FaultSite::Submit(n),
                "poll" => FaultSite::Poll(n),
                "decode" => FaultSite::Decode(n),
                "load" => FaultSite::Load(n),
                other => return Err(format!("fault `{entry}`: unknown site `{other}`")),
            };
            faults.push(FaultSpec { kind, site });
        }
        Ok(FaultPlan { faults })
    }

    /// A seeded mid-decode kill: panic on the n-th decode poll, with
    /// `n` drawn deterministically from `[lo, hi)` — the canonical
    /// "backend dies while streaming" chaos experiment.
    pub fn seeded_mid_decode_kill(seed: u64, lo: usize, hi: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let n = if hi > lo { rng.range(lo, hi) } else { lo.max(1) };
        FaultPlan::one(FaultKind::Panic, FaultSite::Decode(n.max(1)))
    }

    /// Is the plan empty?
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.faults.is_empty() {
            return f.write_str("(no faults)");
        }
        for (i, spec) in self.faults.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{spec}")?;
        }
        Ok(())
    }
}

/// How a triggered fault manifests at one call site.
enum Fire {
    Panic,
    Error,
}

/// A [`ServingFront`] decorator that executes a [`FaultPlan`] against
/// any boxed backend. Transparent when the plan is empty (or spent):
/// every call forwards to the inner backend.
pub struct ChaosFront {
    inner: Box<dyn ServingFront>,
    plan: FaultPlan,
    polls: usize,
    decode_polls: usize,
    submits: usize,
    loads: usize,
    /// `Some(end)` while wedged: polls `< end` claim empty progress
    /// (`usize::MAX` = wedged forever).
    stalled_until: Option<usize>,
    /// `Some(factor)` once a slow fault triggered.
    slow: Option<usize>,
    /// Set once the backend died (panic or `die`); every later call
    /// re-fails the same way.
    dead: Option<Fire>,
}

impl ChaosFront {
    /// Wrap `inner` with a fault schedule.
    pub fn new(inner: Box<dyn ServingFront>, plan: FaultPlan) -> ChaosFront {
        ChaosFront {
            inner,
            plan,
            polls: 0,
            decode_polls: 0,
            submits: 0,
            loads: 0,
            stalled_until: None,
            slow: None,
            dead: None,
        }
    }

    /// `poll()` calls so far (for asserting fault timing in tests).
    pub fn polls(&self) -> usize {
        self.polls
    }

    /// Has a panic/die fault permanently killed this backend?
    pub fn is_dead(&self) -> bool {
        self.dead.is_some()
    }

    /// Check one site occurrence against the plan; returns how to fail
    /// (if at all) and applies stateful kinds (stall/slow/die).
    fn trigger(&mut self, hit: impl Fn(&FaultSite) -> bool) -> Option<Fire> {
        let mut fire = None;
        for spec in &self.plan.faults {
            if !hit(&spec.site) {
                continue;
            }
            match spec.kind {
                FaultKind::Panic => {
                    self.dead = Some(Fire::Panic);
                    fire = Some(Fire::Panic);
                }
                FaultKind::Error => fire = fire.or(Some(Fire::Error)),
                FaultKind::Die => {
                    self.dead = Some(Fire::Error);
                    fire = fire.or(Some(Fire::Error));
                }
                FaultKind::Stall { polls } => {
                    self.stalled_until = Some(if polls == 0 {
                        usize::MAX
                    } else {
                        self.polls.saturating_add(polls)
                    });
                }
                FaultKind::Slow { factor } => self.slow = Some(factor),
            }
        }
        fire
    }

    /// Fail the current call according to `fire`.
    fn fail<T>(&self, fire: &Fire, site: &str, ok_err: impl FnOnce(String) -> T) -> T {
        match fire {
            Fire::Panic => panic!("chaos: injected panic at {site}"),
            Fire::Error => ok_err(format!("chaos: injected fault at {site}")),
        }
    }
}

impl ServingFront for ChaosFront {
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        self.submits += 1;
        let n = self.submits;
        if let Some(fire) =
            self.trigger(|s| matches!(s, FaultSite::Submit(m) if *m == n))
        {
            return self.fail(&fire, "submit", |msg| {
                let (handle, chan) = RequestHandle::new(u64::MAX - n as u64);
                chan.lock()
                    .unwrap()
                    .push(RequestEvent::Rejected(RejectReason::Other(msg)));
                handle
            });
        }
        if let Some(fire) = &self.dead {
            return self.fail(fire, "submit (dead backend)", |msg| {
                let (handle, chan) = RequestHandle::new(u64::MAX - n as u64);
                chan.lock()
                    .unwrap()
                    .push(RequestEvent::Rejected(RejectReason::Other(msg)));
                handle
            });
        }
        self.inner.submit(req)
    }

    fn poll(&mut self) -> anyhow::Result<bool> {
        self.polls += 1;
        let n = self.polls;
        // Mid-decode means some request is past prefill (running).
        let mid_decode = !self.inner.stats().running_ranks.is_empty();
        if mid_decode {
            self.decode_polls += 1;
        }
        let dn = self.decode_polls;
        let fire = self.trigger(|s| {
            matches!(s, FaultSite::Poll(m) if *m == n)
                || (mid_decode && matches!(s, FaultSite::Decode(m) if *m == dn))
        });
        if let Some(fire) = fire {
            return self.fail(&fire, "poll", |msg| Err(anyhow!(msg)));
        }
        if let Some(fire) = &self.dead {
            return self.fail(fire, "poll (dead backend)", |msg| Err(anyhow!(msg)));
        }
        if let Some(end) = self.stalled_until {
            if self.polls < end {
                // Wedged: claim progress, make none.
                return Ok(true);
            }
            self.stalled_until = None;
        }
        if let Some(factor) = self.slow {
            if self.polls % factor != 0 {
                // Degraded, not wedged: skip the poll, but never fake
                // progress on an idle backend (that would wedge
                // `run_until_idle` forever once the work drains).
                return Ok(self.inner.stats().total_requests() > 0);
            }
        }
        self.inner.poll()
    }

    fn cancel(&mut self, id: u64) -> bool {
        self.inner.cancel(id)
    }

    fn stats(&self) -> ServerStats {
        self.inner.stats()
    }

    fn install_adapter(&mut self, spec: &LoraSpec) -> anyhow::Result<()> {
        self.loads += 1;
        let n = self.loads;
        if let Some(fire) = self.trigger(|s| matches!(s, FaultSite::Load(m) if *m == n)) {
            return self.fail(&fire, "install_adapter", |msg| Err(anyhow!(msg)));
        }
        if let Some(fire) = &self.dead {
            return self.fail(fire, "install_adapter (dead backend)", |msg| {
                Err(anyhow!(msg))
            });
        }
        self.inner.install_adapter(spec)
    }

    fn uninstall_adapter(&mut self, adapter: u64) -> anyhow::Result<()> {
        if let Some(fire) = &self.dead {
            return self.fail(fire, "uninstall_adapter (dead backend)", |msg| {
                Err(anyhow!(msg))
            });
        }
        self.inner.uninstall_adapter(adapter)
    }

    fn prewarm_adapter(&mut self, adapter: u64) -> anyhow::Result<bool> {
        self.loads += 1;
        let n = self.loads;
        if let Some(fire) = self.trigger(|s| matches!(s, FaultSite::Load(m) if *m == n)) {
            return self.fail(&fire, "prewarm_adapter", |msg| Err(anyhow!(msg)));
        }
        if let Some(fire) = &self.dead {
            return self.fail(fire, "prewarm_adapter (dead backend)", |msg| {
                Err(anyhow!(msg))
            });
        }
        self.inner.prewarm_adapter(adapter)
    }

    fn cold_start_stats(&self) -> Option<crate::server::metrics::ColdStartStats> {
        self.inner.cold_start_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::server::api::LifecycleState;
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};

    fn sim() -> Box<dyn ServingFront> {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::Cached, 8, 8, 16);
        let mut f = SimFront::new(inst, 128);
        f.register_adapter(1, 16);
        Box::new(f)
    }

    #[test]
    fn parse_roundtrips_every_kind() {
        let s = "panic@poll:12,error@submit:2,die@poll:7,stall@poll:5:20,slow@poll:3:4,\
                 panic@decode:1,error@load:1,stall@poll:9";
        let plan = FaultPlan::parse(s).unwrap();
        assert_eq!(plan.faults.len(), 8);
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("panic@poll").is_err());
        assert!(FaultPlan::parse("panic@nowhere:1").is_err());
        assert!(FaultPlan::parse("wat@poll:1").is_err());
        assert!(FaultPlan::parse("slow@poll:1").is_err());
    }

    #[test]
    fn empty_plan_is_transparent() {
        let mut chaos = ChaosFront::new(sim(), FaultPlan::none());
        let h = chaos.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(4));
        chaos.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        assert_eq!(h.tokens(), vec![0, 1, 2, 3]);
        assert!(!chaos.is_dead());
    }

    #[test]
    fn error_fault_fires_once_then_recovers() {
        let mut chaos =
            ChaosFront::new(sim(), FaultPlan::parse("error@poll:2").unwrap());
        let h = chaos.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(3));
        assert!(chaos.poll().is_ok());
        assert!(chaos.poll().is_err(), "2nd poll must fail");
        assert!(!chaos.is_dead());
        chaos.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
    }

    #[test]
    fn die_fault_fails_every_later_call() {
        let mut chaos = ChaosFront::new(sim(), FaultPlan::parse("die@poll:1").unwrap());
        let _h = chaos.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(3));
        assert!(chaos.poll().is_err());
        assert!(chaos.poll().is_err());
        assert!(chaos.is_dead());
        assert!(chaos.install_adapter(&LoraSpec::standard(2, 8, "sim")).is_err());
    }

    #[test]
    #[should_panic(expected = "injected panic at poll")]
    fn panic_fault_panics_at_the_scheduled_poll() {
        let mut chaos = ChaosFront::new(sim(), FaultPlan::parse("panic@poll:2").unwrap());
        let _h = chaos.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(3));
        let _ = chaos.poll();
        let _ = chaos.poll(); // boom
    }

    #[test]
    fn decode_site_waits_for_a_running_request() {
        let mut chaos =
            ChaosFront::new(sim(), FaultPlan::parse("die@decode:1").unwrap());
        // No work: plain polls are not decode polls, nothing fires.
        assert!(chaos.poll().is_ok());
        let _h = chaos.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(8));
        // Prefill poll: still nothing running when the poll *starts*.
        assert!(chaos.poll().is_ok());
        // Now the request is running (mid-decode) → the fault fires.
        assert!(chaos.poll().is_err());
    }

    #[test]
    fn stall_claims_progress_without_making_any() {
        let mut chaos =
            ChaosFront::new(sim(), FaultPlan::parse("stall@poll:1:3").unwrap());
        let h = chaos.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(2));
        for _ in 0..3 {
            // Wedged: claims progress, emits nothing.
            assert!(chaos.poll().unwrap());
            assert!(h.tokens().is_empty());
        }
        // Recovered after the window.
        chaos.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
    }

    #[test]
    fn seeded_mid_decode_kill_is_deterministic() {
        let a = FaultPlan::seeded_mid_decode_kill(7, 1, 10);
        let b = FaultPlan::seeded_mid_decode_kill(7, 1, 10);
        assert_eq!(a, b);
        assert!(matches!(
            a.faults[0],
            FaultSpec {
                kind: FaultKind::Panic,
                site: FaultSite::Decode(n)
            } if (1..10).contains(&n)
        ));
    }
}
