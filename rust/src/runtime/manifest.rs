//! `artifacts/manifest.json` parsing.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Metadata for one compiled artifact (one phase × bucket).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// "prefill" or "decode".
    pub phase: String,
    /// Batch-size bucket.
    pub batch: usize,
    /// Prompt-length bucket (prefill) or cache capacity M (decode).
    pub seq: usize,
    /// HLO text file, relative to the artifacts dir.
    pub path: PathBuf,
    /// Input tensor names, in argument order.
    pub inputs: Vec<String>,
    /// Output tensor names, in tuple order.
    pub outputs: Vec<String>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model config as key → value (vocab, hidden, layers, ...).
    pub model: Vec<(String, usize)>,
    /// Number of device adapter slots.
    pub lora_slots: usize,
    /// Padded max rank of the LoRA stacks.
    pub lora_max_rank: usize,
    /// True rank per slot.
    pub slot_ranks: Vec<usize>,
    /// Weights npz file name.
    pub weights: String,
    /// Weight array names in argument order.
    pub weight_names: Vec<String>,
    /// LoRA array names in argument order.
    pub lora_names: Vec<String>,
    pub artifacts: Vec<ArtifactMeta>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let model_obj = j.req("model").map_err(|e| anyhow::anyhow!("{e}"))?;
        let model: Vec<(String, usize)> = model_obj
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("model not an object"))?
            .iter()
            .filter_map(|(k, v)| v.as_usize().map(|n| (k.clone(), n)))
            .collect();
        let lora = j.req("lora").map_err(|e| anyhow::anyhow!("{e}"))?;
        let lora_slots = lora
            .req("slots")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("bad lora.slots"))?;
        let lora_max_rank = lora
            .req("max_rank")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("bad lora.max_rank"))?;
        let slot_ranks: Vec<usize> = lora
            .get("slot_ranks")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default();

        let strings = |key: &str| -> anyhow::Result<Vec<String>> {
            Ok(j.req(key)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{key} not an array"))?
                .iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect())
        };
        let weight_names = strings("weight_names")?;
        let lora_names = strings("lora_names")?;
        let weights = j
            .get("weights")
            .and_then(Json::as_str)
            .unwrap_or("weights.npz")
            .to_string();

        let mut artifacts = Vec::new();
        for item in j
            .req("artifacts")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("artifacts not an array"))?
        {
            let get_str = |k: &str| -> anyhow::Result<String> {
                Ok(item
                    .get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let get_n = |k: &str| -> anyhow::Result<usize> {
                item.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing {k}"))
            };
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                phase: get_str("phase")?,
                batch: get_n("batch")?,
                seq: get_n("seq")?,
                path: PathBuf::from(get_str("path")?),
                inputs: item
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect()
                    })
                    .unwrap_or_default(),
                outputs: item
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect()
                    })
                    .unwrap_or_default(),
            });
        }
        Ok(Manifest {
            model,
            lora_slots,
            lora_max_rank,
            slot_ranks,
            weights,
            weight_names,
            lora_names,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Model config value by key.
    pub fn model_value(&self, key: &str) -> Option<usize> {
        self.model.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }

    /// Prefill buckets, sorted by (batch, seq).
    pub fn prefill_buckets(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.phase == "prefill")
            .map(|a| (a.batch, a.seq))
            .collect();
        v.sort_unstable();
        v
    }

    /// Decode buckets, sorted by batch.
    pub fn decode_buckets(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.phase == "decode")
            .map(|a| (a.batch, a.seq))
            .collect();
        v.sort_unstable();
        v
    }

    /// Smallest prefill bucket that fits (batch, prompt_len); `None` if
    /// nothing fits.
    pub fn pick_prefill_bucket(&self, batch: usize, prompt: usize) -> Option<(usize, usize)> {
        self.prefill_buckets()
            .into_iter()
            .filter(|&(b, s)| b >= batch && s >= prompt)
            .min_by_key(|&(b, s)| (b, s))
    }

    /// Smallest decode bucket with capacity ≥ batch.
    pub fn pick_decode_bucket(&self, batch: usize) -> Option<(usize, usize)> {
        self.decode_buckets()
            .into_iter()
            .filter(|&(b, _)| b >= batch)
            .min_by_key(|&(b, _)| b)
    }

    /// Find the artifact for (phase, batch, seq).
    pub fn artifact(&self, phase: &str, batch: usize, seq: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.phase == phase && a.batch == batch && a.seq == seq)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"vocab": 1024, "hidden": 256, "layers": 4, "heads": 8,
                "kv_heads": 8, "intermediate": 688, "max_seq": 256},
      "lora": {"slots": 8, "max_rank": 8, "slot_ranks": [8,8,4,4,8,2,8,8]},
      "weights": "weights.npz",
      "weight_names": ["embed", "wq"],
      "lora_names": ["a_q", "b_q"],
      "artifacts": [
        {"name": "prefill_b1_s16", "phase": "prefill", "batch": 1, "seq": 16,
         "path": "prefill_b1_s16.hlo.txt",
         "inputs": ["embed", "wq", "a_q", "b_q", "idx", "tokens", "lens"],
         "outputs": ["logits", "k_cache", "v_cache"]},
        {"name": "prefill_b4_s32", "phase": "prefill", "batch": 4, "seq": 32,
         "path": "prefill_b4_s32.hlo.txt", "inputs": [], "outputs": []},
        {"name": "decode_b2_m128", "phase": "decode", "batch": 2, "seq": 128,
         "path": "decode_b2_m128.hlo.txt", "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.model_value("hidden"), Some(256));
        assert_eq!(m.lora_slots, 8);
        assert_eq!(m.slot_ranks, vec![8, 8, 4, 4, 8, 2, 8, 8]);
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.prefill_buckets(), vec![(1, 16), (4, 32)]);
        assert_eq!(m.decode_buckets(), vec![(2, 128)]);
    }

    #[test]
    fn bucket_picking() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.pick_prefill_bucket(1, 10), Some((1, 16)));
        assert_eq!(m.pick_prefill_bucket(1, 17), Some((4, 32)));
        assert_eq!(m.pick_prefill_bucket(2, 20), Some((4, 32)));
        assert_eq!(m.pick_prefill_bucket(5, 20), None);
        assert_eq!(m.pick_decode_bucket(1), Some((2, 128)));
        assert_eq!(m.pick_decode_bucket(3), None);
    }

    #[test]
    fn artifact_lookup() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.artifact("prefill", 1, 16).is_some());
        assert!(m.artifact("decode", 2, 128).is_some());
        assert!(m.artifact("decode", 4, 128).is_none());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration-lite: parse the actual artifacts dir when present.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.model_value("hidden"), Some(256));
            assert!(!m.prefill_buckets().is_empty());
            assert!(!m.decode_buckets().is_empty());
            assert_eq!(m.weight_names.len(), 12);
            assert_eq!(m.lora_names.len(), 6);
        }
    }
}
