//! [`NativeRuntime`]: a pure-Rust model backend with an *open* layer
//! loop.
//!
//! The PJRT path executes AOT-compiled artifacts whose LoRA stacks are
//! baked in — a black box the engine cannot reach into mid-layer. The
//! paper's CPU-assisted prefill (§4) however is exactly a mid-layer
//! intervention: while an adapter streams host→device, the per-layer
//! `xAB` delta is computed on host cores and merged into the Q/K/V
//! projections. This backend provides that seam:
//!
//! - same call contract as the PJRT executor ([`PrefillOut`] /
//!   [`DecodeOut`], bucketed shapes, last-token logits), so
//!   [`crate::server::InferenceServer`] drives either interchangeably;
//! - per-request [`RowLora`] modes: `Base` (no adaptation), `Slot`
//!   (device-resident stack, applied through the rank-grouped
//!   [`crate::kernels::bgmv::sgmv_grouped`] kernel — the GPU decode
//!   path), or `Assist` (delta supplied by an [`ExternalLora`] — the
//!   shared-memory CPU worker pool during a cold start);
//! - [`NativeRuntime::install_slot`]: the moment a modeled host→device
//!   transfer completes, the adapter's weight stack becomes resident and
//!   subsequent iterations may switch from `Assist` to `Slot` (§4.3
//!   handoff). Both paths read the *same* `Arc`-shared weights, so the
//!   switch is invisible in the token stream — the property the
//!   cold-start oracle test pins down.
//!
//! The transformer itself is a small deterministic pre-norm model
//! (token+position embeddings, multi-head causal attention with
//! per-layer LoRA on Q/K/V, ReLU MLP, unit-gain RMSNorm) with synthetic
//! seeded weights: content is not the point, faithful serving dataflow
//! is. Rows are computed independently, so batch composition never
//! changes a request's values (continuous batching invariant).
//!
//! # §Perf — paged KV layout and the threading contract
//!
//! **Paged KV.** Decode never sees a dense `[layers, batch, M, hidden]`
//! cache: [`NativeRuntime::decode`] takes a [`KvView`] and attention
//! iterates each request's cached rows *in place* — for the engine's
//! paged pool that means block-table lookups into fixed-size token
//! pages, zero per-step assembly (the pre-paged path re-materialized
//! the entire KV history of every running request every token).
//! Prefill is symmetric: [`NativeRuntime::prefill`] streams each
//! freshly computed K/V row into a per-request [`KvWrite`] handle, so
//! prompt KV lands in its pages exactly once instead of dense-then-
//! recopy. The S-LoRA-style unified paging (arXiv 2311.03285) this
//! reproduces is what keeps per-token cost flat in context length.
//!
//! **Threading.** Batch rows are independent, so prefill and decode fan
//! rows across a shared persistent [`ThreadPool`] (`NativeConfig::threads`
//! workers, parked between steps); a lone large prefill additionally fans its attention
//! *positions* across the pool. Two invariants make this safe and
//! bitwise-deterministic:
//!
//! 1. every worker writes only its own row's outputs (disjoint `&mut`
//!    chunks behind per-row `Mutex`es) and reads only shared immutable
//!    state, and
//! 2. parallelism never changes the arithmetic — each row/position runs
//!    the identical serial code — so an N-thread run equals the
//!    1-thread run bit for bit (pinned by
//!    `parallel_forward_is_bitwise_deterministic`).
//!
//! `Assist` rows are the one exception to fan-out: [`ExternalLora`]
//! providers front a single-submitter shm worker pool, so those rows
//! all execute on the calling thread — overlapped with the pooled
//! rows via [`ThreadPool::run_overlapping`], not serialized before
//! them (order among rows is irrelevant — they share no state).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::executor::{DecodeOut, PrefillOut};
use super::pool::ThreadPool;
use super::{KvView, KvWrite};
use crate::kernels::bgmv::sgmv_grouped;
use crate::kernels::gemm::gemm;
use crate::kernels::AdapterWeights;
use crate::model::TargetMatrix;
use crate::util::rng::Rng;

/// Provider of externally computed LoRA deltas (the CPU-assisted path).
/// Implemented by [`crate::cpu_lora::CpuLoraEngine`] over the
/// shared-memory worker pool.
///
/// `Sync` so a `RowLora::Assist` row may sit in a batch that is fanned
/// across threads; the runtime still *calls* `delta` from one thread at
/// a time (the shm pool is single-submitter), it just needs to share
/// the reference.
pub trait ExternalLora: Sync {
    /// The `n_tok × hidden` delta `xAB` for `adapter` at `target`, given
    /// the (normalized) layer input `x` (`n_tok × hidden`, row-major).
    fn delta(&self, adapter: u64, target: TargetMatrix, n_tok: usize, x: &[f32])
        -> Vec<f32>;
}

/// How one request's LoRA adaptation is sourced for an iteration.
#[derive(Clone, Copy)]
pub enum RowLora<'a> {
    /// Base model only (no adapter).
    Base,
    /// Device-resident stack in this slot (the `bgmv` GPU path).
    Slot(usize),
    /// Externally computed delta (CPU-assisted cold-start path).
    Assist {
        /// Delta provider (the CPU-LoRA engine).
        lora: &'a dyn ExternalLora,
        /// Adapter to compute against.
        adapter: u64,
    },
}

/// Shapes and capacities of a native runtime.
#[derive(Debug, Clone)]
pub struct NativeConfig {
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub vocab: usize,
    pub intermediate: usize,
    /// Positions the position embedding covers (≥ `cache_m` + 1).
    pub max_seq: usize,
    /// Device adapter slots.
    pub lora_slots: usize,
    /// Largest prompt accepted.
    pub max_prompt: usize,
    /// Largest prefill batch.
    pub max_prefill_batch: usize,
    /// Largest decode batch.
    pub max_decode_batch: usize,
    /// Decode KV capacity M per request.
    pub cache_m: usize,
    /// Weight seed (same seed ⇒ same model).
    pub seed: u64,
    /// Forward-pass worker threads (batch rows fan across these; 0/1 =
    /// serial). N-thread output is bitwise identical to 1-thread (§Perf).
    pub threads: usize,
}

impl NativeConfig {
    /// The serving-scale config mirroring the PJRT tiny model's shapes.
    pub fn tiny() -> NativeConfig {
        NativeConfig {
            hidden: 256,
            layers: 4,
            heads: 8,
            vocab: 1024,
            intermediate: 688,
            max_seq: 256,
            lora_slots: 8,
            max_prompt: 64,
            max_prefill_batch: 4,
            max_decode_batch: 8,
            cache_m: 128,
            seed: 0xCA7A_5E27,
            threads: default_threads(),
        }
    }

    /// A minimal config for fast tests (serial: determinism tests opt
    /// into threads explicitly).
    pub fn test_tiny() -> NativeConfig {
        NativeConfig {
            hidden: 32,
            layers: 2,
            heads: 4,
            vocab: 64,
            intermediate: 48,
            max_seq: 64,
            lora_slots: 4,
            max_prompt: 16,
            max_prefill_batch: 4,
            max_decode_batch: 8,
            cache_m: 48,
            seed: 0xCA7A_5E27,
            threads: 1,
        }
    }

    /// This config with `threads` forward workers.
    pub fn with_threads(mut self, threads: usize) -> NativeConfig {
        self.threads = threads;
        self
    }
}

/// Default forward-pass width: the machine's parallelism, capped so the
/// runtime leaves cores for the CPU-LoRA workers and the caller.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

struct LayerWeights {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    w1: Vec<f32>,
    w2: Vec<f32>,
}

/// The native model backend. See the module docs.
pub struct NativeRuntime {
    pub cfg: NativeConfig,
    embed: Vec<f32>,
    pos_embed: Vec<f32>,
    layer_w: Vec<LayerWeights>,
    lm_head: Vec<f32>,
    /// Device-resident LoRA stacks, one per slot ([`Self::install_slot`]).
    slot_stacks: Vec<Option<Arc<[AdapterWeights; 4]>>>,
    /// Scoped row fan-out shared by prefill and decode (§Perf).
    pool: ThreadPool,
}

fn synth(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

/// Per-row decode outputs handed to whichever pool thread runs the row.
struct DecodeRowTask<'t> {
    /// This row's `vocab`-sized logits chunk (zeroed).
    logits: &'t mut [f32],
    /// The new token's K rows, `[layers, hidden]` row-major.
    k: &'t mut [f32],
    /// The new token's V rows, `[layers, hidden]` row-major.
    v: &'t mut [f32],
}

/// Per-row prefill outputs: logits chunk + the KV page writer.
struct PrefillRowTask<'t> {
    logits: &'t mut [f32],
    writer: &'t mut (dyn KvWrite + 't),
}

/// Reusable buffers for the rank-grouped LoRA kernel, one set per row
/// forward — `delta`/`indices` are cleared and refilled per projection,
/// `t` grows to the largest group's `n_tok·rank` and stays.
#[derive(Default)]
struct LoraScratch {
    indices: Vec<usize>,
    delta: Vec<f32>,
    t: Vec<f32>,
}

impl NativeRuntime {
    /// Build the runtime with seeded synthetic weights.
    pub fn new(cfg: NativeConfig) -> NativeRuntime {
        assert!(cfg.hidden % cfg.heads == 0, "heads must divide hidden");
        assert!(cfg.max_seq > cfg.cache_m, "max_seq must exceed cache_m");
        let h = cfg.hidden;
        let mut rng = Rng::new(cfg.seed);
        let s = 1.0 / (h as f32).sqrt();
        let embed = synth(&mut rng, cfg.vocab * h, 1.0);
        let pos_embed = synth(&mut rng, cfg.max_seq * h, 0.3);
        let layer_w = (0..cfg.layers)
            .map(|_| LayerWeights {
                wq: synth(&mut rng, h * h, s),
                wk: synth(&mut rng, h * h, s),
                wv: synth(&mut rng, h * h, s),
                wo: synth(&mut rng, h * h, s),
                w1: synth(&mut rng, h * cfg.intermediate, s),
                w2: synth(&mut rng, cfg.intermediate * h, s),
            })
            .collect();
        let lm_head = synth(&mut rng, h * cfg.vocab, s);
        let slot_stacks = vec![None; cfg.lora_slots];
        let pool = ThreadPool::new(cfg.threads);
        NativeRuntime {
            cfg,
            embed,
            pos_embed,
            layer_w,
            lm_head,
            slot_stacks,
            pool,
        }
    }

    /// Make `weights` resident in `slot` (or clear it with `None`) — the
    /// native analogue of a completed host→device adapter transfer.
    pub fn install_slot(&mut self, slot: usize, weights: Option<Arc<[AdapterWeights; 4]>>) {
        self.slot_stacks[slot] = weights;
    }

    /// Stack resident in `slot`.
    pub fn slot_stack(&self, slot: usize) -> Option<&Arc<[AdapterWeights; 4]>> {
        self.slot_stacks.get(slot).and_then(|s| s.as_ref())
    }

    fn target_index(t: TargetMatrix) -> usize {
        match t {
            TargetMatrix::Q => 0,
            TargetMatrix::K => 1,
            TargetMatrix::V => 2,
            TargetMatrix::O => 3,
        }
    }

    /// Add the LoRA delta for `target` onto `proj` (`n × hidden`), with
    /// `x` the normalized layer input the projection was computed from.
    /// `ls` is the row's reusable kernel scratch — one set of buffers
    /// serves every (layer, target) of the row's forward, so the
    /// resident decode path allocates nothing per projection (§Perf).
    fn apply_lora(
        &self,
        lora: &RowLora<'_>,
        target: TargetMatrix,
        n: usize,
        x: &[f32],
        proj: &mut [f32],
        ls: &mut LoraScratch,
    ) {
        let h = self.cfg.hidden;
        match lora {
            RowLora::Base => {}
            RowLora::Slot(slot) => {
                if let Some(stack) = self.slot_stacks.get(*slot).and_then(|s| s.as_ref())
                {
                    // The resident path goes through the rank-grouped
                    // kernel: all n rows share this adapter, so the
                    // whole block is ONE lora_apply instead of n
                    // per-token gathers. The delta is materialized into
                    // zeros and then added, mirroring the CPU workers'
                    // accumulation order so the two paths agree bitwise
                    // (§4.3 handoff must not perturb the token stream).
                    let ad = &stack[Self::target_index(target)];
                    ls.indices.clear();
                    ls.indices.resize(n, 0);
                    ls.delta.clear();
                    ls.delta.resize(n * h, 0.0);
                    sgmv_grouped(&[ad], &ls.indices, h, h, x, &mut ls.delta, &mut ls.t);
                    for (p, d) in proj.iter_mut().zip(&ls.delta) {
                        *p += d;
                    }
                }
            }
            RowLora::Assist { lora, adapter } => {
                let delta = lora.delta(*adapter, target, n, x);
                debug_assert_eq!(delta.len(), n * h);
                for (p, d) in proj.iter_mut().zip(&delta) {
                    *p += d;
                }
            }
        }
    }

    /// Unit-gain RMSNorm per token row.
    fn rmsnorm(x: &[f32], h: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(x.len());
        for row in x.chunks_exact(h) {
            let ss: f32 = row.iter().map(|v| v * v).sum();
            let scale = 1.0 / (ss / h as f32 + 1e-5).sqrt();
            out.extend(row.iter().map(|v| v * scale));
        }
    }

    /// Attention output for one query position `i` of one row: softmax
    /// over `history_len` cached rows plus in-flight rows `0..=i`, value-
    /// weighted into `out` (`hidden`-sized, zeroed). Factored out so the
    /// serial and position-parallel paths run literally the same code.
    #[allow(clippy::too_many_arguments)]
    fn attend_position(
        &self,
        i: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        hist_k: &[&[f32]],
        hist_v: &[&[f32]],
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let h = self.cfg.hidden;
        let hd = h / self.cfg.heads;
        let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
        let history_len = hist_k.len();
        for head in 0..self.cfg.heads {
            let off = head * hd;
            let qi = &q[i * h + off..i * h + off + hd];
            scores.clear();
            // Cached history rows.
            for kj in hist_k {
                let s: f32 = qi.iter().zip(&kj[off..off + hd]).map(|(a, b)| a * b).sum();
                scores.push(s * inv_sqrt_hd);
            }
            // In-flight rows (causal).
            for j in 0..=i {
                let kj = &k[j * h + off..j * h + off + hd];
                let s: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                scores.push(s * inv_sqrt_hd);
            }
            // Stable softmax.
            let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                denom += *s;
            }
            let inv = 1.0 / denom;
            // Weighted value sum.
            let out_h = &mut out[off..off + hd];
            for (j, &p) in scores.iter().enumerate() {
                let w = p * inv;
                let vj: &[f32] = if j < history_len {
                    &hist_v[j][off..off + hd]
                } else {
                    let jj = j - history_len;
                    &v[jj * h + off..jj * h + off + hd]
                };
                for (ov, vv) in out_h.iter_mut().zip(vj) {
                    *ov += w * vv;
                }
            }
        }
    }

    /// One request's forward pass over `tokens`, writing per-layer K/V
    /// rows through `store(layer, position, k_row, v_row)`. For decode,
    /// `history(layer, position, want_v)` yields previously cached K/V
    /// rows as borrowed slices (no per-token copies on the decode hot
    /// path); the base position of `tokens[0]` is `start_pos`. When
    /// `inner` carries a pool, attention positions of a large prompt fan
    /// across it (only the row's owning thread passes one — see §Perf).
    /// Returns the final hidden states (`n × hidden`).
    #[allow(clippy::too_many_arguments)]
    fn forward<'h>(
        &self,
        tokens: &[i32],
        start_pos: usize,
        lora: &RowLora<'_>,
        history: &dyn Fn(usize, usize, bool) -> &'h [f32],
        history_len: usize,
        inner: Option<&ThreadPool>,
        mut store: impl FnMut(usize, usize, &[f32], &[f32]),
    ) -> Vec<f32> {
        let h = self.cfg.hidden;
        let n = tokens.len();

        // Token + position embeddings.
        let mut x = vec![0.0f32; n * h];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = (tok.max(0) as usize) % self.cfg.vocab;
            let pos = (start_pos + t) % self.cfg.max_seq;
            let e = &self.embed[tok * h..(tok + 1) * h];
            let p = &self.pos_embed[pos * h..(pos + 1) * h];
            for ((xv, ev), pv) in x[t * h..(t + 1) * h].iter_mut().zip(e).zip(p) {
                *xv = ev + pv;
            }
        }

        let mut hbuf: Vec<f32> = Vec::new();
        let mut ls = LoraScratch::default();
        for (l, lw) in self.layer_w.iter().enumerate() {
            Self::rmsnorm(&x, h, &mut hbuf);

            // Projections + per-layer LoRA deltas on Q/K/V.
            let mut q = vec![0.0f32; n * h];
            let mut k = vec![0.0f32; n * h];
            let mut v = vec![0.0f32; n * h];
            gemm(n, h, h, &hbuf, &lw.wq, &mut q);
            gemm(n, h, h, &hbuf, &lw.wk, &mut k);
            gemm(n, h, h, &hbuf, &lw.wv, &mut v);
            self.apply_lora(lora, TargetMatrix::Q, n, &hbuf, &mut q, &mut ls);
            self.apply_lora(lora, TargetMatrix::K, n, &hbuf, &mut k, &mut ls);
            self.apply_lora(lora, TargetMatrix::V, n, &hbuf, &mut v, &mut ls);

            for t in 0..n {
                store(l, start_pos + t, &k[t * h..(t + 1) * h], &v[t * h..(t + 1) * h]);
            }

            // Borrow this layer's cached history rows once (decode path).
            let hist_k: Vec<&[f32]> =
                (0..history_len).map(|j| history(l, j, false)).collect();
            let hist_v: Vec<&[f32]> =
                (0..history_len).map(|j| history(l, j, true)).collect();

            // Causal multi-head attention. Positions are independent, so
            // a lone large prefill fans them across the pool; the
            // arithmetic per position is identical either way (§Perf).
            let mut attn = vec![0.0f32; n * h];
            match inner.filter(|p| p.threads() > 1 && n >= 16) {
                None => {
                    let mut scores: Vec<f32> = Vec::new();
                    for (i, out) in attn.chunks_mut(h).enumerate() {
                        self.attend_position(
                            i, &q, &k, &v, &hist_k, &hist_v, &mut scores, out,
                        );
                    }
                }
                Some(pool) => {
                    let rows: Vec<Mutex<&mut [f32]>> =
                        attn.chunks_mut(h).map(Mutex::new).collect();
                    pool.run(n, &|i| {
                        let mut out = rows[i].lock().expect("attention row mutex poisoned");
                        let mut scores: Vec<f32> = Vec::new();
                        self.attend_position(
                            i, &q, &k, &v, &hist_k, &hist_v, &mut scores, &mut out,
                        );
                    });
                }
            }

            // Output projection + residual.
            let mut o = vec![0.0f32; n * h];
            gemm(n, h, h, &attn, &lw.wo, &mut o);
            for (xv, ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }

            // ReLU MLP + residual.
            Self::rmsnorm(&x, h, &mut hbuf);
            let inter = self.cfg.intermediate;
            let mut f = vec![0.0f32; n * inter];
            gemm(n, h, inter, &hbuf, &lw.w1, &mut f);
            for fv in f.iter_mut() {
                if *fv < 0.0 {
                    *fv = 0.0;
                }
            }
            let mut m = vec![0.0f32; n * h];
            gemm(n, inter, h, &f, &lw.w2, &mut m);
            for (xv, mv) in x.iter_mut().zip(&m) {
                *xv += mv;
            }
        }
        x
    }

    /// Final-norm + LM head over one hidden-state row, written into the
    /// caller's (zeroed) `vocab`-sized slice — the decode hot path hands
    /// each row its chunk of the step's logits buffer instead of
    /// allocating a vocab-sized `Vec` per row per step (§Perf).
    fn logits_into(&self, x_row: &[f32], out: &mut [f32]) {
        let h = self.cfg.hidden;
        debug_assert_eq!(out.len(), self.cfg.vocab);
        let mut normed = Vec::new();
        Self::rmsnorm(x_row, h, &mut normed);
        gemm(1, h, self.cfg.vocab, &normed, &self.lm_head, out);
    }

    /// Prefill a batch. `rows[b]` selects each request's LoRA source;
    /// `idx` is accepted for PJRT interface parity (slot routing travels
    /// in `rows` here). Each row's K/V rows stream into `writers[b]`
    /// (`write_kv(layer, pos, k, v)` for every prompt position) — for
    /// the engine that is a zero-copy page writer. The returned
    /// [`PrefillOut`] carries `[batch, vocab]` last-token logits; its
    /// dense `k_cache`/`v_cache` are empty.
    pub fn prefill(
        &self,
        idx: &[i32],
        tokens: &[Vec<i32>],
        lens: &[i32],
        rows: &[RowLora<'_>],
        writers: &mut [&mut dyn KvWrite],
    ) -> Result<PrefillOut> {
        let batch = tokens.len();
        anyhow::ensure!(batch > 0, "empty prefill batch");
        anyhow::ensure!(
            batch <= self.cfg.max_prefill_batch,
            "prefill batch {batch} exceeds {}",
            self.cfg.max_prefill_batch
        );
        anyhow::ensure!(idx.len() == batch && lens.len() == batch && rows.len() == batch);
        anyhow::ensure!(
            writers.len() == batch,
            "writer count {} != batch {batch}",
            writers.len()
        );
        let max_len = tokens.iter().map(Vec::len).max().unwrap_or(1).max(1);
        anyhow::ensure!(
            max_len <= self.cfg.max_prompt,
            "prompt {max_len} exceeds bucket {}",
            self.cfg.max_prompt
        );
        for (b, toks) in tokens.iter().enumerate() {
            anyhow::ensure!(!toks.is_empty(), "empty prompt in row {b}");
        }
        let (bb, bs) = (batch, max_len);
        let h = self.cfg.hidden;

        let mut logits = vec![0.0f32; bb * self.cfg.vocab];
        {
            let tasks: Vec<Mutex<PrefillRowTask<'_>>> = logits
                .chunks_mut(self.cfg.vocab)
                .zip(writers.iter_mut())
                .map(|(lg, w)| {
                    Mutex::new(PrefillRowTask {
                        logits: lg,
                        writer: &mut **w,
                    })
                })
                .collect();
            let run_row = |b: usize, inner: Option<&ThreadPool>| {
                let mut guard = tasks[b].lock().expect("row task mutex poisoned");
                let task = &mut *guard;
                let writer = &mut *task.writer;
                let len = (lens[b].max(1) as usize).min(tokens[b].len());
                let no_history = |_: usize, _: usize, _: bool| -> &'static [f32] { &[] };
                let x = self.forward(
                    &tokens[b][..len],
                    0,
                    &rows[b],
                    &no_history,
                    0,
                    inner,
                    |l, pos, krow, vrow| writer.write_kv(l, pos, krow, vrow),
                );
                self.logits_into(&x[(len - 1) * h..len * h], task.logits);
            };
            // Assist rows stay on the calling thread (single-submitter
            // shm pool) but overlap with the plain rows fanning across
            // the pool. A lone row with the pool otherwise idle fans its
            // attention positions instead.
            let mut plain: Vec<usize> = Vec::with_capacity(batch);
            let mut assist: Vec<usize> = Vec::new();
            for b in 0..batch {
                if matches!(rows[b], RowLora::Assist { .. }) {
                    assist.push(b);
                } else {
                    plain.push(b);
                }
            }
            if assist.is_empty() && plain.len() == 1 {
                run_row(plain[0], Some(&self.pool));
            } else {
                let assist_inner = if plain.is_empty() {
                    Some(&self.pool)
                } else {
                    None
                };
                self.pool.run_overlapping(
                    plain.len(),
                    &|i| run_row(plain[i], None),
                    || {
                        for &b in &assist {
                            run_row(b, assist_inner);
                        }
                    },
                );
            }
        }
        Ok(PrefillOut {
            logits,
            k_cache: Vec::new(),
            v_cache: Vec::new(),
            bucket: (bb, bs),
        })
    }

    /// One decode step over the paged cache: `kv` yields each request's
    /// cached K/V rows in place (`pos[b]` rows per request — the
    /// engine's block tables over the page pool), attention iterates
    /// them with zero assembly, and batch rows fan across the shared
    /// pool. Output contract is unchanged: `[batch, vocab]` logits plus
    /// the new token's `[layers, batch, hidden]` K/V rows for the caller
    /// to append.
    pub fn decode(
        &self,
        idx: &[i32],
        tokens: &[i32],
        pos: &[i32],
        kv: &dyn KvView,
        rows: &[RowLora<'_>],
    ) -> Result<DecodeOut> {
        let batch = tokens.len();
        anyhow::ensure!(batch > 0, "empty decode batch");
        anyhow::ensure!(
            batch <= self.cfg.max_decode_batch,
            "decode batch {batch} exceeds {}",
            self.cfg.max_decode_batch
        );
        anyhow::ensure!(idx.len() == batch && pos.len() == batch && rows.len() == batch);
        let (bb, m) = (batch, self.cfg.cache_m);
        let h = self.cfg.hidden;
        let layers = self.cfg.layers;
        for (b, &p) in pos.iter().enumerate() {
            let ctx = p.max(0) as usize;
            anyhow::ensure!(ctx <= m, "row {b}: pos {ctx} exceeds cache capacity {m}");
        }

        let mut logits = vec![0.0f32; bb * self.cfg.vocab];
        // Per-row contiguous [batch][layers][hidden] buffers so rows can
        // be written in parallel; transposed to the [layers, batch,
        // hidden] output contract after the join.
        let mut k_rows = vec![0.0f32; bb * layers * h];
        let mut v_rows = vec![0.0f32; bb * layers * h];
        {
            let tasks: Vec<Mutex<DecodeRowTask<'_>>> = logits
                .chunks_mut(self.cfg.vocab)
                .zip(k_rows.chunks_mut(layers * h))
                .zip(v_rows.chunks_mut(layers * h))
                .map(|((lg, kr), vr)| {
                    Mutex::new(DecodeRowTask {
                        logits: lg,
                        k: kr,
                        v: vr,
                    })
                })
                .collect();
            let run_row = |b: usize| {
                let mut guard = tasks[b].lock().expect("row task mutex poisoned");
                let task = &mut *guard;
                let (kr, vr) = (&mut *task.k, &mut *task.v);
                let ctx = pos[b].max(0) as usize;
                let history =
                    |l: usize, j: usize, want_v: bool| kv.kv_row(b, l, j, want_v);
                let x = self.forward(
                    &tokens[b..b + 1],
                    ctx,
                    &rows[b],
                    &history,
                    ctx,
                    None,
                    |l, _pos, krow, vrow| {
                        kr[l * h..(l + 1) * h].copy_from_slice(krow);
                        vr[l * h..(l + 1) * h].copy_from_slice(vrow);
                    },
                );
                self.logits_into(&x[..h], task.logits);
            };
            // Assist rows on the calling thread, overlapped with the
            // pooled resident/base rows (see prefill).
            let mut plain: Vec<usize> = Vec::with_capacity(batch);
            let mut assist: Vec<usize> = Vec::new();
            for b in 0..batch {
                if matches!(rows[b], RowLora::Assist { .. }) {
                    assist.push(b);
                } else {
                    plain.push(b);
                }
            }
            self.pool.run_overlapping(
                plain.len(),
                &|i| run_row(plain[i]),
                || {
                    for &b in &assist {
                        run_row(b);
                    }
                },
            );
        }

        // Transpose to the executor's [layers, batch, hidden] order.
        let mut k_new = vec![0.0f32; layers * bb * h];
        let mut v_new = vec![0.0f32; layers * bb * h];
        for b in 0..bb {
            for l in 0..layers {
                let src = (b * layers + l) * h;
                let dst = (l * bb + b) * h;
                k_new[dst..dst + h].copy_from_slice(&k_rows[src..src + h]);
                v_new[dst..dst + h].copy_from_slice(&v_rows[src..src + h]);
            }
        }
        Ok(DecodeOut {
            logits,
            k_new,
            v_new,
            bucket: (bb, m),
        })
    }

    /// Greedy argmax over one logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> i32 {
        let v = self.cfg.vocab;
        let slice = &logits[row * v..(row + 1) * v];
        let mut best = 0usize;
        for (i, &x) in slice.iter().enumerate() {
            if x > slice[best] {
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::super::{DenseKv, DenseKvBuffer, Runtime};
    use super::*;
    use crate::kernels::gemm::lora_apply;

    fn stack(seed: u64, hidden: usize, rank: usize) -> Arc<[AdapterWeights; 4]> {
        let mk = |t: u64| AdapterWeights::synthetic(seed * 31 + t, hidden, hidden, rank);
        Arc::new([mk(0), mk(1), mk(2), mk(3)])
    }

    /// Direct (in-process) delta provider — the arithmetic the CPU
    /// workers perform, minus the shm hop.
    struct Direct(Arc<[AdapterWeights; 4]>);

    impl ExternalLora for Direct {
        fn delta(
            &self,
            _adapter: u64,
            target: TargetMatrix,
            n_tok: usize,
            x: &[f32],
        ) -> Vec<f32> {
            let ad = &self.0[NativeRuntime::target_index(target)];
            let mut y = vec![0.0f32; n_tok * ad.h2];
            let mut scratch = vec![0.0f32; n_tok * ad.rank];
            lora_apply(
                n_tok, ad.h1, ad.h2, ad.rank, x, &ad.a, &ad.b, &mut y, &mut scratch,
            );
            y
        }
    }

    fn runtime() -> NativeRuntime {
        NativeRuntime::new(NativeConfig::test_tiny())
    }

    /// Prefill into a fresh dense buffer (the test-side stand-in for the
    /// engine's page writers); returns (out, buffer).
    fn dense_prefill(
        rt: &NativeRuntime,
        idx: &[i32],
        toks: &[Vec<i32>],
        lens: &[i32],
        rows: &[RowLora<'_>],
    ) -> (PrefillOut, DenseKvBuffer) {
        let bs = toks.iter().map(Vec::len).max().unwrap_or(1).max(1);
        let mut buf = DenseKvBuffer::new(rt.cfg.layers, toks.len(), bs, rt.cfg.hidden);
        let out = {
            let mut row_writers = buf.row_writers();
            let mut writers: Vec<&mut dyn KvWrite> = row_writers
                .iter_mut()
                .map(|w| w as &mut dyn KvWrite)
                .collect();
            rt.prefill(idx, toks, lens, rows, &mut writers).unwrap()
        };
        (out, buf)
    }

    #[test]
    fn deterministic_given_seed() {
        let a = runtime();
        let b = runtime();
        let toks = vec![vec![1, 5, 9, 2]];
        let (o1, kv1) = dense_prefill(&a, &[0], &toks, &[4], &[RowLora::Base]);
        let (o2, kv2) = dense_prefill(&b, &[0], &toks, &[4], &[RowLora::Base]);
        assert_eq!(o1.logits, o2.logits);
        assert_eq!(kv1.to_lbsh().0, kv2.to_lbsh().0);
    }

    #[test]
    fn shapes_match_pjrt_contract() {
        let rt = runtime();
        let cfg = rt.cfg.clone();
        let toks = vec![vec![1, 2, 3], vec![4, 5, 6, 7, 8]];
        let rows = [RowLora::Base, RowLora::Base];
        let (out, kv) = dense_prefill(&rt, &[0, 1], &toks, &[3, 5], &rows);
        assert_eq!(out.bucket, (2, 5));
        assert_eq!(out.logits.len(), 2 * cfg.vocab);
        // KV travels through the writers now; the dense fields are empty.
        assert!(out.k_cache.is_empty() && out.v_cache.is_empty());
        let (k_dense, _) = kv.to_lbsh();
        assert_eq!(k_dense.len(), cfg.layers * 2 * 5 * cfg.hidden);
        // Positions beyond each row's length were never written.
        let h = cfg.hidden;
        let at = 4 * h; // layer 0, row 0, pos 4 (row 0 has len 3)
        assert!(k_dense[at..at + h].iter().all(|&v| v == 0.0));

        let m = cfg.cache_m;
        let zeros = vec![0.0f32; cfg.layers * 2 * m * h];
        let view = DenseKv::new(&zeros, &zeros, cfg.layers, 2, m, h);
        let dec = rt.decode(&[0, 1], &[1, 2], &[3, 5], &view, &rows).unwrap();
        assert_eq!(dec.bucket, (2, m));
        assert_eq!(dec.k_new.len(), cfg.layers * 2 * h);
    }

    #[test]
    fn rows_are_independent_of_batch_composition() {
        let rt = runtime();
        let probe = vec![3, 1, 4, 1, 5];
        let (solo, _) = dense_prefill(&rt, &[0], &[probe.clone()], &[5], &[RowLora::Base]);
        let (batched, _) = dense_prefill(
            &rt,
            &[0, 0],
            &[vec![9, 9, 9, 9, 9, 9, 9], probe.clone()],
            &[7, 5],
            &[RowLora::Base, RowLora::Base],
        );
        let v = rt.cfg.vocab;
        assert_eq!(solo.logits[..v], batched.logits[v..2 * v]);
    }

    #[test]
    fn resident_slot_equals_external_delta() {
        // The §4.3 handoff invariant: resident (rank-grouped sgmv) and
        // CPU-assisted (external delta) paths produce the same outputs
        // given the same adapter weights.
        let mut rt = runtime();
        let st = stack(7, rt.cfg.hidden, 4);
        rt.install_slot(2, Some(st.clone()));
        let direct = Direct(st);
        let toks = vec![vec![10, 20, 30, 40]];

        let (resident, kv_r) = dense_prefill(&rt, &[2], &toks, &[4], &[RowLora::Slot(2)]);
        let (assisted, kv_a) = dense_prefill(
            &rt,
            &[2],
            &toks,
            &[4],
            &[RowLora::Assist {
                lora: &direct,
                adapter: 99,
            }],
        );
        for (a, b) in resident.logits.iter().zip(&assisted.logits) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for (a, b) in kv_r.to_lbsh().0.iter().zip(&kv_a.to_lbsh().0) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lora_changes_outputs_vs_base() {
        let mut rt = runtime();
        rt.install_slot(1, Some(stack(3, rt.cfg.hidden, 4)));
        let toks = vec![vec![2, 4, 6]];
        let (base, _) = dense_prefill(&rt, &[1], &toks, &[3], &[RowLora::Base]);
        let (adapted, _) = dense_prefill(&rt, &[1], &toks, &[3], &[RowLora::Slot(1)]);
        assert_ne!(base.logits, adapted.logits);
        // Empty slot behaves as base.
        let (empty, _) = dense_prefill(&rt, &[3], &toks, &[3], &[RowLora::Slot(3)]);
        assert_eq!(base.logits, empty.logits);
    }

    #[test]
    fn decode_continues_from_prefill_cache() {
        let rt = runtime();
        let cfg = rt.cfg.clone();
        let prompt = vec![1, 2, 3, 4];
        let (out, kv) =
            dense_prefill(&rt, &[0], &[prompt.clone()], &[4], &[RowLora::Base]);
        let first = rt.argmax_row(&out.logits, 0);

        // Decode straight over the prefill buffer: DenseKvBuffer is a
        // KvView, so no assembly step exists anymore.
        let dec = rt
            .decode(&[0], &[first], &[4], &kv, &[RowLora::Base])
            .unwrap();
        // Sanity: it produces a valid next token and fresh KV rows.
        let next = rt.argmax_row(&dec.logits, 0);
        assert!((0..cfg.vocab as i32).contains(&next));
        assert!(dec.k_new.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn parallel_forward_is_bitwise_deterministic() {
        // N-thread prefill and decode must equal the 1-thread run bit
        // for bit — the threading contract of §Perf.
        let serial = NativeRuntime::new(NativeConfig::test_tiny());
        let threaded = NativeRuntime::new(NativeConfig::test_tiny().with_threads(4));
        assert_eq!(threaded.pool.threads(), 4);

        let toks: Vec<Vec<i32>> = (0..4)
            .map(|r| (0..(6 + r)).map(|i| (i * 13 + r * 7) % 64).collect())
            .collect();
        let lens: Vec<i32> = toks.iter().map(|t| t.len() as i32).collect();
        let rows = vec![RowLora::Base; 4];
        let idx = [0i32, 1, 2, 3];
        let (o_s, kv_s) = dense_prefill(&serial, &idx, &toks, &lens, &rows);
        let (o_t, kv_t) = dense_prefill(&threaded, &idx, &toks, &lens, &rows);
        assert_eq!(o_s.logits, o_t.logits, "prefill logits diverged");
        assert_eq!(kv_s.to_lbsh(), kv_t.to_lbsh(), "prefill KV diverged");

        // A long single-row prefill exercises the position fan-out.
        let long: Vec<i32> = (0..16).map(|i| i * 5 % 64).collect();
        let (l_s, lkv_s) =
            dense_prefill(&serial, &[0], &[long.clone()], &[16], &[RowLora::Base]);
        let (l_t, lkv_t) = dense_prefill(&threaded, &[0], &[long], &[16], &[RowLora::Base]);
        assert_eq!(l_s.logits, l_t.logits, "position fan-out diverged");
        assert_eq!(lkv_s.to_lbsh(), lkv_t.to_lbsh());

        // Decode over the batch: same view, both widths.
        let pos: Vec<i32> = lens.clone();
        let next: Vec<i32> = (0..4).map(|b| serial.argmax_row(&o_s.logits, b)).collect();
        let d_s = serial
            .decode(&idx, &next, &pos, &kv_s, &rows)
            .unwrap();
        let d_t = threaded
            .decode(&idx, &next, &pos, &kv_t, &rows)
            .unwrap();
        assert_eq!(d_s.logits, d_t.logits, "decode logits diverged");
        assert_eq!(d_s.k_new, d_t.k_new);
        assert_eq!(d_s.v_new, d_t.v_new);
    }

    #[test]
    fn dense_facade_rejects_wrong_kv_len() {
        // The pre-paged contract returned a typed Err for mis-sized
        // dense caches; the facade's dense arm must keep doing so (not
        // panic in DenseKv::new).
        let rt = Runtime::Native(runtime());
        let err = rt.decode_dense(&[0], &[1], &[1], &[0.0; 8], &[0.0; 8], &[RowLora::Base]);
        assert!(err.is_err(), "wrong KV length must be a recoverable error");
    }

    #[test]
    fn shape_violations_are_errors() {
        let rt = runtime();
        // Over-bucket prompt.
        let long = vec![vec![1; rt.cfg.max_prompt + 1]];
        let mut buf = DenseKvBuffer::new(
            rt.cfg.layers,
            1,
            rt.cfg.max_prompt + 1,
            rt.cfg.hidden,
        );
        {
            let mut row_writers = buf.row_writers();
            let mut writers: Vec<&mut dyn KvWrite> = row_writers
                .iter_mut()
                .map(|w| w as &mut dyn KvWrite)
                .collect();
            assert!(rt
                .prefill(
                    &[0],
                    &long,
                    &[rt.cfg.max_prompt as i32 + 1],
                    &[RowLora::Base],
                    &mut writers
                )
                .is_err());
            // Writer-count mismatch.
            let toks = vec![vec![1, 2], vec![3, 4]];
            assert!(rt
                .prefill(
                    &[0, 1],
                    &toks,
                    &[2, 2],
                    &[RowLora::Base, RowLora::Base],
                    &mut writers
                )
                .is_err());
        }
        // Over decode batch.
        let nb = rt.cfg.max_decode_batch + 1;
        let zeros = vec![0.0f32; rt.cfg.layers * nb * rt.cfg.cache_m * rt.cfg.hidden];
        let view = DenseKv::new(&zeros, &zeros, rt.cfg.layers, nb, rt.cfg.cache_m, rt.cfg.hidden);
        let rows = vec![RowLora::Base; nb];
        assert!(rt
            .decode(&vec![0; nb], &vec![1; nb], &vec![1; nb], &view, &rows)
            .is_err());
        // Context beyond capacity.
        let m1 = vec![0.0f32; rt.cfg.layers * rt.cfg.cache_m * rt.cfg.hidden];
        let v1 = DenseKv::new(&m1, &m1, rt.cfg.layers, 1, rt.cfg.cache_m, rt.cfg.hidden);
        assert!(rt
            .decode(
                &[0],
                &[1],
                &[rt.cfg.cache_m as i32 + 1],
                &v1,
                &[RowLora::Base]
            )
            .is_err());
    }
}
