//! [`NativeRuntime`]: a pure-Rust model backend with an *open* layer
//! loop.
//!
//! The PJRT path executes AOT-compiled artifacts whose LoRA stacks are
//! baked in — a black box the engine cannot reach into mid-layer. The
//! paper's CPU-assisted prefill (§4) however is exactly a mid-layer
//! intervention: while an adapter streams host→device, the per-layer
//! `xAB` delta is computed on host cores and merged into the Q/K/V
//! projections. This backend provides that seam:
//!
//! - same call contract as the PJRT executor ([`PrefillOut`] /
//!   [`DecodeOut`], bucketed shapes, last-token logits), so
//!   [`crate::server::InferenceServer`] drives either interchangeably;
//! - per-request [`RowLora`] modes: `Base` (no adaptation), `Slot`
//!   (device-resident stack, applied through the batched-gather
//!   [`crate::kernels::bgmv`] kernel — the GPU decode path), or
//!   `Assist` (delta supplied by an [`ExternalLora`] — the shared-memory
//!   CPU worker pool during a cold start);
//! - [`NativeRuntime::install_slot`]: the moment a modeled host→device
//!   transfer completes, the adapter's weight stack becomes resident and
//!   subsequent iterations may switch from `Assist` to `Slot` (§4.3
//!   handoff). Both paths read the *same* `Arc`-shared weights, so the
//!   switch is invisible in the token stream — the property the
//!   cold-start oracle test pins down.
//!
//! The transformer itself is a small deterministic pre-norm model
//! (token+position embeddings, multi-head causal attention with
//! per-layer LoRA on Q/K/V, ReLU MLP, unit-gain RMSNorm) with synthetic
//! seeded weights: content is not the point, faithful serving dataflow
//! is. Rows are computed independently, so batch composition never
//! changes a request's values (continuous batching invariant).

use std::sync::Arc;

use anyhow::Result;

use super::executor::{DecodeOut, PrefillOut};
use crate::kernels::bgmv::mbgmv_ref;
use crate::kernels::gemm::gemm;
use crate::kernels::AdapterWeights;
use crate::model::TargetMatrix;
use crate::util::rng::Rng;

/// Provider of externally computed LoRA deltas (the CPU-assisted path).
/// Implemented by [`crate::cpu_lora::CpuLoraEngine`] over the
/// shared-memory worker pool.
pub trait ExternalLora {
    /// The `n_tok × hidden` delta `xAB` for `adapter` at `target`, given
    /// the (normalized) layer input `x` (`n_tok × hidden`, row-major).
    fn delta(&self, adapter: u64, target: TargetMatrix, n_tok: usize, x: &[f32])
        -> Vec<f32>;
}

/// How one request's LoRA adaptation is sourced for an iteration.
#[derive(Clone, Copy)]
pub enum RowLora<'a> {
    /// Base model only (no adapter).
    Base,
    /// Device-resident stack in this slot (the `bgmv` GPU path).
    Slot(usize),
    /// Externally computed delta (CPU-assisted cold-start path).
    Assist {
        /// Delta provider (the CPU-LoRA engine).
        lora: &'a dyn ExternalLora,
        /// Adapter to compute against.
        adapter: u64,
    },
}

/// Shapes and capacities of a native runtime.
#[derive(Debug, Clone)]
pub struct NativeConfig {
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub vocab: usize,
    pub intermediate: usize,
    /// Positions the position embedding covers (≥ `cache_m` + 1).
    pub max_seq: usize,
    /// Device adapter slots.
    pub lora_slots: usize,
    /// Largest prompt accepted.
    pub max_prompt: usize,
    /// Largest prefill batch.
    pub max_prefill_batch: usize,
    /// Largest decode batch.
    pub max_decode_batch: usize,
    /// Decode KV capacity M per request.
    pub cache_m: usize,
    /// Weight seed (same seed ⇒ same model).
    pub seed: u64,
}

impl NativeConfig {
    /// The serving-scale config mirroring the PJRT tiny model's shapes.
    pub fn tiny() -> NativeConfig {
        NativeConfig {
            hidden: 256,
            layers: 4,
            heads: 8,
            vocab: 1024,
            intermediate: 688,
            max_seq: 256,
            lora_slots: 8,
            max_prompt: 64,
            max_prefill_batch: 4,
            max_decode_batch: 8,
            cache_m: 128,
            seed: 0xCA7A_5E27,
        }
    }

    /// A minimal config for fast tests.
    pub fn test_tiny() -> NativeConfig {
        NativeConfig {
            hidden: 32,
            layers: 2,
            heads: 4,
            vocab: 64,
            intermediate: 48,
            max_seq: 64,
            lora_slots: 4,
            max_prompt: 16,
            max_prefill_batch: 4,
            max_decode_batch: 8,
            cache_m: 48,
            seed: 0xCA7A_5E27,
        }
    }
}

struct LayerWeights {
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    w1: Vec<f32>,
    w2: Vec<f32>,
}

/// The native model backend. See the module docs.
pub struct NativeRuntime {
    pub cfg: NativeConfig,
    embed: Vec<f32>,
    pos_embed: Vec<f32>,
    layer_w: Vec<LayerWeights>,
    lm_head: Vec<f32>,
    /// Device-resident LoRA stacks, one per slot ([`Self::install_slot`]).
    slot_stacks: Vec<Option<Arc<[AdapterWeights; 4]>>>,
}

fn synth(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

impl NativeRuntime {
    /// Build the runtime with seeded synthetic weights.
    pub fn new(cfg: NativeConfig) -> NativeRuntime {
        assert!(cfg.hidden % cfg.heads == 0, "heads must divide hidden");
        assert!(cfg.max_seq > cfg.cache_m, "max_seq must exceed cache_m");
        let h = cfg.hidden;
        let mut rng = Rng::new(cfg.seed);
        let s = 1.0 / (h as f32).sqrt();
        let embed = synth(&mut rng, cfg.vocab * h, 1.0);
        let pos_embed = synth(&mut rng, cfg.max_seq * h, 0.3);
        let layer_w = (0..cfg.layers)
            .map(|_| LayerWeights {
                wq: synth(&mut rng, h * h, s),
                wk: synth(&mut rng, h * h, s),
                wv: synth(&mut rng, h * h, s),
                wo: synth(&mut rng, h * h, s),
                w1: synth(&mut rng, h * cfg.intermediate, s),
                w2: synth(&mut rng, cfg.intermediate * h, s),
            })
            .collect();
        let lm_head = synth(&mut rng, h * cfg.vocab, s);
        let slot_stacks = vec![None; cfg.lora_slots];
        NativeRuntime {
            cfg,
            embed,
            pos_embed,
            layer_w,
            lm_head,
            slot_stacks,
        }
    }

    /// Make `weights` resident in `slot` (or clear it with `None`) — the
    /// native analogue of a completed host→device adapter transfer.
    pub fn install_slot(&mut self, slot: usize, weights: Option<Arc<[AdapterWeights; 4]>>) {
        self.slot_stacks[slot] = weights;
    }

    /// Stack resident in `slot`.
    pub fn slot_stack(&self, slot: usize) -> Option<&Arc<[AdapterWeights; 4]>> {
        self.slot_stacks.get(slot).and_then(|s| s.as_ref())
    }

    fn target_index(t: TargetMatrix) -> usize {
        match t {
            TargetMatrix::Q => 0,
            TargetMatrix::K => 1,
            TargetMatrix::V => 2,
            TargetMatrix::O => 3,
        }
    }

    /// Add the LoRA delta for `target` onto `proj` (`n × hidden`), with
    /// `x` the normalized layer input the projection was computed from.
    fn apply_lora(
        &self,
        lora: &RowLora<'_>,
        target: TargetMatrix,
        n: usize,
        x: &[f32],
        proj: &mut [f32],
    ) {
        let h = self.cfg.hidden;
        match lora {
            RowLora::Base => {}
            RowLora::Slot(slot) => {
                if let Some(stack) = self.slot_stacks.get(*slot).and_then(|s| s.as_ref())
                {
                    // The resident path goes through the batched-gather
                    // kernel (the CPU twin of the GPU BGMV decode path).
                    // The delta is materialized into zeros and then added,
                    // mirroring the CPU workers' accumulation order so
                    // the two paths agree bitwise (§4.3 handoff must not
                    // perturb the token stream).
                    let ad = &stack[Self::target_index(target)];
                    let indices = vec![0usize; n];
                    let mut delta = vec![0.0f32; n * h];
                    mbgmv_ref(&[ad], &indices, h, h, x, &mut delta);
                    for (p, d) in proj.iter_mut().zip(&delta) {
                        *p += d;
                    }
                }
            }
            RowLora::Assist { lora, adapter } => {
                let delta = lora.delta(*adapter, target, n, x);
                debug_assert_eq!(delta.len(), n * h);
                for (p, d) in proj.iter_mut().zip(&delta) {
                    *p += d;
                }
            }
        }
    }

    /// Unit-gain RMSNorm per token row.
    fn rmsnorm(x: &[f32], h: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(x.len());
        for row in x.chunks_exact(h) {
            let ss: f32 = row.iter().map(|v| v * v).sum();
            let scale = 1.0 / (ss / h as f32 + 1e-5).sqrt();
            out.extend(row.iter().map(|v| v * scale));
        }
    }

    /// One request's forward pass over `tokens`, writing per-layer K/V
    /// rows through `store(layer, position, k_row, v_row)`. For decode,
    /// `history(layer, position, want_v)` yields previously cached K/V
    /// rows as borrowed slices (no per-token copies on the decode hot
    /// path); the base position of `tokens[0]` is `start_pos`. Returns
    /// the final hidden states (`n × hidden`).
    fn forward<'h>(
        &self,
        tokens: &[i32],
        start_pos: usize,
        lora: &RowLora<'_>,
        history: &dyn Fn(usize, usize, bool) -> &'h [f32],
        history_len: usize,
        mut store: impl FnMut(usize, usize, &[f32], &[f32]),
    ) -> Vec<f32> {
        let h = self.cfg.hidden;
        let hd = h / self.cfg.heads;
        let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
        let n = tokens.len();

        // Token + position embeddings.
        let mut x = vec![0.0f32; n * h];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = (tok.max(0) as usize) % self.cfg.vocab;
            let pos = (start_pos + t) % self.cfg.max_seq;
            let e = &self.embed[tok * h..(tok + 1) * h];
            let p = &self.pos_embed[pos * h..(pos + 1) * h];
            for ((xv, ev), pv) in x[t * h..(t + 1) * h].iter_mut().zip(e).zip(p) {
                *xv = ev + pv;
            }
        }

        let mut hbuf: Vec<f32> = Vec::new();
        for (l, lw) in self.layer_w.iter().enumerate() {
            Self::rmsnorm(&x, h, &mut hbuf);

            // Projections + per-layer LoRA deltas on Q/K/V.
            let mut q = vec![0.0f32; n * h];
            let mut k = vec![0.0f32; n * h];
            let mut v = vec![0.0f32; n * h];
            gemm(n, h, h, &hbuf, &lw.wq, &mut q);
            gemm(n, h, h, &hbuf, &lw.wk, &mut k);
            gemm(n, h, h, &hbuf, &lw.wv, &mut v);
            self.apply_lora(lora, TargetMatrix::Q, n, &hbuf, &mut q);
            self.apply_lora(lora, TargetMatrix::K, n, &hbuf, &mut k);
            self.apply_lora(lora, TargetMatrix::V, n, &hbuf, &mut v);

            for t in 0..n {
                store(l, start_pos + t, &k[t * h..(t + 1) * h], &v[t * h..(t + 1) * h]);
            }

            // Borrow this layer's cached history rows once (decode path).
            let hist_k: Vec<&[f32]> =
                (0..history_len).map(|j| history(l, j, false)).collect();
            let hist_v: Vec<&[f32]> =
                (0..history_len).map(|j| history(l, j, true)).collect();

            // Causal multi-head attention: position `start_pos + i`
            // attends to `history_len` cached rows plus the in-flight
            // rows 0..=i.
            let mut attn = vec![0.0f32; n * h];
            let mut scores: Vec<f32> = Vec::new();
            for i in 0..n {
                for head in 0..self.cfg.heads {
                    let off = head * hd;
                    let qi = &q[i * h + off..i * h + off + hd];
                    scores.clear();
                    // Cached history rows.
                    for kj in &hist_k {
                        let s: f32 =
                            qi.iter().zip(&kj[off..off + hd]).map(|(a, b)| a * b).sum();
                        scores.push(s * inv_sqrt_hd);
                    }
                    // In-flight rows (causal).
                    for j in 0..=i {
                        let kj = &k[j * h + off..j * h + off + hd];
                        let s: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                        scores.push(s * inv_sqrt_hd);
                    }
                    // Stable softmax.
                    let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - max).exp();
                        denom += *s;
                    }
                    let inv = 1.0 / denom;
                    // Weighted value sum.
                    let out = &mut attn[i * h + off..i * h + off + hd];
                    for (j, &p) in scores.iter().enumerate() {
                        let w = p * inv;
                        let vj: &[f32] = if j < history_len {
                            &hist_v[j][off..off + hd]
                        } else {
                            let jj = j - history_len;
                            &v[jj * h + off..jj * h + off + hd]
                        };
                        for (ov, vv) in out.iter_mut().zip(vj) {
                            *ov += w * vv;
                        }
                    }
                }
            }

            // Output projection + residual.
            let mut o = vec![0.0f32; n * h];
            gemm(n, h, h, &attn, &lw.wo, &mut o);
            for (xv, ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }

            // ReLU MLP + residual.
            Self::rmsnorm(&x, h, &mut hbuf);
            let inter = self.cfg.intermediate;
            let mut f = vec![0.0f32; n * inter];
            gemm(n, h, inter, &hbuf, &lw.w1, &mut f);
            for fv in f.iter_mut() {
                if *fv < 0.0 {
                    *fv = 0.0;
                }
            }
            let mut m = vec![0.0f32; n * h];
            gemm(n, inter, h, &f, &lw.w2, &mut m);
            for (xv, mv) in x.iter_mut().zip(&m) {
                *xv += mv;
            }
        }
        x
    }

    /// Final-norm + LM head over one hidden-state row.
    fn logits_of(&self, x_row: &[f32]) -> Vec<f32> {
        let h = self.cfg.hidden;
        let mut normed = Vec::new();
        Self::rmsnorm(x_row, h, &mut normed);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        gemm(1, h, self.cfg.vocab, &normed, &self.lm_head, &mut logits);
        logits
    }

    /// Prefill a batch. `rows[b]` selects each request's LoRA source;
    /// `idx` is accepted for PJRT interface parity (slot routing travels
    /// in `rows` here). Output shapes match the PJRT executor: logits
    /// `[batch, vocab]`, K/V caches `[layers, batch, seq, hidden]` with
    /// positions beyond each request's length zeroed.
    pub fn prefill(
        &self,
        idx: &[i32],
        tokens: &[Vec<i32>],
        lens: &[i32],
        rows: &[RowLora<'_>],
    ) -> Result<PrefillOut> {
        let batch = tokens.len();
        anyhow::ensure!(batch > 0, "empty prefill batch");
        anyhow::ensure!(
            batch <= self.cfg.max_prefill_batch,
            "prefill batch {batch} exceeds {}",
            self.cfg.max_prefill_batch
        );
        anyhow::ensure!(idx.len() == batch && lens.len() == batch && rows.len() == batch);
        let max_len = tokens.iter().map(Vec::len).max().unwrap_or(1).max(1);
        anyhow::ensure!(
            max_len <= self.cfg.max_prompt,
            "prompt {max_len} exceeds bucket {}",
            self.cfg.max_prompt
        );
        let (bb, bs) = (batch, max_len);
        let h = self.cfg.hidden;
        let layers = self.cfg.layers;

        let mut logits = vec![0.0f32; bb * self.cfg.vocab];
        let mut k_cache = vec![0.0f32; layers * bb * bs * h];
        let mut v_cache = vec![0.0f32; layers * bb * bs * h];

        for (b, toks) in tokens.iter().enumerate() {
            let len = (lens[b].max(1) as usize).min(toks.len());
            anyhow::ensure!(len > 0, "empty prompt in row {b}");
            // Never invoked: prefill passes history_len = 0.
            let no_history = |_: usize, _: usize, _: bool| -> &'static [f32] { &[] };
            let (kc, vc) = (&mut k_cache, &mut v_cache);
            let x = self.forward(
                &toks[..len],
                0,
                &rows[b],
                &no_history,
                0,
                |l, pos, krow, vrow| {
                    let at = ((l * bb + b) * bs + pos) * h;
                    kc[at..at + h].copy_from_slice(krow);
                    vc[at..at + h].copy_from_slice(vrow);
                },
            );
            let row_logits = self.logits_of(&x[(len - 1) * h..len * h]);
            logits[b * self.cfg.vocab..(b + 1) * self.cfg.vocab]
                .copy_from_slice(&row_logits);
        }
        Ok(PrefillOut {
            logits,
            k_cache,
            v_cache,
            bucket: (bb, bs),
        })
    }

    /// One decode step. `k_cache`/`v_cache` are `[layers, batch, M,
    /// hidden]` (caller-assembled, zero-padded); `pos[b]` is each
    /// request's current context length.
    pub fn decode(
        &self,
        idx: &[i32],
        tokens: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        rows: &[RowLora<'_>],
    ) -> Result<DecodeOut> {
        let batch = tokens.len();
        anyhow::ensure!(batch > 0, "empty decode batch");
        anyhow::ensure!(
            batch <= self.cfg.max_decode_batch,
            "decode batch {batch} exceeds {}",
            self.cfg.max_decode_batch
        );
        anyhow::ensure!(idx.len() == batch && pos.len() == batch && rows.len() == batch);
        let (bb, m) = (batch, self.cfg.cache_m);
        let h = self.cfg.hidden;
        let layers = self.cfg.layers;
        let expect = layers * bb * m * h;
        anyhow::ensure!(
            k_cache.len() == expect && v_cache.len() == expect,
            "KV cache len {} != {expect}",
            k_cache.len()
        );

        let mut logits = vec![0.0f32; bb * self.cfg.vocab];
        let mut k_new = vec![0.0f32; layers * bb * h];
        let mut v_new = vec![0.0f32; layers * bb * h];

        for b in 0..batch {
            let ctx = pos[b].max(0) as usize;
            anyhow::ensure!(ctx <= m, "pos {ctx} exceeds cache capacity {m}");
            let history = move |l: usize, j: usize, want_v: bool| {
                let at = ((l * bb + b) * m + j) * h;
                let src: &[f32] = if want_v { v_cache } else { k_cache };
                &src[at..at + h]
            };
            let (kn, vn) = (&mut k_new, &mut v_new);
            let x = self.forward(
                &tokens[b..b + 1],
                ctx,
                &rows[b],
                &history,
                ctx,
                |l, _pos, krow, vrow| {
                    let at = (l * bb + b) * h;
                    kn[at..at + h].copy_from_slice(krow);
                    vn[at..at + h].copy_from_slice(vrow);
                },
            );
            let row_logits = self.logits_of(&x[..h]);
            logits[b * self.cfg.vocab..(b + 1) * self.cfg.vocab]
                .copy_from_slice(&row_logits);
        }
        Ok(DecodeOut {
            logits,
            k_new,
            v_new,
            bucket: (bb, m),
        })
    }

    /// Greedy argmax over one logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> i32 {
        let v = self.cfg.vocab;
        let slice = &logits[row * v..(row + 1) * v];
        let mut best = 0usize;
        for (i, &x) in slice.iter().enumerate() {
            if x > slice[best] {
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::lora_apply;

    fn stack(seed: u64, hidden: usize, rank: usize) -> Arc<[AdapterWeights; 4]> {
        let mk = |t: u64| AdapterWeights::synthetic(seed * 31 + t, hidden, hidden, rank);
        Arc::new([mk(0), mk(1), mk(2), mk(3)])
    }

    /// Direct (in-process) delta provider — the arithmetic the CPU
    /// workers perform, minus the shm hop.
    struct Direct(Arc<[AdapterWeights; 4]>);

    impl ExternalLora for Direct {
        fn delta(
            &self,
            _adapter: u64,
            target: TargetMatrix,
            n_tok: usize,
            x: &[f32],
        ) -> Vec<f32> {
            let ad = &self.0[NativeRuntime::target_index(target)];
            let mut y = vec![0.0f32; n_tok * ad.h2];
            let mut scratch = vec![0.0f32; n_tok * ad.rank];
            lora_apply(
                n_tok, ad.h1, ad.h2, ad.rank, x, &ad.a, &ad.b, &mut y, &mut scratch,
            );
            y
        }
    }

    fn runtime() -> NativeRuntime {
        NativeRuntime::new(NativeConfig::test_tiny())
    }

    #[test]
    fn deterministic_given_seed() {
        let a = runtime();
        let b = runtime();
        let toks = vec![vec![1, 5, 9, 2]];
        let o1 = a.prefill(&[0], &toks, &[4], &[RowLora::Base]).unwrap();
        let o2 = b.prefill(&[0], &toks, &[4], &[RowLora::Base]).unwrap();
        assert_eq!(o1.logits, o2.logits);
        assert_eq!(o1.k_cache, o2.k_cache);
    }

    #[test]
    fn shapes_match_pjrt_contract() {
        let rt = runtime();
        let cfg = &rt.cfg;
        let toks = vec![vec![1, 2, 3], vec![4, 5, 6, 7, 8]];
        let rows = [RowLora::Base, RowLora::Base];
        let out = rt.prefill(&[0, 1], &toks, &[3, 5], &rows).unwrap();
        assert_eq!(out.bucket, (2, 5));
        assert_eq!(out.logits.len(), 2 * cfg.vocab);
        assert_eq!(out.k_cache.len(), cfg.layers * 2 * 5 * cfg.hidden);
        // Padding beyond each row's length is zeroed.
        let h = cfg.hidden;
        let at = 4 * h; // layer 0, row 0, pos 4 (row 0 has len 3)
        assert!(out.k_cache[at..at + h].iter().all(|&v| v == 0.0));

        let m = cfg.cache_m;
        let kv = vec![0.0f32; cfg.layers * 2 * m * h];
        let dec = rt
            .decode(&[0, 1], &[1, 2], &[3, 5], &kv, &kv, &rows)
            .unwrap();
        assert_eq!(dec.bucket, (2, m));
        assert_eq!(dec.k_new.len(), cfg.layers * 2 * h);
    }

    #[test]
    fn rows_are_independent_of_batch_composition() {
        let rt = runtime();
        let probe = vec![3, 1, 4, 1, 5];
        let solo = rt
            .prefill(&[0], &[probe.clone()], &[5], &[RowLora::Base])
            .unwrap();
        let batched = rt
            .prefill(
                &[0, 0],
                &[vec![9, 9, 9, 9, 9, 9, 9], probe.clone()],
                &[7, 5],
                &[RowLora::Base, RowLora::Base],
            )
            .unwrap();
        let v = rt.cfg.vocab;
        assert_eq!(solo.logits[..v], batched.logits[v..2 * v]);
    }

    #[test]
    fn resident_slot_equals_external_delta() {
        // The §4.3 handoff invariant: resident (bgmv) and CPU-assisted
        // (external delta) paths produce the same outputs given the same
        // adapter weights.
        let mut rt = runtime();
        let st = stack(7, rt.cfg.hidden, 4);
        rt.install_slot(2, Some(st.clone()));
        let direct = Direct(st);
        let toks = vec![vec![10, 20, 30, 40]];

        let resident = rt.prefill(&[2], &toks, &[4], &[RowLora::Slot(2)]).unwrap();
        let assisted = rt
            .prefill(
                &[2],
                &toks,
                &[4],
                &[RowLora::Assist {
                    lora: &direct,
                    adapter: 99,
                }],
            )
            .unwrap();
        for (a, b) in resident.logits.iter().zip(&assisted.logits) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        for (a, b) in resident.k_cache.iter().zip(&assisted.k_cache) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lora_changes_outputs_vs_base() {
        let mut rt = runtime();
        rt.install_slot(1, Some(stack(3, rt.cfg.hidden, 4)));
        let toks = vec![vec![2, 4, 6]];
        let base = rt.prefill(&[1], &toks, &[3], &[RowLora::Base]).unwrap();
        let adapted = rt.prefill(&[1], &toks, &[3], &[RowLora::Slot(1)]).unwrap();
        assert_ne!(base.logits, adapted.logits);
        // Empty slot behaves as base.
        let empty = rt.prefill(&[3], &toks, &[3], &[RowLora::Slot(3)]).unwrap();
        assert_eq!(base.logits, empty.logits);
    }

    #[test]
    fn decode_continues_from_prefill_cache() {
        let rt = runtime();
        let cfg = &rt.cfg;
        let (h, m) = (cfg.hidden, cfg.cache_m);
        let prompt = vec![1, 2, 3, 4];
        let out = rt
            .prefill(&[0], &[prompt.clone()], &[4], &[RowLora::Base])
            .unwrap();
        let first = rt.argmax_row(&out.logits, 0);

        // Assemble a decode cache from the prefill output.
        let (bb, bs) = out.bucket;
        let mut k = vec![0.0f32; cfg.layers * m * h];
        let mut v = vec![0.0f32; cfg.layers * m * h];
        for l in 0..cfg.layers {
            for t in 0..4 {
                let src = ((l * bb) * bs + t) * h;
                let dst = (l * m + t) * h;
                k[dst..dst + h].copy_from_slice(&out.k_cache[src..src + h]);
                v[dst..dst + h].copy_from_slice(&out.v_cache[src..src + h]);
            }
        }
        let dec = rt
            .decode(&[0], &[first], &[4], &k, &v, &[RowLora::Base])
            .unwrap();
        // Sanity: it produces a valid next token and fresh KV rows.
        let next = rt.argmax_row(&dec.logits, 0);
        assert!((0..cfg.vocab as i32).contains(&next));
        assert!(dec.k_new.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn shape_violations_are_errors() {
        let rt = runtime();
        // Over-bucket prompt.
        let long = vec![vec![1; rt.cfg.max_prompt + 1]];
        assert!(rt
            .prefill(&[0], &long, &[rt.cfg.max_prompt as i32 + 1], &[RowLora::Base])
            .is_err());
        // Wrong KV length.
        assert!(rt
            .decode(&[0], &[1], &[1], &[0.0; 8], &[0.0; 8], &[RowLora::Base])
            .is_err());
        // Over decode batch.
        let nb = rt.cfg.max_decode_batch + 1;
        let kv = vec![0.0f32; rt.cfg.layers * nb * rt.cfg.cache_m * rt.cfg.hidden];
        let rows = vec![RowLora::Base; nb];
        assert!(rt
            .decode(
                &vec![0; nb],
                &vec![1; nb],
                &vec![1; nb],
                &kv,
                &kv,
                &rows
            )
            .is_err());
    }
}
