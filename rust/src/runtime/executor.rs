//! [`ModelRuntime`]: compile-once / execute-many PJRT wrapper.
//!
//! Adapted from `/opt/xla-example/load_hlo`: HLO **text** → proto →
//! `XlaComputation` → `client.compile`. The weight + LoRA arrays from
//! `weights.npz` are uploaded to device buffers **once** at startup and
//! reused by every call (`execute_b`), so the per-iteration host→device
//! traffic is only the small dynamic inputs (tokens, positions, KV) —
//! the same buffer-residency discipline a real serving stack uses.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::Manifest;

/// Prefill call result.
///
/// The executor fills `k_cache`/`v_cache` densely (its artifacts only
/// produce dense tensors); the [`super::Runtime`] facade scatters them
/// into the caller's paged [`super::KvWrite`] handles and returns them
/// empty — the native backend never materializes them at all.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// [batch, vocab] last-token logits (row-major, bucket batch rows).
    pub logits: Vec<f32>,
    /// [layers, batch, seq, hidden] KV rows for the prompt positions
    /// (dense backends only; empty on the facade's writer path).
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    /// Bucket used: (batch, seq).
    pub bucket: (usize, usize),
}

/// Decode call result.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    /// [batch, vocab] next-token logits.
    pub logits: Vec<f32>,
    /// [layers, batch, hidden] the new token's K rows.
    pub k_new: Vec<f32>,
    /// [layers, batch, hidden] the new token's V rows.
    pub v_new: Vec<f32>,
    /// Bucket used: (batch, cache capacity M).
    pub bucket: (usize, usize),
}

/// The compiled model runtime.
pub struct ModelRuntime {
    client: PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<(String, usize, usize), PjRtLoadedExecutable>,
    /// Device-resident weight+LoRA buffers, in manifest argument order.
    weight_buffers: Vec<PjRtBuffer>,
    /// Model dims cached from the manifest.
    pub hidden: usize,
    pub layers: usize,
    pub vocab: usize,
}

impl ModelRuntime {
    /// Load everything from an artifacts directory: parse the manifest,
    /// compile every artifact, upload the weights.
    pub fn load(dir: &Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        Self::load_with_manifest(manifest)
    }

    /// Load from a pre-parsed manifest (tests use a subset manifest).
    pub fn load_with_manifest(manifest: Manifest) -> Result<ModelRuntime> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;

        // Upload weights once.
        let npz = manifest.dir.join(&manifest.weights);
        let arrays = Literal::read_npz(&npz, &())
            .map_err(|e| anyhow!("read {npz:?}: {e}"))?;
        let by_name: HashMap<String, Literal> = arrays.into_iter().collect();
        let mut weight_buffers = Vec::new();
        for name in manifest.weight_names.iter().chain(&manifest.lora_names) {
            let lit = by_name
                .get(name)
                .ok_or_else(|| anyhow!("weights.npz missing array {name}"))?;
            let buf = client
                .buffer_from_host_literal(None, lit)
                .map_err(|e| anyhow!("upload {name}: {e}"))?;
            weight_buffers.push(buf);
        }

        // Compile all artifacts.
        let mut executables = HashMap::new();
        for art in &manifest.artifacts {
            let path = manifest.dir.join(&art.path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", art.name))?;
            executables.insert((art.phase.clone(), art.batch, art.seq), exe);
        }

        let hidden = manifest
            .model_value("hidden")
            .context("manifest missing hidden")?;
        let layers = manifest
            .model_value("layers")
            .context("manifest missing layers")?;
        let vocab = manifest
            .model_value("vocab")
            .context("manifest missing vocab")?;
        Ok(ModelRuntime {
            client,
            manifest,
            executables,
            weight_buffers,
            hidden,
            layers,
            vocab,
        })
    }

    fn i32_buffer(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("h2d i32: {e}"))
    }

    fn f32_buffer(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("h2d f32: {e}"))
    }

    fn run(
        &self,
        phase: &str,
        bucket: (usize, usize),
        dynamic: Vec<PjRtBuffer>,
    ) -> Result<Vec<Literal>> {
        let exe = self
            .executables
            .get(&(phase.to_string(), bucket.0, bucket.1))
            .ok_or_else(|| anyhow!("no executable for {phase} {bucket:?}"))?;
        let mut inputs: Vec<&PjRtBuffer> = self.weight_buffers.iter().collect();
        for b in &dynamic {
            inputs.push(b);
        }
        let result = exe
            .execute_b(&inputs)
            .map_err(|e| anyhow!("execute {phase} {bucket:?}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("d2h: {e}"))?;
        tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))
    }

    /// Run prefill for up to `bucket.0` requests.
    ///
    /// `idx[b]` adapter slot, `tokens` row-major [batch, prompt], `lens`
    /// true lengths. Inputs are padded to the chosen bucket; rows beyond
    /// `idx.len()` in the outputs are padding garbage the caller must
    /// ignore.
    pub fn prefill(
        &self,
        idx: &[i32],
        tokens: &[Vec<i32>],
        lens: &[i32],
    ) -> Result<PrefillOut> {
        let batch = idx.len();
        assert_eq!(tokens.len(), batch);
        assert_eq!(lens.len(), batch);
        let max_prompt = tokens.iter().map(Vec::len).max().unwrap_or(1);
        let bucket = self
            .manifest
            .pick_prefill_bucket(batch, max_prompt)
            .ok_or_else(|| anyhow!("no prefill bucket for b={batch} s={max_prompt}"))?;
        let (bb, bs) = bucket;

        let mut idx_p = vec![0i32; bb];
        idx_p[..batch].copy_from_slice(idx);
        let mut lens_p = vec![1i32; bb];
        lens_p[..batch].copy_from_slice(lens);
        let mut tok_p = vec![0i32; bb * bs];
        for (b, row) in tokens.iter().enumerate() {
            tok_p[b * bs..b * bs + row.len()].copy_from_slice(row);
        }

        let dynamic = vec![
            self.i32_buffer(&idx_p, &[bb])?,
            self.i32_buffer(&tok_p, &[bb, bs])?,
            self.i32_buffer(&lens_p, &[bb])?,
        ];
        let outs = self.run("prefill", bucket, dynamic)?;
        anyhow::ensure!(outs.len() == 3, "expected 3 outputs, got {}", outs.len());
        Ok(PrefillOut {
            logits: outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            k_cache: outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            v_cache: outs[2].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            bucket,
        })
    }

    /// Run one decode step for up to `bucket.0` requests.
    ///
    /// `k_cache`/`v_cache` are row-major [layers, batch, M, hidden] for
    /// the *bucket* batch (caller pads); `pos[b]` is each request's
    /// current length.
    pub fn decode(
        &self,
        idx: &[i32],
        tokens: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
    ) -> Result<DecodeOut> {
        let batch = idx.len();
        let bucket = self
            .manifest
            .pick_decode_bucket(batch)
            .ok_or_else(|| anyhow!("no decode bucket for b={batch}"))?;
        let (bb, m) = bucket;
        let expect = self.layers * bb * m * self.hidden;
        anyhow::ensure!(
            k_cache.len() == expect,
            "k_cache len {} != {expect} (caller must pad to bucket {bucket:?})",
            k_cache.len()
        );

        let mut idx_p = vec![0i32; bb];
        idx_p[..batch].copy_from_slice(idx);
        let mut tok_p = vec![0i32; bb];
        tok_p[..batch].copy_from_slice(tokens);
        let mut pos_p = vec![0i32; bb];
        pos_p[..batch].copy_from_slice(pos);

        let dims = [self.layers, bb, m, self.hidden];
        let dynamic = vec![
            self.i32_buffer(&idx_p, &[bb])?,
            self.i32_buffer(&tok_p, &[bb])?,
            self.i32_buffer(&pos_p, &[bb])?,
            self.f32_buffer(k_cache, &dims)?,
            self.f32_buffer(v_cache, &dims)?,
        ];
        let outs = self.run("decode", bucket, dynamic)?;
        anyhow::ensure!(outs.len() == 3, "expected 3 outputs, got {}", outs.len());
        Ok(DecodeOut {
            logits: outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            k_new: outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            v_new: outs[2].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            bucket,
        })
    }

    /// Greedy argmax over one logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> i32 {
        let start = row * self.vocab;
        let slice = &logits[start..start + self.vocab];
        let mut best = 0usize;
        for (i, &v) in slice.iter().enumerate() {
            if v > slice[best] {
                best = i;
            }
        }
        best as i32
    }
}

// PJRT integration tests live in rust/tests/integration_runtime.rs (they
// need `make artifacts` to have run).
