//! [`ThreadPool`]: a persistent parallel-for over independent batch rows.
//!
//! The native runtime computes each batch row's forward pass
//! independently (the continuous-batching invariant), so prefill and
//! decode fan rows across cores with no synchronization beyond the
//! join. Workers are spawned **once** at pool construction and parked
//! on a condvar between jobs — per-step dispatch is a publish + wake,
//! not a thread spawn, which matters when every decode iteration fans
//! out (hundreds of microseconds of spawn/join per step otherwise).
//! Callers still pass plain borrowed closures: a job is published to
//! the parked workers as a type-erased pointer, and the dispatching
//! call blocks until every worker has finished the job, so the borrow
//! outlives every dereference (see the `SAFETY` notes inline).
//!
//! Determinism contract: the pool only changes *where* a row is
//! computed, never *what* it computes. Each row reads shared immutable
//! state and writes its own disjoint outputs, so an N-thread run is
//! bitwise identical to the 1-thread run (pinned by the
//! `parallel_forward_is_bitwise_deterministic` test in
//! [`super::native`]).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Type-erased pointer to a caller's `&(dyn Fn(usize) + Sync)` job
/// closure, smuggled to the persistent workers.
///
/// SAFETY: the pointer is only ever dereferenced by workers between a
/// job's publication and the dispatching caller's done-barrier, and
/// the caller blocks inside [`Inner::dispatch`] (holding the borrow of
/// `f` live in its frame) for exactly that window. The closure is
/// `Sync`, so concurrent calls from several workers are sound.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: see `JobPtr` — the pointee is `Sync` and outlives every
// dereference, so moving the pointer across threads is sound.
unsafe impl Send for JobPtr {}

/// One published job: the row closure and the index range `0..n`.
#[derive(Clone, Copy)]
struct Job {
    f: JobPtr,
    n: usize,
}

/// Worker-visible pool state, guarded by [`Shared::state`].
struct State {
    /// Monotone job counter; a bump while parked means new work.
    generation: u64,
    /// The currently (or most recently) published job. Stale entries
    /// are never dereferenced: workers only read `job` after observing
    /// a generation they have not run yet.
    job: Option<Job>,
    /// Workers still executing the current job; the dispatching caller
    /// returns only once this reaches zero (the join barrier).
    active: usize,
    /// Workers currently alive — the `active` quota per job. Drops
    /// below the spawn count only if a row closure panics (that worker
    /// dies after flagging `panicked`).
    live: usize,
    /// A worker's row closure panicked; the dispatching caller re-raises
    /// after its join barrier, mirroring the old scoped-join behavior.
    panicked: bool,
    /// Set once, on pool drop — parked workers exit.
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Wakes parked workers on publish (and on shutdown).
    work_cv: Condvar,
    /// Wakes the dispatching caller when the last worker finishes.
    done_cv: Condvar,
    /// Next unclaimed row index of the current job.
    next: AtomicUsize,
}

/// Condvar wait that shrugs off poisoning: pool state is a couple of
/// counters whose invariants hold at every await point, so a panicked
/// row closure on one worker must not wedge the rest of the pool.
fn wait<'a>(cv: &Condvar, g: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// The persistent half of the pool: parked workers plus the dispatch
/// plumbing. Absent entirely on serial (`threads == 1`) pools.
struct Inner {
    shared: Arc<Shared>,
    /// Serializes whole jobs: two concurrent `run` calls must not
    /// interleave their index counters or done-barriers.
    run_lock: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

impl Inner {
    fn new(workers: usize) -> Inner {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                active: 0,
                live: workers,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Inner {
            shared,
            run_lock: Mutex::new(()),
            handles,
        }
    }

    /// Publish `f(0..n)` to the parked workers, run `foreground` on the
    /// calling thread, help drain remaining rows, and block until every
    /// worker is parked again. Returning only after the join barrier is
    /// what makes handing workers a raw pointer to `f` sound.
    fn dispatch(&self, n: usize, f: &(dyn Fn(usize) + Sync), foreground: impl FnOnce()) {
        let _job_guard = match self.run_lock.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        {
            let mut g = lock(&self.shared.state);
            // ORDERING: Relaxed is enough — this store happens under the
            // state mutex before the generation bump that workers
            // observe under the same mutex, which orders it for them.
            self.shared.next.store(0, Ordering::Relaxed);
            g.job = Some(Job {
                f: JobPtr(f as *const _),
                n,
            });
            g.generation += 1;
            // Quota by *live* workers: one that died panicking can no
            // longer report done, and waiting on it would hang forever.
            g.active = g.live;
            drop(g);
            self.shared.work_cv.notify_all();
        }
        // The join barrier is a drop guard: even when `foreground` or
        // one of the caller's own rows panics, this frame must not
        // unwind (ending the borrow of `f`) while workers still hold
        // the raw pointer — the guard blocks until they are parked.
        let barrier = BarrierGuard(&self.shared);
        foreground();
        loop {
            // ORDERING: Relaxed — the counter only distributes disjoint
            // indices (RMW atomicity gives uniqueness); workers' row
            // writes are published to the caller by the done-barrier's
            // mutex, not by this counter.
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        }
        drop(barrier);
        let mut g = lock(&self.shared.state);
        if g.panicked {
            g.panicked = false;
            drop(g);
            panic!("thread-pool worker panicked while running a job");
        }
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        {
            let mut g = lock(&self.shared.state);
            g.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Blocks until every worker has reported done for the current job —
/// on the normal path and during caller unwind alike (see
/// [`Inner::dispatch`]).
struct BarrierGuard<'a>(&'a Shared);

impl Drop for BarrierGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock(&self.0.state);
        while g.active > 0 {
            g = wait(&self.0.done_cv, g);
        }
    }
}

/// Reports one worker's share of the current job done — on the normal
/// path *and* during unwind if the row closure panics, so the caller's
/// join barrier always completes. A panicking worker also flags
/// `panicked` (re-raised by the caller) and retires itself from `live`.
struct DoneGuard<'a>(&'a Shared);

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let mut g = lock(&self.0.state);
        if std::thread::panicking() {
            g.panicked = true;
            g.live -= 1;
        }
        g.active -= 1;
        if g.active == 0 {
            self.0.done_cv.notify_all();
        }
    }
}

/// Park until a job (or shutdown) is published, drain row indices,
/// report done, repeat.
fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut g = lock(&shared.state);
            while !g.shutdown && g.generation == seen {
                g = wait(&shared.work_cv, g);
            }
            if g.shutdown {
                return;
            }
            seen = g.generation;
            g.job
        };
        let done = DoneGuard(shared);
        if let Some(Job { f, n }) = job {
            loop {
                // ORDERING: Relaxed index distribution, as in
                // `dispatch` — the done-barrier is the publication edge
                // for row outputs.
                let i = shared.next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // SAFETY: the dispatching caller blocks until `active`
                // reaches zero, so the closure behind this pointer is
                // still borrowed (alive) in its frame; `Sync` makes the
                // concurrent calls sound. See `JobPtr`.
                unsafe { (*f.0)(i) };
            }
        }
        drop(done);
    }
}

/// A fixed-width parallel-for executor over persistent workers. `new`
/// spawns `threads − 1` parked workers once; each [`ThreadPool::run`]
/// wakes them, lets them pull row indices from a shared atomic counter
/// (the calling thread participates), and parks them again at the join
/// barrier. Serial pools (`threads == 1`) spawn nothing, ever.
#[derive(Clone)]
pub struct ThreadPool {
    threads: usize,
    /// `None` iff `threads == 1` (pure serial — no worker threads).
    inner: Option<Arc<Inner>>,
}

impl fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ThreadPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl ThreadPool {
    /// A pool of `threads` workers; 0 is treated as 1 (serial).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        ThreadPool {
            threads,
            inner: (threads > 1).then(|| Arc::new(Inner::new(threads - 1))),
        }
    }

    /// Worker width.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Invoke `f(i)` for every `i` in `0..n`, fanning across up to
    /// `threads` workers. `f` must only write state that is disjoint
    /// per index (enforce with per-index `Mutex`es or disjoint `&mut`
    /// chunks). Serial (`threads == 1` or `n <= 1`) runs inline without
    /// touching the workers at all.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        match &self.inner {
            Some(inner) if n > 1 => inner.dispatch(n, f, || ()),
            _ => {
                for i in 0..n {
                    f(i);
                }
            }
        }
    }

    /// Run `f(0..n)` on the parked workers while the calling thread
    /// executes `foreground` concurrently; returns once both are done
    /// (the caller helps drain rows after its foreground work, then
    /// joins). Used to overlap single-submitter work (CPU-assist rows)
    /// with the pooled rows instead of serializing the two. Total width
    /// stays within `threads`: `threads − 1` workers plus the caller.
    /// Serial pools run `foreground` first, then `f` — outputs are
    /// disjoint per the [`ThreadPool::run`] contract, so ordering is
    /// unobservable.
    pub fn run_overlapping(
        &self,
        n: usize,
        f: &(dyn Fn(usize) + Sync),
        foreground: impl FnOnce(),
    ) {
        match &self.inner {
            Some(inner) if n > 0 => inner.dispatch(n, f, foreground),
            _ => {
                foreground();
                for i in 0..n {
                    f(i);
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Mutex;
    use std::thread::ThreadId;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 4, 9] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<Mutex<u32>> = (0..23).map(|_| Mutex::new(0)).collect();
            pool.run(hits.len(), &|i| *hits[i].lock().unwrap() += 1);
            assert!(hits.iter().all(|h| *h.lock().unwrap() == 1));
        }
    }

    #[test]
    fn zero_threads_is_serial() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
        let mut out = vec![0usize; 5];
        let cells: Vec<Mutex<&mut usize>> = out.iter_mut().map(Mutex::new).collect();
        pool.run(5, &|i| **cells[i].lock().unwrap() = i);
        drop(cells);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_range_is_noop() {
        ThreadPool::new(4).run(0, &|_| panic!("must not be called"));
    }

    #[test]
    fn overlapping_runs_foreground_and_pool() {
        for threads in [1usize, 4] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<Mutex<u32>> = (0..9).map(|_| Mutex::new(0)).collect();
            let fg = Mutex::new(false);
            pool.run_overlapping(
                hits.len(),
                &|i| *hits[i].lock().unwrap() += 1,
                || *fg.lock().unwrap() = true,
            );
            assert!(*fg.lock().unwrap(), "foreground must run (threads={threads})");
            assert!(hits.iter().all(|h| *h.lock().unwrap() == 1));
        }
        // Empty fan-out still runs the foreground.
        let fg = Mutex::new(0u32);
        ThreadPool::new(4).run_overlapping(0, &|_| panic!("no items"), || {
            *fg.lock().unwrap() += 1
        });
        assert_eq!(*fg.lock().unwrap(), 1);
    }

    #[test]
    fn disjoint_chunk_writes_survive_parallelism() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0.0f32; 16 * 8];
        {
            let tasks: Vec<Mutex<&mut [f32]>> =
                buf.chunks_mut(8).map(Mutex::new).collect();
            pool.run(tasks.len(), &|i| {
                let mut chunk = tasks[i].lock().unwrap();
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 8 + j) as f32;
                }
            });
        }
        for (at, v) in buf.iter().enumerate() {
            assert_eq!(*v, at as f32);
        }
    }

    #[test]
    fn workers_persist_across_jobs() {
        // The whole point of the parked pool: many dispatches, one
        // fixed worker set. Every index across every job must land on
        // one of at most `threads` distinct threads (the caller plus
        // the `threads − 1` persistent workers) — the per-call scoped
        // version would mint fresh thread ids per run.
        let pool = ThreadPool::new(3);
        let ids: Mutex<HashSet<ThreadId>> = Mutex::new(HashSet::new());
        for _ in 0..8 {
            pool.run(32, &|_| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
            pool.run_overlapping(
                32,
                &|_| {
                    ids.lock().unwrap().insert(std::thread::current().id());
                },
                || (),
            );
        }
        assert!(
            ids.lock().unwrap().len() <= 3,
            "more distinct threads than the pool owns: {}",
            ids.lock().unwrap().len()
        );
    }

    #[test]
    #[should_panic]
    fn row_panic_propagates_to_the_caller() {
        // Whichever thread claims the poisoned row — a parked worker
        // (flagged and re-raised at the join barrier) or the caller
        // itself — the dispatch must end in a panic, never in a silent
        // partial result.
        let pool = ThreadPool::new(4);
        pool.run(64, &|i| {
            if i == 40 {
                panic!("row failure");
            }
        });
    }

    #[test]
    fn pool_survives_a_worker_panic() {
        let pool = ThreadPool::new(3);
        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(32, &|i| {
                if i == 20 {
                    panic!("row failure");
                }
            });
        }));
        assert!(poisoned.is_err());
        // Later jobs still cover every index with the surviving crew.
        let hits: Vec<Mutex<u32>> = (0..23).map(|_| Mutex::new(0)).collect();
        pool.run(hits.len(), &|i| *hits[i].lock().unwrap() += 1);
        assert!(hits.iter().all(|h| *h.lock().unwrap() == 1));
    }

    #[test]
    fn clones_share_the_worker_set() {
        let pool = ThreadPool::new(4);
        let twin = pool.clone();
        let hits: Vec<Mutex<u32>> = (0..17).map(|_| Mutex::new(0)).collect();
        pool.run(hits.len(), &|i| *hits[i].lock().unwrap() += 1);
        twin.run(hits.len(), &|i| *hits[i].lock().unwrap() += 1);
        assert!(hits.iter().all(|h| *h.lock().unwrap() == 2));
        // Dropping one clone must not tear down the shared workers.
        drop(twin);
        pool.run(hits.len(), &|i| *hits[i].lock().unwrap() += 1);
        assert!(hits.iter().all(|h| *h.lock().unwrap() == 3));
    }
}
