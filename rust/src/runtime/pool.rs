//! [`ThreadPool`]: a scoped parallel-for over independent batch rows.
//!
//! The native runtime computes each batch row's forward pass
//! independently (the continuous-batching invariant), so prefill and
//! decode fan rows across cores with no synchronization beyond the
//! join. Scoped threads keep the borrow story simple — workers borrow
//! the runtime, the KV view, and per-row output slices directly, no
//! `'static` bounds, no channels — and the join guarantees every row's
//! writes are visible before the caller reads the outputs.
//!
//! Determinism contract: the pool only changes *where* a row is
//! computed, never *what* it computes. Each row reads shared immutable
//! state and writes its own disjoint outputs, so an N-thread run is
//! bitwise identical to the 1-thread run (pinned by the
//! `parallel_forward_is_bitwise_deterministic` test in
//! [`super::native`]).

use std::sync::atomic::{AtomicUsize, Ordering};

/// A fixed-width scoped parallel-for executor. Holds no threads between
/// calls: each [`ThreadPool::run`] spawns up to `threads − 1` scoped
/// workers (the calling thread participates) that pull row indices from
/// a shared atomic counter, then joins them.
#[derive(Debug, Clone)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// A pool of `threads` workers; 0 is treated as 1 (serial).
    pub fn new(threads: usize) -> ThreadPool {
        ThreadPool {
            threads: threads.max(1),
        }
    }

    /// Worker width.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Invoke `f(i)` for every `i` in `0..n`, fanning across up to
    /// `threads` workers. `f` must only write state that is disjoint
    /// per index (enforce with per-index `Mutex`es or disjoint `&mut`
    /// chunks). Serial (`threads == 1` or `n <= 1`) runs inline with no
    /// spawn at all.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if self.threads == 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let work = || loop {
            // ORDERING: Relaxed is enough — the counter only distributes
            // disjoint indices (RMW atomicity gives uniqueness); workers'
            // writes are published to the caller by the scope join, not
            // by this counter.
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        };
        std::thread::scope(|s| {
            for _ in 1..self.threads.min(n) {
                s.spawn(work);
            }
            work();
        });
    }

    /// Run `f(0..n)` on spawned workers while the calling thread
    /// executes `foreground` concurrently; returns once both are done
    /// (the caller joins the fan-out after its foreground work). Used
    /// to overlap single-submitter work (CPU-assist rows) with the
    /// pooled rows instead of serializing the two. Total width stays
    /// within `threads`: `threads − 1` spawned workers plus the caller
    /// (on foreground, then draining rows). Serial pools run
    /// `foreground` first, then `f` — outputs are disjoint per the
    /// [`ThreadPool::run`] contract, so ordering is unobservable.
    pub fn run_overlapping(
        &self,
        n: usize,
        f: &(dyn Fn(usize) + Sync),
        foreground: impl FnOnce(),
    ) {
        if self.threads == 1 || n == 0 {
            foreground();
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let work = || loop {
            // ORDERING: Relaxed index distribution, as in `run` — the
            // scope join is the publication edge for row outputs.
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        };
        std::thread::scope(|s| {
            for _ in 0..(self.threads - 1).min(n) {
                s.spawn(work);
            }
            foreground();
            // Help drain whatever the workers haven't claimed yet.
            work();
        });
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 4, 9] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<Mutex<u32>> = (0..23).map(|_| Mutex::new(0)).collect();
            pool.run(hits.len(), &|i| *hits[i].lock().unwrap() += 1);
            assert!(hits.iter().all(|h| *h.lock().unwrap() == 1));
        }
    }

    #[test]
    fn zero_threads_is_serial() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
        let mut out = vec![0usize; 5];
        let cells: Vec<Mutex<&mut usize>> = out.iter_mut().map(Mutex::new).collect();
        pool.run(5, &|i| **cells[i].lock().unwrap() = i);
        drop(cells);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_range_is_noop() {
        ThreadPool::new(4).run(0, &|_| panic!("must not be called"));
    }

    #[test]
    fn overlapping_runs_foreground_and_pool() {
        for threads in [1usize, 4] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<Mutex<u32>> = (0..9).map(|_| Mutex::new(0)).collect();
            let fg = Mutex::new(false);
            pool.run_overlapping(
                hits.len(),
                &|i| *hits[i].lock().unwrap() += 1,
                || *fg.lock().unwrap() = true,
            );
            assert!(*fg.lock().unwrap(), "foreground must run (threads={threads})");
            assert!(hits.iter().all(|h| *h.lock().unwrap() == 1));
        }
        // Empty fan-out still runs the foreground.
        let fg = Mutex::new(0u32);
        ThreadPool::new(4).run_overlapping(0, &|_| panic!("no items"), || {
            *fg.lock().unwrap() += 1
        });
        assert_eq!(*fg.lock().unwrap(), 1);
    }

    #[test]
    fn disjoint_chunk_writes_survive_parallelism() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0.0f32; 16 * 8];
        {
            let tasks: Vec<Mutex<&mut [f32]>> =
                buf.chunks_mut(8).map(Mutex::new).collect();
            pool.run(tasks.len(), &|i| {
                let mut chunk = tasks[i].lock().unwrap();
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 8 + j) as f32;
                }
            });
        }
        for (at, v) in buf.iter().enumerate() {
            assert_eq!(*v, at as f32);
        }
    }
}
