//! Model runtimes: the PJRT executor for AOT artifacts and the native
//! pure-Rust backend, behind one [`Runtime`] facade.
//!
//! - [`manifest`] — parses `artifacts/manifest.json` (model config,
//!   bucket table, per-artifact input ordering).
//! - [`executor`] — wraps `xla::PjRtClient`: compiles each
//!   `*.hlo.txt` once, uploads the weight arrays once as device
//!   buffers, and serves `prefill`/`decode` calls with bucket routing.
//!   Its LoRA stacks are baked into the artifacts, so per-request LoRA
//!   routing travels in the slot-index input.
//! - [`native`] — [`NativeRuntime`]: a pure-Rust backend with an open
//!   layer loop, per-slot installable LoRA stacks, and per-request
//!   [`RowLora`] sourcing (resident `bgmv` path vs. externally computed
//!   CPU-assist deltas). This is the backend on which the paper's §4
//!   CPU-assisted cold-start mechanism actually executes.
//! - [`pool`] — the persistent parked-worker [`ThreadPool`] the native
//!   backend fans batch rows across (spawned once, woken per step).
//!
//! ## The paged KV contract
//!
//! The engine's KV cache is paged ([`crate::server::KvCacheManager`]);
//! runtimes reach it through two one-method traits instead of dense
//! `[layers, batch, M, hidden]` tensors:
//!
//! - [`KvView`] — read access to a request's cached K/V rows in place
//!   (decode attention iterates pages directly; no per-step assembly).
//! - [`KvWrite`] — write access for freshly computed rows (prefill
//!   streams each position straight into its page; no dense
//!   double-buffer).
//!
//! The native backend is zero-copy on both sides. The PJRT executor
//! only speaks dense tensors, so the facade keeps a dense fallback
//! behind the same traits: prefill scatters the executor's dense
//! output into the caller's writers, and decode accepts a
//! caller-assembled dense cache ([`Runtime::decode_dense`], fed by
//! `KvCacheManager::assemble_into`). [`DenseKv`] / [`DenseKvBuffer`]
//! adapt dense storage to the traits for that fallback and for tests.
//!
//! Python never runs here; for the PJRT path the artifacts directory is
//! the only contract between the layers.

pub mod executor;
pub mod manifest;
pub mod native;
pub mod pool;

pub use executor::{DecodeOut, ModelRuntime, PrefillOut};
pub use manifest::{ArtifactMeta, Manifest};
pub use native::{ExternalLora, NativeConfig, NativeRuntime, RowLora};
pub use pool::ThreadPool;

use anyhow::Result;
use std::sync::Arc;

use crate::kernels::AdapterWeights;

/// Read access to cached K/V rows, however they are laid out. The
/// decode hot path calls this once per (row, layer, position, K|V) —
/// implementations must return a borrowed `hidden`-sized slice with no
/// copying. `Sync` because batch rows are read concurrently by the
/// native backend's thread pool.
pub trait KvView: Sync {
    /// The cached K (`want_v == false`) or V row for request `row` at
    /// token position `pos` in `layer`.
    fn kv_row(&self, row: usize, layer: usize, pos: usize, want_v: bool) -> &[f32];
}

/// Write access for one request's freshly computed K/V rows. Prefill
/// calls this once per (layer, position); decode appends go through
/// [`crate::server::KvCacheManager::append_token`] instead (the
/// per-step rows are tiny). `Send` because each row's writer moves to
/// whichever pool thread computes that row.
pub trait KvWrite: Send {
    /// Store the `hidden`-sized K and V rows for token `pos` of `layer`.
    fn write_kv(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]);
}

/// [`KvView`] over dense row-major `[layers, batch, M, hidden]` slices —
/// the PJRT fallback layout and the dense reference in the
/// paged-vs-dense equivalence tests.
pub struct DenseKv<'a> {
    k: &'a [f32],
    v: &'a [f32],
    batch: usize,
    m: usize,
    hidden: usize,
}

impl<'a> DenseKv<'a> {
    /// Wrap dense caches of shape `[layers, batch, m, hidden]`.
    pub fn new(
        k: &'a [f32],
        v: &'a [f32],
        layers: usize,
        batch: usize,
        m: usize,
        hidden: usize,
    ) -> DenseKv<'a> {
        assert_eq!(k.len(), layers * batch * m * hidden, "K shape");
        assert_eq!(v.len(), layers * batch * m * hidden, "V shape");
        DenseKv {
            k,
            v,
            batch,
            m,
            hidden,
        }
    }
}

impl KvView for DenseKv<'_> {
    fn kv_row(&self, row: usize, layer: usize, pos: usize, want_v: bool) -> &[f32] {
        let at = ((layer * self.batch + row) * self.m + pos) * self.hidden;
        let src = if want_v { self.v } else { self.k };
        &src[at..at + self.hidden]
    }
}

/// An owned dense K/V buffer exposing per-row [`KvWrite`] handles and a
/// whole-buffer [`KvView`] — the bridge for code that still wants a
/// dense cache (tests, the PJRT assembly fallback).
///
/// Internal layout is `[batch, layers, seq, hidden]` (row-major), i.e.
/// per-*request* contiguous, so the batch can be written by concurrent
/// row writers via disjoint `&mut` chunks. [`DenseKvBuffer::to_lbsh`]
/// transposes to the executor's `[layers, batch, seq, hidden]` order.
pub struct DenseKvBuffer {
    layers: usize,
    batch: usize,
    seq: usize,
    hidden: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl DenseKvBuffer {
    /// A zeroed buffer for `batch` requests of up to `seq` tokens.
    pub fn new(layers: usize, batch: usize, seq: usize, hidden: usize) -> DenseKvBuffer {
        let n = layers * batch * seq * hidden;
        DenseKvBuffer {
            layers,
            batch,
            seq,
            hidden,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// One [`KvWrite`] handle per batch row (disjoint `&mut` chunks).
    pub fn row_writers(&mut self) -> Vec<DenseRowWriter<'_>> {
        let (seq, hidden) = (self.seq, self.hidden);
        let per_row = self.layers * seq * hidden;
        self.k
            .chunks_mut(per_row)
            .zip(self.v.chunks_mut(per_row))
            .map(|(k, v)| DenseRowWriter { seq, hidden, k, v })
            .collect()
    }

    /// Copy out as `[layers, batch, seq, hidden]` dense (K, V) tensors —
    /// the PJRT executor's order.
    pub fn to_lbsh(&self) -> (Vec<f32>, Vec<f32>) {
        let (l, b, s, h) = (self.layers, self.batch, self.seq, self.hidden);
        let mut k = vec![0.0f32; l * b * s * h];
        let mut v = vec![0.0f32; l * b * s * h];
        for layer in 0..l {
            for row in 0..b {
                for t in 0..s {
                    let dst = ((layer * b + row) * s + t) * h;
                    let src = ((row * l + layer) * s + t) * h;
                    k[dst..dst + h].copy_from_slice(&self.k[src..src + h]);
                    v[dst..dst + h].copy_from_slice(&self.v[src..src + h]);
                }
            }
        }
        (k, v)
    }
}

impl KvView for DenseKvBuffer {
    fn kv_row(&self, row: usize, layer: usize, pos: usize, want_v: bool) -> &[f32] {
        let at = ((row * self.layers + layer) * self.seq + pos) * self.hidden;
        let src = if want_v { &self.v } else { &self.k };
        &src[at..at + self.hidden]
    }
}

/// Per-row writer into a [`DenseKvBuffer`].
pub struct DenseRowWriter<'a> {
    seq: usize,
    hidden: usize,
    k: &'a mut [f32],
    v: &'a mut [f32],
}

impl KvWrite for DenseRowWriter<'_> {
    fn write_kv(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let at = (layer * self.seq + pos) * self.hidden;
        self.k[at..at + self.hidden].copy_from_slice(k_row);
        self.v[at..at + self.hidden].copy_from_slice(v_row);
    }
}

/// A serving backend: either the PJRT executor or the native model.
/// [`crate::server::InferenceServer`] drives this facade so the whole
/// engine (batching, paged KV, cold-start handling, metrics) is backend-
/// agnostic.
pub enum Runtime {
    /// AOT artifacts through PJRT (baked LoRA stacks).
    Pjrt(ModelRuntime),
    /// Pure-Rust native model (installable stacks + CPU-assist seam).
    Native(NativeRuntime),
}

impl From<ModelRuntime> for Runtime {
    fn from(rt: ModelRuntime) -> Runtime {
        Runtime::Pjrt(rt)
    }
}

impl From<NativeRuntime> for Runtime {
    fn from(rt: NativeRuntime) -> Runtime {
        Runtime::Native(rt)
    }
}

impl Runtime {
    /// Hidden dimension H.
    pub fn hidden(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.hidden,
            Runtime::Native(rt) => rt.cfg.hidden,
        }
    }

    /// Transformer layer count.
    pub fn layers(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.layers,
            Runtime::Native(rt) => rt.cfg.layers,
        }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.vocab,
            Runtime::Native(rt) => rt.cfg.vocab,
        }
    }

    /// Device adapter slots.
    pub fn lora_slots(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.manifest.lora_slots,
            Runtime::Native(rt) => rt.cfg.lora_slots,
        }
    }

    /// Largest prompt any prefill bucket accepts.
    pub fn max_prompt(&self) -> Option<usize> {
        match self {
            Runtime::Pjrt(rt) => {
                rt.manifest.prefill_buckets().iter().map(|&(_, s)| s).max()
            }
            Runtime::Native(rt) => Some(rt.cfg.max_prompt),
        }
    }

    /// Decode cache capacity M.
    pub fn cache_m(&self) -> Option<usize> {
        match self {
            Runtime::Pjrt(rt) => rt.manifest.decode_buckets().first().map(|&(_, m)| m),
            Runtime::Native(rt) => Some(rt.cfg.cache_m),
        }
    }

    /// Largest decode batch.
    pub fn max_decode_batch(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt
                .manifest
                .decode_buckets()
                .iter()
                .map(|&(b, _)| b)
                .max()
                .unwrap_or(1),
            Runtime::Native(rt) => rt.cfg.max_decode_batch,
        }
    }

    /// The decode bucket serving `batch` requests: (bucket batch, M).
    pub fn pick_decode_bucket(&self, batch: usize) -> Option<(usize, usize)> {
        match self {
            Runtime::Pjrt(rt) => rt.manifest.pick_decode_bucket(batch),
            Runtime::Native(rt) => {
                (batch <= rt.cfg.max_decode_batch).then_some((batch, rt.cfg.cache_m))
            }
        }
    }

    /// Does this backend support externally supplied per-layer LoRA
    /// deltas (the real CPU-assisted path)? The PJRT artifacts bake their
    /// stacks in, so there the cold-start overlap stays a modeled window.
    pub fn supports_cpu_assist(&self) -> bool {
        matches!(self, Runtime::Native(_))
    }

    /// Does this backend need a caller-assembled dense decode cache?
    /// True only for PJRT (its compiled artifacts take dense `[layers,
    /// batch, M, hidden]` inputs); the native backend reads the paged
    /// pool in place through [`KvView`].
    pub fn needs_dense_kv(&self) -> bool {
        matches!(self, Runtime::Pjrt(_))
    }

    /// Make `weights` resident in `slot` — the completion of a modeled
    /// host→device transfer. No-op on the PJRT backend (baked stacks).
    pub fn install_slot(&mut self, slot: usize, weights: Option<Arc<[AdapterWeights; 4]>>) {
        match self {
            Runtime::Pjrt(_) => {}
            Runtime::Native(rt) => rt.install_slot(slot, weights),
        }
    }

    /// Prefill a batch. `idx[b]` is each request's device slot; `rows[b]`
    /// its LoRA sourcing (the native backend consumes `rows`, PJRT
    /// consumes `idx`). Each row's K/V rows stream into `writers[b]` —
    /// zero-copy into the paged pool on the native backend; the PJRT arm
    /// scatters its dense bucket output through the same writers (one
    /// copy). The returned [`PrefillOut`] carries logits only; its
    /// `k_cache`/`v_cache` are empty.
    pub fn prefill(
        &self,
        idx: &[i32],
        tokens: &[Vec<i32>],
        lens: &[i32],
        rows: &[RowLora<'_>],
        writers: &mut [&mut dyn KvWrite],
    ) -> Result<PrefillOut> {
        match self {
            Runtime::Pjrt(rt) => {
                let out = rt.prefill(idx, tokens, lens)?;
                let (bb, bs) = out.bucket;
                let h = rt.hidden;
                anyhow::ensure!(
                    writers.len() == tokens.len(),
                    "writer count {} != batch {}",
                    writers.len(),
                    tokens.len()
                );
                for (b, w) in writers.iter_mut().enumerate() {
                    let len = (lens[b].max(1) as usize).min(tokens[b].len());
                    for layer in 0..rt.layers {
                        for t in 0..len {
                            let src = ((layer * bb + b) * bs + t) * h;
                            w.write_kv(
                                layer,
                                t,
                                &out.k_cache[src..src + h],
                                &out.v_cache[src..src + h],
                            );
                        }
                    }
                }
                Ok(PrefillOut {
                    logits: out.logits,
                    k_cache: Vec::new(),
                    v_cache: Vec::new(),
                    bucket: out.bucket,
                })
            }
            Runtime::Native(rt) => rt.prefill(idx, tokens, lens, rows, writers),
        }
    }

    /// One decode step over the paged cache — the zero-copy hot path.
    /// The native backend reads history rows in place through `kv`; the
    /// PJRT arm materializes a dense cache from the view first (prefer
    /// [`Runtime::decode_dense`] with a reused scratch buffer there —
    /// see [`Runtime::needs_dense_kv`]).
    pub fn decode_paged(
        &self,
        idx: &[i32],
        tokens: &[i32],
        pos: &[i32],
        kv: &dyn KvView,
        rows: &[RowLora<'_>],
    ) -> Result<DecodeOut> {
        match self {
            Runtime::Pjrt(rt) => {
                let (bb, m) = rt
                    .manifest
                    .pick_decode_bucket(tokens.len())
                    .ok_or_else(|| {
                        anyhow::anyhow!("no decode bucket for b={}", tokens.len())
                    })?;
                let h = rt.hidden;
                let n = rt.layers * bb * m * h;
                let mut k = vec![0.0f32; n];
                let mut v = vec![0.0f32; n];
                for (b, &p) in pos.iter().enumerate() {
                    // Same typed error the native arm returns — not a
                    // slice panic mid-copy.
                    anyhow::ensure!(
                        p.max(0) as usize <= m,
                        "row {b}: pos {p} exceeds cache capacity {m}"
                    );
                    for layer in 0..rt.layers {
                        for t in 0..(p.max(0) as usize) {
                            let dst = ((layer * bb + b) * m + t) * h;
                            k[dst..dst + h]
                                .copy_from_slice(kv.kv_row(b, layer, t, false));
                            v[dst..dst + h]
                                .copy_from_slice(kv.kv_row(b, layer, t, true));
                        }
                    }
                }
                rt.decode(idx, tokens, pos, &k, &v)
            }
            Runtime::Native(rt) => rt.decode(idx, tokens, pos, kv, rows),
        }
    }

    /// One decode step over caller-assembled dense caches (`[layers,
    /// bucket_batch, M, hidden]`) — the PJRT input layout, kept for
    /// backends without paged access and for dense-reference tests. The
    /// native arm wraps the slices in a [`DenseKv`] view and runs the
    /// same code path as [`Runtime::decode_paged`].
    pub fn decode_dense(
        &self,
        idx: &[i32],
        tokens: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        rows: &[RowLora<'_>],
    ) -> Result<DecodeOut> {
        match self {
            Runtime::Pjrt(rt) => rt.decode(idx, tokens, pos, k_cache, v_cache),
            Runtime::Native(rt) => {
                let (bb, m) = (tokens.len(), rt.cfg.cache_m);
                let expect = rt.cfg.layers * bb * m * rt.cfg.hidden;
                anyhow::ensure!(
                    k_cache.len() == expect && v_cache.len() == expect,
                    "KV cache len {} != {expect}",
                    k_cache.len()
                );
                let view =
                    DenseKv::new(k_cache, v_cache, rt.cfg.layers, bb, m, rt.cfg.hidden);
                rt.decode(idx, tokens, pos, &view, rows)
            }
        }
    }

    /// Greedy argmax over one logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> i32 {
        match self {
            Runtime::Pjrt(rt) => rt.argmax_row(logits, row),
            Runtime::Native(rt) => rt.argmax_row(logits, row),
        }
    }
}
