//! Model runtimes: the PJRT executor for AOT artifacts and the native
//! pure-Rust backend, behind one [`Runtime`] facade.
//!
//! - [`manifest`] — parses `artifacts/manifest.json` (model config,
//!   bucket table, per-artifact input ordering).
//! - [`executor`] — wraps `xla::PjRtClient`: compiles each
//!   `*.hlo.txt` once, uploads the weight arrays once as device
//!   buffers, and serves `prefill`/`decode` calls with bucket routing.
//!   Its LoRA stacks are baked into the artifacts, so per-request LoRA
//!   routing travels in the slot-index input.
//! - [`native`] — [`NativeRuntime`]: a pure-Rust backend with an open
//!   layer loop, per-slot installable LoRA stacks, and per-request
//!   [`RowLora`] sourcing (resident `bgmv` path vs. externally computed
//!   CPU-assist deltas). This is the backend on which the paper's §4
//!   CPU-assisted cold-start mechanism actually executes.
//!
//! Python never runs here; for the PJRT path the artifacts directory is
//! the only contract between the layers.

pub mod executor;
pub mod manifest;
pub mod native;

pub use executor::{DecodeOut, ModelRuntime, PrefillOut};
pub use manifest::{ArtifactMeta, Manifest};
pub use native::{ExternalLora, NativeConfig, NativeRuntime, RowLora};

use anyhow::Result;
use std::sync::Arc;

use crate::kernels::AdapterWeights;

/// A serving backend: either the PJRT executor or the native model.
/// [`crate::server::InferenceServer`] drives this facade so the whole
/// engine (batching, paged KV, cold-start handling, metrics) is backend-
/// agnostic.
pub enum Runtime {
    /// AOT artifacts through PJRT (baked LoRA stacks).
    Pjrt(ModelRuntime),
    /// Pure-Rust native model (installable stacks + CPU-assist seam).
    Native(NativeRuntime),
}

impl From<ModelRuntime> for Runtime {
    fn from(rt: ModelRuntime) -> Runtime {
        Runtime::Pjrt(rt)
    }
}

impl From<NativeRuntime> for Runtime {
    fn from(rt: NativeRuntime) -> Runtime {
        Runtime::Native(rt)
    }
}

impl Runtime {
    /// Hidden dimension H.
    pub fn hidden(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.hidden,
            Runtime::Native(rt) => rt.cfg.hidden,
        }
    }

    /// Transformer layer count.
    pub fn layers(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.layers,
            Runtime::Native(rt) => rt.cfg.layers,
        }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.vocab,
            Runtime::Native(rt) => rt.cfg.vocab,
        }
    }

    /// Device adapter slots.
    pub fn lora_slots(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt.manifest.lora_slots,
            Runtime::Native(rt) => rt.cfg.lora_slots,
        }
    }

    /// Largest prompt any prefill bucket accepts.
    pub fn max_prompt(&self) -> Option<usize> {
        match self {
            Runtime::Pjrt(rt) => {
                rt.manifest.prefill_buckets().iter().map(|&(_, s)| s).max()
            }
            Runtime::Native(rt) => Some(rt.cfg.max_prompt),
        }
    }

    /// Decode cache capacity M.
    pub fn cache_m(&self) -> Option<usize> {
        match self {
            Runtime::Pjrt(rt) => rt.manifest.decode_buckets().first().map(|&(_, m)| m),
            Runtime::Native(rt) => Some(rt.cfg.cache_m),
        }
    }

    /// Largest decode batch.
    pub fn max_decode_batch(&self) -> usize {
        match self {
            Runtime::Pjrt(rt) => rt
                .manifest
                .decode_buckets()
                .iter()
                .map(|&(b, _)| b)
                .max()
                .unwrap_or(1),
            Runtime::Native(rt) => rt.cfg.max_decode_batch,
        }
    }

    /// The decode bucket serving `batch` requests: (bucket batch, M).
    pub fn pick_decode_bucket(&self, batch: usize) -> Option<(usize, usize)> {
        match self {
            Runtime::Pjrt(rt) => rt.manifest.pick_decode_bucket(batch),
            Runtime::Native(rt) => {
                (batch <= rt.cfg.max_decode_batch).then_some((batch, rt.cfg.cache_m))
            }
        }
    }

    /// Does this backend support externally supplied per-layer LoRA
    /// deltas (the real CPU-assisted path)? The PJRT artifacts bake their
    /// stacks in, so there the cold-start overlap stays a modeled window.
    pub fn supports_cpu_assist(&self) -> bool {
        matches!(self, Runtime::Native(_))
    }

    /// Make `weights` resident in `slot` — the completion of a modeled
    /// host→device transfer. No-op on the PJRT backend (baked stacks).
    pub fn install_slot(&mut self, slot: usize, weights: Option<Arc<[AdapterWeights; 4]>>) {
        match self {
            Runtime::Pjrt(_) => {}
            Runtime::Native(rt) => rt.install_slot(slot, weights),
        }
    }

    /// Prefill a batch. `idx[b]` is each request's device slot; `rows[b]`
    /// its LoRA sourcing (the native backend consumes `rows`, PJRT
    /// consumes `idx`).
    pub fn prefill(
        &self,
        idx: &[i32],
        tokens: &[Vec<i32>],
        lens: &[i32],
        rows: &[RowLora<'_>],
    ) -> Result<PrefillOut> {
        match self {
            Runtime::Pjrt(rt) => rt.prefill(idx, tokens, lens),
            Runtime::Native(rt) => rt.prefill(idx, tokens, lens, rows),
        }
    }

    /// One decode step over assembled KV (`[layers, bucket_batch, M,
    /// hidden]`).
    pub fn decode(
        &self,
        idx: &[i32],
        tokens: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        rows: &[RowLora<'_>],
    ) -> Result<DecodeOut> {
        match self {
            Runtime::Pjrt(rt) => rt.decode(idx, tokens, pos, k_cache, v_cache),
            Runtime::Native(rt) => rt.decode(idx, tokens, pos, k_cache, v_cache, rows),
        }
    }

    /// Greedy argmax over one logits row.
    pub fn argmax_row(&self, logits: &[f32], row: usize) -> i32 {
        match self {
            Runtime::Pjrt(rt) => rt.argmax_row(logits, row),
            Runtime::Native(rt) => rt.argmax_row(logits, row),
        }
    }
}
