//! The PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! - [`manifest`] — parses `artifacts/manifest.json` (model config,
//!   bucket table, per-artifact input ordering).
//! - [`executor`] — wraps `xla::PjRtClient`: compiles each
//!   `*.hlo.txt` once, uploads the weight arrays once as device
//!   buffers, and serves `prefill`/`decode` calls with bucket routing.
//!
//! Python never runs here; the artifacts directory is the only contract
//! between the layers.

pub mod executor;
pub mod manifest;

pub use executor::{DecodeOut, ModelRuntime, PrefillOut};
pub use manifest::{ArtifactMeta, Manifest};
