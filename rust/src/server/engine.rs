//! [`InferenceServer`]: the complete single-server serving engine.
//!
//! Wires the continuous batcher, the paged KV manager, the device slot
//! cache, and the PJRT [`ModelRuntime`] into the iteration loop of
//! Fig 2, behind the streaming lifecycle API ([`super::api`]): `submit`
//! returns a [`RequestHandle`] whose event stream the prefill/decode
//! loop feeds token by token, honoring cancellation and stop tokens
//! mid-flight. Cold starts follow the configured [`ColdStartMode`]:
//!
//! - `Cached` — oracle: every adapter pre-resident, no load delay.
//! - `OnDemand` — the load window *serializes* with prefill (Punica/
//!   S-LoRA behaviour).
//! - `CaraServe` — the load window runs **concurrently** with prefill
//!   compute. On this CPU-PJRT testbed the "GPU" prefill literally runs
//!   on host cores, so overlapping it with the load window reproduces
//!   the paper's CPU-assisted mechanism: compute proceeds while the
//!   (modeled) PCIe transfer completes, and TTFT absorbs only
//!   `max(load, prefill)` instead of `load + prefill`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::api::{
    ActiveRequest, EventChannel, FinishReason, RequestEvent, RequestHandle, SamplingParams,
    ServeRequest, ServingFront,
};
use super::batcher::{Batcher, NextAction, RunningReq};
use super::kvcache::KvCacheManager;
use super::metrics::MetricsRecorder;
use crate::adapters::{DeviceSlotCache, HostRepository, LoaderModel};
use crate::model::LoraSpec;
use crate::runtime::ModelRuntime;
use crate::scheduler::ServerStats;
use crate::util::rng::Rng;

/// Cold-start handling mode (§7.1 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColdStartMode {
    Cached,
    OnDemand,
    CaraServe,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max running batch (≤ largest decode bucket).
    pub max_batch: usize,
    /// Max admits per prefill pass (≤ largest prefill bucket batch).
    pub max_prefill_batch: usize,
    /// Cold-start behaviour.
    pub cold_start: ColdStartMode,
    /// KV pool size in pages.
    pub kv_pages: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// Scale on the modeled adapter-load latency (1.0 = A10-realistic
    /// times for the configured LoRA rank).
    pub load_scale: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            max_prefill_batch: 4,
            cold_start: ColdStartMode::CaraServe,
            kv_pages: 256,
            page_size: 16,
            load_scale: 1.0,
        }
    }
}

/// The serving engine for one base model on one (virtual) device.
pub struct InferenceServer {
    pub runtime: ModelRuntime,
    pub config: EngineConfig,
    batcher: Batcher,
    kv: KvCacheManager,
    slot_cache: DeviceSlotCache,
    repo: HostRepository,
    loader: LoaderModel,
    metrics: MetricsRecorder,
    /// Event channels of live (non-terminal) requests.
    handles: HashMap<u64, Arc<Mutex<EventChannel>>>,
    /// Next engine-assigned request id.
    next_id: u64,
    /// Per-request device slot.
    slots: HashMap<u64, usize>,
    /// Largest prompt the compiled buckets accept.
    max_prompt: usize,
    /// Decode cache capacity M.
    cache_m: usize,
    /// Reused KV assembly buffers (decode hot path; §Perf).
    k_scratch: Vec<f32>,
    v_scratch: Vec<f32>,
}

impl InferenceServer {
    /// Build a server over a loaded runtime.
    pub fn new(runtime: ModelRuntime, config: EngineConfig) -> Result<InferenceServer> {
        let max_prompt = runtime
            .manifest
            .prefill_buckets()
            .iter()
            .map(|&(_, s)| s)
            .max()
            .ok_or_else(|| anyhow!("no prefill buckets"))?;
        let cache_m = runtime
            .manifest
            .decode_buckets()
            .first()
            .map(|&(_, m)| m)
            .ok_or_else(|| anyhow!("no decode buckets"))?;
        let max_decode_batch = runtime
            .manifest
            .decode_buckets()
            .iter()
            .map(|&(b, _)| b)
            .max()
            .unwrap_or(1);
        anyhow::ensure!(
            config.max_batch <= max_decode_batch,
            "max_batch {} exceeds decode bucket {}",
            config.max_batch,
            max_decode_batch
        );
        let kv = KvCacheManager::new(
            runtime.layers,
            runtime.hidden,
            config.page_size,
            config.kv_pages,
            cache_m,
        );
        let slot_cache = DeviceSlotCache::new(runtime.manifest.lora_slots);
        let model_cfg = crate::model::LlamaConfig::tiny();
        let loader = LoaderModel {
            cfg: model_cfg,
            gpu: crate::config::GpuSpec::a10(),
            scale: config.load_scale,
        };
        Ok(InferenceServer {
            batcher: Batcher::new(config.max_batch, config.max_prefill_batch),
            kv,
            slot_cache,
            repo: HostRepository::new(),
            loader,
            metrics: MetricsRecorder::new(),
            handles: HashMap::new(),
            next_id: 0,
            slots: HashMap::new(),
            max_prompt,
            cache_m,
            k_scratch: Vec::new(),
            v_scratch: Vec::new(),
            runtime,
            config,
        })
    }

    /// Register an adapter in the host repository. Requests against
    /// uninstalled adapters are rejected at submission.
    pub fn install_adapter(&mut self, spec: LoraSpec) {
        self.repo.install(spec);
    }

    /// Submit a request. Validation failures (empty/over-bucket prompt,
    /// over-capacity generation, uninstalled adapter) surface as a
    /// terminal [`RequestEvent::Rejected`] on the returned handle.
    pub fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        let id = self.next_id;
        self.next_id += 1;
        let (handle, channel) = RequestHandle::new(id);
        if let Err(reason) = self.validate(&req) {
            channel.lock().unwrap().push(RequestEvent::Rejected(reason));
            return handle;
        }
        self.metrics.arrived(id, req.slo);
        channel.lock().unwrap().push(RequestEvent::Admitted);
        self.handles.insert(id, channel);
        self.batcher.enqueue(ActiveRequest::from_submit(id, req));
        handle
    }

    fn validate(&self, req: &ServeRequest) -> std::result::Result<(), String> {
        super::api::validate_shape(req, self.max_prompt, self.cache_m)?;
        if self.repo.get(req.adapter).is_none() {
            return Err(format!("adapter {} not installed", req.adapter));
        }
        Ok(())
    }

    /// Request cancellation of `id`. Returns true if the request was
    /// live; the terminal `Cancelled` event lands at the next iteration
    /// boundary.
    pub fn cancel(&mut self, id: u64) -> bool {
        match self.handles.get(&id) {
            Some(chan) => chan.lock().unwrap().try_request_cancel(),
            None => false,
        }
    }

    /// Metrics recorder.
    pub fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    /// Pending + running work?
    pub fn has_work(&self) -> bool {
        self.batcher.load() > 0
    }

    /// The scheduler's `GetStats` view: running/queued adapter ranks and
    /// the tightest per-token SLO among live requests.
    pub fn stats(&self) -> ServerStats {
        let rank = |adapter: u64| self.repo.get(adapter).map_or(0, |s| s.rank);
        let tpot_slo = super::api::tightest_tpot_slo(
            self.batcher
                .running
                .iter()
                .map(|r| &r.slo)
                .chain(self.batcher.queue.iter().map(|q| &q.req.slo)),
        );
        ServerStats {
            running_ranks: self
                .batcher
                .running
                .iter()
                .map(|r| rank(r.adapter))
                .collect(),
            queued_ranks: self
                .batcher
                .queue
                .iter()
                .map(|q| rank(q.req.adapter))
                .collect(),
            eligible: true,
            tpot_slo,
        }
    }

    /// Run one iteration (Fig 2). Returns false when idle. Cancellation
    /// requests are honored at this boundary, before prefill/decode.
    pub fn step(&mut self) -> Result<bool> {
        self.reap_cancelled()?;
        let kv = &self.kv;
        let action = self.batcher.next_action(|tokens| kv.can_admit(tokens));
        match action {
            NextAction::Idle => Ok(false),
            NextAction::Prefill { admit } => {
                self.run_prefill(admit)?;
                Ok(true)
            }
            NextAction::Decode => {
                self.run_decode()?;
                Ok(true)
            }
        }
    }

    /// Drive until all submitted requests complete.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    fn emit_to(handles: &HashMap<u64, Arc<Mutex<EventChannel>>>, id: u64, event: RequestEvent) {
        if let Some(chan) = handles.get(&id) {
            chan.lock().unwrap().push(event);
        }
    }

    /// Remove requests whose handles requested cancellation: queued ones
    /// simply leave the queue; running ones free their KV pages and
    /// device slot. Each gets exactly one terminal `Cancelled` event.
    fn reap_cancelled(&mut self) -> Result<()> {
        let cancelled: Vec<u64> = self
            .handles
            .iter()
            .filter(|(_, chan)| {
                let c = chan.lock().unwrap();
                c.cancel_requested() && !c.is_terminal()
            })
            .map(|(&id, _)| id)
            .collect();
        for id in cancelled {
            if self.batcher.remove_queued(id).is_none() {
                if self.batcher.remove_running(id).is_some() {
                    self.kv.free_request(id)?;
                    self.slots.remove(&id);
                } else {
                    continue; // neither queued nor running: already terminating
                }
            }
            self.metrics.cancelled(id);
            Self::emit_to(&self.handles, id, RequestEvent::Cancelled);
            self.handles.remove(&id);
        }
        Ok(())
    }

    /// Pick the next token for one logits row: greedy argmax, or seeded
    /// top-k sampling when the request asks for it. Sampling is seeded
    /// per (request seed, id, position) so results are independent of
    /// batch composition.
    fn pick_token(
        &self,
        logits: &[f32],
        row: usize,
        sampling: &SamplingParams,
        id: u64,
        position: usize,
    ) -> i32 {
        if sampling.top_k <= 1 {
            return self.runtime.argmax_row(logits, row);
        }
        let vocab = self.runtime.vocab;
        let slice = &logits[row * vocab..(row + 1) * vocab];
        let k = sampling.top_k.min(vocab);
        // k-sized partial scan, descending: avoids a vocab-sized
        // allocation per sampled token on the decode hot path.
        let mut top: Vec<(f32, usize)> = Vec::with_capacity(k + 1);
        for (i, &v) in slice.iter().enumerate() {
            if top.len() < k || v > top.last().unwrap().0 {
                let pos = top.partition_point(|&(t, _)| t >= v);
                top.insert(pos, (v, i));
                if top.len() > k {
                    top.pop();
                }
            }
        }
        let max = top[0].0;
        let weights: Vec<f64> = top
            .iter()
            .map(|&(v, _)| f64::from(v - max).exp())
            .collect();
        let mut rng = Rng::new(
            sampling
                .seed
                .wrapping_add(id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_add((position as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)),
        );
        top[rng.discrete(&weights)].1 as i32
    }

    fn run_prefill(&mut self, admit: usize) -> Result<()> {
        let admits = self.batcher.take_admits(admit);

        // Acquire device slots; compute the cold-start window.
        let mut total_load = 0.0f64;
        let mut slot_of: Vec<usize> = Vec::with_capacity(admits.len());
        for q in &admits {
            // Fixed adapter→slot mapping: the baked LoRA stacks make the
            // slot index part of the adapter's identity (see
            // DeviceSlotCache::acquire_fixed).
            let acq = self.slot_cache.acquire_fixed(q.req.adapter);
            slot_of.push(acq.slot);
            if acq.cold && self.config.cold_start != ColdStartMode::Cached {
                // submit() validated installation, so a missing spec is
                // an engine invariant breach — never fabricate one.
                let spec = self.repo.get(q.req.adapter).ok_or_else(|| {
                    anyhow!("adapter {} missing from repository", q.req.adapter)
                })?;
                total_load += self.loader.load_time(spec);
            }
        }

        // Build bucket inputs.
        let idx: Vec<i32> = slot_of.iter().map(|&s| s as i32).collect();
        let tokens: Vec<Vec<i32>> = admits.iter().map(|q| q.req.prompt.clone()).collect();
        let lens: Vec<i32> = admits.iter().map(|q| q.req.prompt.len() as i32).collect();

        // Execute with the configured cold-start semantics.
        let load_window = Duration::from_secs_f64(total_load);
        let out = match self.config.cold_start {
            ColdStartMode::Cached => self.runtime.prefill(&idx, &tokens, &lens)?,
            ColdStartMode::OnDemand => {
                // Load serializes with prefill.
                spin_sleep(load_window);
                self.runtime.prefill(&idx, &tokens, &lens)?
            }
            ColdStartMode::CaraServe => {
                // Load overlaps prefill compute (the paper's mechanism;
                // see module docs). The iteration ends when both finish.
                let t0 = Instant::now();
                let result = self.runtime.prefill(&idx, &tokens, &lens)?;
                if let Some(rem) = load_window.checked_sub(t0.elapsed()) {
                    spin_sleep(rem);
                }
                result
            }
        };

        // Apply results per admitted request: first token, KV admission,
        // FirstToken event, stop-token check.
        let (bb, bs) = out.bucket;
        for (row, q) in admits.iter().enumerate() {
            let id = q.req.id;
            let first = self.pick_token(&out.logits, row, &q.req.sampling, id, 0);
            self.kv.admit_from_prefill(
                id,
                &out.k_cache,
                &out.v_cache,
                bb,
                bs,
                row,
                q.req.prompt.len(),
            )?;
            self.metrics.token(id);
            Self::emit_to(&self.handles, id, RequestEvent::FirstToken(first));
            self.slots.insert(id, slot_of[row]);
            let running = RunningReq {
                id,
                adapter: q.req.adapter,
                ctx: q.req.prompt.len(),
                generated: 1,
                sampling: q.req.sampling.clone(),
                slo: q.req.slo,
                last_token: first,
                stopped: q.req.sampling.stop_tokens.contains(&first),
            };
            if running.finished() {
                self.finish(running)?;
            } else {
                self.batcher.start_running(running);
            }
        }
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        let batch = self.batcher.running.len();
        let bucket = self
            .runtime
            .manifest
            .pick_decode_bucket(batch)
            .ok_or_else(|| anyhow!("no decode bucket for batch {batch}"))?;
        let (bb, m) = bucket;

        let ids: Vec<u64> = self.batcher.running.iter().map(|r| r.id).collect();
        let idx: Vec<i32> = self
            .batcher
            .running
            .iter()
            .map(|r| self.slots[&r.id] as i32)
            .collect();
        let tokens: Vec<i32> = self.batcher.running.iter().map(|r| r.last_token).collect();
        let pos: Vec<i32> = self.batcher.running.iter().map(|r| r.ctx as i32).collect();
        let (mut k, mut v) =
            (std::mem::take(&mut self.k_scratch), std::mem::take(&mut self.v_scratch));
        self.kv.assemble_into(&ids, bb, m, &mut k, &mut v)?;

        let out = self.runtime.decode(&idx, &tokens, &pos, &k, &v)?;
        self.k_scratch = k;
        self.v_scratch = v;
        for (row, id) in ids.iter().enumerate() {
            let tok = {
                let r = &self.batcher.running[row];
                self.pick_token(&out.logits, row, &r.sampling, *id, r.generated)
            };
            self.kv.append_token(*id, &out.k_new, &out.v_new, bb, row)?;
            self.metrics.token(*id);
            Self::emit_to(&self.handles, *id, RequestEvent::Token(tok));
            let r = &mut self.batcher.running[row];
            r.generated += 1;
            r.ctx += 1;
            r.last_token = tok;
            if r.sampling.stop_tokens.contains(&tok) {
                r.stopped = true;
            }
        }
        for done in self.batcher.reap_finished() {
            self.finish(done)?;
        }
        Ok(())
    }

    fn finish(&mut self, r: RunningReq) -> Result<()> {
        self.kv.free_request(r.id)?;
        self.slots.remove(&r.id);
        self.metrics.finished(r.id);
        let reason = if r.stopped {
            FinishReason::Stop
        } else {
            FinishReason::Length
        };
        Self::emit_to(&self.handles, r.id, RequestEvent::Finished(reason));
        self.handles.remove(&r.id);
        Ok(())
    }
}

impl ServingFront for InferenceServer {
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        InferenceServer::submit(self, req)
    }

    fn poll(&mut self) -> Result<bool> {
        self.step()
    }

    fn cancel(&mut self, id: u64) -> bool {
        InferenceServer::cancel(self, id)
    }

    fn stats(&self) -> ServerStats {
        InferenceServer::stats(self)
    }
}

/// Sleep that is accurate at sub-millisecond scale (std sleep can
/// overshoot badly; load windows here are single-digit ms).
fn spin_sleep(d: Duration) {
    if d.is_zero() {
        return;
    }
    let t0 = Instant::now();
    if d > Duration::from_millis(2) {
        std::thread::sleep(d - Duration::from_millis(1));
    }
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

// Engine integration tests (require built artifacts) live in
// rust/tests/integration_engine.rs and rust/tests/integration_front.rs.
