//! [`InferenceServer`]: the complete single-server serving engine.
//!
//! Wires the continuous batcher, the paged KV manager, the device slot
//! cache, and the PJRT [`ModelRuntime`] into the iteration loop of
//! Fig 2. Cold starts follow the configured [`ColdStartMode`]:
//!
//! - `Cached` — oracle: every adapter pre-resident, no load delay.
//! - `OnDemand` — the load window *serializes* with prefill (Punica/
//!   S-LoRA behaviour).
//! - `CaraServe` — the load window runs **concurrently** with prefill
//!   compute. On this CPU-PJRT testbed the "GPU" prefill literally runs
//!   on host cores, so overlapping it with the load window reproduces
//!   the paper's CPU-assisted mechanism: compute proceeds while the
//!   (modeled) PCIe transfer completes, and TTFT absorbs only
//!   `max(load, prefill)` instead of `load + prefill`.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::api::{InferenceRequest, RequestOutput};
use super::batcher::{Batcher, NextAction, RunningReq};
use super::kvcache::KvCacheManager;
use super::metrics::MetricsRecorder;
use crate::adapters::{DeviceSlotCache, HostRepository, LoaderModel};
use crate::model::LoraSpec;
use crate::runtime::ModelRuntime;

/// Cold-start handling mode (§7.1 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColdStartMode {
    Cached,
    OnDemand,
    CaraServe,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max running batch (≤ largest decode bucket).
    pub max_batch: usize,
    /// Max admits per prefill pass (≤ largest prefill bucket batch).
    pub max_prefill_batch: usize,
    /// Cold-start behaviour.
    pub cold_start: ColdStartMode,
    /// KV pool size in pages.
    pub kv_pages: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// Scale on the modeled adapter-load latency (1.0 = A10-realistic
    /// times for the configured LoRA rank).
    pub load_scale: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            max_prefill_batch: 4,
            cold_start: ColdStartMode::CaraServe,
            kv_pages: 256,
            page_size: 16,
            load_scale: 1.0,
        }
    }
}

/// The serving engine for one base model on one (virtual) device.
pub struct InferenceServer {
    pub runtime: ModelRuntime,
    pub config: EngineConfig,
    batcher: Batcher,
    kv: KvCacheManager,
    slot_cache: DeviceSlotCache,
    repo: HostRepository,
    loader: LoaderModel,
    metrics: MetricsRecorder,
    outputs: Vec<RequestOutput>,
    /// Per-request generated tokens (accumulating).
    generating: HashMap<u64, Vec<i32>>,
    /// Per-request device slot.
    slots: HashMap<u64, usize>,
    /// Largest prompt the compiled buckets accept.
    max_prompt: usize,
    /// Decode cache capacity M.
    cache_m: usize,
    /// Reused KV assembly buffers (decode hot path; §Perf).
    k_scratch: Vec<f32>,
    v_scratch: Vec<f32>,
}

impl InferenceServer {
    /// Build a server over a loaded runtime.
    pub fn new(runtime: ModelRuntime, config: EngineConfig) -> Result<InferenceServer> {
        let max_prompt = runtime
            .manifest
            .prefill_buckets()
            .iter()
            .map(|&(_, s)| s)
            .max()
            .ok_or_else(|| anyhow!("no prefill buckets"))?;
        let cache_m = runtime
            .manifest
            .decode_buckets()
            .first()
            .map(|&(_, m)| m)
            .ok_or_else(|| anyhow!("no decode buckets"))?;
        let max_decode_batch = runtime
            .manifest
            .decode_buckets()
            .iter()
            .map(|&(b, _)| b)
            .max()
            .unwrap_or(1);
        anyhow::ensure!(
            config.max_batch <= max_decode_batch,
            "max_batch {} exceeds decode bucket {}",
            config.max_batch,
            max_decode_batch
        );
        let kv = KvCacheManager::new(
            runtime.layers,
            runtime.hidden,
            config.page_size,
            config.kv_pages,
            cache_m,
        );
        let slot_cache = DeviceSlotCache::new(runtime.manifest.lora_slots);
        let model_cfg = crate::model::LlamaConfig::tiny();
        let loader = LoaderModel {
            cfg: model_cfg,
            gpu: crate::config::GpuSpec::a10(),
            scale: config.load_scale,
        };
        Ok(InferenceServer {
            batcher: Batcher::new(config.max_batch, config.max_prefill_batch),
            kv,
            slot_cache,
            repo: HostRepository::new(),
            loader,
            metrics: MetricsRecorder::new(),
            outputs: Vec::new(),
            generating: HashMap::new(),
            slots: HashMap::new(),
            max_prompt,
            cache_m,
            k_scratch: Vec::new(),
            v_scratch: Vec::new(),
            runtime,
            config,
        })
    }

    /// Register an adapter in the host repository.
    pub fn install_adapter(&mut self, spec: LoraSpec) {
        self.repo.install(spec);
    }

    /// Submit a request (must fit the compiled buckets).
    pub fn submit(&mut self, req: InferenceRequest) -> Result<()> {
        anyhow::ensure!(
            !req.prompt.is_empty() && req.prompt.len() <= self.max_prompt,
            "prompt length {} outside (0, {}]",
            req.prompt.len(),
            self.max_prompt
        );
        anyhow::ensure!(
            req.prompt.len() + req.max_new_tokens <= self.cache_m + 1,
            "prompt+output exceeds KV capacity {}",
            self.cache_m
        );
        anyhow::ensure!(req.max_new_tokens >= 1, "must generate ≥ 1 token");
        self.metrics.arrived(req.id);
        self.batcher.enqueue(req);
        Ok(())
    }

    /// Completed outputs so far.
    pub fn outputs(&self) -> &[RequestOutput] {
        &self.outputs
    }

    /// Metrics recorder.
    pub fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    /// Pending + running work?
    pub fn has_work(&self) -> bool {
        self.batcher.load() > 0
    }

    /// Run one iteration (Fig 2). Returns false when idle.
    pub fn step(&mut self) -> Result<bool> {
        let kv = &self.kv;
        let action = self.batcher.next_action(|tokens| kv.can_admit(tokens));
        match action {
            NextAction::Idle => Ok(false),
            NextAction::Prefill { admit } => {
                self.run_prefill(admit)?;
                Ok(true)
            }
            NextAction::Decode => {
                self.run_decode()?;
                Ok(true)
            }
        }
    }

    /// Drive until all submitted requests complete.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    fn run_prefill(&mut self, admit: usize) -> Result<()> {
        let admits = self.batcher.take_admits(admit);

        // Acquire device slots; compute the cold-start window.
        let mut total_load = 0.0f64;
        let mut slot_of: Vec<usize> = Vec::with_capacity(admits.len());
        for q in &admits {
            // Fixed adapter→slot mapping: the baked LoRA stacks make the
            // slot index part of the adapter's identity (see
            // DeviceSlotCache::acquire_fixed).
            let acq = self.slot_cache.acquire_fixed(q.req.adapter);
            slot_of.push(acq.slot);
            if acq.cold && self.config.cold_start != ColdStartMode::Cached {
                let spec = self
                    .repo
                    .get(q.req.adapter)
                    .cloned()
                    .unwrap_or_else(|| LoraSpec::standard(q.req.adapter, 8, "tiny"));
                total_load += self.loader.load_time(&spec);
            }
        }

        // Build bucket inputs.
        let idx: Vec<i32> = slot_of.iter().map(|&s| s as i32).collect();
        let tokens: Vec<Vec<i32>> = admits.iter().map(|q| q.req.prompt.clone()).collect();
        let lens: Vec<i32> = admits.iter().map(|q| q.req.prompt.len() as i32).collect();

        // Execute with the configured cold-start semantics.
        let load_window = Duration::from_secs_f64(total_load);
        let out = match self.config.cold_start {
            ColdStartMode::Cached => self.runtime.prefill(&idx, &tokens, &lens)?,
            ColdStartMode::OnDemand => {
                // Load serializes with prefill.
                spin_sleep(load_window);
                self.runtime.prefill(&idx, &tokens, &lens)?
            }
            ColdStartMode::CaraServe => {
                // Load overlaps prefill compute (the paper's mechanism;
                // see module docs). The iteration ends when both finish.
                let t0 = Instant::now();
                let result = self.runtime.prefill(&idx, &tokens, &lens)?;
                if let Some(rem) = load_window.checked_sub(t0.elapsed()) {
                    spin_sleep(rem);
                }
                result
            }
        };

        // Apply results per admitted request.
        let (bb, bs) = out.bucket;
        for (row, q) in admits.iter().enumerate() {
            let id = q.req.id;
            let first = self.runtime.argmax_row(&out.logits, row);
            self.kv.admit_from_prefill(
                id,
                &out.k_cache,
                &out.v_cache,
                bb,
                bs,
                row,
                q.req.prompt.len(),
            )?;
            self.metrics.token(id);
            self.generating.insert(id, vec![first]);
            self.slots.insert(id, slot_of[row]);
            let running = RunningReq {
                id,
                adapter: q.req.adapter,
                ctx: q.req.prompt.len(),
                generated: 1,
                max_new_tokens: q.req.max_new_tokens,
                last_token: first,
            };
            if running.finished() {
                self.finish(running)?;
            } else {
                self.batcher.start_running(running);
            }
        }
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        let batch = self.batcher.running.len();
        let bucket = self
            .runtime
            .manifest
            .pick_decode_bucket(batch)
            .ok_or_else(|| anyhow!("no decode bucket for batch {batch}"))?;
        let (bb, m) = bucket;

        let ids: Vec<u64> = self.batcher.running.iter().map(|r| r.id).collect();
        let idx: Vec<i32> = self
            .batcher
            .running
            .iter()
            .map(|r| self.slots[&r.id] as i32)
            .collect();
        let tokens: Vec<i32> = self.batcher.running.iter().map(|r| r.last_token).collect();
        let pos: Vec<i32> = self.batcher.running.iter().map(|r| r.ctx as i32).collect();
        let (mut k, mut v) =
            (std::mem::take(&mut self.k_scratch), std::mem::take(&mut self.v_scratch));
        self.kv.assemble_into(&ids, bb, m, &mut k, &mut v)?;

        let out = self.runtime.decode(&idx, &tokens, &pos, &k, &v)?;
        self.k_scratch = k;
        self.v_scratch = v;
        for (row, id) in ids.iter().enumerate() {
            let tok = self.runtime.argmax_row(&out.logits, row);
            self.kv.append_token(*id, &out.k_new, &out.v_new, bb, row)?;
            self.metrics.token(*id);
            self.generating.get_mut(id).unwrap().push(tok);
            let r = &mut self.batcher.running[row];
            r.generated += 1;
            r.ctx += 1;
            r.last_token = tok;
        }
        for done in self.batcher.reap_finished() {
            self.finish(done)?;
        }
        Ok(())
    }

    fn finish(&mut self, r: RunningReq) -> Result<()> {
        self.kv.free_request(r.id)?;
        self.slots.remove(&r.id);
        let tokens = self.generating.remove(&r.id).unwrap_or_default();
        self.metrics.finished(r.id);
        self.outputs.push(RequestOutput { id: r.id, tokens });
        Ok(())
    }
}

/// Sleep that is accurate at sub-millisecond scale (std sleep can
/// overshoot badly; load windows here are single-digit ms).
fn spin_sleep(d: Duration) {
    if d.is_zero() {
        return;
    }
    let t0 = Instant::now();
    if d > Duration::from_millis(2) {
        std::thread::sleep(d - Duration::from_millis(1));
    }
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

// Engine integration tests (require built artifacts) live in
// rust/tests/integration_engine.rs.
