//! [`InferenceServer`]: the complete single-server serving engine.
//!
//! Wires the continuous batcher, the paged KV manager, the device slot
//! cache, the CPU-LoRA worker pool, and a [`Runtime`] backend (PJRT or
//! native) into the iteration loop of Fig 2, behind the streaming
//! lifecycle API ([`super::api`]): `submit` returns a
//! [`super::api::RequestHandle`] whose event stream the prefill/decode
//! loop feeds token by token, honoring cancellation and stop tokens
//! mid-flight. Cold starts follow the configured [`ColdStartMode`]:
//!
//! - `Cached` — oracle: every adapter becomes resident at admit with no
//!   load delay.
//! - `OnDemand` — the load window *serializes* with prefill (Punica/
//!   S-LoRA behaviour).
//! - `CaraServe` — the paper's §4 mechanism, run for real when the
//!   backend is the native runtime and a CPU worker pool is attached
//!   ([`InferenceServer::enable_cpu_assist`]): the adapter load becomes
//!   an asynchronous window tracked by [`AsyncLoader`] while prefill
//!   starts immediately, with every layer's `xAB` delta computed by the
//!   shared-memory CPU workers (sharded across workers by token range)
//!   and merged into the Q/K/V projections. Requests keep decoding
//!   through the CPU path until their adapter's load deadline passes,
//!   then hand off to the device-resident `bgmv` path (§4.3) — both
//!   paths read the same `Arc`-shared weights, so the handoff never
//!   changes token values. TTFT absorbs only the prefill compute
//!   (≤ `max(load, prefill)`), not `load + prefill`. On the PJRT
//!   backend (baked LoRA stacks, no mid-layer seam) or without a worker
//!   pool, the mode falls back to the modeled overlap: the iteration
//!   spans `max(load, prefill)`.
//!
//! On the native backend the engine runs **unified paging** (S-LoRA
//! style): adapter weights and KV cache compete for one bounded page
//! pool ([`super::kvcache`]). Admission debits both budgets jointly,
//! cold starts page weights in (evicting idle adapters by decayed-
//! popularity LRU — never ones with queued/running requests), and
//! decode growth reclaims idle adapter pages before resorting to
//! request preemption. This removes the fixed-slot ceiling: catalogs of
//! 1,000+ adapters serve through [`crate::adapters::AdapterResidency`]
//! (`rust/tests/integration_unified_pool.rs` pins the behaviour).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::api::{
    ActiveRequest, EventChannel, FinishReason, InstallSourceStats, RejectReason, RequestEvent,
    RequestHandle, ResumeState, SamplingParams, ServeRequest, ServingFront,
};
use crate::artifacts::{ArtifactStore, StoreError};
use super::batcher::{Batcher, NextAction, RunningReq};
use super::kvcache::{KvCacheManager, KvError};
use super::metrics::{ColdStartStats, MetricsRecorder, TtftBreakdown};
use crate::adapters::{
    flatten_stack, stack_from_flat, AdapterResidency, AsyncLoader, DeviceSlotCache,
    HostRepository, LoaderModel,
};
use crate::cpu_lora::{AdapterTable, CoreProfile, CpuLoraEngine};
use crate::model::{LoraSpec, TargetMatrix};
use crate::runtime::{ExternalLora, KvWrite, RowLora, Runtime};
use crate::scheduler::{AdapterSet, ServerStats};
use crate::util::rng::Rng;

/// Cold-start handling mode (§7.1 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColdStartMode {
    Cached,
    OnDemand,
    CaraServe,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max running batch (≤ largest decode bucket).
    pub max_batch: usize,
    /// Max admits per prefill pass (≤ largest prefill bucket batch).
    pub max_prefill_batch: usize,
    /// Cold-start behaviour.
    pub cold_start: ColdStartMode,
    /// KV pool size in pages.
    pub kv_pages: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// Scale on the modeled adapter-load latency (1.0 = A10-realistic
    /// times for the configured LoRA rank).
    pub load_scale: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            max_prefill_batch: 4,
            cold_start: ColdStartMode::CaraServe,
            kv_pages: 256,
            page_size: 16,
            load_scale: 1.0,
        }
    }
}

/// Wraps the CPU-LoRA engine so the runtime's per-layer `delta` calls
/// are wall-clock accounted (the `assist` component of the TTFT
/// breakdown / the decode-assist counter). The accumulator is a
/// `Mutex` (not a `Cell`) because `ExternalLora: Sync` — assist rows
/// may sit in a batch shared with the runtime's forward threads, even
/// though the runtime only *calls* `delta` from one thread at a time.
struct TimedAssist<'a> {
    engine: &'a CpuLoraEngine,
    spent: Mutex<f64>,
}

impl<'a> TimedAssist<'a> {
    fn new(engine: &'a CpuLoraEngine) -> TimedAssist<'a> {
        TimedAssist {
            engine,
            spent: Mutex::new(0.0),
        }
    }

    fn spent(&self) -> f64 {
        *self.spent.lock().unwrap()
    }
}

impl ExternalLora for TimedAssist<'_> {
    fn delta(
        &self,
        adapter: u64,
        target: TargetMatrix,
        n_tok: usize,
        x: &[f32],
    ) -> Vec<f32> {
        let t0 = Instant::now();
        let y = self.engine.delta(adapter, target, n_tok, x);
        *self.spent.lock().unwrap() += t0.elapsed().as_secs_f64();
        y
    }
}

/// How one admitted request's LoRA is sourced this iteration.
#[derive(Clone, Copy, PartialEq)]
enum RowPlan {
    /// Device-resident slot stack.
    Resident,
    /// CPU-assisted deltas (adapter still loading).
    Assist,
}

/// The serving engine for one base model on one (virtual) device.
pub struct InferenceServer {
    pub runtime: Runtime,
    pub config: EngineConfig,
    batcher: Batcher,
    kv: KvCacheManager,
    slot_cache: DeviceSlotCache,
    /// Paged adapter residency over the unified pool (native path);
    /// `slot_cache` keeps serving the fixed-slot PJRT path.
    residency: AdapterResidency,
    /// Unified paging active: adapter weights share the page pool with
    /// KV. True exactly when the backend reads paged KV in place (the
    /// native runtime); the PJRT arm keeps fixed slots because its
    /// compiled artifacts bake one weight stack per slot.
    unified: bool,
    repo: HostRepository,
    loader: LoaderModel,
    metrics: MetricsRecorder,
    /// Host-memory adapter weights, shared with the CPU workers and the
    /// native runtime's slot stacks (one copy, `Arc`ed everywhere).
    table: Arc<AdapterTable>,
    /// CPU-LoRA worker pool (None ⇒ CaraServe falls back to the modeled
    /// overlap).
    cpu: Option<CpuLoraEngine>,
    /// Content-addressed artifact store installs source weights from
    /// (None ⇒ every install seeds synthetically). Shared with the wire
    /// serving loop so streamed blobs become installable immediately.
    store: Option<Arc<Mutex<ArtifactStore>>>,
    /// Install provenance counters (store vs synthetic).
    install_sources: InstallSourceStats,
    /// In-flight adapter load windows (real CaraServe path).
    loads: AsyncLoader,
    /// Requests already counted in the deferred-collision metric (each
    /// blocked request counts once, not once per iteration it waits).
    deferred_ids: std::collections::HashSet<u64>,
    /// Event channels of live (non-terminal) requests.
    handles: HashMap<u64, Arc<Mutex<EventChannel>>>,
    /// Event-buffer overflows accumulated from already-retired
    /// handles, so `stats().event_overflows` stays monotone after
    /// requests complete.
    retired_overflows: usize,
    /// Next engine-assigned request id.
    next_id: u64,
    /// Per-request device slot.
    slots: HashMap<u64, usize>,
    /// Largest prompt the backend accepts.
    max_prompt: usize,
    /// Decode cache capacity M.
    cache_m: usize,
    /// Reused KV assembly buffers — PJRT fallback only; the native
    /// decode path reads the paged pool in place (§Perf).
    k_scratch: Vec<f32>,
    v_scratch: Vec<f32>,
}

impl InferenceServer {
    /// Build a server over a backend (PJRT [`crate::runtime::ModelRuntime`]
    /// or [`crate::runtime::NativeRuntime`], via `Into<Runtime>`).
    pub fn new(runtime: impl Into<Runtime>, config: EngineConfig) -> Result<InferenceServer> {
        let runtime: Runtime = runtime.into();
        let max_prompt = runtime
            .max_prompt()
            .ok_or_else(|| anyhow!("no prefill buckets"))?;
        let cache_m = runtime
            .cache_m()
            .ok_or_else(|| anyhow!("no decode buckets"))?;
        let max_decode_batch = runtime.max_decode_batch();
        anyhow::ensure!(
            config.max_batch <= max_decode_batch,
            "max_batch {} exceeds decode bucket {}",
            config.max_batch,
            max_decode_batch
        );
        let kv = KvCacheManager::new(
            runtime.layers(),
            runtime.hidden(),
            config.page_size,
            config.kv_pages,
            cache_m,
        );
        let slot_cache =
            DeviceSlotCache::new(runtime.lora_slots()).map_err(|e| anyhow!("{e}"))?;
        let residency =
            AdapterResidency::new(runtime.lora_slots()).map_err(|e| anyhow!("{e}"))?;
        let unified = !runtime.needs_dense_kv();
        let model_cfg = crate::model::LlamaConfig::tiny();
        let loader = LoaderModel {
            cfg: model_cfg,
            gpu: crate::config::GpuSpec::a10(),
            scale: config.load_scale,
        };
        Ok(InferenceServer {
            batcher: Batcher::new(config.max_batch, config.max_prefill_batch),
            kv,
            slot_cache,
            residency,
            unified,
            repo: HostRepository::new(),
            loader,
            metrics: MetricsRecorder::new(),
            table: Arc::new(AdapterTable::new()),
            cpu: None,
            store: None,
            install_sources: InstallSourceStats::default(),
            loads: AsyncLoader::new(),
            deferred_ids: std::collections::HashSet::new(),
            handles: HashMap::new(),
            retired_overflows: 0,
            next_id: 0,
            slots: HashMap::new(),
            max_prompt,
            cache_m,
            k_scratch: Vec::new(),
            v_scratch: Vec::new(),
            runtime,
            config,
        })
    }

    /// Attach a CPU-LoRA worker pool of `workers` shared-memory workers.
    /// With the native backend this turns `ColdStartMode::CaraServe` into
    /// the real §4 mechanism (see module docs); the pool shares this
    /// engine's [`AdapterTable`], which is what makes CPU-assisted and
    /// resident outputs agree.
    pub fn enable_cpu_assist(&mut self, workers: usize) -> Result<()> {
        anyhow::ensure!(workers > 0, "need ≥ 1 CPU worker");
        let hidden = self.runtime.hidden();
        let profile = CoreProfile::default_for(hidden, 8);
        let engine = CpuLoraEngine::new(
            workers,
            hidden,
            self.max_prompt,
            self.table.clone(),
            profile,
        )
        .map_err(|e| anyhow!("cpu worker pool: {e}"))?;
        self.cpu = Some(engine);
        Ok(())
    }

    /// Is the real CPU-assisted path active (pool attached + backend with
    /// a per-layer LoRA seam)?
    pub fn cpu_assist_active(&self) -> bool {
        self.cpu.is_some() && self.runtime.supports_cpu_assist()
    }

    /// Attach a content-addressed artifact store:
    /// [`ServingFront::install_adapter`] sources weights from it
    /// (digest-verified on every read) and falls back to synthetic
    /// seeding only for adapters the store has no manifest for. The
    /// store is shared (`Arc<Mutex<..>>`) with the wire serving loop,
    /// so blobs a router pushes mid-flight become installable without a
    /// restart — the streamed-migration path.
    pub fn attach_store(&mut self, store: Arc<Mutex<ArtifactStore>>) {
        self.store = Some(store);
    }

    /// Requests (queued or running) currently bound to `adapter` — what
    /// gates a runtime uninstall.
    fn inflight_on(&self, adapter: u64) -> usize {
        let queued = self.batcher.queue.iter().filter(|q| q.req.adapter == adapter);
        let running = self.batcher.running.iter().filter(|r| r.adapter == adapter);
        queued.count() + running.count()
    }

    /// Reconstruct an adapter's Q/K/V/O stack from its unified-pool
    /// pages — the install source on the unified path, so the runtime
    /// serves exactly what the pool holds. The gathered copy is
    /// value-identical to the host table's, which is what keeps token
    /// streams bitwise stable across evict/re-page cycles.
    fn paged_stack(
        &self,
        adapter: u64,
    ) -> Option<Arc<[crate::kernels::bgmv::AdapterWeights; 4]>> {
        let flat = self.kv.adapter_weights(adapter)?;
        let rank = self.repo.get(adapter)?.rank;
        Some(Arc::new(stack_from_flat(
            &flat,
            self.runtime.hidden(),
            rank,
        )))
    }

    /// Evict the coldest *idle* resident adapter from the unified pool:
    /// release its weight pages, clear its runtime slot, drop its
    /// residency. Adapters with queued or running requests, in-flight
    /// loads, or in `protect` (the current admit batch — mid-admission,
    /// so `inflight_on` doesn't see them) are never victims, preserving
    /// the PR 5 busy guards. Returns whether an eviction happened.
    fn evict_idle_adapter(&mut self, protect: &[u64]) -> Result<bool> {
        let victim = {
            let batcher = &self.batcher;
            let loads = &self.loads;
            self.residency.victim(|a| {
                protect.contains(&a)
                    || loads.loading(a)
                    || batcher.queue.iter().any(|q| q.req.adapter == a)
                    || batcher.running.iter().any(|r| r.adapter == a)
            })
        };
        let Some(victim) = victim else {
            return Ok(false);
        };
        let slot = self
            .residency
            .evict(victim)
            .ok_or_else(|| anyhow!("eviction victim {victim} not resident"))?;
        self.kv
            .free_adapter(victim)
            .ok_or_else(|| anyhow!("eviction victim {victim} held no pool pages"))?;
        self.runtime.install_slot(slot, None);
        self.metrics.adapter_eviction();
        Ok(true)
    }

    /// Unified path: make `adapter` weight-resident in the pool, evicting
    /// idle residents as needed (acquire = page-in). Weights are
    /// flattened from the host table into rank-proportional pages;
    /// `install` controls whether the runtime slot is loaded now (false
    /// on the real CPU-assist path, where §4.3's `finish_loads` installs
    /// at the load deadline instead). Returns `(slot, cold)`.
    fn ensure_resident(
        &mut self,
        adapter: u64,
        protect: &[u64],
        install: bool,
    ) -> Result<(usize, bool)> {
        if let Some(slot) = self.residency.slot_of(adapter) {
            self.residency.touch(adapter);
            return Ok((slot, false));
        }
        let stack = self
            .table
            .get(adapter)
            .ok_or_else(|| anyhow!("adapter {adapter} has no host weights"))?;
        let flat = flatten_stack(&stack);
        let need = self.kv.pages_for_elems(flat.len());
        while !self.residency.has_free_slot() || self.kv.free_pages() < need {
            if !self.evict_idle_adapter(protect)? {
                anyhow::bail!(
                    "cannot page in adapter {adapter}: need {need} pages + a \
                     residency slot ({} pages free, {} of {} slots held) and \
                     every resident adapter is busy",
                    self.kv.free_pages(),
                    self.residency.len(),
                    self.residency.capacity()
                );
            }
        }
        self.kv
            .reserve_adapter(adapter, &flat)
            .map_err(|e| anyhow!("page in adapter {adapter}: {e}"))?;
        let slot = self
            .residency
            .insert(adapter)
            .ok_or_else(|| anyhow!("no residency slot for adapter {adapter}"))?;
        if install {
            self.runtime.install_slot(slot, self.paged_stack(adapter));
        }
        Ok((slot, true))
    }

    /// Unified-pool admission: each provisional admit debits its KV
    /// pages and — when its adapter is not yet resident — the adapter's
    /// rank-proportional weight pages plus a residency slot, from a
    /// running model of what `run_prefill`'s evictions can actually
    /// free. Idle residents count as reclaimable (pages and slot);
    /// adapters of already-provisioned admits are pinned. Conservative
    /// by construction: any batch admitted here is satisfiable by
    /// `ensure_resident`, so its hard-error path stays unreachable
    /// under ordinary load.
    fn unified_admission_action(&self) -> NextAction {
        use std::cell::{Cell, RefCell};
        let kv = &self.kv;
        let residency = &self.residency;
        let repo = &self.repo;
        let hidden = self.runtime.hidden();
        // Idle residents, by id: pages (and a slot) we could reclaim.
        let reclaim: RefCell<std::collections::BTreeMap<u64, usize>> = RefCell::new(
            residency
                .residents()
                .iter()
                .filter(|&&a| self.inflight_on(a) == 0 && !self.loads.loading(a))
                .filter_map(|&a| kv.adapter_pages(a).map(|p| (a, p)))
                .collect(),
        );
        let free = Cell::new(kv.free_pages());
        let free_slots = Cell::new(residency.capacity() - residency.len());
        let pinned: RefCell<std::collections::HashSet<u64>> =
            RefCell::new(std::collections::HashSet::new());
        self.batcher.next_action_by(|q| {
            let a = q.req.adapter;
            let kv_need = kv.pages_for(q.req.context_len().max(1));
            let mut rc = reclaim.borrow_mut();
            // The candidate's own adapter is never an eviction victim.
            let held = rc.remove(&a);
            let resident = residency.resident(a) || pinned.borrow().contains(&a);
            let w_need = if resident {
                0
            } else {
                let rank = repo.get(a).map_or(1, |s| s.rank.max(1));
                kv.pages_for_elems(8 * hidden * rank)
            };
            let reclaimable: usize = rc.values().sum();
            let slot_ok = resident || free_slots.get() > 0 || !rc.is_empty();
            if !slot_ok || kv_need + w_need > free.get() + reclaimable {
                if let Some(p) = held {
                    rc.insert(a, p); // restore: not admitted, still idle
                }
                return false;
            }
            // Commit. A residency slot first (an eviction frees one as a
            // side effect, so only a slot-motivated eviction skips the
            // slot credit)…
            if !resident {
                if free_slots.get() > 0 {
                    free_slots.set(free_slots.get() - 1);
                } else if let Some((&victim, _)) = rc.iter().next() {
                    if let Some(p) = rc.remove(&victim) {
                        free.set(free.get() + p);
                    }
                }
            }
            // …then pages, draining reclaimable idles (ascending id —
            // deterministic) while short.
            let need = kv_need + w_need;
            while need > free.get() {
                let Some((&victim, _)) = rc.iter().next() else {
                    break;
                };
                if let Some(p) = rc.remove(&victim) {
                    free.set(free.get() + p);
                    free_slots.set(free_slots.get() + 1);
                }
            }
            free.set(free.get().saturating_sub(need));
            pinned.borrow_mut().insert(a);
            true
        })
    }

    /// Submit a request. Validation failures (empty/over-bucket prompt,
    /// over-capacity generation, uninstalled adapter) surface as a
    /// terminal [`RequestEvent::Rejected`] on the returned handle.
    pub fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        let id = self.next_id;
        self.next_id += 1;
        let (handle, channel) = RequestHandle::new(id);
        if let Err(reason) = self.validate(&req) {
            channel.lock().unwrap().push(RequestEvent::Rejected(reason));
            return handle;
        }
        self.metrics.arrived(id, req.slo);
        channel.lock().unwrap().push(RequestEvent::Admitted);
        self.handles.insert(id, channel);
        self.batcher.enqueue(ActiveRequest::from_submit(id, req));
        handle
    }

    fn validate(&self, req: &ServeRequest) -> std::result::Result<(), RejectReason> {
        super::api::validate_shape(req, self.max_prompt, self.cache_m)?;
        let Some(spec) = self.repo.get(req.adapter) else {
            return Err(RejectReason::AdapterNotInstalled {
                adapter: req.adapter,
            });
        };
        if self.unified {
            // Joint bound: the request's adapter weights and its prompt
            // KV must be able to coexist in the pool, or admission could
            // never succeed (rejecting here prevents a permanent stall).
            let w = self
                .kv
                .pages_for_elems(8 * self.runtime.hidden() * spec.rank.max(1));
            let p = self.kv.pages_for(req.prompt.len().max(1));
            if w + p > self.kv.total_pages() {
                return Err(RejectReason::PoolTooSmall {
                    adapter: req.adapter,
                    pool_pages: self.kv.total_pages(),
                });
            }
        }
        Ok(())
    }

    /// Request cancellation of `id`. Returns true if the request was
    /// live; the terminal `Cancelled` event lands at the next iteration
    /// boundary.
    pub fn cancel(&mut self, id: u64) -> bool {
        match self.handles.get(&id) {
            Some(chan) => chan.lock().unwrap().try_request_cancel(),
            None => false,
        }
    }

    /// Metrics recorder.
    pub fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    /// Pending + running work?
    pub fn has_work(&self) -> bool {
        self.batcher.load() > 0
    }

    /// The scheduler's `GetStats` view: running/queued adapter ranks,
    /// the real eligibility data (locally installed adapter set, prompt
    /// capacity, free-page headroom, preemption count), the tightest
    /// per-token SLO among live requests, and the unified pool's
    /// per-class occupancy counters.
    ///
    /// On the unified path `kv_free_tokens` counts *reclaimable*
    /// headroom — free pages plus pages held by idle (evictable)
    /// adapter residents — so the router neither overestimates (the
    /// free list already nets out adapter-held pages, the two budgets
    /// being one pool) nor writes off capacity a pressure eviction
    /// would recover.
    pub fn stats(&self) -> ServerStats {
        let rank = |adapter: u64| self.repo.get(adapter).map_or(0, |s| s.rank);
        let tpot_slo = super::api::tightest_tpot_slo(
            self.batcher
                .running
                .iter()
                .map(|r| &r.slo)
                .chain(self.batcher.queue.iter().map(|q| &q.req.slo)),
        );
        let evictable_pages: usize = if self.unified {
            self.residency
                .residents()
                .iter()
                .filter(|&&a| self.inflight_on(a) == 0 && !self.loads.loading(a))
                .filter_map(|&a| self.kv.adapter_pages(a))
                .sum()
        } else {
            0
        };
        ServerStats {
            running_ranks: self
                .batcher
                .running
                .iter()
                .map(|r| rank(r.adapter))
                .collect(),
            queued_ranks: self
                .batcher
                .queue
                .iter()
                .map(|q| rank(q.req.adapter))
                .collect(),
            adapters: AdapterSet::only(self.repo.ids()),
            max_prompt_tokens: self
                .max_prompt
                .min(self.kv.total_pages() * self.config.page_size),
            kv_free_tokens: (self.kv.free_pages() + evictable_pages) * self.config.page_size,
            tpot_slo,
            preemptions: self.metrics.preemptions(),
            pool_pages: self.kv.total_pages(),
            kv_held_pages: self.kv.kv_held_pages(),
            adapter_held_pages: self.kv.adapter_held_pages(),
            adapter_evictions: self.metrics.adapter_evictions(),
            event_overflows: self.retired_overflows
                + self
                    .handles
                    .values()
                    .map(|c| c.lock().unwrap().overflows())
                    .sum::<usize>(),
        }
    }

    /// Run one iteration (Fig 2). Returns false when idle. Cancellation
    /// requests are honored at this boundary, before prefill/decode, and
    /// completed adapter loads are installed (the §4.3 handoff point).
    pub fn step(&mut self) -> Result<bool> {
        self.reap_cancelled()?;
        self.finish_loads();
        let action = if self.unified {
            self.unified_admission_action()
        } else {
            let kv = &self.kv;
            // Cumulative admission accounting: each provisional admit
            // debits its page need from a running free count, so a batch
            // of requests that individually fit but jointly exhaust the
            // pool is trimmed here — run_prefill's reservations then
            // cannot fail under ordinary load (its rollback stays as a
            // backstop).
            let free = std::cell::Cell::new(kv.free_pages());
            self.batcher.next_action(|tokens| {
                let need = kv.pages_for(tokens.max(1));
                if need > free.get() {
                    return false;
                }
                free.set(free.get() - need);
                true
            })
        };
        match action {
            NextAction::Idle => Ok(false),
            NextAction::Prefill { admit } => {
                // Fixed-slot collisions only exist on the PJRT path;
                // unified residency assigns slots dynamically.
                let admit = if self.unified {
                    admit
                } else {
                    self.collision_free_admit(admit)
                };
                if admit > 0 {
                    self.run_prefill(admit)?;
                } else if !self.batcher.running.is_empty() {
                    // The whole admissible prefix collides with busy
                    // slots: decode this iteration, admit later.
                    self.run_decode()?;
                } else {
                    // Colliding with an in-flight load and nothing to
                    // decode: wait the load out, then retry.
                    let deadline = self
                        .loads
                        .earliest_deadline()
                        .ok_or_else(|| anyhow!("slot collision with no live owner"))?;
                    spin_sleep(deadline.saturating_duration_since(Instant::now()));
                    self.finish_loads();
                }
                Ok(true)
            }
            NextAction::Decode => {
                self.run_decode()?;
                Ok(true)
            }
        }
    }

    /// Drive until all submitted requests complete.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    fn emit_to(handles: &HashMap<u64, Arc<Mutex<EventChannel>>>, id: u64, event: RequestEvent) {
        if let Some(chan) = handles.get(&id) {
            chan.lock().unwrap().push(event);
        }
    }

    /// Drop a terminal request's handle, folding its event-buffer
    /// overflow count into the server's running total.
    fn retire_handle(&mut self, id: u64) {
        if let Some(chan) = self.handles.remove(&id) {
            self.retired_overflows += chan.lock().unwrap().overflows();
        }
    }

    /// Remove requests whose handles requested cancellation: queued ones
    /// simply leave the queue; running ones free their KV pages and
    /// device slot. Each gets exactly one terminal `Cancelled` event.
    fn reap_cancelled(&mut self) -> Result<()> {
        let cancelled: Vec<u64> = self
            .handles
            .iter()
            .filter(|(_, chan)| {
                let c = chan.lock().unwrap();
                c.cancel_requested() && !c.is_terminal()
            })
            .map(|(&id, _)| id)
            .collect();
        for id in cancelled {
            if self.batcher.remove_queued(id).is_none() {
                if self.batcher.remove_running(id).is_some() {
                    self.kv.free_request(id)?;
                    self.slots.remove(&id);
                } else {
                    continue; // neither queued nor running: already terminating
                }
            }
            self.metrics.cancelled(id);
            self.deferred_ids.remove(&id);
            Self::emit_to(&self.handles, id, RequestEvent::Cancelled);
            self.retire_handle(id);
        }
        Ok(())
    }

    /// Poll the async loader: adapters whose modeled transfer completed
    /// become device-resident, and running requests on them hand off from
    /// the CPU path to the resident path at this boundary (§4.3).
    fn finish_loads(&mut self) {
        let done = self.loads.poll(Instant::now());
        for adapter in done {
            if self.unified {
                // The transfer destination was the pool pages reserved at
                // admission; install the runtime slot from them now.
                if let Some(slot) = self.residency.slot_of(adapter) {
                    self.runtime.install_slot(slot, self.paged_stack(adapter));
                }
            } else if let Some(slot) = self.slot_cache.slot_of(adapter) {
                if self.slot_cache.occupant(slot) == Some(adapter) {
                    self.runtime.install_slot(slot, self.table.get(adapter));
                }
            }
            let running = self
                .batcher
                .running
                .iter()
                .filter(|r| r.adapter == adapter)
                .count();
            if running > 0 {
                self.metrics.handoffs(running);
            }
        }
    }

    /// Shrink a proposed admit count to the longest collision-free
    /// prefix: an admit whose fixed device slot is held by a *different*
    /// adapter — by a running request, an in-flight load, or an earlier
    /// admit in this very batch — must wait, otherwise its `acquire_fixed`
    /// would silently evict live weights before they execute. FIFO order
    /// is preserved (we stop at the first collider rather than skipping
    /// it).
    fn collision_free_admit(&mut self, admit: usize) -> usize {
        let mut busy: HashMap<usize, u64> = HashMap::new();
        for r in &self.batcher.running {
            if let Some(&slot) = self.slots.get(&r.id) {
                busy.insert(slot, r.adapter);
            }
        }
        for adapter in self.loads.adapters() {
            if let Some(slot) = self.slot_cache.slot_of(adapter) {
                busy.insert(slot, adapter);
            }
        }
        let mut granted = 0;
        for q in self.batcher.queue.iter().take(admit) {
            let adapter = q.req.adapter;
            let slot = self.slot_cache.fixed_slot(adapter);
            match busy.get(&slot) {
                Some(&other) if other != adapter => break,
                _ => {
                    busy.insert(slot, adapter);
                    granted += 1;
                }
            }
        }
        if granted < admit {
            // The scan stopped at a collider; count that request once
            // across however many iterations it stays blocked.
            let blocked = self.batcher.queue[granted].req.id;
            if self.deferred_ids.insert(blocked) {
                self.metrics.deferred_collisions(1);
            }
        }
        granted
    }

    /// Modeled host→device load window for an adapter (seconds).
    fn load_window(&self, adapter: u64) -> Result<f64> {
        // submit() validated installation, so a missing spec is an
        // engine invariant breach — never fabricate one.
        let spec = self
            .repo
            .get(adapter)
            .ok_or_else(|| anyhow!("adapter {adapter} missing from repository"))?;
        Ok(self.loader.load_time(spec))
    }

    /// Pick the next token for one logits row: greedy argmax, or seeded
    /// top-k sampling when the request asks for it. Sampling is seeded
    /// per (request seed, id, position) so results are independent of
    /// batch composition.
    fn pick_token(
        &self,
        logits: &[f32],
        row: usize,
        sampling: &SamplingParams,
        id: u64,
        position: usize,
    ) -> i32 {
        if sampling.top_k <= 1 {
            return self.runtime.argmax_row(logits, row);
        }
        let vocab = self.runtime.vocab();
        let slice = &logits[row * vocab..(row + 1) * vocab];
        let k = sampling.top_k.min(vocab);
        // k-sized partial scan, descending: avoids a vocab-sized
        // allocation per sampled token on the decode hot path.
        let mut top: Vec<(f32, usize)> = Vec::with_capacity(k + 1);
        for (i, &v) in slice.iter().enumerate() {
            if top.len() < k || top.last().is_some_and(|&(worst, _)| v > worst) {
                let pos = top.partition_point(|&(t, _)| t >= v);
                top.insert(pos, (v, i));
                if top.len() > k {
                    top.pop();
                }
            }
        }
        let max = top[0].0;
        let weights: Vec<f64> = top
            .iter()
            .map(|&(v, _)| f64::from(v - max).exp())
            .collect();
        let mut rng = Rng::new(
            sampling
                .seed
                .wrapping_add(id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_add((position as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)),
        );
        top[rng.discrete(&weights)].1 as i32
    }

    fn run_prefill(&mut self, admit: usize) -> Result<()> {
        let admits = self.batcher.take_admits(admit);
        let real_assist = self.cpu_assist_active();
        let now = Instant::now();

        // Acquire adapter residency (or device slots) and plan each
        // row's LoRA sourcing.
        let mut modeled_load = 0.0f64; // serialized / modeled-overlap window
        let mut slot_of: Vec<usize> = Vec::with_capacity(admits.len());
        let mut plans: Vec<RowPlan> = Vec::with_capacity(admits.len());
        let mut windows: Vec<(f64, bool)> = Vec::with_capacity(admits.len());
        // Adapters of this batch are mid-admission (no longer queued, not
        // yet running), so inflight_on can't see them — pin them against
        // pressure eviction explicitly.
        let protect: Vec<u64> = admits.iter().map(|q| q.req.adapter).collect();
        for q in &admits {
            let adapter = q.req.adapter;
            // A re-admitted (preempted) request goes through the same
            // slot/load mechanics but was already counted cold or warm at
            // its first admission — don't count it twice.
            let resumed = q.req.resume.is_some();
            // Once admitted, a previously deferred request may be counted
            // again if it ever re-collides (it can't, but keep the set
            // bounded by currently blocked requests either way).
            self.deferred_ids.remove(&q.req.id);
            // Unified path: page the adapter's weights into the pool,
            // evicting idle residents under pressure; the real CPU-assist
            // arm defers the runtime install to finish_loads (§4.3),
            // every other arm installs from the pool pages now. PJRT
            // path: fixed adapter→slot mapping — the baked LoRA stacks
            // make the slot index part of the adapter's identity (see
            // DeviceSlotCache::acquire_fixed); collision_free_admit
            // guaranteed no live occupant is evicted here.
            let (slot, cold) = if self.unified {
                let defer =
                    self.config.cold_start == ColdStartMode::CaraServe && real_assist;
                self.ensure_resident(adapter, &protect, !defer)?
            } else {
                let acq = self.slot_cache.acquire_fixed(adapter);
                (acq.slot, acq.cold)
            };
            slot_of.push(slot);
            let loading = self.loads.loading(adapter);
            match self.config.cold_start {
                ColdStartMode::Cached => {
                    // Oracle: instant residency, no load window.
                    if cold && !self.unified {
                        self.runtime.install_slot(slot, self.table.get(adapter));
                    }
                    if !resumed {
                        self.metrics.warm_admit();
                    }
                    plans.push(RowPlan::Resident);
                    windows.push((0.0, false));
                }
                ColdStartMode::OnDemand => {
                    if cold {
                        let w = self.load_window(adapter)?;
                        modeled_load += w;
                        if !self.unified {
                            self.runtime.install_slot(slot, self.table.get(adapter));
                        }
                        if !resumed {
                            self.metrics.cold_admit(false);
                        }
                        windows.push((w, true));
                    } else {
                        if !resumed {
                            self.metrics.warm_admit();
                        }
                        windows.push((0.0, false));
                    }
                    plans.push(RowPlan::Resident);
                }
                ColdStartMode::CaraServe => {
                    if cold || loading {
                        let w = if loading {
                            // Mid-load admit: only the remaining window.
                            self.loads
                                .remaining(adapter, now)
                                .map_or(0.0, |d| d.as_secs_f64())
                        } else {
                            self.load_window(adapter)?
                        };
                        if real_assist {
                            // The real mechanism: start the async load,
                            // prefill immediately via CPU-side xAB.
                            if !loading {
                                self.loads.begin(adapter, Duration::from_secs_f64(w));
                            }
                            if !resumed {
                                self.metrics.cold_admit(true);
                            }
                            plans.push(RowPlan::Assist);
                        } else {
                            // Modeled fallback: overlap the window with
                            // this iteration's compute.
                            modeled_load += w;
                            if !self.unified {
                                self.runtime
                                    .install_slot(slot, self.table.get(adapter));
                            }
                            if !resumed {
                                self.metrics.cold_admit(false);
                            }
                            plans.push(RowPlan::Resident);
                        }
                        windows.push((w, true));
                    } else {
                        if !resumed {
                            self.metrics.warm_admit();
                        }
                        plans.push(RowPlan::Resident);
                        windows.push((0.0, false));
                    }
                }
            }
        }

        // Build bucket inputs. The prefill context is the prompt for a
        // fresh admit and prompt + replayed tokens for a resumed one
        // (decode-growth preemption rebuilds KV here, silently).
        let idx: Vec<i32> = slot_of.iter().map(|&s| s as i32).collect();
        let ids: Vec<u64> = admits.iter().map(|q| q.req.id).collect();
        let tokens: Vec<Vec<i32>> = admits.iter().map(|q| q.req.context()).collect();
        let lens: Vec<i32> = tokens.iter().map(|t| t.len() as i32).collect();

        // Reserve KV pages up front: prefill streams each row's K/V
        // straight into its pages through a writer handle (zero-copy on
        // the native backend; the PJRT arm scatters its dense output
        // through the same writers). A mid-batch reservation failure
        // rolls the whole batch back before any compute runs.
        for (row, q) in admits.iter().enumerate() {
            if let Err(e) = self.kv.reserve(q.req.id, tokens[row].len()) {
                for done in &ids[..row] {
                    let _ = self.kv.free_request(*done);
                }
                return Err(anyhow!("kv reserve for request {}: {e}", q.req.id));
            }
        }

        // Execute with the configured cold-start semantics.
        let load_window = Duration::from_secs_f64(modeled_load);
        if self.config.cold_start == ColdStartMode::OnDemand {
            // Load serializes with prefill.
            spin_sleep(load_window);
        }
        // One timer per assisted row, so the TTFT breakdown attributes
        // each request its own xAB wall time (not the batch total).
        let assists: Vec<Option<TimedAssist<'_>>> = plans
            .iter()
            .map(|plan| match plan {
                RowPlan::Resident => None,
                // Assist rows are only planned when the pool is attached.
                RowPlan::Assist => Some(TimedAssist::new(
                    self.cpu.as_ref().expect("Assist planned without a pool"),
                )),
            })
            .collect();
        let rows: Vec<RowLora<'_>> = plans
            .iter()
            .enumerate()
            .map(|(i, plan)| match plan {
                RowPlan::Resident => RowLora::Slot(slot_of[i]),
                RowPlan::Assist => RowLora::Assist {
                    lora: assists[i].as_ref().expect("Assist planned without a pool"),
                    adapter: admits[i].req.adapter,
                },
            })
            .collect();
        let t0 = Instant::now();
        let out = {
            let mut writers = match self.kv.writers(&ids) {
                Ok(w) => w,
                Err(e) => {
                    drop(rows);
                    drop(assists);
                    for id in &ids {
                        let _ = self.kv.free_request(*id);
                    }
                    return Err(anyhow!("kv writers: {e}"));
                }
            };
            let mut writer_refs: Vec<&mut dyn KvWrite> = writers
                .iter_mut()
                .map(|w| w as &mut dyn KvWrite)
                .collect();
            self.runtime
                .prefill(&idx, &tokens, &lens, &rows, &mut writer_refs)
        };
        let prefill_dt = t0.elapsed().as_secs_f64();
        drop(rows);
        // Materialize the timings so `assists` (which borrows the pool)
        // is dead before the bookkeeping loop below re-borrows self.
        let assist_times: Vec<f64> = assists
            .iter()
            .map(|a| a.as_ref().map_or(0.0, |t| t.spent()))
            .collect();
        drop(assists);
        let out = match out {
            Ok(o) => o,
            Err(e) => {
                // Roll the reservations back so the pool cannot leak.
                for id in &ids {
                    let _ = self.kv.free_request(*id);
                }
                return Err(e);
            }
        };
        let modeled_overlap =
            self.config.cold_start == ColdStartMode::CaraServe && !self.cpu_assist_active();
        if modeled_overlap {
            // Modeled overlap: the iteration ends when both the compute
            // and the load window finish — max(load, prefill).
            if let Some(rem) = load_window.checked_sub(t0.elapsed()) {
                spin_sleep(rem);
            }
        }

        // Apply results per admitted request: first token (the KV rows
        // already landed in their pages), FirstToken event, stop-token
        // check. Resumed rows re-enter the running batch exactly where
        // preemption stopped them — the rebuilt prefix was already
        // streamed to the client, so nothing is emitted here.
        for (row, q) in admits.iter().enumerate() {
            let id = q.req.id;
            self.slots.insert(id, slot_of[row]);
            if let Some(rs) = &q.req.resume {
                let running = RunningReq {
                    id,
                    adapter: q.req.adapter,
                    prompt: q.req.prompt.clone(),
                    ctx: tokens[row].len(),
                    generated: rs.tokens.len(),
                    sampling: q.req.sampling.clone(),
                    priority: q.req.priority,
                    slo: q.req.slo,
                    last_token: *rs.tokens.last().expect("resume carries ≥ 1 token"),
                    stopped: false,
                };
                self.batcher.start_running(running);
                continue;
            }
            let first = self.pick_token(&out.logits, row, &q.req.sampling, id, 0);
            let (load, cold) = windows[row];
            self.metrics.prefill_breakdown(
                id,
                TtftBreakdown {
                    load,
                    prefill: prefill_dt,
                    assist: assist_times[row],
                    cold,
                },
            );
            self.metrics.token(id);
            Self::emit_to(&self.handles, id, RequestEvent::FirstToken(first));
            let running = RunningReq {
                id,
                adapter: q.req.adapter,
                prompt: q.req.prompt.clone(),
                ctx: tokens[row].len(),
                generated: 1,
                sampling: q.req.sampling.clone(),
                priority: q.req.priority,
                slo: q.req.slo,
                last_token: first,
                stopped: q.req.sampling.stop_tokens.contains(&first),
            };
            if running.finished() {
                self.finish(running)?;
            } else {
                self.batcher.start_running(running);
            }
        }
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        let batch = self.batcher.running.len();
        let bucket = self
            .runtime
            .pick_decode_bucket(batch)
            .ok_or_else(|| anyhow!("no decode bucket for batch {batch}"))?;
        let (bb, m) = bucket;

        let ids: Vec<u64> = self.batcher.running.iter().map(|r| r.id).collect();
        let idx: Vec<i32> = self
            .batcher
            .running
            .iter()
            .map(|r| self.slots[&r.id] as i32)
            .collect();
        let tokens: Vec<i32> = self.batcher.running.iter().map(|r| r.last_token).collect();
        let pos: Vec<i32> = self.batcher.running.iter().map(|r| r.ctx as i32).collect();

        // Requests whose adapter is still loading keep decoding through
        // the CPU-assisted path; the rest use the resident bgmv path.
        let real_assist = self.cpu_assist_active();
        let assist: Option<TimedAssist<'_>> = self.cpu.as_ref().map(TimedAssist::new);
        let rows: Vec<RowLora<'_>> = self
            .batcher
            .running
            .iter()
            .zip(&idx)
            .map(|(r, &slot)| {
                if real_assist && self.loads.loading(r.adapter) {
                    RowLora::Assist {
                        lora: assist.as_ref().expect("assist active without a pool"),
                        adapter: r.adapter,
                    }
                } else {
                    RowLora::Slot(slot as usize)
                }
            })
            .collect();
        let out = if self.runtime.needs_dense_kv() {
            // PJRT fallback: its compiled artifacts take dense [layers,
            // batch, M, hidden] inputs, so assemble into the reused
            // scratch buffers (the pre-paged contract).
            let (mut k, mut v) = (
                std::mem::take(&mut self.k_scratch),
                std::mem::take(&mut self.v_scratch),
            );
            self.kv.assemble_into(&ids, bb, m, &mut k, &mut v)?;
            let out = self.runtime.decode_dense(&idx, &tokens, &pos, &k, &v, &rows);
            self.k_scratch = k;
            self.v_scratch = v;
            out?
        } else {
            // Zero-copy hot path: hand the runtime per-request block
            // tables over the page pool; attention reads rows in place
            // — no per-step KV materialization at all (§Perf).
            let view = self.kv.paged_view(&ids).map_err(|e| anyhow!("{e}"))?;
            self.runtime.decode_paged(&idx, &tokens, &pos, &view, &rows)?
        };
        drop(rows);
        let assist_dt = assist.as_ref().map_or(0.0, |a| a.spent());
        // Explicit drop: the timer's Mutex gives it drop glue, which
        // would otherwise pin the `self.cpu` borrow across the `&mut
        // self` bookkeeping below.
        drop(assist);
        if assist_dt > 0.0 {
            self.metrics.assist_decode(assist_dt);
        }
        self.apply_decode_out(&ids, &out, bb)
    }

    /// Shared post-decode bookkeeping: sampling, KV append, events.
    ///
    /// Decode-growth headroom: a request crossing a page boundary with
    /// an empty pool used to surface `OutOfPages` as a fatal engine
    /// error. Instead, on the unified path idle adapters are paged out
    /// first (weights are re-fetchable; KV is not), and only then is the
    /// youngest preemptible running request evicted — its pages freed,
    /// itself re-queued with a [`ResumeState`] — and the append retried,
    /// so the serving loop keeps going and the preempted request resumes
    /// later with an unchanged client-visible stream.
    fn apply_decode_out(
        &mut self,
        ids: &[u64],
        out: &crate::runtime::DecodeOut,
        bb: usize,
    ) -> Result<()> {
        // Preemption order is recorded in a Vec (not a set) so re-queue
        // order — and with it subsequent admission — is deterministic.
        let mut preempted: Vec<u64> = Vec::new();
        for (row, id) in ids.iter().enumerate() {
            if preempted.contains(id) {
                continue;
            }
            loop {
                match self.kv.append_token(*id, &out.k_new, &out.v_new, bb, row) {
                    Ok(()) => break,
                    Err(KvError::OutOfPages { need, free }) => {
                        // Unified pool: decode growth first reclaims an
                        // idle adapter's weight pages; only when every
                        // resident adapter is busy does it sacrifice a
                        // running request.
                        if self.unified && self.evict_idle_adapter(&[])? {
                            continue;
                        }
                        let victim =
                            self.pick_preempt_victim(&preempted).ok_or_else(|| {
                                anyhow!(
                                    "out of KV pages (need {need}, free {free}) \
                                     with no preemptible request"
                                )
                            })?;
                        self.kv.free_request(victim)?;
                        preempted.push(victim);
                        if victim == *id {
                            // This row yields its own step; it resumes
                            // from the pre-step state after re-admission.
                            break;
                        }
                    }
                    Err(e) => return Err(anyhow!("kv append for request {id}: {e}")),
                }
            }
            if preempted.contains(id) {
                continue;
            }
            let tok = {
                let r = &self.batcher.running[row];
                self.pick_token(&out.logits, row, &r.sampling, *id, r.generated)
            };
            self.metrics.token(*id);
            Self::emit_to(&self.handles, *id, RequestEvent::Token(tok));
            let r = &mut self.batcher.running[row];
            r.generated += 1;
            r.ctx += 1;
            r.last_token = tok;
            if r.sampling.stop_tokens.contains(&tok) {
                r.stopped = true;
            }
        }
        self.requeue_preempted(&preempted);
        for done in self.batcher.reap_finished() {
            self.finish(done)?;
        }
        Ok(())
    }

    /// The youngest (most recently admitted, i.e. highest id) running
    /// request that can be preempted: not already preempted, not
    /// finished (a finished row's pages free at reap anyway), and
    /// resumable — its rebuilt context must fit a prefill bucket. `None`
    /// when fewer than two live requests remain: self-preempting the
    /// lone page holder would re-admit into the same exhausted pool and
    /// livelock, so that case stays a hard error.
    fn pick_preempt_victim(&self, preempted: &[u64]) -> Option<u64> {
        let live: Vec<&RunningReq> = self
            .batcher
            .running
            .iter()
            .filter(|r| !preempted.contains(&r.id) && !r.finished())
            .collect();
        if live.len() < 2 {
            return None;
        }
        live.iter()
            .filter(|r| {
                // Resumable: the rebuilt context must fit a prefill
                // bucket and be re-admittable into the pool at all.
                r.ctx <= self.max_prompt
                    && self.kv.pages_for(r.ctx) <= self.kv.total_pages()
            })
            .max_by_key(|r| r.id)
            .map(|r| r.id)
    }

    /// Move preempted requests out of the running batch and back into
    /// the admission queue as resume entries (priority preserved; FIFO
    /// within their class puts them behind newer arrivals — "re-admit
    /// later"). Their KV pages were already freed at preemption time.
    fn requeue_preempted(&mut self, preempted: &[u64]) {
        for &id in preempted {
            let Some(r) = self.batcher.remove_running(id) else {
                continue;
            };
            self.slots.remove(&id);
            let tokens = self
                .handles
                .get(&id)
                .expect("preempted request has a live handle")
                .lock()
                .unwrap()
                .tokens()
                .to_vec();
            self.metrics.preemption();
            self.batcher.enqueue(ActiveRequest {
                id,
                adapter: r.adapter,
                prompt: r.prompt,
                sampling: r.sampling,
                priority: r.priority,
                slo: r.slo,
                resume: Some(ResumeState { tokens }),
            });
        }
    }

    fn finish(&mut self, r: RunningReq) -> Result<()> {
        self.kv.free_request(r.id)?;
        self.slots.remove(&r.id);
        self.metrics.finished(r.id);
        let reason = if r.stopped {
            FinishReason::Stop
        } else {
            FinishReason::Length
        };
        Self::emit_to(&self.handles, r.id, RequestEvent::Finished(reason));
        self.retire_handle(r.id);
        Ok(())
    }
}

impl ServingFront for InferenceServer {
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        InferenceServer::submit(self, req)
    }

    fn poll(&mut self) -> Result<bool> {
        self.step()
    }

    fn cancel(&mut self, id: u64) -> bool {
        InferenceServer::cancel(self, id)
    }

    fn stats(&self) -> ServerStats {
        InferenceServer::stats(self)
    }

    /// Register the adapter in the host repository and install its
    /// weights in the shared host-memory table — digest-verified from
    /// the attached artifact store when it holds a manifest for the
    /// adapter, synthetically seeded otherwise (provenance counted in
    /// [`ServingFront::install_source_stats`]).
    /// Requests against uninstalled adapters are rejected at submission.
    /// Callable at any point in the server's lifetime — the coordinator
    /// installs adapters on live servers during migration. Re-installing
    /// the identical spec is a no-op; a re-install that *changes* the
    /// spec refreshes both the host table and any device-resident slot,
    /// and refuses while requests on the adapter are in flight (swapping
    /// weights under a live request would corrupt its token stream).
    fn install_adapter(&mut self, spec: &LoraSpec) -> Result<()> {
        match self.repo.get(spec.id) {
            Some(existing) if existing == spec => return Ok(()),
            Some(_) => {
                let busy = self.inflight_on(spec.id);
                anyhow::ensure!(
                    busy == 0,
                    "adapter {} busy: {busy} in-flight requests block a weight swap",
                    spec.id
                );
            }
            None => {}
        }
        let hidden = self.runtime.hidden();
        let stored = match &self.store {
            Some(store) => {
                // The lock-idiom `.unwrap()` the hot-path lint exempts:
                // a poisoned store lock is unrecoverable process state.
                match store.lock().unwrap().load_stack(spec.id, hidden) {
                    Ok((rank, stack)) => {
                        anyhow::ensure!(
                            rank == spec.rank,
                            "artifact store holds adapter {} at rank {rank}, spec says {}",
                            spec.id,
                            spec.rank
                        );
                        Some(stack)
                    }
                    // No manifest ⇒ the synthetic fallback below. Every
                    // *other* store failure (corrupt blob, size
                    // mismatch) must refuse the install: serving wrong
                    // bytes is worse than refusing.
                    Err(StoreError::NotFound { .. }) => None,
                    Err(e) => return Err(anyhow!("artifact store: {e}")),
                }
            }
            None => None,
        };
        match stored {
            Some(stack) => {
                self.table.install(spec.id, stack);
                self.install_sources.store_hits += 1;
            }
            None => {
                self.table.install_synthetic(spec.id, hidden, spec.rank);
                self.install_sources.synthetic_seeds += 1;
            }
        }
        self.repo.install(spec.clone());
        if self.unified {
            // A spec change invalidates any paged residency (the rank —
            // and with it the page footprint — may differ): release the
            // stale pages; the next request pages the new weights in.
            if let Some(slot) = self.residency.evict(spec.id) {
                self.kv.free_adapter(spec.id);
                self.runtime.install_slot(slot, None);
            }
        } else if let Some(slot) = self.slot_cache.slot_of(spec.id) {
            // Device-resident already: refresh the baked slot stack so
            // warm admits serve the new weights.
            self.runtime.install_slot(slot, self.table.get(spec.id));
        }
        Ok(())
    }

    /// Remove the adapter from this server: abort any in-flight load,
    /// clear its device slot and runtime weight stack, and drop it from
    /// the repository and host-memory table. Refuses while requests on
    /// the adapter are queued or running — in-flight token streams stay
    /// bitwise untouched; the caller retries after they drain.
    fn uninstall_adapter(&mut self, adapter: u64) -> Result<()> {
        anyhow::ensure!(
            self.repo.get(adapter).is_some(),
            "adapter {adapter} not installed"
        );
        let busy = self.inflight_on(adapter);
        anyhow::ensure!(busy == 0, "adapter {adapter} busy: {busy} in-flight requests");
        self.loads.cancel(adapter);
        if self.unified {
            if let Some(slot) = self.residency.evict(adapter) {
                self.kv.free_adapter(adapter);
                self.runtime.install_slot(slot, None);
            }
        } else if let Some(slot) = self.slot_cache.evict(adapter) {
            self.runtime.install_slot(slot, None);
        }
        self.repo.remove(adapter);
        self.table.remove(adapter);
        Ok(())
    }

    /// Load the adapter's weights ahead of traffic, so its first request
    /// admits warm instead of paying the cold-start window. On the
    /// unified path this is pre-*paging*: weights go into pool pages,
    /// evicting idle residents if needed; refuses (`Ok(false)`) when the
    /// pool or every residency slot is pinned by busy adapters. On the
    /// PJRT path, refuses when the fixed slot is pinned by a *different*
    /// adapter with live requests or an in-flight load — pre-warming
    /// must never evict weights a running request reads.
    fn prewarm_adapter(&mut self, adapter: u64) -> Result<bool> {
        anyhow::ensure!(
            self.repo.get(adapter).is_some(),
            "adapter {adapter} not installed"
        );
        if self.unified {
            return Ok(self.ensure_resident(adapter, &[], true).is_ok());
        }
        let slot = self.slot_cache.fixed_slot(adapter);
        if self.slot_cache.occupant(slot) == Some(adapter) {
            return Ok(true); // already resident
        }
        if let Some(other) = self.slot_cache.occupant(slot) {
            if self.inflight_on(other) > 0 || self.loads.loading(other) {
                return Ok(false);
            }
        }
        let acq = self.slot_cache.acquire_fixed(adapter);
        debug_assert!(acq.cold);
        self.runtime.install_slot(acq.slot, self.table.get(adapter));
        Ok(true)
    }

    fn cold_start_stats(&self) -> Option<ColdStartStats> {
        Some(self.metrics.cold_start().clone())
    }

    fn install_source_stats(&self) -> InstallSourceStats {
        self.install_sources
    }
}

/// Sleep that is accurate at sub-millisecond scale (std sleep can
/// overshoot badly; load windows here are single-digit ms). The OS
/// sleep covers everything but the last ~200 µs; only that tail is
/// spun — the previous version busy-spun entire sub-2 ms windows and a
/// full trailing millisecond of larger ones, burning a core inside
/// every modeled load window.
fn spin_sleep(d: Duration) {
    const SPIN_TAIL: Duration = Duration::from_micros(200);
    if d.is_zero() {
        return;
    }
    let t0 = Instant::now();
    if d > SPIN_TAIL {
        std::thread::sleep(d - SPIN_TAIL);
    }
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

// Engine integration tests live in rust/tests/integration_engine.rs
// (PJRT backend; skip without artifacts), rust/tests/integration_front.rs,
// and rust/tests/integration_coldstart.rs (native backend + CPU assist;
// always runs).
