//! The LLM inference server (the paper's per-server component, §3):
//! request queue + continuous batcher + paged KV-cache manager + the
//! PJRT model runtime + cold-start handling.
//!
//! - [`api`] — request/response types and per-request lifecycle state.
//! - [`kvcache`] — paged KV-cache manager (block-granular alloc/free,
//!   batch assembly for the decode bucket inputs).
//! - [`batcher`] — iteration-level continuous-batching policy (Fig 2):
//!   arrivals preempt decode; completed requests leave every iteration.
//! - [`engine`] — [`InferenceServer`]: drives the runtime, streams
//!   tokens, records TTFT / time-per-token / request latency, and
//!   applies the serving mode's cold-start behaviour (Cached / OnDemand
//!   / CaraServe overlap).
//! - [`metrics`] — per-request metric recording and summaries.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod kvcache;
pub mod metrics;

pub use api::{InferenceRequest, RequestOutput};
pub use batcher::{Batcher, NextAction};
pub use engine::{ColdStartMode, EngineConfig, InferenceServer};
pub use kvcache::KvCacheManager;
pub use metrics::{MetricsRecorder, RequestRecord};
