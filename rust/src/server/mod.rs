//! The LLM inference server (the paper's per-server component, §3):
//! streaming request-lifecycle API + continuous batcher + paged
//! KV-cache manager + the PJRT model runtime + cold-start handling.
//!
//! - [`api`] — the request-lifecycle API: [`ServeRequest`] builder,
//!   [`RequestHandle`] event streams with cancellation, and the
//!   [`ServingFront`] trait both the engine and the simulator
//!   ([`crate::sim::front::SimFront`]) implement — including the
//!   runtime adapter-management surface (`install_adapter` /
//!   `uninstall_adapter` / `prewarm_adapter`) the
//!   [`crate::coordinator`] drives for placement and live migration.
//! - [`kvcache`] — paged KV-cache manager: block-granular alloc/free,
//!   zero-copy [`PagedKv`] views + [`PageWriter`] handles for the
//!   native runtime, dense batch assembly for the PJRT fallback.
//! - [`batcher`] — iteration-level continuous-batching policy (Fig 2):
//!   arrivals preempt decode; completed requests leave every iteration;
//!   priority classes order admission.
//! - [`engine`] — [`InferenceServer`]: drives a [`crate::runtime::Runtime`]
//!   backend (PJRT or native), streams per-token [`RequestEvent`]s,
//!   honors cancellation and stop tokens mid-flight, and applies the
//!   serving mode's cold-start behaviour — including the real §4
//!   CPU-assisted path (shm worker pool + async load windows + §4.3
//!   decode handoff) when a pool is attached. Decode-growth KV pressure
//!   preempts/re-queues the youngest request instead of erroring.
//! - [`cluster`] — [`ClusterFront`]: the §5 rank-aware scheduler in
//!   front of N boxed backends (real engines, simulators, or a mix),
//!   itself a [`ServingFront`] — routing, re-routing on backend
//!   refusal, and fan-out cancellation behind the same trait. Backend
//!   faults are contained (catch-unwind at the poll boundary), health
//!   is tracked per backend (Healthy→Suspect→Down→Probation), and
//!   in-flight requests fail over to survivors with bitwise-identical
//!   client streams via the resume machinery.
//! - [`metrics`] — per-request TTFT / TPOT / latency recording, SLO
//!   attainment, the cold-start TTFT decomposition, and per-mode
//!   cold-start counters.

pub mod api;
pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod kvcache;
pub mod metrics;

pub use api::{
    FinishReason, InstallSourceStats, LifecycleState, Priority, RejectReason, RequestEvent,
    RequestHandle, ResumeState, SamplingParams, ServeRequest, ServingFront, SloSpec,
};
pub use batcher::{Batcher, NextAction};
pub use cluster::{ClusterFront, Health, RetryPolicy};
pub use engine::{ColdStartMode, EngineConfig, InferenceServer};
pub use kvcache::{KvCacheManager, KvError, PageWriter, PagedKv};
pub use metrics::{ColdStartStats, MetricsRecorder, RequestRecord, TtftBreakdown};
