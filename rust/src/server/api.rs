//! The streaming request-lifecycle API.
//!
//! CaraServe's headline properties — cold-start masking and rank-aware
//! SLO scheduling — are *per-token, per-request* properties, so the
//! serving surface is built around an observable request lifecycle
//! rather than a batch-drain call:
//!
//! - [`ServeRequest`] — what a client submits: adapter id, prompt,
//!   [`SamplingParams`], a [`Priority`] class, and an optional
//!   [`SloSpec`] carried on the wire to the scheduler and metrics.
//! - [`RequestHandle`] — returned by `submit()`: a pollable stream of
//!   [`RequestEvent`]s plus mid-flight [`RequestHandle::cancel`].
//! - [`ServingFront`] — the uniform backend surface (submit / poll /
//!   cancel / stats) implemented by both the PJRT engine
//!   ([`crate::server::InferenceServer`]) and the simulator
//!   ([`crate::sim::front::SimFront`]), so schedulers and drivers route
//!   against one interface.
//!
//! Every submitted request terminates in **exactly one** terminal event:
//! `Finished`, `Cancelled`, or `Rejected`.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::model::LoraSpec;
use crate::scheduler::ServerStats;

/// Request priority class (admission order within a backend's queue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Throughput-oriented background work; admitted last.
    Batch,
    /// The default class.
    #[default]
    Standard,
    /// Latency-sensitive traffic; jumps ahead of other classes.
    Interactive,
}

/// Per-request latency SLO (§5, §7.5: TTFT and per-output-token targets).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Time-to-first-token target, milliseconds.
    pub ttft_ms: f64,
    /// Time-per-output-token (decode) target, milliseconds.
    pub tpot_ms: f64,
}

/// Token sampling configuration carried with each request.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Generation budget (≥ 1).
    pub max_new_tokens: usize,
    /// Generation halts after emitting any of these tokens.
    pub stop_tokens: Vec<i32>,
    /// `0` or `1` ⇒ greedy argmax; `k > 1` ⇒ top-k sampling.
    pub top_k: usize,
    /// Seed for top-k sampling (ignored when greedy).
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_new_tokens: 16,
            stop_tokens: Vec::new(),
            top_k: 0,
            seed: 0,
        }
    }
}

/// A user inference request, built fluently:
///
/// ```ignore
/// let req = ServeRequest::new(adapter, prompt)
///     .max_new_tokens(32)
///     .stop_token(2)
///     .priority(Priority::Interactive)
///     .slo(200.0, 50.0);
/// let handle = front.submit(req);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRequest {
    /// LoRA adapter id (must be installed/registered on the backend).
    pub adapter: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Sampling configuration.
    pub sampling: SamplingParams,
    /// Priority class.
    pub priority: Priority,
    /// Optional latency SLO.
    pub slo: Option<SloSpec>,
    /// `Some` when this submission *resumes* a request whose previous
    /// backend died: the tokens already generated (and delivered to the
    /// client). The backend re-prefills `prompt + tokens[..n-1]`, emits
    /// nothing for the rebuilt prefix, and continues decoding at the
    /// recorded position — so the client stream stays bitwise identical
    /// across the failover. Fresh client submissions leave it `None`.
    pub resume: Option<ResumeState>,
}

impl ServeRequest {
    /// A request against `adapter` with default sampling and priority.
    pub fn new(adapter: u64, prompt: Vec<i32>) -> ServeRequest {
        ServeRequest {
            adapter,
            prompt,
            sampling: SamplingParams::default(),
            priority: Priority::default(),
            slo: None,
            resume: None,
        }
    }

    /// Set the generation budget.
    pub fn max_new_tokens(mut self, n: usize) -> ServeRequest {
        self.sampling.max_new_tokens = n;
        self
    }

    /// Add one stop token.
    pub fn stop_token(mut self, token: i32) -> ServeRequest {
        self.sampling.stop_tokens.push(token);
        self
    }

    /// Enable seeded top-k sampling.
    pub fn top_k(mut self, k: usize, seed: u64) -> ServeRequest {
        self.sampling.top_k = k;
        self.sampling.seed = seed;
        self
    }

    /// Replace the whole sampling configuration.
    pub fn sampling(mut self, sampling: SamplingParams) -> ServeRequest {
        self.sampling = sampling;
        self
    }

    /// Set the priority class.
    pub fn priority(mut self, priority: Priority) -> ServeRequest {
        self.priority = priority;
        self
    }

    /// Attach a latency SLO (TTFT and per-output-token, milliseconds).
    pub fn slo(mut self, ttft_ms: f64, tpot_ms: f64) -> ServeRequest {
        self.slo = Some(SloSpec { ttft_ms, tpot_ms });
        self
    }
}

/// Why a request finished generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generation budget (`max_new_tokens`) exhausted.
    Length,
    /// A configured stop token was emitted.
    Stop,
}

/// Why a request was refused — the typed taxonomy carried by
/// [`RequestEvent::Rejected`]. Every [`ServingFront`] backend rejects
/// through these variants, so the router and tests match on structure
/// instead of substrings; `Display` renders the human-readable message
/// the CLI and logs print.
#[derive(Debug, Clone, PartialEq)]
pub enum RejectReason {
    /// Prompt length outside `(0, max_prompt]`.
    PromptBounds {
        /// Submitted prompt length.
        len: usize,
        /// The backend's largest admissible prompt.
        max_prompt: usize,
    },
    /// `max_new_tokens` < 1.
    EmptyBudget,
    /// `prompt + max_new_tokens` exceeds the backend's KV capacity.
    KvCapacity {
        /// The backend's KV token capacity.
        kv_capacity: usize,
    },
    /// The adapter is not installed on the backend (engine/sim check).
    AdapterNotInstalled {
        /// The requested adapter id.
        adapter: u64,
    },
    /// The adapter is not in the cluster's [`GlobalRegistry`]
    /// (routing-front check).
    ///
    /// [`GlobalRegistry`]: crate::scheduler::registry::GlobalRegistry
    AdapterNotRegistered {
        /// The requested adapter id.
        adapter: u64,
    },
    /// Unified pool: adapter weights + one prompt page can never fit,
    /// even with every other page free.
    PoolTooSmall {
        /// The requested adapter id.
        adapter: u64,
        /// Total pages in the unified pool.
        pool_pages: usize,
    },
    /// Routing: every candidate server refused or was excluded; carries
    /// the last backend refusal when one was observed.
    NoEligibleServer {
        /// The final refusal that exhausted the candidate list.
        last: Option<Box<RejectReason>>,
    },
    /// Routing: the policy re-picked a server that just refused
    /// (policy bug surfaced as a rejection, not a livelock).
    PolicyRepick {
        /// The re-picked server index.
        server: usize,
    },
    /// Graceful degradation: the cluster is shedding this request's
    /// [`Priority`] class instead of queuing unboundedly.
    Overloaded {
        /// Backends currently able to take work.
        healthy: usize,
        /// The priority class being shed.
        shed: Priority,
    },
    /// The owning backend died mid-flight and no surviving server
    /// could resume the request.
    BackendFailed {
        /// Index of the failed backend.
        server: usize,
    },
    /// Backend-specific reason outside the shared taxonomy.
    Other(String),
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::PromptBounds { len, max_prompt } => {
                write!(f, "prompt length {len} outside (0, {max_prompt}]")
            }
            RejectReason::EmptyBudget => write!(f, "must generate ≥ 1 token"),
            RejectReason::KvCapacity { kv_capacity } => {
                write!(f, "prompt+output exceeds KV capacity {kv_capacity}")
            }
            RejectReason::AdapterNotInstalled { adapter } => {
                write!(f, "adapter {adapter} not installed")
            }
            RejectReason::AdapterNotRegistered { adapter } => {
                write!(f, "adapter {adapter} not registered")
            }
            RejectReason::PoolTooSmall {
                adapter,
                pool_pages,
            } => write!(
                f,
                "adapter {adapter} + prompt can never fit the {pool_pages}-page unified pool"
            ),
            RejectReason::NoEligibleServer { last: None } => write!(f, "no eligible server"),
            RejectReason::NoEligibleServer { last: Some(r) } => {
                write!(f, "no eligible server (last refusal: {r})")
            }
            RejectReason::PolicyRepick { server } => {
                write!(f, "policy re-picked refusing server {server}")
            }
            RejectReason::Overloaded { healthy, shed } => write!(
                f,
                "overloaded: shedding {shed:?}-priority traffic ({healthy} healthy backends)"
            ),
            RejectReason::BackendFailed { server } => write!(
                f,
                "backend {server} failed; no surviving server could resume the request"
            ),
            RejectReason::Other(s) => f.write_str(s),
        }
    }
}

impl From<String> for RejectReason {
    fn from(s: String) -> RejectReason {
        RejectReason::Other(s)
    }
}

impl From<&str> for RejectReason {
    fn from(s: &str) -> RejectReason {
        RejectReason::Other(s.to_string())
    }
}

/// One step of a request's observable lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestEvent {
    /// Validated and accepted into the serving queue.
    Admitted,
    /// Placed on backend `server` by a routing front
    /// ([`crate::server::ClusterFront`]) — non-terminal, emitted between
    /// `Admitted` and `FirstToken` so clients observe placement.
    /// Single-backend fronts never emit it.
    Routed {
        /// Index of the chosen backend within the routing front.
        server: usize,
    },
    /// Prefill completed; the first output token.
    FirstToken(i32),
    /// One decode-step output token.
    Token(i32),
    /// Terminal: generation completed.
    Finished(FinishReason),
    /// Re-placed on backend `to` after backend `from` died or stalled
    /// mid-flight — non-terminal, emitted by a routing front before the
    /// surviving backend's continuation tokens. The token stream stays
    /// bitwise identical across it (the resume machinery re-prefills
    /// `prompt + generated` without replaying delivered tokens).
    Rerouted {
        /// The failed backend the request was moved off.
        from: usize,
        /// The surviving backend now carrying the request.
        to: usize,
    },
    /// Terminal: cancelled by the client before completion.
    Cancelled,
    /// Terminal: the backend refused the request (with the typed reason).
    Rejected(RejectReason),
}

impl RequestEvent {
    /// Is this one of the three terminal events?
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            RequestEvent::Finished(_) | RequestEvent::Cancelled | RequestEvent::Rejected(_)
        )
    }
}

/// Coarse request state, derived from the event stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifecycleState {
    /// Admitted, waiting for prefill.
    Queued,
    /// Emitted at least one token; decoding.
    Running,
    /// Terminal: finished generating.
    Finished,
    /// Terminal: cancelled.
    Cancelled,
    /// Terminal: rejected at submission.
    Rejected,
}

impl LifecycleState {
    /// Is the request done (any terminal state)?
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            LifecycleState::Finished | LifecycleState::Cancelled | LifecycleState::Rejected
        )
    }
}

/// Default bound on an [`EventChannel`]'s undelivered-event buffer.
/// Past it, consecutive `Token` events coalesce (see
/// [`EventChannel::push`]); the token *values* always survive in the
/// channel's token log.
pub const DEFAULT_EVENT_CAP: usize = 1024;

/// The shared per-request channel between a backend and its
/// [`RequestHandle`]: the backend pushes events, the handle polls them.
///
/// Public so [`ServingFront`] backends outside this module (the
/// simulator front) can emit events; user code only ever touches
/// [`RequestHandle`].
///
/// The undelivered-event buffer is bounded: a consumer that stops
/// polling (a stalled remote router, a hung HTTP client) must not grow
/// it without limit. Past the cap, consecutive `Token` events coalesce
/// — the newest token overwrites the buffered one and the overflow is
/// counted — while lifecycle events (and every terminal) always
/// enqueue, so the exactly-one-terminal contract is never traded for
/// the bound.
#[derive(Debug)]
pub struct EventChannel {
    events: VecDeque<RequestEvent>,
    tokens: Vec<i32>,
    cancel_requested: bool,
    state: Option<LifecycleState>,
    /// Buffer bound; `Token` events coalesce past it.
    cap: usize,
    /// `Token` events coalesced away by the cap (each one a token the
    /// consumer will not see as its own event, though its value is in
    /// `tokens`). Surfaced as `ServerStats::event_overflows`.
    overflows: usize,
}

impl Default for EventChannel {
    fn default() -> EventChannel {
        EventChannel {
            events: VecDeque::new(),
            tokens: Vec::new(),
            cancel_requested: false,
            state: None,
            cap: DEFAULT_EVENT_CAP,
            overflows: 0,
        }
    }
}

impl EventChannel {
    /// Record an event, updating derived token/state views.
    ///
    /// Panics if pushed after a terminal event — backends must uphold the
    /// exactly-one-terminal-event contract.
    pub fn push(&mut self, event: RequestEvent) {
        assert!(
            !self.state.is_some_and(|s| s.is_terminal()),
            "event {event:?} pushed after terminal state {:?}",
            self.state
        );
        match &event {
            RequestEvent::Admitted => self.state = Some(LifecycleState::Queued),
            RequestEvent::Routed { .. } | RequestEvent::Rerouted { .. } => {
                // Placement is metadata: record Queued only if nothing
                // has run yet (re-routing must not regress a stream).
                if self.state.is_none() {
                    self.state = Some(LifecycleState::Queued);
                }
            }
            RequestEvent::FirstToken(t) | RequestEvent::Token(t) => {
                self.tokens.push(*t);
                self.state = Some(LifecycleState::Running);
            }
            RequestEvent::Finished(_) => self.state = Some(LifecycleState::Finished),
            RequestEvent::Cancelled => self.state = Some(LifecycleState::Cancelled),
            RequestEvent::Rejected(_) => self.state = Some(LifecycleState::Rejected),
        }
        // Buffer bound: a plain Token landing on a full buffer whose
        // newest entry is also a plain Token coalesces into it. Only
        // this pairing is eligible — FirstToken, placement events, and
        // terminals always enqueue — so a drained prefix of the stream
        // never changes shape, only how many interior Token events
        // represent the (fully preserved) token log.
        let coalesce = self.events.len() >= self.cap
            && matches!(event, RequestEvent::Token(_))
            && matches!(self.events.back(), Some(RequestEvent::Token(_)));
        if coalesce {
            if let Some(back) = self.events.back_mut() {
                *back = event;
            }
            self.overflows += 1;
        } else {
            self.events.push_back(event);
        }
    }

    /// `Token` events coalesced away by the buffer bound so far.
    pub fn overflows(&self) -> usize {
        self.overflows
    }

    /// Override the undelivered-event buffer bound (tests; tiny caps
    /// make the coalescing path observable).
    pub fn set_event_cap(&mut self, cap: usize) {
        self.cap = cap.max(1);
    }

    /// Has the client requested cancellation?
    pub fn cancel_requested(&self) -> bool {
        self.cancel_requested
    }

    /// Mark a cancellation request (observed by the backend at its next
    /// iteration boundary).
    pub fn request_cancel(&mut self) {
        self.cancel_requested = true;
    }

    /// Request cancellation unless the request already terminated.
    /// Returns true if it was still live (a terminal `Cancelled` event
    /// will follow) — the one cancel semantic every backend shares.
    pub fn try_request_cancel(&mut self) -> bool {
        if self.is_terminal() {
            false
        } else {
            self.cancel_requested = true;
            true
        }
    }

    /// Has a terminal event been recorded?
    pub fn is_terminal(&self) -> bool {
        self.state.is_some_and(|s| s.is_terminal())
    }

    /// Current derived state (Queued before any event).
    pub fn state(&self) -> LifecycleState {
        self.state.unwrap_or(LifecycleState::Queued)
    }

    /// Tokens emitted so far.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Pop the oldest undelivered event.
    pub fn pop_event(&mut self) -> Option<RequestEvent> {
        self.events.pop_front()
    }
}

/// A client's view of one in-flight request: poll events, read the
/// token stream, cancel. Cheap to clone; all clones observe the same
/// request (but each event is delivered to only one poller).
#[derive(Debug, Clone)]
pub struct RequestHandle {
    id: u64,
    channel: Arc<Mutex<EventChannel>>,
}

impl RequestHandle {
    /// Create a handle plus the backend half of its channel.
    pub fn new(id: u64) -> (RequestHandle, Arc<Mutex<EventChannel>>) {
        let channel = Arc::new(Mutex::new(EventChannel::default()));
        (
            RequestHandle {
                id,
                channel: Arc::clone(&channel),
            },
            channel,
        )
    }

    /// The backend-assigned request id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Pop the next undelivered lifecycle event, if any.
    pub fn poll_event(&self) -> Option<RequestEvent> {
        self.channel.lock().unwrap().pop_event()
    }

    /// Drain all undelivered events.
    pub fn drain_events(&self) -> Vec<RequestEvent> {
        let mut chan = self.channel.lock().unwrap();
        let mut out = Vec::new();
        while let Some(ev) = chan.pop_event() {
            out.push(ev);
        }
        out
    }

    /// Request cancellation. The backend acknowledges with a terminal
    /// `Cancelled` event at its next iteration boundary (a no-op if the
    /// request already terminated).
    pub fn cancel(&self) {
        self.channel.lock().unwrap().request_cancel();
    }

    /// Current coarse state.
    pub fn state(&self) -> LifecycleState {
        self.channel.lock().unwrap().state()
    }

    /// Has the request reached a terminal state?
    pub fn is_terminal(&self) -> bool {
        self.channel.lock().unwrap().is_terminal()
    }

    /// Snapshot of the tokens emitted so far.
    pub fn tokens(&self) -> Vec<i32> {
        self.channel.lock().unwrap().tokens().to_vec()
    }
}

/// The backend-independent admission checks: prompt within `(0,
/// max_prompt]`, a positive generation budget, and `prompt + output ≤
/// kv_capacity + 1`. Shared by every [`ServingFront`] backend so the
/// same request is admitted (or rejected, with the same message) on
/// engine and simulator alike; only the adapter-installation check
/// stays backend-specific.
pub fn validate_shape(
    req: &ServeRequest,
    max_prompt: usize,
    kv_capacity: usize,
) -> Result<(), RejectReason> {
    if req.prompt.is_empty() || req.prompt.len() > max_prompt {
        return Err(RejectReason::PromptBounds {
            len: req.prompt.len(),
            max_prompt,
        });
    }
    if req.sampling.max_new_tokens < 1 {
        return Err(RejectReason::EmptyBudget);
    }
    let total = req.prompt.len().saturating_add(req.sampling.max_new_tokens);
    if total > kv_capacity.saturating_add(1) {
        return Err(RejectReason::KvCapacity { kv_capacity });
    }
    Ok(())
}

/// Insertion position for a new request of priority `p` into a queue
/// whose current priorities are yielded front-to-back: after every
/// entry of equal-or-higher priority, ahead of lower ones (FIFO within
/// a class). Shared by every [`ServingFront`] backend so their
/// admission orders cannot drift apart.
pub fn priority_insert_pos<I>(queue: I, p: Priority) -> usize
where
    I: IntoIterator<Item = Priority>,
    I::IntoIter: DoubleEndedIterator + ExactSizeIterator,
{
    queue.into_iter().rposition(|q| q >= p).map_or(0, |i| i + 1)
}

/// The tightest per-output-token SLO (seconds) among an iterator of
/// per-request SLOs — the `ServerStats::tpot_slo` every backend
/// reports, computed one way.
pub fn tightest_tpot_slo<'a, I>(slos: I) -> Option<f64>
where
    I: IntoIterator<Item = &'a Option<SloSpec>>,
{
    let mut out: Option<f64> = None;
    for slo in slos {
        if let Some(s) = slo {
            let v = s.tpot_ms / 1e3;
            out = Some(out.map_or(v, |t| f64::min(t, v)));
        }
    }
    out
}

/// Carried by a re-queued (preempted) request: the tokens it had already
/// generated and emitted when its KV pages were reclaimed. Re-admission
/// re-prefills over `prompt + tokens[..n-1]` to rebuild exactly the KV
/// state it held, emits nothing for the rebuilt prefix, and resumes
/// decoding with `tokens[n-1]` as the next input — so the client-visible
/// stream is bitwise unaffected by the preemption.
#[derive(Debug, Clone, PartialEq)]
pub struct ResumeState {
    /// All tokens generated before preemption (never empty: a request
    /// only becomes preemptible after its first token).
    pub tokens: Vec<i32>,
}

/// A validated request as backends carry it internally: the wire fields
/// of [`ServeRequest`] plus the backend-assigned id, and — for requests
/// re-queued after a decode-growth preemption — the [`ResumeState`]
/// needed to rebuild their KV without replaying the token stream.
#[derive(Debug, Clone)]
pub struct ActiveRequest {
    pub id: u64,
    pub adapter: u64,
    /// The original user prompt (never includes generated tokens; resume
    /// context is derived via [`ActiveRequest::context`]).
    pub prompt: Vec<i32>,
    pub sampling: SamplingParams,
    pub priority: Priority,
    pub slo: Option<SloSpec>,
    /// `Some` when this entry re-admits a preempted request.
    pub resume: Option<ResumeState>,
}

impl ActiveRequest {
    /// Bind a submitted request to its backend id. A failover
    /// resubmission's [`ServeRequest::resume`] rides along, so its
    /// re-admission prefills the rebuilt context exactly like a
    /// preemption re-queue does.
    pub fn from_submit(id: u64, req: ServeRequest) -> ActiveRequest {
        ActiveRequest {
            id,
            adapter: req.adapter,
            prompt: req.prompt,
            sampling: req.sampling,
            priority: req.priority,
            slo: req.slo,
            resume: req.resume,
        }
    }

    /// The token sequence prefill must run over: the prompt, plus — when
    /// resuming — every generated token except the last (the last is the
    /// next decode input, exactly as it was at preemption time).
    pub fn context(&self) -> Vec<i32> {
        match &self.resume {
            None => self.prompt.clone(),
            Some(rs) => {
                let mut ctx = Vec::with_capacity(self.context_len());
                ctx.extend_from_slice(&self.prompt);
                ctx.extend_from_slice(&rs.tokens[..rs.tokens.len() - 1]);
                ctx
            }
        }
    }

    /// Length of [`ActiveRequest::context`] without materializing it —
    /// what admission control sizes KV reservations by.
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.resume.as_ref().map_or(0, |rs| rs.tokens.len() - 1)
    }
}

/// The uniform serving surface every backend exposes — the real engine
/// ([`crate::server::InferenceServer`]), the simulator
/// ([`crate::sim::front::SimFront`]), and the routing cluster front
/// ([`crate::server::ClusterFront`]) all implement this trait, so
/// `scheduler::Policy` and drivers route against one interface.
///
/// Besides the request path (submit / poll / cancel / stats), the trait
/// carries the **adapter-management surface** the global coordinator
/// drives at runtime: [`ServingFront::install_adapter`] /
/// [`ServingFront::uninstall_adapter`] are callable after construction
/// (uninstall refuses while requests on the adapter are in flight, so a
/// migration can never corrupt a live token stream), and
/// [`ServingFront::prewarm_adapter`] makes an installed adapter
/// device-resident ahead of first traffic.
///
/// The trait is **object-safe**: cluster composition works over
/// `Box<dyn ServingFront>` backends, and a `ClusterFront` is itself a
/// `ServingFront`, so drivers, tests, and the CLI run unchanged against
/// one engine or a whole routed cluster.
pub trait ServingFront {
    /// Submit a request. Rejection surfaces as a terminal
    /// [`RequestEvent::Rejected`] on the returned handle, never as a
    /// panic or a silent drop.
    fn submit(&mut self, req: ServeRequest) -> RequestHandle;

    /// Advance the backend by one iteration. Returns `false` when idle.
    fn poll(&mut self) -> anyhow::Result<bool>;

    /// Request cancellation of request `id`. Returns `true` if the
    /// request was still live (a terminal `Cancelled` event follows).
    fn cancel(&mut self, id: u64) -> bool;

    /// The scheduler's `GetStats` view of this backend's load.
    fn stats(&self) -> ServerStats;

    /// Install an adapter at runtime: after `Ok`, requests against
    /// `spec.id` are admissible. Idempotent — re-installing an adapter
    /// updates its metadata/weights in place.
    fn install_adapter(&mut self, spec: &LoraSpec) -> anyhow::Result<()>;

    /// Remove an adapter at runtime. Refuses (`Err`) while requests on
    /// the adapter are queued or running — callers (the migration
    /// engine) retry after the in-flight work drains, so an evicted
    /// adapter's live token streams are never perturbed. After `Ok`,
    /// new submissions against the adapter are rejected.
    fn uninstall_adapter(&mut self, adapter: u64) -> anyhow::Result<()>;

    /// Make an installed adapter device-resident ahead of first traffic
    /// (the coordinator's pre-warming of hot adapters), so its first
    /// request admits warm. Returns `Ok(false)` when the backend cannot
    /// warm it right now (e.g. the target slot is pinned by a live
    /// adapter); `Err` when the adapter is not installed at all.
    fn prewarm_adapter(&mut self, adapter: u64) -> anyhow::Result<bool> {
        let _ = adapter;
        Ok(false)
    }

    /// Cold-start counters, for backends that track them (`None`
    /// otherwise). Cluster fronts aggregate their backends' counters.
    fn cold_start_stats(&self) -> Option<crate::server::metrics::ColdStartStats> {
        None
    }

    /// Where this front's `install_adapter` calls sourced their weights:
    /// the content-addressed [`crate::artifacts::ArtifactStore`] vs
    /// synthetic re-seeding. Backends without install tracking report
    /// zeros; cluster fronts aggregate. The migration acceptance
    /// assertion — "zero synthetic re-seeding on the target" — reads
    /// these counters.
    fn install_source_stats(&self) -> InstallSourceStats {
        InstallSourceStats::default()
    }

    /// Drive iterations until idle.
    fn run_until_idle(&mut self) -> anyhow::Result<()> {
        while self.poll()? {}
        Ok(())
    }
}

/// Install provenance counters (see
/// [`ServingFront::install_source_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InstallSourceStats {
    /// Installs whose weights came from the artifact store, digest-
    /// verified.
    pub store_hits: u64,
    /// Installs that fell back to synthetic seeding (no manifest in the
    /// store, or no store attached).
    pub synthetic_seeds: u64,
}

impl InstallSourceStats {
    /// Component-wise sum — cluster aggregation.
    pub fn merge(&self, other: &InstallSourceStats) -> InstallSourceStats {
        InstallSourceStats {
            store_hits: self.store_hits + other.store_hits,
            synthetic_seeds: self.synthetic_seeds + other.synthetic_seeds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_every_field() {
        let r = ServeRequest::new(7, vec![1, 2, 3])
            .max_new_tokens(9)
            .stop_token(42)
            .top_k(4, 123)
            .priority(Priority::Interactive)
            .slo(200.0, 50.0);
        assert_eq!(r.adapter, 7);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.sampling.max_new_tokens, 9);
        assert_eq!(r.sampling.stop_tokens, vec![42]);
        assert_eq!(r.sampling.top_k, 4);
        assert_eq!(r.sampling.seed, 123);
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(
            r.slo,
            Some(SloSpec {
                ttft_ms: 200.0,
                tpot_ms: 50.0
            })
        );
    }

    #[test]
    fn priority_ordering() {
        assert!(Priority::Interactive > Priority::Standard);
        assert!(Priority::Standard > Priority::Batch);
        assert_eq!(Priority::default(), Priority::Standard);
    }

    #[test]
    fn event_buffer_cap_coalesces_tokens_but_never_terminals() {
        let mut c = EventChannel::default();
        c.set_event_cap(3);
        c.push(RequestEvent::Admitted);
        c.push(RequestEvent::FirstToken(0));
        for t in 1..10 {
            c.push(RequestEvent::Token(t));
        }
        c.push(RequestEvent::Finished(FinishReason::Length));
        // Token values always survive in the log...
        assert_eq!(c.tokens(), (0..10).collect::<Vec<i32>>());
        // ...while interior Token events coalesced: buffer holds
        // Admitted, FirstToken(0), Token(9) — then the terminal, which
        // must enqueue past the cap rather than drop.
        assert_eq!(c.overflows(), 8);
        let mut drained = Vec::new();
        while let Some(ev) = c.pop_event() {
            drained.push(ev);
        }
        assert_eq!(
            drained,
            vec![
                RequestEvent::Admitted,
                RequestEvent::FirstToken(0),
                RequestEvent::Token(9),
                RequestEvent::Finished(FinishReason::Length),
            ]
        );
        assert_eq!(c.state(), LifecycleState::Finished);
    }

    #[test]
    fn event_buffer_cap_spares_drained_consumers() {
        // A consumer that keeps up never overflows, whatever the cap.
        let mut c = EventChannel::default();
        c.set_event_cap(1);
        c.push(RequestEvent::Admitted);
        assert!(c.pop_event().is_some());
        c.push(RequestEvent::FirstToken(0));
        assert!(c.pop_event().is_some());
        for t in 1..5 {
            c.push(RequestEvent::Token(t));
            assert!(c.pop_event().is_some());
        }
        assert_eq!(c.overflows(), 0);
    }

    #[test]
    fn handle_streams_events_and_tokens() {
        let (handle, chan) = RequestHandle::new(3);
        assert_eq!(handle.id(), 3);
        assert_eq!(handle.state(), LifecycleState::Queued);
        {
            let mut c = chan.lock().unwrap();
            c.push(RequestEvent::Admitted);
            c.push(RequestEvent::FirstToken(5));
            c.push(RequestEvent::Token(6));
            c.push(RequestEvent::Finished(FinishReason::Length));
        }
        assert_eq!(handle.tokens(), vec![5, 6]);
        assert!(handle.is_terminal());
        assert_eq!(handle.state(), LifecycleState::Finished);
        let events = handle.drain_events();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0], RequestEvent::Admitted);
        assert!(events[3].is_terminal());
        assert_eq!(handle.poll_event(), None);
    }

    #[test]
    fn cancel_flag_is_visible_to_backend() {
        let (handle, chan) = RequestHandle::new(1);
        assert!(!chan.lock().unwrap().cancel_requested());
        handle.cancel();
        assert!(chan.lock().unwrap().cancel_requested());
    }

    #[test]
    #[should_panic(expected = "after terminal state")]
    fn channel_rejects_events_after_terminal() {
        let (_handle, chan) = RequestHandle::new(1);
        let mut c = chan.lock().unwrap();
        c.push(RequestEvent::Cancelled);
        c.push(RequestEvent::Token(1));
    }

    #[test]
    fn validate_shape_covers_all_bounds() {
        let ok = ServeRequest::new(1, vec![1; 8]).max_new_tokens(4);
        assert!(validate_shape(&ok, 64, 128).is_ok());
        let empty = ServeRequest::new(1, vec![]);
        assert_eq!(
            validate_shape(&empty, 64, 128).unwrap_err(),
            RejectReason::PromptBounds {
                len: 0,
                max_prompt: 64
            }
        );
        let long = ServeRequest::new(1, vec![1; 65]);
        assert!(validate_shape(&long, 64, 128).is_err());
        let zero = ServeRequest::new(1, vec![1; 8]).max_new_tokens(0);
        assert_eq!(validate_shape(&zero, 64, 128).unwrap_err(), RejectReason::EmptyBudget);
        let over = ServeRequest::new(1, vec![1; 8]).max_new_tokens(122);
        assert_eq!(
            validate_shape(&over, 64, 128).unwrap_err(),
            RejectReason::KvCapacity { kv_capacity: 128 }
        );
        let fits = ServeRequest::new(1, vec![1; 8]).max_new_tokens(121);
        assert!(validate_shape(&fits, 64, 128).is_ok());
    }

    #[test]
    fn reject_reason_renders_human_readable() {
        assert_eq!(
            RejectReason::PromptBounds {
                len: 0,
                max_prompt: 64
            }
            .to_string(),
            "prompt length 0 outside (0, 64]"
        );
        assert_eq!(
            RejectReason::AdapterNotInstalled { adapter: 9 }.to_string(),
            "adapter 9 not installed"
        );
        let nested = RejectReason::NoEligibleServer {
            last: Some(Box::new(RejectReason::KvCapacity { kv_capacity: 32 })),
        };
        assert_eq!(
            nested.to_string(),
            "no eligible server (last refusal: prompt+output exceeds KV capacity 32)"
        );
        assert_eq!(
            RejectReason::from("engine exploded").to_string(),
            "engine exploded"
        );
    }

    #[test]
    fn priority_insert_pos_orders_classes() {
        use Priority::{Batch, Interactive, Standard};
        assert_eq!(priority_insert_pos([], Standard), 0);
        assert_eq!(priority_insert_pos([Standard, Batch], Interactive), 0);
        assert_eq!(priority_insert_pos([Interactive, Standard, Batch], Standard), 2);
        assert_eq!(priority_insert_pos([Interactive, Standard], Batch), 2);
        // FIFO within a class: equal priority lands after.
        assert_eq!(priority_insert_pos([Standard, Standard], Standard), 2);
    }

    #[test]
    fn tightest_tpot_slo_folds_minimum() {
        assert_eq!(tightest_tpot_slo([]), None);
        assert_eq!(tightest_tpot_slo([&None, &None]), None);
        let a = Some(SloSpec {
            ttft_ms: 100.0,
            tpot_ms: 60.0,
        });
        let b = Some(SloSpec {
            ttft_ms: 100.0,
            tpot_ms: 40.0,
        });
        let got = tightest_tpot_slo([&a, &None, &b]).unwrap();
        assert!((got - 0.040).abs() < 1e-12);
    }

    #[test]
    fn try_request_cancel_respects_terminal_state() {
        let (_h, chan) = RequestHandle::new(1);
        assert!(chan.lock().unwrap().try_request_cancel());
        assert!(chan.lock().unwrap().try_request_cancel()); // still live
        let (_h2, chan2) = RequestHandle::new(2);
        chan2.lock().unwrap().push(RequestEvent::Cancelled);
        assert!(!chan2.lock().unwrap().try_request_cancel());
    }

    #[test]
    fn routed_is_non_terminal_and_preserves_running_state() {
        let (handle, chan) = RequestHandle::new(4);
        assert!(!RequestEvent::Routed { server: 1 }.is_terminal());
        {
            let mut c = chan.lock().unwrap();
            c.push(RequestEvent::Admitted);
            c.push(RequestEvent::Routed { server: 1 });
        }
        assert_eq!(handle.state(), LifecycleState::Queued);
        chan.lock().unwrap().push(RequestEvent::FirstToken(9));
        // A (hypothetical) late placement note must not regress Running.
        chan.lock().unwrap().push(RequestEvent::Routed { server: 0 });
        assert_eq!(handle.state(), LifecycleState::Running);
        assert_eq!(handle.tokens(), vec![9]);
    }

    #[test]
    fn resume_context_rebuilds_prefix_without_last_token() {
        let mut r = ActiveRequest::from_submit(1, ServeRequest::new(7, vec![10, 11, 12]));
        assert_eq!(r.context(), vec![10, 11, 12]);
        assert_eq!(r.context_len(), 3);
        r.resume = Some(ResumeState {
            tokens: vec![20, 21, 22],
        });
        // KV held prompt + first two generated tokens; 22 is the next
        // decode input and stays out of the rebuilt prefix.
        assert_eq!(r.context(), vec![10, 11, 12, 20, 21]);
        assert_eq!(r.context_len(), 5);
    }

    #[test]
    fn rejected_is_terminal_with_reason() {
        let (handle, chan) = RequestHandle::new(9);
        chan.lock()
            .unwrap()
            .push(RequestEvent::Rejected("no such adapter".into()));
        assert_eq!(handle.state(), LifecycleState::Rejected);
        match handle.poll_event() {
            Some(RequestEvent::Rejected(reason)) => {
                assert!(reason.to_string().contains("adapter"));
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
    }

    #[test]
    fn rerouted_is_non_terminal_and_preserves_running_state() {
        let (handle, chan) = RequestHandle::new(5);
        assert!(!RequestEvent::Rerouted { from: 2, to: 0 }.is_terminal());
        {
            let mut c = chan.lock().unwrap();
            c.push(RequestEvent::Admitted);
            c.push(RequestEvent::FirstToken(3));
            // A mid-stream failover note must not regress Running or
            // perturb the token view.
            c.push(RequestEvent::Rerouted { from: 2, to: 0 });
            c.push(RequestEvent::Token(4));
        }
        assert_eq!(handle.state(), LifecycleState::Running);
        assert_eq!(handle.tokens(), vec![3, 4]);
    }
}
