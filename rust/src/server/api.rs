//! Request/response types for the inference server.

/// A user inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// LoRA adapter id (mapped to a device slot by the engine).
    pub adapter: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Number of tokens to generate.
    pub max_new_tokens: usize,
}

/// The completed output for a request.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: u64,
    /// Generated token ids (greedy).
    pub tokens: Vec<i32>,
}

/// Lifecycle state the engine tracks per request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefilling,
    Decoding,
    Finished,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = InferenceRequest {
            id: 1,
            adapter: 3,
            prompt: vec![1, 2, 3],
            max_new_tokens: 8,
        };
        assert_eq!(r.prompt.len(), 3);
        assert_eq!(Phase::Queued, Phase::Queued);
    }
}
