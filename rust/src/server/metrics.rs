//! Per-request serving metrics (§7.1: time-to-first-token, time per
//! token, request latency) and aggregation, including per-request TPOT
//! (decode-only time per output token) and per-SLO attainment — the
//! paper's §7 headline metrics.

use std::collections::HashMap;
use std::time::Instant;

use super::api::SloSpec;
use crate::util::stats::{Ecdf, Summary};

/// One request's completed timing record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    /// Time to first token (s).
    pub ttft: f64,
    /// Whole-request time per token: latency / output_len (s).
    pub time_per_token: f64,
    /// Decode-only time per output token: (latency − ttft) / (n − 1),
    /// zero for single-token outputs (s).
    pub tpot: f64,
    /// End-to-end latency (s).
    pub latency: f64,
    pub output_len: usize,
    /// The SLO the request carried, if any.
    pub slo: Option<SloSpec>,
}

impl RequestRecord {
    /// Did this request meet its SLO? `None` if it carried none.
    pub fn slo_met(&self) -> Option<bool> {
        self.slo.map(|s| {
            self.ttft * 1e3 <= s.ttft_ms && self.tpot * 1e3 <= s.tpot_ms
        })
    }
}

struct InFlight {
    arrival: Instant,
    first_token: Option<Instant>,
    tokens: usize,
    slo: Option<SloSpec>,
}

/// Records request lifecycles and produces summaries.
#[derive(Default)]
pub struct MetricsRecorder {
    inflight: HashMap<u64, InFlight>,
    done: Vec<RequestRecord>,
    cancelled: usize,
}

impl MetricsRecorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// A request arrived (carrying an optional SLO).
    pub fn arrived(&mut self, id: u64, slo: Option<SloSpec>) {
        self.inflight.insert(
            id,
            InFlight {
                arrival: Instant::now(),
                first_token: None,
                tokens: 0,
                slo,
            },
        );
    }

    /// A token was emitted for a request.
    pub fn token(&mut self, id: u64) {
        if let Some(f) = self.inflight.get_mut(&id) {
            f.tokens += 1;
            if f.first_token.is_none() {
                f.first_token = Some(Instant::now());
            }
        }
    }

    /// The request finished; finalize its record.
    pub fn finished(&mut self, id: u64) {
        if let Some(f) = self.inflight.remove(&id) {
            let now = Instant::now();
            let latency = now.duration_since(f.arrival).as_secs_f64();
            let ttft = f
                .first_token
                .map(|t| t.duration_since(f.arrival).as_secs_f64())
                .unwrap_or(latency);
            let tpot = if f.tokens > 1 {
                (latency - ttft).max(0.0) / (f.tokens - 1) as f64
            } else {
                0.0
            };
            self.done.push(RequestRecord {
                id,
                ttft,
                time_per_token: latency / f.tokens.max(1) as f64,
                tpot,
                latency,
                output_len: f.tokens,
                slo: f.slo,
            });
        }
    }

    /// The request was cancelled before completion; drop its in-flight
    /// record (cancelled requests don't pollute latency distributions).
    pub fn cancelled(&mut self, id: u64) {
        if self.inflight.remove(&id).is_some() {
            self.cancelled += 1;
        }
    }

    /// Completed records.
    pub fn records(&self) -> &[RequestRecord] {
        &self.done
    }

    /// Requests cancelled before completion.
    pub fn cancelled_count(&self) -> usize {
        self.cancelled
    }

    /// Requests still in flight.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Fraction of completed SLO-carrying requests that met both their
    /// TTFT and TPOT targets; `None` if no completed request carried one.
    pub fn slo_attainment(&self) -> Option<f64> {
        let judged: Vec<bool> = self.done.iter().filter_map(|r| r.slo_met()).collect();
        if judged.is_empty() {
            return None;
        }
        let met = judged.iter().filter(|&&m| m).count();
        Some(met as f64 / judged.len() as f64)
    }

    /// Summary of one metric column ("ttft" | "tpt" | "tpot" | "latency").
    pub fn summary(&self, metric: &str) -> Option<Summary> {
        Summary::of(&self.column(metric))
    }

    /// ECDF of one metric column.
    pub fn ecdf(&self, metric: &str) -> Ecdf {
        Ecdf::new(&self.column(metric))
    }

    fn column(&self, metric: &str) -> Vec<f64> {
        self.done
            .iter()
            .map(|r| match metric {
                "ttft" => r.ttft,
                "tpt" => r.time_per_token,
                "tpot" => r.tpot,
                "latency" => r.latency,
                other => panic!("unknown metric {other}"),
            })
            .collect()
    }

    /// Aggregate throughput over the recorded window: (requests/s,
    /// tokens/s) given the wall-clock duration of the run.
    pub fn throughput(&self, wall_seconds: f64) -> (f64, f64) {
        let reqs = self.done.len() as f64 / wall_seconds;
        let toks: usize = self.done.iter().map(|r| r.output_len).sum();
        (reqs, toks as f64 / wall_seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_produces_record() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.token(1);
        m.token(1);
        m.finished(1);
        assert_eq!(m.records().len(), 1);
        let r = &m.records()[0];
        assert!(r.ttft >= 5e-3);
        assert!(r.latency >= r.ttft);
        assert_eq!(r.output_len, 2);
        assert!(r.time_per_token > 0.0);
        assert!(r.tpot >= 0.0);
        assert!(r.slo_met().is_none());
        assert_eq!(m.inflight(), 0);
    }

    #[test]
    fn tpot_measures_decode_only() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        m.token(1); // first token: ends TTFT window
        std::thread::sleep(std::time::Duration::from_millis(6));
        m.token(1);
        m.token(1);
        m.finished(1);
        let r = &m.records()[0];
        // 2 decode tokens over ≥6 ms → tpot ≥ 3 ms, and well above the
        // (near-zero) ttft.
        assert!(r.tpot >= 3e-3, "tpot {}", r.tpot);
        assert!(r.tpot > r.ttft);
    }

    #[test]
    fn slo_attainment_judges_only_slo_requests() {
        let mut m = MetricsRecorder::new();
        // Generous SLO: met.
        m.arrived(
            1,
            Some(SloSpec {
                ttft_ms: 1e6,
                tpot_ms: 1e6,
            }),
        );
        m.token(1);
        m.finished(1);
        // Impossible SLO: missed.
        m.arrived(
            2,
            Some(SloSpec {
                ttft_ms: 0.0,
                tpot_ms: 0.0,
            }),
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.token(2);
        m.finished(2);
        // No SLO: not judged.
        m.arrived(3, None);
        m.token(3);
        m.finished(3);
        assert_eq!(m.slo_attainment(), Some(0.5));
    }

    #[test]
    fn no_slo_requests_means_no_attainment() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        m.token(1);
        m.finished(1);
        assert_eq!(m.slo_attainment(), None);
    }

    #[test]
    fn cancelled_requests_drop_from_inflight() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        m.token(1);
        m.cancelled(1);
        m.cancelled(99); // unknown: ignored
        assert_eq!(m.cancelled_count(), 1);
        assert_eq!(m.inflight(), 0);
        assert!(m.records().is_empty());
    }

    #[test]
    fn summary_and_ecdf() {
        let mut m = MetricsRecorder::new();
        for id in 0..10 {
            m.arrived(id, None);
            m.token(id);
            m.finished(id);
        }
        let s = m.summary("latency").unwrap();
        assert_eq!(s.count, 10);
        assert_eq!(m.ecdf("ttft").len(), 10);
        assert!(m.summary("tpot").is_some());
    }

    #[test]
    fn unknown_ids_ignored() {
        let mut m = MetricsRecorder::new();
        m.token(99);
        m.finished(99);
        assert!(m.records().is_empty());
    }

    #[test]
    fn throughput_math() {
        let mut m = MetricsRecorder::new();
        for id in 0..4 {
            m.arrived(id, None);
            m.token(id);
            m.token(id);
            m.finished(id);
        }
        let (rps, tps) = m.throughput(2.0);
        assert!((rps - 2.0).abs() < 1e-9);
        assert!((tps - 4.0).abs() < 1e-9);
    }
}
