//! Per-request serving metrics (§7.1: time-to-first-token, time per
//! token, request latency) and aggregation, including per-request TPOT
//! (decode-only time per output token), per-SLO attainment, and the
//! cold-start decomposition of TTFT (load window vs. prefill compute vs.
//! CPU-assist time) — the paper's §7 headline metrics plus the §4
//! mechanism counters.

use std::collections::HashMap;
use std::time::Instant;

use super::api::SloSpec;
use crate::util::stats::{Ecdf, Summary};

/// How one request's admitting prefill iteration spent its time — the
/// decomposition that distinguishes `load + prefill` (OnDemand) from
/// `max(load, prefill)` / prefill-only (CaraServe) cold starts.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TtftBreakdown {
    /// Modeled host→device load window for this request's adapter (s);
    /// zero on a warm admit.
    pub load: f64,
    /// Prefill compute of the admitting iteration (s).
    pub prefill: f64,
    /// CPU-LoRA `xAB` wall time inside that prefill (s); zero when the
    /// request wasn't CPU-assisted.
    pub assist: f64,
    /// Was the adapter cold (load in flight or required) at admit?
    pub cold: bool,
}

/// Per-mode cold-start counters for one engine lifetime.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ColdStartStats {
    /// Admits that found their adapter cold (load required/in flight).
    pub cold_admits: usize,
    /// Admits that found their adapter device-resident.
    pub warm_admits: usize,
    /// Cold admits served through the real CPU-assisted path.
    pub cpu_assisted: usize,
    /// Mid-load CPU→resident decode handoffs (§4.3): running requests
    /// whose adapter finished loading while they decoded.
    pub handoffs: usize,
    /// Requests whose admission was deferred (counted once per request)
    /// because their fixed device slot collided with a different live
    /// adapter (intra-batch or vs. running/loading).
    pub deferred_collisions: usize,
    /// Wall time spent computing CPU-LoRA deltas during decode (s).
    pub assist_decode_s: f64,
}

/// One request's completed timing record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    /// Time to first token (s).
    pub ttft: f64,
    /// Whole-request time per token: latency / output_len (s).
    pub time_per_token: f64,
    /// Decode-only time per output token: (latency − ttft) / (n − 1),
    /// zero for single-token outputs (s).
    pub tpot: f64,
    /// End-to-end latency (s).
    pub latency: f64,
    pub output_len: usize,
    /// The SLO the request carried, if any.
    pub slo: Option<SloSpec>,
    /// Cold-start decomposition of the admitting prefill, when the
    /// engine recorded one.
    pub breakdown: Option<TtftBreakdown>,
}

impl RequestRecord {
    /// Did this request meet its SLO? `None` if it carried none.
    pub fn slo_met(&self) -> Option<bool> {
        self.slo.map(|s| {
            self.ttft * 1e3 <= s.ttft_ms && self.tpot * 1e3 <= s.tpot_ms
        })
    }
}

struct InFlight {
    arrival: Instant,
    first_token: Option<Instant>,
    tokens: usize,
    slo: Option<SloSpec>,
    breakdown: Option<TtftBreakdown>,
}

/// Records request lifecycles and produces summaries.
#[derive(Default)]
pub struct MetricsRecorder {
    inflight: HashMap<u64, InFlight>,
    done: Vec<RequestRecord>,
    cancelled: usize,
    cold: ColdStartStats,
    preempted: usize,
    adapter_evicted: usize,
}

impl MetricsRecorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// A request arrived (carrying an optional SLO).
    pub fn arrived(&mut self, id: u64, slo: Option<SloSpec>) {
        self.inflight.insert(
            id,
            InFlight {
                arrival: Instant::now(),
                first_token: None,
                tokens: 0,
                slo,
                breakdown: None,
            },
        );
    }

    /// Attach the cold-start decomposition of a request's admitting
    /// prefill iteration.
    pub fn prefill_breakdown(&mut self, id: u64, breakdown: TtftBreakdown) {
        if let Some(f) = self.inflight.get_mut(&id) {
            f.breakdown = Some(breakdown);
        }
    }

    /// Count a cold admit (`assisted` when served through the real
    /// CPU-assisted path).
    pub fn cold_admit(&mut self, assisted: bool) {
        self.cold.cold_admits += 1;
        if assisted {
            self.cold.cpu_assisted += 1;
        }
    }

    /// Count a warm (device-resident) admit.
    pub fn warm_admit(&mut self) {
        self.cold.warm_admits += 1;
    }

    /// Count mid-load CPU→resident decode handoffs.
    pub fn handoffs(&mut self, n: usize) {
        self.cold.handoffs += n;
    }

    /// Count admits deferred by a device-slot collision.
    pub fn deferred_collisions(&mut self, n: usize) {
        self.cold.deferred_collisions += n;
    }

    /// Accumulate CPU-LoRA wall time spent during decode iterations.
    pub fn assist_decode(&mut self, seconds: f64) {
        self.cold.assist_decode_s += seconds;
    }

    /// The engine's cold-start counters.
    pub fn cold_start(&self) -> &ColdStartStats {
        &self.cold
    }

    /// Count a decode-growth preemption (a running request whose KV
    /// pages were reclaimed and that was re-queued for later re-admit).
    pub fn preemption(&mut self) {
        self.preempted += 1;
    }

    /// Decode-growth preemptions so far — surfaced through
    /// `ServerStats::preemptions` so the cluster router steers away from
    /// memory-pressured servers.
    pub fn preemptions(&self) -> usize {
        self.preempted
    }

    /// Count a pressure eviction: an idle adapter's weight pages were
    /// reclaimed from the unified pool (to page in a different adapter
    /// or to extend KV under decode growth).
    pub fn adapter_eviction(&mut self) {
        self.adapter_evicted += 1;
    }

    /// Adapter pressure evictions so far — surfaced through
    /// `ServerStats::adapter_evictions` so placement can see real memory
    /// churn, not just slot pressure.
    pub fn adapter_evictions(&self) -> usize {
        self.adapter_evicted
    }

    /// A token was emitted for a request.
    pub fn token(&mut self, id: u64) {
        if let Some(f) = self.inflight.get_mut(&id) {
            f.tokens += 1;
            if f.first_token.is_none() {
                f.first_token = Some(Instant::now());
            }
        }
    }

    /// The request finished; finalize its record.
    pub fn finished(&mut self, id: u64) {
        if let Some(f) = self.inflight.remove(&id) {
            let now = Instant::now();
            let latency = now.duration_since(f.arrival).as_secs_f64();
            let ttft = f
                .first_token
                .map(|t| t.duration_since(f.arrival).as_secs_f64())
                .unwrap_or(latency);
            let tpot = if f.tokens > 1 {
                (latency - ttft).max(0.0) / (f.tokens - 1) as f64
            } else {
                0.0
            };
            self.done.push(RequestRecord {
                id,
                ttft,
                time_per_token: latency / f.tokens.max(1) as f64,
                tpot,
                latency,
                output_len: f.tokens,
                slo: f.slo,
                breakdown: f.breakdown,
            });
        }
    }

    /// The request was cancelled before completion; drop its in-flight
    /// record (cancelled requests don't pollute latency distributions).
    pub fn cancelled(&mut self, id: u64) {
        if self.inflight.remove(&id).is_some() {
            self.cancelled += 1;
        }
    }

    /// The request was rejected after being recorded (e.g. a routing
    /// front relaying a backend's refusal): drop the in-flight record
    /// without counting it as a cancellation.
    pub fn rejected(&mut self, id: u64) {
        self.inflight.remove(&id);
    }

    /// Completed records.
    pub fn records(&self) -> &[RequestRecord] {
        &self.done
    }

    /// Requests cancelled before completion.
    pub fn cancelled_count(&self) -> usize {
        self.cancelled
    }

    /// Requests still in flight.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Fraction of completed SLO-carrying requests that met both their
    /// TTFT and TPOT targets; `None` if no completed request carried one.
    pub fn slo_attainment(&self) -> Option<f64> {
        let judged: Vec<bool> = self.done.iter().filter_map(|r| r.slo_met()).collect();
        if judged.is_empty() {
            return None;
        }
        let met = judged.iter().filter(|&&m| m).count();
        Some(met as f64 / judged.len() as f64)
    }

    /// Summary of one metric column ("ttft" | "tpt" | "tpot" | "latency"
    /// | "ttft_load" | "ttft_prefill" | "ttft_assist").
    pub fn summary(&self, metric: &str) -> Option<Summary> {
        Summary::of(&self.column(metric))
    }

    /// ECDF of one metric column.
    pub fn ecdf(&self, metric: &str) -> Ecdf {
        Ecdf::new(&self.column(metric))
    }

    fn column(&self, metric: &str) -> Vec<f64> {
        self.done
            .iter()
            .map(|r| match metric {
                "ttft" => r.ttft,
                "tpt" => r.time_per_token,
                "tpot" => r.tpot,
                "latency" => r.latency,
                "ttft_load" => r.breakdown.map_or(0.0, |b| b.load),
                "ttft_prefill" => r.breakdown.map_or(0.0, |b| b.prefill),
                "ttft_assist" => r.breakdown.map_or(0.0, |b| b.assist),
                other => panic!("unknown metric {other}"),
            })
            .collect()
    }

    /// Aggregate throughput over the recorded window: (requests/s,
    /// tokens/s) given the wall-clock duration of the run.
    pub fn throughput(&self, wall_seconds: f64) -> (f64, f64) {
        let reqs = self.done.len() as f64 / wall_seconds;
        let toks: usize = self.done.iter().map(|r| r.output_len).sum();
        (reqs, toks as f64 / wall_seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_produces_record() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.token(1);
        m.token(1);
        m.finished(1);
        assert_eq!(m.records().len(), 1);
        let r = &m.records()[0];
        assert!(r.ttft >= 5e-3);
        assert!(r.latency >= r.ttft);
        assert_eq!(r.output_len, 2);
        assert!(r.time_per_token > 0.0);
        assert!(r.tpot >= 0.0);
        assert!(r.slo_met().is_none());
        assert_eq!(m.inflight(), 0);
    }

    #[test]
    fn tpot_measures_decode_only() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        m.token(1); // first token: ends TTFT window
        std::thread::sleep(std::time::Duration::from_millis(6));
        m.token(1);
        m.token(1);
        m.finished(1);
        let r = &m.records()[0];
        // 2 decode tokens over ≥6 ms → tpot ≥ 3 ms, and well above the
        // (near-zero) ttft.
        assert!(r.tpot >= 3e-3, "tpot {}", r.tpot);
        assert!(r.tpot > r.ttft);
    }

    #[test]
    fn slo_attainment_judges_only_slo_requests() {
        let mut m = MetricsRecorder::new();
        // Generous SLO: met.
        m.arrived(
            1,
            Some(SloSpec {
                ttft_ms: 1e6,
                tpot_ms: 1e6,
            }),
        );
        m.token(1);
        m.finished(1);
        // Impossible SLO: missed.
        m.arrived(
            2,
            Some(SloSpec {
                ttft_ms: 0.0,
                tpot_ms: 0.0,
            }),
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.token(2);
        m.finished(2);
        // No SLO: not judged.
        m.arrived(3, None);
        m.token(3);
        m.finished(3);
        assert_eq!(m.slo_attainment(), Some(0.5));
    }

    #[test]
    fn no_slo_requests_means_no_attainment() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        m.token(1);
        m.finished(1);
        assert_eq!(m.slo_attainment(), None);
    }

    #[test]
    fn cancelled_requests_drop_from_inflight() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        m.token(1);
        m.cancelled(1);
        m.cancelled(99); // unknown: ignored
        assert_eq!(m.cancelled_count(), 1);
        assert_eq!(m.inflight(), 0);
        assert!(m.records().is_empty());
        // A relayed rejection drops the in-flight record without
        // inflating the cancelled count.
        m.arrived(2, None);
        m.rejected(2);
        assert_eq!(m.cancelled_count(), 1);
        assert_eq!(m.inflight(), 0);
    }

    #[test]
    fn summary_and_ecdf() {
        let mut m = MetricsRecorder::new();
        for id in 0..10 {
            m.arrived(id, None);
            m.token(id);
            m.finished(id);
        }
        let s = m.summary("latency").unwrap();
        assert_eq!(s.count, 10);
        assert_eq!(m.ecdf("ttft").len(), 10);
        assert!(m.summary("tpot").is_some());
    }

    #[test]
    fn unknown_ids_ignored() {
        let mut m = MetricsRecorder::new();
        m.token(99);
        m.finished(99);
        assert!(m.records().is_empty());
    }

    #[test]
    fn breakdown_rides_along_to_the_record() {
        let mut m = MetricsRecorder::new();
        m.arrived(1, None);
        m.prefill_breakdown(
            1,
            TtftBreakdown {
                load: 0.05,
                prefill: 0.01,
                assist: 0.002,
                cold: true,
            },
        );
        m.token(1);
        m.finished(1);
        let b = m.records()[0].breakdown.unwrap();
        assert!(b.cold);
        assert_eq!(b.load, 0.05);
        let s = m.summary("ttft_load").unwrap();
        assert!((s.mean - 0.05).abs() < 1e-12);
        assert!(m.summary("ttft_prefill").is_some());
        assert!(m.summary("ttft_assist").is_some());
        // Unknown ids ignored.
        m.prefill_breakdown(99, TtftBreakdown::default());
    }

    #[test]
    fn cold_start_counters_accumulate() {
        let mut m = MetricsRecorder::new();
        m.cold_admit(true);
        m.cold_admit(false);
        m.warm_admit();
        m.handoffs(2);
        m.deferred_collisions(1);
        m.assist_decode(0.25);
        m.preemption();
        assert_eq!(m.preemptions(), 1);
        m.adapter_eviction();
        m.adapter_eviction();
        assert_eq!(m.adapter_evictions(), 2);
        let c = m.cold_start();
        assert_eq!(c.cold_admits, 2);
        assert_eq!(c.cpu_assisted, 1);
        assert_eq!(c.warm_admits, 1);
        assert_eq!(c.handoffs, 2);
        assert_eq!(c.deferred_collisions, 1);
        assert!((c.assist_decode_s - 0.25).abs() < 1e-12);
    }

    #[test]
    fn throughput_math() {
        let mut m = MetricsRecorder::new();
        for id in 0..4 {
            m.arrived(id, None);
            m.token(id);
            m.token(id);
            m.finished(id);
        }
        let (rps, tps) = m.throughput(2.0);
        assert!((rps - 2.0).abs() < 1e-9);
        assert!((tps - 4.0).abs() < 1e-9);
    }
}
