//! Per-request serving metrics (§7.1: time-to-first-token, time per
//! token, request latency) and aggregation.

use std::collections::HashMap;
use std::time::Instant;

use crate::util::stats::{Ecdf, Summary};

/// One request's completed timing record.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub ttft: f64,
    pub time_per_token: f64,
    pub latency: f64,
    pub output_len: usize,
}

struct InFlight {
    arrival: Instant,
    first_token: Option<Instant>,
    tokens: usize,
}

/// Records request lifecycles and produces summaries.
#[derive(Default)]
pub struct MetricsRecorder {
    inflight: HashMap<u64, InFlight>,
    done: Vec<RequestRecord>,
}

impl MetricsRecorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// A request arrived.
    pub fn arrived(&mut self, id: u64) {
        self.inflight.insert(
            id,
            InFlight {
                arrival: Instant::now(),
                first_token: None,
                tokens: 0,
            },
        );
    }

    /// A token was emitted for a request.
    pub fn token(&mut self, id: u64) {
        if let Some(f) = self.inflight.get_mut(&id) {
            f.tokens += 1;
            if f.first_token.is_none() {
                f.first_token = Some(Instant::now());
            }
        }
    }

    /// The request finished; finalize its record.
    pub fn finished(&mut self, id: u64) {
        if let Some(f) = self.inflight.remove(&id) {
            let now = Instant::now();
            let latency = now.duration_since(f.arrival).as_secs_f64();
            let ttft = f
                .first_token
                .map(|t| t.duration_since(f.arrival).as_secs_f64())
                .unwrap_or(latency);
            self.done.push(RequestRecord {
                id,
                ttft,
                time_per_token: latency / f.tokens.max(1) as f64,
                latency,
                output_len: f.tokens,
            });
        }
    }

    /// Completed records.
    pub fn records(&self) -> &[RequestRecord] {
        &self.done
    }

    /// Requests still in flight.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Summary of one metric column ("ttft" | "tpt" | "latency").
    pub fn summary(&self, metric: &str) -> Option<Summary> {
        Summary::of(&self.column(metric))
    }

    /// ECDF of one metric column.
    pub fn ecdf(&self, metric: &str) -> Ecdf {
        Ecdf::new(&self.column(metric))
    }

    fn column(&self, metric: &str) -> Vec<f64> {
        self.done
            .iter()
            .map(|r| match metric {
                "ttft" => r.ttft,
                "tpt" => r.time_per_token,
                "latency" => r.latency,
                other => panic!("unknown metric {other}"),
            })
            .collect()
    }

    /// Aggregate throughput over the recorded window: (requests/s,
    /// tokens/s) given the wall-clock duration of the run.
    pub fn throughput(&self, wall_seconds: f64) -> (f64, f64) {
        let reqs = self.done.len() as f64 / wall_seconds;
        let toks: usize = self.done.iter().map(|r| r.output_len).sum();
        (reqs, toks as f64 / wall_seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_produces_record() {
        let mut m = MetricsRecorder::new();
        m.arrived(1);
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.token(1);
        m.token(1);
        m.finished(1);
        assert_eq!(m.records().len(), 1);
        let r = &m.records()[0];
        assert!(r.ttft >= 5e-3);
        assert!(r.latency >= r.ttft);
        assert_eq!(r.output_len, 2);
        assert!(r.time_per_token > 0.0);
        assert_eq!(m.inflight(), 0);
    }

    #[test]
    fn summary_and_ecdf() {
        let mut m = MetricsRecorder::new();
        for id in 0..10 {
            m.arrived(id);
            m.token(id);
            m.finished(id);
        }
        let s = m.summary("latency").unwrap();
        assert_eq!(s.count, 10);
        assert_eq!(m.ecdf("ttft").len(), 10);
    }

    #[test]
    fn unknown_ids_ignored() {
        let mut m = MetricsRecorder::new();
        m.token(99);
        m.finished(99);
        assert!(m.records().is_empty());
    }

    #[test]
    fn throughput_math() {
        let mut m = MetricsRecorder::new();
        for id in 0..4 {
            m.arrived(id);
            m.token(id);
            m.token(id);
            m.finished(id);
        }
        let (rps, tps) = m.throughput(2.0);
        assert!((rps - 2.0).abs() < 1e-9);
        assert!((tps - 4.0).abs() < 1e-9);
    }
}
