//! Unified paged device-memory pool: KV cache **and** adapter weights.
//!
//! vLLM-style block allocation: each request's KV rows live in
//! fixed-size token pages drawn from a bounded pool, so memory is
//! reclaimed at request completion without fragmentation (§8 of the
//! paper credits this mechanism; LightLLM/vLLM both use it).
//!
//! Since the unified-paging refactor (S-LoRA's key idea, see
//! ROADMAP direction 2), the same bounded pool also holds **adapter
//! weight residency**: every page is owned either by a request's KV
//! ([`KvCacheManager::reserve`] / [`KvCacheManager::append_token`]) or
//! by a resident adapter's flattened LoRA stack
//! ([`KvCacheManager::reserve_adapter`], rank-proportional page
//! counts). KV growth and adapter page-in compete for the one free
//! list, which is what lets a 1,000+ adapter catalog share a device:
//! idle adapters are evicted ([`KvCacheManager::free_adapter`]) to
//! make room for KV under pressure, and re-paged on the next request.
//! The accounting invariant `free + kv_held + adapter_held == total`
//! holds at every step ([`KvCacheManager::accounting_balanced`]) and is
//! property-checked in `tests/prop_invariants.rs`.
//!
//! Layout: one page holds `page_size` token rows for **all** layers,
//! K and V, i.e. `2 · layers · page_size · hidden` f32s. Adapter holds
//! use the same page granularity: a rank-`r` stack needs
//! `ceil(8·hidden·r / page_elems)` pages (A and B for each of the four
//! Q/K/V/O targets), so footprints are rank-proportional exactly as
//! the scheduler and coordinator assume.
//!
//! The runtime reaches the pool **in place** (§Perf):
//!
//! - [`KvCacheManager::paged_view`] builds a [`PagedKv`] — per-request
//!   block tables (page ids + length) over a shared borrow of the pool
//!   — implementing [`crate::runtime::KvView`], so decode attention
//!   reads cached rows directly from their pages with zero per-step
//!   assembly.
//! - [`KvCacheManager::reserve`] + [`KvCacheManager::writers`] hand out
//!   per-request [`PageWriter`]s (disjoint `&mut` borrows of each
//!   request's pages), implementing [`crate::runtime::KvWrite`], so
//!   prefill streams K/V rows straight into pages — no dense-then-
//!   recopy double buffer, and rows can be written from concurrent
//!   forward threads.
//!
//! The dense assembly path ([`KvCacheManager::assemble_into`]) remains
//! for the PJRT backend (dense tensor inputs) and as the reference in
//! paged-vs-dense equivalence tests.

use std::collections::HashMap;

use crate::runtime::KvWrite;

/// Errors from the unified pool manager.
#[derive(Debug, PartialEq)]
pub enum KvError {
    OutOfPages { need: usize, free: usize },
    UnknownRequest(u64),
    TooLong(u64, usize),
    AlreadyAdmitted(u64),
    AlreadyResident(u64),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages { need, free } => {
                write!(f, "out of KV pages (need {need}, free {free})")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::TooLong(id, cap) => {
                write!(f, "request {id} exceeds cache capacity {cap}")
            }
            KvError::AlreadyAdmitted(id) => {
                write!(f, "request {id} already holds KV pages")
            }
            KvError::AlreadyResident(id) => {
                write!(f, "adapter {id} already holds weight pages")
            }
        }
    }
}

impl std::error::Error for KvError {}

struct RequestKv {
    pages: Vec<usize>,
    len: usize,
}

/// One resident adapter's weight pages: the flattened LoRA stack is
/// chunked page-elems at a time across `pages` (block-table order),
/// with `elems` real f32s (the last page is zero-padded).
struct AdapterHold {
    pages: Vec<usize>,
    elems: usize,
}

/// Element offset of (layer, slot, K|V) inside a page of the layout
/// `[K/V][layer][slot][hidden]`.
#[inline]
fn page_offset(
    layers: usize,
    page_size: usize,
    hidden: usize,
    layer: usize,
    slot: usize,
    is_v: bool,
) -> usize {
    let half = layers * page_size * hidden;
    (if is_v { half } else { 0 }) + layer * page_size * hidden + slot * hidden
}

/// The unified paged pool manager: request KV and adapter weight
/// residency draw pages from one bounded free list (see module docs).
pub struct KvCacheManager {
    layers: usize,
    hidden: usize,
    page_size: usize,
    /// Max tokens a single request may hold (decode bucket capacity M).
    max_tokens: usize,
    /// Page pool: each page is `2·layers·page_size·hidden` f32s
    /// (K rows then V rows per layer-major order).
    pool: Vec<Vec<f32>>,
    free: Vec<usize>,
    requests: HashMap<u64, RequestKv>,
    /// Resident adapters' weight pages (the other page-owner class).
    adapter_holds: HashMap<u64, AdapterHold>,
}

impl KvCacheManager {
    /// A pool of `n_pages` pages of `page_size` tokens each.
    pub fn new(
        layers: usize,
        hidden: usize,
        page_size: usize,
        n_pages: usize,
        max_tokens: usize,
    ) -> KvCacheManager {
        let page_elems = 2 * layers * page_size * hidden;
        KvCacheManager {
            layers,
            hidden,
            page_size,
            max_tokens,
            pool: (0..n_pages).map(|_| vec![0.0; page_elems]).collect(),
            free: (0..n_pages).rev().collect(),
            requests: HashMap::new(),
            adapter_holds: HashMap::new(),
        }
    }

    /// Free pages remaining.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Total pages.
    pub fn total_pages(&self) -> usize {
        self.pool.len()
    }

    /// Tokens currently cached for a request.
    pub fn len_of(&self, req: u64) -> Option<usize> {
        self.requests.get(&req).map(|r| r.len)
    }

    /// Pages needed for `tokens`.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Can a request of `tokens` prompt tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    /// f32 elements per page.
    pub fn page_elems(&self) -> usize {
        2 * self.layers * self.page_size * self.hidden
    }

    /// Pages needed to hold `elems` flattened f32s (≥ 1).
    pub fn pages_for_elems(&self, elems: usize) -> usize {
        elems.max(1).div_ceil(self.page_elems())
    }

    /// Page in an adapter's flattened weight stack: allocate
    /// `pages_for_elems(weights.len())` pages from the shared free list
    /// and copy the weights into them chunk by chunk. Returns the page
    /// count charged to the adapter. Fails typed — `AlreadyResident`
    /// for a double page-in, `OutOfPages` when KV holds too much of the
    /// pool (the caller evicts an idle adapter or defers).
    pub fn reserve_adapter(&mut self, adapter: u64, weights: &[f32]) -> Result<usize, KvError> {
        if self.adapter_holds.contains_key(&adapter) {
            return Err(KvError::AlreadyResident(adapter));
        }
        let need = self.pages_for_elems(weights.len());
        if need > self.free.len() {
            return Err(KvError::OutOfPages {
                need,
                free: self.free.len(),
            });
        }
        let at = self.free.len() - need;
        let pages: Vec<usize> = self.free.split_off(at);
        let chunk = self.page_elems();
        for (ord, &p) in pages.iter().enumerate() {
            let lo = (ord * chunk).min(weights.len());
            let hi = ((ord + 1) * chunk).min(weights.len());
            let page = &mut self.pool[p];
            page[..hi - lo].copy_from_slice(&weights[lo..hi]);
            // Zero the tail so a later partial overwrite never leaks a
            // previous owner's rows through `adapter_weights`.
            for v in page[hi - lo..].iter_mut() {
                *v = 0.0;
            }
        }
        self.adapter_holds.insert(
            adapter,
            AdapterHold {
                pages,
                elems: weights.len(),
            },
        );
        Ok(need)
    }

    /// Evict an adapter's weight residency, returning its pages to the
    /// shared free list. Returns the page count released, `None` if the
    /// adapter was not resident (idempotent for callers racing evict
    /// against uninstall).
    pub fn free_adapter(&mut self, adapter: u64) -> Option<usize> {
        let hold = self.adapter_holds.remove(&adapter)?;
        let n = hold.pages.len();
        self.free.extend(hold.pages);
        Some(n)
    }

    /// Is the adapter's weight stack paged in?
    pub fn adapter_resident(&self, adapter: u64) -> bool {
        self.adapter_holds.contains_key(&adapter)
    }

    /// Pages held by one resident adapter (`None` if not resident).
    pub fn adapter_pages(&self, adapter: u64) -> Option<usize> {
        self.adapter_holds.get(&adapter).map(|h| h.pages.len())
    }

    /// Total pages held by adapter weight residency.
    pub fn adapter_held_pages(&self) -> usize {
        self.adapter_holds.values().map(|h| h.pages.len()).sum()
    }

    /// Total pages held by request KV.
    pub fn kv_held_pages(&self) -> usize {
        self.requests.values().map(|r| r.pages.len()).sum()
    }

    /// Resident adapter ids (unordered).
    pub fn resident_adapters(&self) -> Vec<u64> {
        self.adapter_holds.keys().copied().collect()
    }

    /// Gather a resident adapter's flattened weights back out of its
    /// pages — the exact f32s passed to [`Self::reserve_adapter`], so
    /// stacks rebuilt from the pool are value-identical to the host
    /// copy and token streams stay bitwise stable across evict/re-page
    /// cycles.
    pub fn adapter_weights(&self, adapter: u64) -> Option<Vec<f32>> {
        let hold = self.adapter_holds.get(&adapter)?;
        let chunk = self.page_elems();
        let mut out = Vec::with_capacity(hold.elems);
        for (ord, &p) in hold.pages.iter().enumerate() {
            let lo = (ord * chunk).min(hold.elems);
            let hi = ((ord + 1) * chunk).min(hold.elems);
            out.extend_from_slice(&self.pool[p][..hi - lo]);
        }
        Some(out)
    }

    /// The unified-pool conservation law: every page is free, KV-held,
    /// or adapter-held — never two at once, never lost.
    pub fn accounting_balanced(&self) -> bool {
        self.free.len() + self.kv_held_pages() + self.adapter_held_pages()
            == self.pool.len()
    }

    /// Admit `req` by reserving pages for a `len`-token prompt whose
    /// K/V rows will be written through a [`PageWriter`] (see
    /// [`Self::writers`]). The request is live from this point:
    /// `len_of` reports `len`, and `free_request` releases the pages —
    /// callers that fail between reserve and write must free.
    pub fn reserve(&mut self, req: u64, len: usize) -> Result<(), KvError> {
        if len > self.max_tokens {
            return Err(KvError::TooLong(req, self.max_tokens));
        }
        if self.requests.contains_key(&req) {
            return Err(KvError::AlreadyAdmitted(req));
        }
        let need = self.pages_for(len.max(1));
        if need > self.free.len() {
            return Err(KvError::OutOfPages {
                need,
                free: self.free.len(),
            });
        }
        let pages: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.requests.insert(req, RequestKv { pages, len });
        Ok(())
    }

    /// One [`PageWriter`] per request in `reqs`, each holding disjoint
    /// `&mut` borrows of exactly that request's pages — safe to move to
    /// concurrent forward threads. `reqs` must not repeat an id.
    pub fn writers(&mut self, reqs: &[u64]) -> Result<Vec<PageWriter<'_>>, KvError> {
        // page id → (position in reqs, ordinal within the request).
        let mut owner: HashMap<usize, (usize, usize)> = HashMap::new();
        let mut lens: Vec<usize> = Vec::with_capacity(reqs.len());
        for (ri, id) in reqs.iter().enumerate() {
            let r = self
                .requests
                .get(id)
                .ok_or(KvError::UnknownRequest(*id))?;
            for (ord, &p) in r.pages.iter().enumerate() {
                if owner.insert(p, (ri, ord)).is_some() {
                    // A repeated id would leave the earlier occurrence's
                    // writer with missing pages (the owner map can hold
                    // each page once) — reject instead of handing out a
                    // writer that panics mid-prefill.
                    return Err(KvError::AlreadyAdmitted(*id));
                }
            }
            lens.push(r.len);
        }
        // Distribute the pool's &mut pages to their owners.
        let mut parts: Vec<Vec<(usize, &mut [f32])>> =
            reqs.iter().map(|_| Vec::new()).collect();
        for (pid, page) in self.pool.iter_mut().enumerate() {
            if let Some(&(ri, ord)) = owner.get(&pid) {
                parts[ri].push((ord, page.as_mut_slice()));
            }
        }
        let mut out = Vec::with_capacity(reqs.len());
        for (ri, mut part) in parts.into_iter().enumerate() {
            part.sort_by_key(|&(ord, _)| ord);
            out.push(PageWriter {
                layers: self.layers,
                hidden: self.hidden,
                page_size: self.page_size,
                len: lens[ri],
                pages: part.into_iter().map(|(_, s)| s).collect(),
            });
        }
        Ok(out)
    }

    /// A zero-copy read view over the pool for a decode batch: row `i`
    /// of the view is request `reqs[i]`. Implements
    /// [`crate::runtime::KvView`], so the native runtime's attention
    /// iterates pages in place.
    pub fn paged_view(&self, reqs: &[u64]) -> Result<PagedKv<'_>, KvError> {
        let mut tables = Vec::with_capacity(reqs.len());
        for id in reqs {
            let r = self
                .requests
                .get(id)
                .ok_or(KvError::UnknownRequest(*id))?;
            tables.push((r.pages.as_slice(), r.len));
        }
        Ok(PagedKv {
            pool: &self.pool,
            tables,
            layers: self.layers,
            hidden: self.hidden,
            page_size: self.page_size,
        })
    }

    /// Admit a request with the prompt KV produced by a *dense* prefill
    /// output (the PJRT fallback layout).
    ///
    /// `k`/`v` are the full bucket outputs, row-major
    /// [layers, bucket_batch, bucket_seq, hidden]; `row` selects this
    /// request's row; `len` its true prompt length. Implemented over
    /// [`Self::reserve`] + [`Self::writers`] — the zero-copy path minus
    /// the zero-copy.
    pub fn admit_from_prefill(
        &mut self,
        req: u64,
        k: &[f32],
        v: &[f32],
        bucket_batch: usize,
        bucket_seq: usize,
        row: usize,
        len: usize,
    ) -> Result<(), KvError> {
        let (layers, hidden) = (self.layers, self.hidden);
        self.reserve(req, len)?;
        let mut writers = self.writers(&[req])?;
        let w = &mut writers[0];
        for t in 0..len {
            for layer in 0..layers {
                let src = ((layer * bucket_batch + row) * bucket_seq + t) * hidden;
                w.write_kv(layer, t, &k[src..src + hidden], &v[src..src + hidden]);
            }
        }
        Ok(())
    }

    /// Append one token's KV rows (decode output `k_new`/`v_new`,
    /// row-major [layers, bucket_batch, hidden]; `row` selects the
    /// request).
    pub fn append_token(
        &mut self,
        req: u64,
        k_new: &[f32],
        v_new: &[f32],
        bucket_batch: usize,
        row: usize,
    ) -> Result<(), KvError> {
        let layers = self.layers;
        let hidden = self.hidden;
        let page_size = self.page_size;
        let (len, needs_page) = {
            let r = self
                .requests
                .get(&req)
                .ok_or(KvError::UnknownRequest(req))?;
            (r.len, r.len % page_size == 0 || r.pages.is_empty())
        };
        if len + 1 > self.max_tokens {
            return Err(KvError::TooLong(req, self.max_tokens));
        }
        // The slot for the new token: len % page_size in page len/page_size.
        let page_needed = len / page_size;
        let have_pages = self.requests[&req].pages.len();
        if page_needed >= have_pages {
            debug_assert!(needs_page || have_pages == page_needed);
            let page = self.free.pop().ok_or(KvError::OutOfPages {
                need: 1,
                free: 0,
            })?;
            self.requests.get_mut(&req).unwrap().pages.push(page);
        }
        let r = self.requests.get(&req).unwrap();
        let page = r.pages[len / page_size];
        let slot = len % page_size;
        for layer in 0..layers {
            let src = (layer * bucket_batch + row) * hidden;
            let kd = page_offset(layers, page_size, hidden, layer, slot, false);
            self.pool[page][kd..kd + hidden]
                .copy_from_slice(&k_new[src..src + hidden]);
            let vd = page_offset(layers, page_size, hidden, layer, slot, true);
            self.pool[page][vd..vd + hidden]
                .copy_from_slice(&v_new[src..src + hidden]);
        }
        self.requests.get_mut(&req).unwrap().len = len + 1;
        Ok(())
    }

    /// Assemble the padded decode inputs for a batch of requests:
    /// returns (k, v) row-major [layers, bucket_batch, m, hidden], with
    /// rows beyond the batch and positions beyond each request's length
    /// zeroed.
    pub fn assemble(
        &self,
        reqs: &[u64],
        bucket_batch: usize,
        m: usize,
    ) -> Result<(Vec<f32>, Vec<f32>), KvError> {
        let mut k = Vec::new();
        let mut v = Vec::new();
        self.assemble_into(reqs, bucket_batch, m, &mut k, &mut v)?;
        Ok((k, v))
    }

    /// [`Self::assemble`] into caller-owned buffers reused across
    /// iterations. Only the PJRT backend pays this cost now — the
    /// native path reads pages in place via [`Self::paged_view`]
    /// (§Perf).
    pub fn assemble_into(
        &self,
        reqs: &[u64],
        bucket_batch: usize,
        m: usize,
        k: &mut Vec<f32>,
        v: &mut Vec<f32>,
    ) -> Result<(), KvError> {
        assert!(reqs.len() <= bucket_batch);
        let elems = self.layers * bucket_batch * m * self.hidden;
        k.clear();
        k.resize(elems, 0.0);
        v.clear();
        v.resize(elems, 0.0);
        for (row, &id) in reqs.iter().enumerate() {
            let r = self.requests.get(&id).ok_or(KvError::UnknownRequest(id))?;
            if r.len > m {
                return Err(KvError::TooLong(id, m));
            }
            for t in 0..r.len {
                let page = r.pages[t / self.page_size];
                let slot = t % self.page_size;
                for layer in 0..self.layers {
                    let dst = ((layer * bucket_batch + row) * m + t) * self.hidden;
                    let ks = page_offset(
                        self.layers,
                        self.page_size,
                        self.hidden,
                        layer,
                        slot,
                        false,
                    );
                    k[dst..dst + self.hidden]
                        .copy_from_slice(&self.pool[page][ks..ks + self.hidden]);
                    let vs = page_offset(
                        self.layers,
                        self.page_size,
                        self.hidden,
                        layer,
                        slot,
                        true,
                    );
                    v[dst..dst + self.hidden]
                        .copy_from_slice(&self.pool[page][vs..vs + self.hidden]);
                }
            }
        }
        Ok(())
    }

    /// Release a request's pages.
    pub fn free_request(&mut self, req: u64) -> Result<(), KvError> {
        let r = self
            .requests
            .remove(&req)
            .ok_or(KvError::UnknownRequest(req))?;
        self.free.extend(r.pages);
        Ok(())
    }

    /// Number of live requests.
    pub fn live_requests(&self) -> usize {
        self.requests.len()
    }
}

/// Write handle over one request's reserved pages ([`KvCacheManager::
/// writers`]): prefill streams each freshly computed K/V row straight
/// into its page slot. Writers for different requests borrow disjoint
/// pages, so a batch of them moves to concurrent forward threads.
pub struct PageWriter<'a> {
    layers: usize,
    hidden: usize,
    page_size: usize,
    /// Reserved token capacity (the request's prompt length).
    len: usize,
    /// This request's pages, in block-table order.
    pages: Vec<&'a mut [f32]>,
}

impl PageWriter<'_> {
    /// Reserved token capacity.
    pub fn capacity(&self) -> usize {
        self.len
    }
}

impl crate::runtime::KvWrite for PageWriter<'_> {
    fn write_kv(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.len.max(1), "write beyond reservation");
        let slot = pos % self.page_size;
        let page = &mut *self.pages[pos / self.page_size];
        let kd = page_offset(self.layers, self.page_size, self.hidden, layer, slot, false);
        page[kd..kd + self.hidden].copy_from_slice(k_row);
        let vd = page_offset(self.layers, self.page_size, self.hidden, layer, slot, true);
        page[vd..vd + self.hidden].copy_from_slice(v_row);
    }
}

/// Zero-copy read view for a decode batch ([`KvCacheManager::
/// paged_view`]): per-request block tables over a shared borrow of the
/// page pool. Row order matches the `reqs` slice the view was built
/// from.
pub struct PagedKv<'a> {
    pool: &'a [Vec<f32>],
    /// (block table, cached length) per batch row.
    tables: Vec<(&'a [usize], usize)>,
    layers: usize,
    hidden: usize,
    page_size: usize,
}

impl PagedKv<'_> {
    /// Cached tokens for batch row `row`.
    pub fn len_of_row(&self, row: usize) -> usize {
        self.tables[row].1
    }
}

impl crate::runtime::KvView for PagedKv<'_> {
    fn kv_row(&self, row: usize, layer: usize, pos: usize, want_v: bool) -> &[f32] {
        let (pages, len) = self.tables[row];
        debug_assert!(pos < len, "read beyond cached length");
        let page = &self.pool[pages[pos / self.page_size]];
        let at = page_offset(
            self.layers,
            self.page_size,
            self.hidden,
            layer,
            pos % self.page_size,
            want_v,
        );
        &page[at..at + self.hidden]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{KvView, KvWrite};

    fn mgr() -> KvCacheManager {
        KvCacheManager::new(2, 4, 4, 8, 32)
    }

    /// Build fake prefill output [L, B, S, H] where element value encodes
    /// (layer, row, token, dim) for traceability.
    fn fake_prefill(l: usize, b: usize, s: usize, h: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; l * b * s * h];
        for (i, v) in out.iter_mut().enumerate() {
            *v = i as f32;
        }
        out
    }

    #[test]
    fn admit_assemble_roundtrip() {
        let mut m = mgr();
        let (l, b, s, h) = (2, 2, 8, 4);
        let k = fake_prefill(l, b, s, h);
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        m.admit_from_prefill(42, &k, &v, b, s, 1, 5).unwrap();
        assert_eq!(m.len_of(42), Some(5));

        let (ka, va) = m.assemble(&[42], 1, 16).unwrap();
        // Check a few elements: request row 1, token t, layer ly.
        for ly in 0..l {
            for t in 0..5 {
                for d in 0..h {
                    let src = ((ly * b + 1) * s + t) * h + d;
                    let dst = ((ly * 1 + 0) * 16 + t) * h + d;
                    assert_eq!(ka[dst], k[src], "K mismatch ly={ly} t={t} d={d}");
                    assert_eq!(va[dst], v[src]);
                }
            }
            // Beyond len: zeros.
            let dst = ((ly * 1 + 0) * 16 + 7) * h;
            assert_eq!(ka[dst], 0.0);
        }
    }

    #[test]
    fn paged_view_matches_assembly() {
        // The zero-copy view must read exactly what dense assembly
        // copies out — including across page boundaries (page_size 4,
        // len 7 spans two pages).
        let mut m = mgr();
        let (l, b, s, h) = (2, 2, 8, 4);
        let k = fake_prefill(l, b, s, h);
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        m.admit_from_prefill(1, &k, &v, b, s, 0, 7).unwrap();
        m.admit_from_prefill(2, &k, &v, b, s, 1, 3).unwrap();

        let reqs = [2u64, 1];
        let (ka, va) = m.assemble(&reqs, 2, 8).unwrap();
        let view = m.paged_view(&reqs).unwrap();
        assert_eq!(view.len_of_row(0), 3);
        assert_eq!(view.len_of_row(1), 7);
        for (row, len) in [(0usize, 3usize), (1, 7)] {
            for layer in 0..l {
                for t in 0..len {
                    let at = ((layer * 2 + row) * 8 + t) * h;
                    assert_eq!(
                        view.kv_row(row, layer, t, false),
                        &ka[at..at + h],
                        "K row={row} layer={layer} t={t}"
                    );
                    assert_eq!(view.kv_row(row, layer, t, true), &va[at..at + h]);
                }
            }
        }
    }

    #[test]
    fn writers_are_disjoint_and_ordered() {
        // Two requests written through simultaneous writers land in
        // their own pages, in block-table order.
        let mut m = mgr();
        m.reserve(7, 6).unwrap(); // 2 pages
        m.reserve(8, 2).unwrap(); // 1 page
        let (l, h) = (2usize, 4usize);
        {
            let mut ws = m.writers(&[7, 8]).unwrap();
            assert_eq!(ws.len(), 2);
            assert_eq!(ws[0].capacity(), 6);
            let (w7, w8) = ws.split_at_mut(1);
            for layer in 0..l {
                for t in 0..6 {
                    let row: Vec<f32> =
                        (0..h).map(|d| (100 + layer * 10 + t) as f32 + d as f32).collect();
                    w7[0].write_kv(layer, t, &row, &row);
                }
                for t in 0..2 {
                    let row = vec![-((layer * 10 + t) as f32); h];
                    w8[0].write_kv(layer, t, &row, &row);
                }
            }
        }
        let view = m.paged_view(&[7, 8]).unwrap();
        // Request 7, layer 1, token 5 (second page, slot 1).
        assert_eq!(view.kv_row(0, 1, 5, false)[0], 115.0);
        // Request 8 unclobbered.
        assert_eq!(view.kv_row(1, 1, 1, true)[0], -11.0);
    }

    #[test]
    fn writers_reject_duplicate_ids() {
        // A repeated id would hand the first occurrence a writer with
        // missing pages — must be a typed error, not a later panic.
        let mut m = mgr();
        m.reserve(5, 3).unwrap();
        assert!(matches!(
            m.writers(&[5, 5]),
            Err(KvError::AlreadyAdmitted(5))
        ));
    }

    #[test]
    fn reserve_guards() {
        let mut m = KvCacheManager::new(2, 4, 4, 2, 32);
        m.reserve(1, 8).unwrap(); // both pages
        assert_eq!(
            m.reserve(2, 1),
            Err(KvError::OutOfPages { need: 1, free: 0 })
        );
        assert_eq!(m.reserve(1, 1), Err(KvError::AlreadyAdmitted(1)));
        assert_eq!(m.reserve(3, 33), Err(KvError::TooLong(3, 32)));
        m.free_request(1).unwrap();
        assert_eq!(m.free_pages(), 2);
    }

    #[test]
    fn eviction_and_readmission_reuse_pages_cleanly() {
        // Free a request, readmit another over the same pages: the view
        // must serve only the new request's rows (stale data beyond the
        // new length is never addressed: reads are bounded by len).
        let mut m = mgr();
        let (l, b, s, h) = (2, 1, 8, 4);
        let k = fake_prefill(l, b, s, h);
        m.admit_from_prefill(1, &k, &k, b, s, 0, 8).unwrap();
        let stale = m.assemble(&[1], 1, 8).unwrap().0;
        m.free_request(1).unwrap();

        let fresh: Vec<f32> = k.iter().map(|x| x * -3.0).collect();
        m.admit_from_prefill(2, &fresh, &fresh, b, s, 0, 5).unwrap();
        let view = m.paged_view(&[2]).unwrap();
        assert_eq!(view.len_of_row(0), 5);
        for layer in 0..l {
            for t in 0..5 {
                let at = ((layer * b) * s + t) * h;
                assert_eq!(view.kv_row(0, layer, t, false), &fresh[at..at + h]);
            }
        }
        // And dense assembly agrees (zero-pads beyond len even though
        // the reused pages still hold request 1's stale rows).
        let (ka, _) = m.assemble(&[2], 1, 8).unwrap();
        assert_ne!(ka, stale);
        let tail = (5usize..8).all(|t| {
            (0..l).all(|layer| {
                let at = ((layer * 1) * 8 + t) * h;
                ka[at..at + h].iter().all(|&x| x == 0.0)
            })
        });
        assert!(tail, "assembly must zero-pad beyond the new length");
    }

    #[test]
    fn append_grows_and_allocates_pages() {
        let mut m = mgr();
        let (l, b, s, h) = (2, 1, 4, 4);
        let k = fake_prefill(l, b, s, h);
        m.admit_from_prefill(1, &k, &k, b, s, 0, 4).unwrap();
        let free_before = m.free_pages();
        // Appending token 5 crosses into a second page.
        let k_new = vec![7.0f32; l * 1 * h];
        m.append_token(1, &k_new, &k_new, 1, 0).unwrap();
        assert_eq!(m.len_of(1), Some(5));
        assert_eq!(m.free_pages(), free_before - 1);
        let (ka, _) = m.assemble(&[1], 1, 8).unwrap();
        // Token 4 (0-based) must hold 7.0 at layer 0.
        let dst = ((0) * 8 + 4) * h;
        assert_eq!(ka[dst], 7.0);
        // The paged view sees the appended token without assembly.
        let view = m.paged_view(&[1]).unwrap();
        assert_eq!(view.kv_row(0, 0, 4, false), &k_new[..h]);
    }

    #[test]
    fn free_returns_pages() {
        let mut m = mgr();
        let k = fake_prefill(2, 1, 8, 4);
        m.admit_from_prefill(9, &k, &k, 1, 8, 0, 8).unwrap();
        let used = m.total_pages() - m.free_pages();
        assert_eq!(used, 2);
        m.free_request(9).unwrap();
        assert_eq!(m.free_pages(), m.total_pages());
        assert_eq!(m.free_request(9), Err(KvError::UnknownRequest(9)));
    }

    #[test]
    fn admission_control() {
        let mut m = KvCacheManager::new(2, 4, 4, 2, 32);
        assert!(m.can_admit(8));
        assert!(!m.can_admit(9));
        let k = fake_prefill(2, 1, 8, 4);
        m.admit_from_prefill(1, &k, &k, 1, 8, 0, 8).unwrap();
        assert_eq!(
            m.admit_from_prefill(2, &k, &k, 1, 8, 0, 4),
            Err(KvError::OutOfPages { need: 1, free: 0 })
        );
    }

    #[test]
    fn too_long_rejected() {
        let mut m = mgr(); // max_tokens 32
        let k = fake_prefill(2, 1, 8, 4);
        assert!(matches!(
            m.admit_from_prefill(1, &k, &k, 1, 8, 0, 33),
            Err(KvError::TooLong(1, 32))
        ));
    }

    #[test]
    fn adapter_pages_roundtrip_and_share_the_pool() {
        // mgr(): 8 pages of 2·2·4·4 = 64 elems each.
        let mut m = mgr();
        assert_eq!(m.page_elems(), 64);
        let w: Vec<f32> = (0..150).map(|i| i as f32 * 0.5).collect();
        // 150 elems → 3 pages.
        assert_eq!(m.pages_for_elems(150), 3);
        assert_eq!(m.reserve_adapter(7, &w).unwrap(), 3);
        assert!(m.adapter_resident(7));
        assert_eq!(m.adapter_pages(7), Some(3));
        assert_eq!(m.adapter_held_pages(), 3);
        assert_eq!(m.free_pages(), 5);
        assert!(m.accounting_balanced());
        // Readback is the exact flattened weights.
        assert_eq!(m.adapter_weights(7).unwrap(), w);
        // Double page-in is a typed error, not silent re-alloc.
        assert_eq!(m.reserve_adapter(7, &w), Err(KvError::AlreadyResident(7)));
        // KV and adapters compete for the same free list: 5 pages left
        // admit 20 tokens but not 24.
        assert!(m.can_admit(20));
        assert!(!m.can_admit(24));
        // Eviction returns the pages; the id is gone.
        assert_eq!(m.free_adapter(7), Some(3));
        assert_eq!(m.free_adapter(7), None);
        assert!(!m.adapter_resident(7));
        assert_eq!(m.free_pages(), 8);
        assert!(m.accounting_balanced());
    }

    #[test]
    fn adapter_reserve_fails_typed_under_kv_pressure() {
        let mut m = KvCacheManager::new(2, 4, 4, 2, 32);
        m.reserve(1, 5).unwrap(); // 2 of 2 pages to KV
        assert_eq!(
            m.reserve_adapter(9, &[1.0; 10]),
            Err(KvError::OutOfPages { need: 1, free: 0 })
        );
        m.free_request(1).unwrap();
        assert_eq!(m.reserve_adapter(9, &[1.0; 10]).unwrap(), 1);
        // Now the adapter squeezes KV admission: one page left.
        assert_eq!(m.kv_held_pages(), 0);
        assert_eq!(m.adapter_held_pages(), 1);
        assert!(m.can_admit(4));
        assert!(!m.can_admit(5));
        assert!(m.accounting_balanced());
    }

    #[test]
    fn adapter_pages_zero_stale_tails() {
        // A page freed by a bigger owner then reused by a smaller one
        // must not leak the old rows through the gather.
        let mut m = mgr();
        m.reserve_adapter(1, &[9.0f32; 64]).unwrap();
        m.free_adapter(1).unwrap();
        let small = vec![2.0f32; 10];
        m.reserve_adapter(2, &small).unwrap();
        assert_eq!(m.adapter_weights(2).unwrap(), small);
        assert!(m.adapter_weights(99).is_none());
        assert_eq!(m.resident_adapters(), vec![2]);
    }

    #[test]
    fn multi_request_assembly_is_row_ordered() {
        let mut m = mgr();
        let k = fake_prefill(2, 2, 4, 4);
        m.admit_from_prefill(10, &k, &k, 2, 4, 0, 3).unwrap();
        m.admit_from_prefill(20, &k, &k, 2, 4, 1, 2).unwrap();
        let (ka, _) = m.assemble(&[20, 10], 2, 8).unwrap();
        // Row 0 of the assembly = request 20 = prefill row 1.
        let src_20 = ((0 * 2 + 1) * 4 + 0) * 4;
        let dst_row0 = ((0 * 2 + 0) * 8 + 0) * 4;
        assert_eq!(ka[dst_row0], k[src_20]);
    }
}
