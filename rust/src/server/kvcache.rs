//! Paged KV-cache manager.
//!
//! vLLM-style block allocation: each request's KV rows live in
//! fixed-size token pages drawn from a bounded pool, so memory is
//! reclaimed at request completion without fragmentation (§8 of the
//! paper credits this mechanism; LightLLM/vLLM both use it).
//!
//! Layout: one page holds `page_size` token rows for **all** layers,
//! K and V, i.e. `2 · layers · page_size · hidden` f32s. The decode
//! input tensors ([L, B, M, H]) are assembled by gathering each
//! request's pages.

use std::collections::HashMap;

/// Errors from the KV manager.
#[derive(Debug, PartialEq)]
pub enum KvError {
    OutOfPages { need: usize, free: usize },
    UnknownRequest(u64),
    TooLong(u64, usize),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages { need, free } => {
                write!(f, "out of KV pages (need {need}, free {free})")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::TooLong(id, cap) => {
                write!(f, "request {id} exceeds cache capacity {cap}")
            }
        }
    }
}

impl std::error::Error for KvError {}

struct RequestKv {
    pages: Vec<usize>,
    len: usize,
}

/// The paged KV-cache manager.
pub struct KvCacheManager {
    layers: usize,
    hidden: usize,
    page_size: usize,
    /// Max tokens a single request may hold (decode bucket capacity M).
    max_tokens: usize,
    /// Page pool: each page is `2·layers·page_size·hidden` f32s
    /// (K rows then V rows per layer-major order).
    pool: Vec<Vec<f32>>,
    free: Vec<usize>,
    requests: HashMap<u64, RequestKv>,
}

impl KvCacheManager {
    /// A pool of `n_pages` pages of `page_size` tokens each.
    pub fn new(
        layers: usize,
        hidden: usize,
        page_size: usize,
        n_pages: usize,
        max_tokens: usize,
    ) -> KvCacheManager {
        let page_elems = 2 * layers * page_size * hidden;
        KvCacheManager {
            layers,
            hidden,
            page_size,
            max_tokens,
            pool: (0..n_pages).map(|_| vec![0.0; page_elems]).collect(),
            free: (0..n_pages).rev().collect(),
            requests: HashMap::new(),
        }
    }

    /// Free pages remaining.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Total pages.
    pub fn total_pages(&self) -> usize {
        self.pool.len()
    }

    /// Tokens currently cached for a request.
    pub fn len_of(&self, req: u64) -> Option<usize> {
        self.requests.get(&req).map(|r| r.len)
    }

    /// Pages needed for `tokens`.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Can a request of `tokens` prompt tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    fn offsets(&self, layer: usize, slot: usize, is_v: bool) -> usize {
        // Page layout: [K/V][layer][slot][hidden].
        let half = self.layers * self.page_size * self.hidden;
        (if is_v { half } else { 0 })
            + layer * self.page_size * self.hidden
            + slot * self.hidden
    }

    /// Admit a request with the prompt KV produced by a prefill call.
    ///
    /// `k`/`v` are the full bucket outputs, row-major
    /// [layers, bucket_batch, bucket_seq, hidden]; `row` selects this
    /// request's row; `len` its true prompt length.
    pub fn admit_from_prefill(
        &mut self,
        req: u64,
        k: &[f32],
        v: &[f32],
        bucket_batch: usize,
        bucket_seq: usize,
        row: usize,
        len: usize,
    ) -> Result<(), KvError> {
        if len > self.max_tokens {
            return Err(KvError::TooLong(req, self.max_tokens));
        }
        let need = self.pages_for(len.max(1));
        if need > self.free.len() {
            return Err(KvError::OutOfPages {
                need,
                free: self.free.len(),
            });
        }
        let pages: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        for t in 0..len {
            let page = pages[t / self.page_size];
            let slot = t % self.page_size;
            for layer in 0..self.layers {
                let src =
                    ((layer * bucket_batch + row) * bucket_seq + t) * self.hidden;
                let kd = self.offsets(layer, slot, false);
                self.pool[page][kd..kd + self.hidden]
                    .copy_from_slice(&k[src..src + self.hidden]);
                let vd = self.offsets(layer, slot, true);
                self.pool[page][vd..vd + self.hidden]
                    .copy_from_slice(&v[src..src + self.hidden]);
            }
        }
        self.requests.insert(req, RequestKv { pages, len });
        Ok(())
    }

    /// Append one token's KV rows (decode output `k_new`/`v_new`,
    /// row-major [layers, bucket_batch, hidden]; `row` selects the
    /// request).
    pub fn append_token(
        &mut self,
        req: u64,
        k_new: &[f32],
        v_new: &[f32],
        bucket_batch: usize,
        row: usize,
    ) -> Result<(), KvError> {
        let layers = self.layers;
        let hidden = self.hidden;
        let page_size = self.page_size;
        let (len, needs_page) = {
            let r = self
                .requests
                .get(&req)
                .ok_or(KvError::UnknownRequest(req))?;
            (r.len, r.len % page_size == 0 || r.pages.is_empty())
        };
        if len + 1 > self.max_tokens {
            return Err(KvError::TooLong(req, self.max_tokens));
        }
        // The slot for the new token: len % page_size in page len/page_size.
        let page_needed = len / page_size;
        let have_pages = self.requests[&req].pages.len();
        if page_needed >= have_pages {
            debug_assert!(needs_page || have_pages == page_needed);
            let page = self.free.pop().ok_or(KvError::OutOfPages {
                need: 1,
                free: 0,
            })?;
            self.requests.get_mut(&req).unwrap().pages.push(page);
        }
        let r = self.requests.get(&req).unwrap();
        let page = r.pages[len / page_size];
        let slot = len % page_size;
        for layer in 0..layers {
            let src = (layer * bucket_batch + row) * hidden;
            let kd = self.offsets(layer, slot, false);
            self.pool[page][kd..kd + hidden]
                .copy_from_slice(&k_new[src..src + hidden]);
            let vd = self.offsets(layer, slot, true);
            self.pool[page][vd..vd + hidden]
                .copy_from_slice(&v_new[src..src + hidden]);
        }
        self.requests.get_mut(&req).unwrap().len = len + 1;
        Ok(())
    }

    /// Assemble the padded decode inputs for a batch of requests:
    /// returns (k, v) row-major [layers, bucket_batch, m, hidden], with
    /// rows beyond the batch and positions beyond each request's length
    /// zeroed.
    pub fn assemble(
        &self,
        reqs: &[u64],
        bucket_batch: usize,
        m: usize,
    ) -> Result<(Vec<f32>, Vec<f32>), KvError> {
        let mut k = Vec::new();
        let mut v = Vec::new();
        self.assemble_into(reqs, bucket_batch, m, &mut k, &mut v)?;
        Ok((k, v))
    }

    /// [`Self::assemble`] into caller-owned buffers — the decode hot
    /// path reuses these across iterations instead of allocating two
    /// multi-MB vectors per step (§Perf).
    pub fn assemble_into(
        &self,
        reqs: &[u64],
        bucket_batch: usize,
        m: usize,
        k: &mut Vec<f32>,
        v: &mut Vec<f32>,
    ) -> Result<(), KvError> {
        assert!(reqs.len() <= bucket_batch);
        let elems = self.layers * bucket_batch * m * self.hidden;
        k.clear();
        k.resize(elems, 0.0);
        v.clear();
        v.resize(elems, 0.0);
        for (row, &id) in reqs.iter().enumerate() {
            let r = self.requests.get(&id).ok_or(KvError::UnknownRequest(id))?;
            if r.len > m {
                return Err(KvError::TooLong(id, m));
            }
            for t in 0..r.len {
                let page = r.pages[t / self.page_size];
                let slot = t % self.page_size;
                for layer in 0..self.layers {
                    let dst = ((layer * bucket_batch + row) * m + t) * self.hidden;
                    let ks = self.offsets(layer, slot, false);
                    k[dst..dst + self.hidden]
                        .copy_from_slice(&self.pool[page][ks..ks + self.hidden]);
                    let vs = self.offsets(layer, slot, true);
                    v[dst..dst + self.hidden]
                        .copy_from_slice(&self.pool[page][vs..vs + self.hidden]);
                }
            }
        }
        Ok(())
    }

    /// Release a request's pages.
    pub fn free_request(&mut self, req: u64) -> Result<(), KvError> {
        let r = self
            .requests
            .remove(&req)
            .ok_or(KvError::UnknownRequest(req))?;
        self.free.extend(r.pages);
        Ok(())
    }

    /// Number of live requests.
    pub fn live_requests(&self) -> usize {
        self.requests.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvCacheManager {
        KvCacheManager::new(2, 4, 4, 8, 32)
    }

    /// Build fake prefill output [L, B, S, H] where element value encodes
    /// (layer, row, token, dim) for traceability.
    fn fake_prefill(l: usize, b: usize, s: usize, h: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; l * b * s * h];
        for (i, v) in out.iter_mut().enumerate() {
            *v = i as f32;
        }
        out
    }

    #[test]
    fn admit_assemble_roundtrip() {
        let mut m = mgr();
        let (l, b, s, h) = (2, 2, 8, 4);
        let k = fake_prefill(l, b, s, h);
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        m.admit_from_prefill(42, &k, &v, b, s, 1, 5).unwrap();
        assert_eq!(m.len_of(42), Some(5));

        let (ka, va) = m.assemble(&[42], 1, 16).unwrap();
        // Check a few elements: request row 1, token t, layer ly.
        for ly in 0..l {
            for t in 0..5 {
                for d in 0..h {
                    let src = ((ly * b + 1) * s + t) * h + d;
                    let dst = ((ly * 1 + 0) * 16 + t) * h + d;
                    assert_eq!(ka[dst], k[src], "K mismatch ly={ly} t={t} d={d}");
                    assert_eq!(va[dst], v[src]);
                }
            }
            // Beyond len: zeros.
            let dst = ((ly * 1 + 0) * 16 + 7) * h;
            assert_eq!(ka[dst], 0.0);
        }
    }

    #[test]
    fn append_grows_and_allocates_pages() {
        let mut m = mgr();
        let (l, b, s, h) = (2, 1, 4, 4);
        let k = fake_prefill(l, b, s, h);
        m.admit_from_prefill(1, &k, &k, b, s, 0, 4).unwrap();
        let free_before = m.free_pages();
        // Appending token 5 crosses into a second page.
        let k_new = vec![7.0f32; l * 1 * h];
        m.append_token(1, &k_new, &k_new, 1, 0).unwrap();
        assert_eq!(m.len_of(1), Some(5));
        assert_eq!(m.free_pages(), free_before - 1);
        let (ka, _) = m.assemble(&[1], 1, 8).unwrap();
        // Token 4 (0-based) must hold 7.0 at layer 0.
        let dst = ((0) * 8 + 4) * h;
        assert_eq!(ka[dst], 7.0);
    }

    #[test]
    fn free_returns_pages() {
        let mut m = mgr();
        let k = fake_prefill(2, 1, 8, 4);
        m.admit_from_prefill(9, &k, &k, 1, 8, 0, 8).unwrap();
        let used = m.total_pages() - m.free_pages();
        assert_eq!(used, 2);
        m.free_request(9).unwrap();
        assert_eq!(m.free_pages(), m.total_pages());
        assert_eq!(m.free_request(9), Err(KvError::UnknownRequest(9)));
    }

    #[test]
    fn admission_control() {
        let mut m = KvCacheManager::new(2, 4, 4, 2, 32);
        assert!(m.can_admit(8));
        assert!(!m.can_admit(9));
        let k = fake_prefill(2, 1, 8, 4);
        m.admit_from_prefill(1, &k, &k, 1, 8, 0, 8).unwrap();
        assert_eq!(
            m.admit_from_prefill(2, &k, &k, 1, 8, 0, 4),
            Err(KvError::OutOfPages { need: 1, free: 0 })
        );
    }

    #[test]
    fn too_long_rejected() {
        let mut m = mgr(); // max_tokens 32
        let k = fake_prefill(2, 1, 8, 4);
        assert!(matches!(
            m.admit_from_prefill(1, &k, &k, 1, 8, 0, 33),
            Err(KvError::TooLong(1, 32))
        ));
    }

    #[test]
    fn multi_request_assembly_is_row_ordered() {
        let mut m = mgr();
        let k = fake_prefill(2, 2, 4, 4);
        m.admit_from_prefill(10, &k, &k, 2, 4, 0, 3).unwrap();
        m.admit_from_prefill(20, &k, &k, 2, 4, 1, 2).unwrap();
        let (ka, _) = m.assemble(&[20, 10], 2, 8).unwrap();
        // Row 0 of the assembly = request 20 = prefill row 1.
        let src_20 = ((0 * 2 + 1) * 4 + 0) * 4;
        let dst_row0 = ((0 * 2 + 0) * 8 + 0) * 4;
        assert_eq!(ka[dst_row0], k[src_20]);
    }
}
