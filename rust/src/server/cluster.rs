//! [`ClusterFront`]: the rank-aware scheduler in front of real engines.
//!
//! The paper's §5 scheduler (Algorithm 1) routed only *simulated*
//! instances; this module closes the loop for the distributed
//! north-star: a `ClusterFront` owns N boxed [`ServingFront`] backends
//! (real [`InferenceServer`]s, [`crate::sim::SimFront`]s, or a mix), a
//! [`scheduler::Policy`], and the [`GlobalRegistry`] — and **itself
//! implements `ServingFront`**, so drivers, tests, and the CLI run
//! unchanged against one engine or a whole routed cluster.
//!
//! Request path:
//!
//! 1. `submit` validates the adapter against the registry, applies the
//!    graceful-degradation gate (see below), builds a [`SchedRequest`]
//!    from the registered rank + prompt length, gathers every serving
//!    backend's [`ServerStats`] (real eligibility data: local adapter
//!    set, prompt capacity, KV headroom, preemptions), and asks the
//!    policy to pick.
//! 2. The chosen backend's own admission runs. If it rejects (KV bound,
//!    missing adapter, shape), the front marks that backend ineligible
//!    and **re-routes to the next-cheapest eligible server** instead of
//!    surfacing a terminal `Rejected`; only when every candidate has
//!    refused does the client see a typed
//!    [`RejectReason::NoEligibleServer`].
//! 3. On placement the client's handle receives `Admitted` followed by
//!    the non-terminal [`RequestEvent::Routed`]`{ server }`, then the
//!    backend's token stream is relayed verbatim (the backend's own
//!    `Admitted` is elided — the cluster already emitted one).
//!
//! # Fault containment, failover, and degradation
//!
//! Backends fail for real (a panicking runtime, a wedged IPC peer, a
//! dead process behind a socket front). The cluster contains every
//! failure at the poll boundary and keeps client streams intact:
//!
//! - **Containment.** Each backend's `poll()` runs under
//!   `catch_unwind`, so neither an `Err` nor a panic ever escapes
//!   `ClusterFront::poll`. A panicked backend is considered poisoned —
//!   its locks may be unusable — and is never called again.
//! - **Health machine.** Per backend:
//!   `Healthy → Suspect` on the first poll error, `Suspect → Down`
//!   after [`RetryPolicy::down_after`] consecutive errors (a panic goes
//!   straight to `Down`, permanently). A non-poisoned `Down` backend
//!   re-enters as `Probation` after a deterministic backoff measured in
//!   cluster polls ([`RetryPolicy::backoff_base`], doubling per failed
//!   probe up to [`RetryPolicy::backoff_cap`]); one clean probe poll
//!   returns it to `Healthy`. `Down`/`Probation` backends receive no
//!   new placements.
//! - **Failover.** When a backend goes `Down`, every live route on it
//!   is re-placed on a surviving server: the original request is
//!   resubmitted with [`ServeRequest::resume`] carrying exactly the
//!   tokens already delivered to the client, so the survivor re-prefills
//!   `prompt + generated` and continues decoding — the client stream is
//!   **bitwise identical** to the no-fault run (the same machinery that
//!   makes preemption re-queues stream-invisible). The client observes
//!   one non-terminal [`RequestEvent::Rerouted`]`{ from, to }`. Only
//!   when no survivor can take the request (or the
//!   [`RetryPolicy::max_reroutes`] cap is hit) does the client see a
//!   terminal [`RejectReason::BackendFailed`].
//! - **Stall watchdog.** A wedged backend that still claims progress is
//!   caught per request: a route that produces no event for more polls
//!   than its budget — [`RetryPolicy::stall_polls`], tightened for
//!   SLO-carrying requests via [`RetryPolicy::stall_budget`] — declares
//!   the backend wedged. Wedged backends go `Down` without probation
//!   (they lie about progress, so a probe can't be trusted) and their
//!   routes fail over.
//! - **Graceful degradation.** Instead of queueing unboundedly into a
//!   shrinking cluster, `submit` sheds load by [`Priority`] class once
//!   the aggregate queue depth of serving backends passes a per-class
//!   multiple of [`RetryPolicy::shed_queue_depth`] (Batch first,
//!   Interactive last), and rejects everything with a typed
//!   [`RejectReason::Overloaded`] when no backend is serving.
//!
//! `poll` advances every serving backend one iteration and relays
//! events; `cancel` — and client-side [`RequestHandle::cancel`] — fan
//! out to the owning backend; `stats` aggregates the per-server
//! snapshots into one cluster-level view (rank lists concatenated,
//! adapter sets unioned, preemptions summed) so a `ClusterFront` can
//! itself sit behind another router.
//!
//! The [`synthetic`] submodule is the shared driver for the `cluster`
//! and `chaos` CLI subcommands, `benches/cluster_slo.rs`,
//! `benches/failover.rs`, and the multi-engine integration tests: it
//! builds N native-runtime engines with a heterogeneous-rank adapter
//! population (mixed ranks, mixed SLOs, cold and warm adapters, partial
//! placement), optionally wraps victims in
//! [`crate::testkit::faults::ChaosFront`], and measures per-policy
//! TTFT / TPOT / SLO attainment / load balance / failover outcomes.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::api::{
    EventChannel, InstallSourceStats, LifecycleState, Priority, RejectReason, RequestEvent,
    RequestHandle, ResumeState, ServeRequest, ServingFront, SloSpec,
};
use super::metrics::{ColdStartStats, MetricsRecorder};
use crate::model::LoraSpec;
use crate::scheduler::registry::{AdapterMeta, GlobalRegistry};
use crate::scheduler::{AdapterSet, Policy, SchedRequest, ServerStats};

/// One backend's health as the cluster's poll-boundary containment
/// loop sees it. Transitions are driven only by `poll` outcomes and the
/// stall watchdog, so they are deterministic for a deterministic
/// backend + fault schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Polling cleanly; receives placements.
    Healthy,
    /// At least one recent poll error; still serving while consecutive
    /// errors count toward [`RetryPolicy::down_after`].
    Suspect,
    /// Quarantined: not polled, excluded from routing, live routes
    /// failed over. Panicked (poisoned) and watchdog-wedged backends
    /// stay down; error-downed backends re-probe after a backoff.
    Down,
    /// One trial poll decides: clean ⇒ `Healthy`, error ⇒ `Down` with
    /// the backoff doubled (capped).
    Probation,
}

/// Retry / failover / degradation knobs for a [`ClusterFront`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Consecutive poll errors before a `Suspect` backend goes `Down`.
    pub down_after: usize,
    /// Initial probation backoff after an error-driven `Down`, in
    /// cluster polls.
    pub backoff_base: u64,
    /// Backoff cap (the doubling stops here).
    pub backoff_cap: u64,
    /// Failovers per request before the client sees a terminal
    /// [`RejectReason::BackendFailed`].
    pub max_reroutes: usize,
    /// Polls a route may go without producing an event before its
    /// backend is declared wedged (no-SLO requests; SLO-carrying
    /// requests tighten this via [`RetryPolicy::stall_budget`]).
    pub stall_polls: usize,
    /// Per-healthy-backend queue depth at which Batch traffic sheds;
    /// Standard sheds at 2×, Interactive at 4×.
    pub shed_queue_depth: usize,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            down_after: 3,
            backoff_base: 8,
            backoff_cap: 128,
            max_reroutes: 2,
            stall_polls: 512,
            shed_queue_depth: 64,
        }
    }
}

impl RetryPolicy {
    /// The stall watchdog's idle-poll budget for one request. One
    /// cluster poll approximates one decode iteration, so an
    /// SLO-carrying request's budget is derived from its deadline —
    /// `2 × (ttft_ms + tpot_ms)` polls, clamped to `[32, stall_polls]`
    /// — while unconstrained requests get the full
    /// [`RetryPolicy::stall_polls`].
    pub fn stall_budget(&self, slo: Option<&SloSpec>) -> usize {
        match slo {
            Some(s) => {
                let polls = ((s.ttft_ms + s.tpot_ms) * 2.0).ceil() as usize;
                polls.clamp(32.min(self.stall_polls), self.stall_polls)
            }
            None => self.stall_polls,
        }
    }
}

/// Per-backend health bookkeeping.
#[derive(Debug, Clone)]
struct BackendHealth {
    state: Health,
    /// Consecutive failed polls (any clean poll resets).
    errors: usize,
    /// The backend panicked: its internal locks may be poisoned, so it
    /// is never called again (not even `stats`).
    poisoned: bool,
    /// Cluster tick at which a `Down` backend re-enters `Probation`
    /// (`u64::MAX` = never: poisoned or watchdog-wedged).
    probe_at: u64,
    /// Current probation backoff in cluster polls (doubles per failed
    /// probe, capped).
    backoff: u64,
}

impl BackendHealth {
    fn new(retry: &RetryPolicy) -> BackendHealth {
        BackendHealth {
            state: Health::Healthy,
            errors: 0,
            poisoned: false,
            probe_at: u64::MAX,
            backoff: retry.backoff_base,
        }
    }
}

/// Book-keeping for one routed, still-live request.
struct LiveRoute {
    /// Index of the owning backend.
    server: usize,
    /// The backend's handle for this request (its id is backend-local).
    backend: RequestHandle,
    /// The client-facing channel (cluster id space).
    chan: Arc<Mutex<EventChannel>>,
    /// The original submission, retained for failover resubmission
    /// (`resume` always `None` here; failover derives it from the
    /// client channel's delivered tokens).
    req: ServeRequest,
    /// Registered adapter rank (for `routed_rank_sum` on failover).
    rank: usize,
    /// Cluster polls since this route last produced an event — the
    /// stall watchdog's input.
    idle_polls: usize,
    /// Failovers so far (capped by [`RetryPolicy::max_reroutes`]).
    reroutes: usize,
}

/// A routed cluster of [`ServingFront`] backends behind the same trait.
pub struct ClusterFront {
    backends: Vec<Box<dyn ServingFront>>,
    policy: Box<dyn Policy>,
    registry: Arc<GlobalRegistry>,
    metrics: MetricsRecorder,
    retry: RetryPolicy,
    health: Vec<BackendHealth>,
    /// Cluster poll counter — the deterministic clock probation
    /// backoffs are measured against.
    tick: u64,
    next_id: u64,
    live: BTreeMap<u64, LiveRoute>,
    /// Requests routed to each backend (load-balance view; failover
    /// re-placements count).
    routed: Vec<usize>,
    /// Sum of routed adapter ranks per backend (rank-balance view).
    routed_rank_sum: Vec<usize>,
    /// Successful failover re-placements.
    failovers: usize,
    /// Requests shed by the degradation gate.
    shed: usize,
    /// Adapters re-installed onto rejoining Probation backends from
    /// registry placements (the rejoin-*without*-state path).
    rejoin_reinstalls: usize,
}

impl ClusterFront {
    /// A cluster over `backends`, routing with `policy` against adapter
    /// metadata in `registry`. Backends must already have their local
    /// adapters installed; the registry holds every adapter's rank (the
    /// scheduler's `SchedRequest` input) and, optionally, placements.
    pub fn new(
        backends: Vec<Box<dyn ServingFront>>,
        policy: Box<dyn Policy>,
        registry: Arc<GlobalRegistry>,
    ) -> ClusterFront {
        let n = backends.len();
        let retry = RetryPolicy::default();
        ClusterFront {
            backends,
            policy,
            registry,
            metrics: MetricsRecorder::new(),
            health: (0..n).map(|_| BackendHealth::new(&retry)).collect(),
            retry,
            tick: 0,
            next_id: 0,
            live: BTreeMap::new(),
            routed: vec![0; n],
            routed_rank_sum: vec![0; n],
            failovers: 0,
            shed: 0,
            rejoin_reinstalls: 0,
        }
    }

    /// Replace the retry/failover/degradation policy (builder style).
    pub fn with_retry(mut self, retry: RetryPolicy) -> ClusterFront {
        for h in &mut self.health {
            h.backoff = retry.backoff_base;
        }
        self.retry = retry;
        self
    }

    /// Number of backends.
    pub fn len(&self) -> usize {
        self.backends.len()
    }

    /// True when the cluster has no backends.
    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }

    /// The routing policy's name.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// The shared adapter registry.
    pub fn registry(&self) -> &Arc<GlobalRegistry> {
        &self.registry
    }

    /// Cluster-level request metrics (TTFT/TPOT/SLO attainment), fed by
    /// the relayed event stream.
    pub fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    /// Requests routed to each backend so far.
    pub fn routed(&self) -> &[usize] {
        &self.routed
    }

    /// Sum of routed adapter ranks per backend — the balance the
    /// rank-aware policy optimizes.
    pub fn routed_rank_sum(&self) -> &[usize] {
        &self.routed_rank_sum
    }

    /// Health of one backend.
    pub fn health_of(&self, server: usize) -> Health {
        self.health[server].state
    }

    /// Health of every backend, in backend order.
    pub fn health(&self) -> Vec<Health> {
        self.health.iter().map(|h| h.state).collect()
    }

    /// Successful failover re-placements so far.
    pub fn failovers(&self) -> usize {
        self.failovers
    }

    /// Requests shed by the graceful-degradation gate so far.
    pub fn shed_count(&self) -> usize {
        self.shed
    }

    /// Adapters re-installed onto rejoining backends so far (see
    /// [`ClusterFront::restore_placements`]).
    pub fn rejoin_reinstalls(&self) -> usize {
        self.rejoin_reinstalls
    }

    /// Is this backend taking new placements?
    fn accepting(&self, server: usize) -> bool {
        matches!(self.health[server].state, Health::Healthy | Health::Suspect)
    }

    /// Backends currently taking new placements.
    fn healthy_count(&self) -> usize {
        (0..self.backends.len())
            .filter(|&s| self.accepting(s))
            .count()
    }

    /// `stats()` that never calls into a poisoned backend (its locks
    /// may be unusable after the panic): poisoned backends report an
    /// empty adapter set, which makes them ineligible to every policy.
    fn safe_stats(&self, server: usize) -> ServerStats {
        if self.health[server].poisoned {
            ServerStats {
                adapters: AdapterSet::only(vec![]),
                ..Default::default()
            }
        } else {
            self.backends[server].stats()
        }
    }

    /// One [`ServerStats`] snapshot per backend, in backend order
    /// (poisoned backends report empty defaults).
    pub fn per_server_stats(&self) -> Vec<ServerStats> {
        (0..self.backends.len())
            .map(|s| self.safe_stats(s))
            .collect()
    }

    /// Install an adapter on one specific backend and record the
    /// placement — the coordinator's targeted placement/migration
    /// primitive. The backend install lands *before* the registry
    /// placement, and both happen under this one `&mut self` call, so
    /// no interleaved submission can ever observe a placement whose
    /// server cannot actually serve the adapter.
    pub fn install_on(&mut self, server: usize, spec: &LoraSpec) -> Result<()> {
        anyhow::ensure!(
            server < self.backends.len(),
            "server {server} out of range ({} backends)",
            self.backends.len()
        );
        anyhow::ensure!(
            !self.health[server].poisoned,
            "server {server} is down (panicked backend)"
        );
        self.backends[server].install_adapter(spec)?;
        // Register (or refresh) the metadata only after the backend
        // accepted, so the registry's rank — what the scheduler's
        // SchedRequest is built from — can never drift from the weights
        // the backends actually serve. A known weights_path survives
        // the refresh.
        let weights_path = self
            .registry
            .get(spec.id)
            .map(|m| m.weights_path)
            .unwrap_or_default();
        self.registry.register(AdapterMeta {
            id: spec.id,
            rank: spec.rank,
            base_model: spec.base_model.clone(),
            weights_path,
        });
        self.registry.place(spec.id, server);
        Ok(())
    }

    /// Remove an adapter from one specific backend and retire the
    /// placement. The backend refuses while requests on the adapter are
    /// in flight there — in that case nothing changes (the placement
    /// stays, the router keeps routing) and the caller retries later,
    /// so the registry and the backend's real adapter set never
    /// disagree mid-uninstall.
    pub fn uninstall_on(&mut self, server: usize, adapter: u64) -> Result<()> {
        anyhow::ensure!(
            server < self.backends.len(),
            "server {server} out of range ({} backends)",
            self.backends.len()
        );
        anyhow::ensure!(
            !self.health[server].poisoned,
            "server {server} is down (panicked backend)"
        );
        self.backends[server].uninstall_adapter(adapter)?;
        self.registry.unplace(adapter, server);
        Ok(())
    }

    /// Pre-warm an adapter on one specific backend (see
    /// [`ServingFront::prewarm_adapter`]).
    pub fn prewarm_on(&mut self, server: usize, adapter: u64) -> Result<bool> {
        anyhow::ensure!(
            server < self.backends.len(),
            "server {server} out of range ({} backends)",
            self.backends.len()
        );
        anyhow::ensure!(
            !self.health[server].poisoned,
            "server {server} is down (panicked backend)"
        );
        self.backends[server].prewarm_adapter(adapter)
    }

    /// Re-install this backend's registry placements that are missing
    /// from its live adapter set — the readmission gate for a backend
    /// that rejoined *without* its state (process restart, wiped
    /// device). A backend whose adapters survived (reconnect-with-state
    /// — e.g. a `RemoteFront` re-handshaking with a living host)
    /// reports them in `stats().adapters` and nothing is re-installed.
    /// Returns true when every placement is resident afterwards.
    pub fn restore_placements(&mut self, server: usize) -> bool {
        let resident = self.backends[server].stats().adapters;
        let mut complete = true;
        for id in self.registry.ids() {
            if !self.registry.servers_for(id).contains(&server) || resident.contains(id) {
                continue;
            }
            let Some(meta) = self.registry.get(id) else {
                continue;
            };
            let spec = LoraSpec::standard(id, meta.rank, &meta.base_model);
            match self.backends[server].install_adapter(&spec) {
                Ok(()) => self.rejoin_reinstalls += 1,
                Err(_) => complete = false,
            }
        }
        complete
    }

    /// Record a clean poll: consecutive errors reset; `Suspect` and a
    /// successful `Probation` probe return to `Healthy` (backoff
    /// reset). Probation readmission additionally restores any registry
    /// placements the rejoining backend lost; until they are all
    /// resident again the backend stays in Probation (probed — and
    /// retried — every tick) so routing never sees a placement its
    /// server cannot serve.
    fn record_poll_ok(&mut self, server: usize) {
        let base = self.retry.backoff_base;
        self.health[server].errors = 0;
        match self.health[server].state {
            Health::Suspect => self.health[server].state = Health::Healthy,
            Health::Probation => {
                if self.restore_placements(server) {
                    let h = &mut self.health[server];
                    h.state = Health::Healthy;
                    h.backoff = base;
                    h.probe_at = u64::MAX;
                }
            }
            Health::Healthy | Health::Down => {}
        }
    }

    /// Record a failed (or panicked) poll and advance the health
    /// machine. Panics poison permanently; probe failures double the
    /// backoff (capped).
    fn record_poll_error(&mut self, server: usize, poisoned: bool) {
        let tick = self.tick;
        let down_after = self.retry.down_after;
        let cap = self.retry.backoff_cap;
        let h = &mut self.health[server];
        h.errors += 1;
        if poisoned {
            h.poisoned = true;
            h.state = Health::Down;
            h.probe_at = u64::MAX;
            return;
        }
        match h.state {
            Health::Probation => {
                h.backoff = h.backoff.saturating_mul(2).min(cap);
                h.state = Health::Down;
                h.probe_at = tick.saturating_add(h.backoff);
            }
            Health::Healthy | Health::Suspect => {
                if h.errors >= down_after {
                    h.state = Health::Down;
                    h.probe_at = tick.saturating_add(h.backoff);
                } else {
                    h.state = Health::Suspect;
                }
            }
            Health::Down => {}
        }
    }

    /// The watchdog's takedown: a wedged backend claims progress it
    /// doesn't make, so a probe can't be trusted — it stays `Down`.
    fn mark_wedged(&mut self, server: usize) {
        let down_after = self.retry.down_after;
        let h = &mut self.health[server];
        h.errors = h.errors.max(down_after);
        h.state = Health::Down;
        h.probe_at = u64::MAX;
    }

    /// Should this submission be shed instead of queued? `stats` must
    /// be the per-backend snapshots in backend order.
    fn shed_reason(&self, priority: Priority, stats: &[ServerStats]) -> Option<RejectReason> {
        let healthy = self.healthy_count();
        if healthy == 0 {
            return Some(RejectReason::Overloaded {
                healthy: 0,
                shed: priority,
            });
        }
        let depth: usize = (0..self.backends.len())
            .filter(|&s| self.accepting(s))
            .map(|s| stats[s].total_requests())
            .sum();
        let mult = match priority {
            Priority::Batch => 1,
            Priority::Standard => 2,
            Priority::Interactive => 4,
        };
        let limit = self
            .retry
            .shed_queue_depth
            .saturating_mul(healthy)
            .saturating_mul(mult);
        (depth >= limit).then_some(RejectReason::Overloaded {
            healthy,
            shed: priority,
        })
    }

    /// Relay pending backend events into the client-facing channels and
    /// forward client-side cancellations (`handle.cancel()`) to the
    /// owning backends. Terminal events retire the route. Poisoned
    /// backends' handles are never touched (their routes fail over at
    /// this poll's end).
    fn pump(&mut self) {
        let mut done: Vec<u64> = Vec::new();
        for (&id, route) in self.live.iter_mut() {
            if self.health[route.server].poisoned {
                continue;
            }
            let down = self.health[route.server].state == Health::Down;
            let (cancel_wanted, had_tokens) = {
                let chan = route.chan.lock().unwrap();
                (
                    chan.cancel_requested() && !chan.is_terminal(),
                    !chan.tokens().is_empty(),
                )
            };
            if cancel_wanted && !down {
                self.backends[route.server].cancel(route.backend.id());
            }
            let mut relayed = false;
            while let Some(ev) = route.backend.poll_event() {
                relayed = true;
                // The cluster emitted its own Admitted at placement.
                if matches!(ev, RequestEvent::Admitted) {
                    continue;
                }
                // A failover continuation's first token is not the
                // stream's first: map it so the client sees exactly one
                // FirstToken per request.
                let ev = match ev {
                    RequestEvent::FirstToken(t) if had_tokens => RequestEvent::Token(t),
                    ev => ev,
                };
                match &ev {
                    RequestEvent::FirstToken(_) | RequestEvent::Token(_) => {
                        self.metrics.token(id);
                    }
                    RequestEvent::Finished(_) => {
                        self.metrics.finished(id);
                        done.push(id);
                    }
                    RequestEvent::Cancelled => {
                        self.metrics.cancelled(id);
                        done.push(id);
                    }
                    RequestEvent::Rejected(_) => {
                        // Post-admission rejections don't exist today
                        // (backends reject synchronously at submit), but
                        // relay defensively rather than dropping one —
                        // and book it as a rejection, not a cancel.
                        self.metrics.rejected(id);
                        done.push(id);
                    }
                    _ => {}
                }
                route.chan.lock().unwrap().push(ev);
            }
            if relayed {
                route.idle_polls = 0;
            }
        }
        for id in done {
            self.live.remove(&id);
        }
    }

    /// Advance every route's idle counter and take wedged backends
    /// down. Returns true when a backend was newly declared wedged.
    fn reap_stalled(&mut self) -> bool {
        let mut wedged: Vec<usize> = Vec::new();
        for route in self.live.values_mut() {
            if !matches!(
                self.health[route.server].state,
                Health::Healthy | Health::Suspect
            ) {
                continue;
            }
            route.idle_polls += 1;
            if route.idle_polls > self.retry.stall_budget(route.req.slo.as_ref()) {
                wedged.push(route.server);
            }
        }
        let mut any = false;
        for s in wedged {
            if matches!(self.health[s].state, Health::Healthy | Health::Suspect) {
                self.mark_wedged(s);
                any = true;
            }
        }
        any
    }

    /// Fail over every live route whose backend is `Down`. Returns true
    /// when any route moved or terminated.
    fn failover_down(&mut self) -> bool {
        let dead: Vec<u64> = self
            .live
            .iter()
            .filter(|(_, r)| self.health[r.server].state == Health::Down)
            .map(|(&id, _)| id)
            .collect();
        let any = !dead.is_empty();
        for id in dead {
            self.failover_one(id);
        }
        any
    }

    /// Terminate a route whose failover exhausted its options.
    fn fail_route(&mut self, id: u64, route: &LiveRoute, from: usize) {
        self.metrics.rejected(id);
        route
            .chan
            .lock()
            .unwrap()
            .push(RequestEvent::Rejected(RejectReason::BackendFailed {
                server: from,
            }));
    }

    /// Move one live route off its `Down` backend: resubmit on a
    /// surviving server with the client's delivered tokens as the
    /// resume state, so the stream continues bitwise identically.
    /// Exhausting candidates (or the reroute cap) terminates the
    /// request with [`RejectReason::BackendFailed`].
    fn failover_one(&mut self, id: u64) {
        let Some(mut route) = self.live.remove(&id) else {
            return;
        };
        let from = route.server;
        if route.reroutes >= self.retry.max_reroutes {
            self.fail_route(id, &route, from);
            return;
        }
        // The resume state is the *client's* view — tokens already
        // relayed. Tokens the dead backend generated but never
        // delivered are regenerated deterministically by the survivor.
        let tokens = route.chan.lock().unwrap().tokens().to_vec();
        let mut req = route.req.clone();
        req.resume = (!tokens.is_empty()).then_some(ResumeState { tokens });
        let sreq = SchedRequest {
            id,
            adapter: req.adapter,
            rank: route.rank,
            prompt_len: req.prompt.len(),
        };
        let mut stats: Vec<ServerStats> = (0..self.backends.len())
            .map(|s| {
                if s != from && self.accepting(s) {
                    self.backends[s].stats()
                } else {
                    ServerStats {
                        adapters: AdapterSet::only(vec![]),
                        ..Default::default()
                    }
                }
            })
            .collect();
        let mut attempted = vec![false; self.backends.len()];
        loop {
            let Some(target) = self.policy.pick(&sreq, &stats) else {
                self.fail_route(id, &route, from);
                return;
            };
            if std::mem::replace(&mut attempted[target], true) {
                // A policy re-picking an excluded server would
                // livelock; treat it as exhaustion.
                self.fail_route(id, &route, from);
                return;
            }
            if target == from || !self.accepting(target) {
                stats[target].adapters = AdapterSet::only(vec![]);
                continue;
            }
            let backend = self.backends[target].submit(req.clone());
            if backend.state() == LifecycleState::Rejected {
                let _ = backend.drain_events();
                stats[target].adapters = AdapterSet::only(vec![]);
                continue;
            }
            self.routed[target] += 1;
            self.routed_rank_sum[target] += route.rank;
            self.failovers += 1;
            route
                .chan
                .lock()
                .unwrap()
                .push(RequestEvent::Rerouted { from, to: target });
            route.server = target;
            route.backend = backend;
            route.idle_polls = 0;
            route.reroutes += 1;
            self.live.insert(id, route);
            return;
        }
    }
}

impl ServingFront for ClusterFront {
    /// Route and submit. See the module docs for the re-routing and
    /// degradation semantics; every request still terminates in exactly
    /// one terminal event on the returned handle.
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        let id = self.next_id;
        self.next_id += 1;
        let (handle, chan) = RequestHandle::new(id);
        let Some(rank) = self.registry.rank_of(req.adapter) else {
            chan.lock().unwrap().push(RequestEvent::Rejected(
                RejectReason::AdapterNotRegistered {
                    adapter: req.adapter,
                },
            ));
            return handle;
        };
        let mut stats: Vec<ServerStats> = self.per_server_stats();
        if let Some(reason) = self.shed_reason(req.priority, &stats) {
            self.shed += 1;
            chan.lock().unwrap().push(RequestEvent::Rejected(reason));
            return handle;
        }
        // Demand signal for the coordinator's placement/migration
        // scoring: every routed submission bumps the adapter's
        // popularity counter.
        self.registry.record_request(req.adapter);
        let sreq = SchedRequest {
            id,
            adapter: req.adapter,
            rank,
            prompt_len: req.prompt.len(),
        };
        // Non-serving backends are out of the candidate set.
        for s in 0..self.backends.len() {
            if !self.accepting(s) {
                stats[s].adapters = AdapterSet::only(vec![]);
            }
        }
        let mut attempted = vec![false; self.backends.len()];
        let mut last: Option<RejectReason> = None;
        loop {
            let Some(target) = self.policy.pick(&sreq, &stats) else {
                chan.lock().unwrap().push(RequestEvent::Rejected(
                    RejectReason::NoEligibleServer {
                        last: last.map(Box::new),
                    },
                ));
                return handle;
            };
            if std::mem::replace(&mut attempted[target], true) {
                // A policy ignoring eligibility could loop forever on a
                // refusing server — treat a re-pick as exhaustion.
                chan.lock().unwrap().push(RequestEvent::Rejected(
                    RejectReason::PolicyRepick { server: target },
                ));
                return handle;
            }
            if !self.accepting(target) {
                // Eligibility was blanked above; a policy that picked
                // it anyway gets one more chance on the rest.
                stats[target].adapters = AdapterSet::only(vec![]);
                continue;
            }
            let backend = self.backends[target].submit(req.clone());
            if backend.state() == LifecycleState::Rejected {
                // Backend admission refused (synchronously): remember
                // the reason, exclude the server, re-route.
                for ev in backend.drain_events() {
                    if let RequestEvent::Rejected(r) = ev {
                        last = Some(r);
                    }
                }
                stats[target].adapters = AdapterSet::only(vec![]);
                continue;
            }
            self.metrics.arrived(id, req.slo);
            self.routed[target] += 1;
            self.routed_rank_sum[target] += rank;
            {
                let mut c = chan.lock().unwrap();
                c.push(RequestEvent::Admitted);
                c.push(RequestEvent::Routed { server: target });
            }
            self.live.insert(
                id,
                LiveRoute {
                    server: target,
                    backend,
                    chan,
                    req,
                    rank,
                    idle_polls: 0,
                    reroutes: 0,
                },
            );
            return handle;
        }
    }

    /// Advance every serving backend one iteration and relay events.
    /// Backend errors and panics are contained here and fed to the
    /// health machine — they never propagate to the caller. Returns
    /// `false` only when the whole cluster is idle.
    fn poll(&mut self) -> Result<bool> {
        // Forward pending client cancellations first so backends reap
        // them at this iteration boundary.
        self.pump();
        self.tick += 1;
        let mut any = false;
        for s in 0..self.backends.len() {
            if self.health[s].state == Health::Down {
                if self.health[s].poisoned || self.tick < self.health[s].probe_at {
                    continue;
                }
                self.health[s].state = Health::Probation;
            }
            let backend = &mut self.backends[s];
            match catch_unwind(AssertUnwindSafe(|| backend.poll())) {
                Ok(Ok(progress)) => {
                    any |= progress;
                    self.record_poll_ok(s);
                }
                Ok(Err(_)) => self.record_poll_error(s, false),
                Err(_) => self.record_poll_error(s, true),
            }
        }
        self.pump();
        any |= self.reap_stalled();
        any |= self.failover_down();
        Ok(any)
    }

    /// Fan a cancellation out to the owning backend. The terminal
    /// `Cancelled` is relayed at the next poll boundary. A route whose
    /// backend is down gets the cancel queued on the client channel, to
    /// land on whichever backend the failover picks.
    fn cancel(&mut self, id: u64) -> bool {
        let Some(route) = self.live.get(&id) else {
            return false;
        };
        if route.chan.lock().unwrap().is_terminal() {
            return false;
        }
        if !self.accepting(route.server) {
            return route.chan.lock().unwrap().try_request_cancel();
        }
        self.backends[route.server].cancel(route.backend.id())
    }

    /// The cluster as one server: rank lists concatenated, adapter sets
    /// unioned, prompt capacity and KV headroom at the per-backend
    /// maximum (a request needs *one* server that fits it), the
    /// tightest onboard SLO, preemptions/evictions and the unified-pool
    /// occupancy counters summed.
    fn stats(&self) -> ServerStats {
        let mut agg = ServerStats {
            adapters: AdapterSet::only(vec![]),
            max_prompt_tokens: 0,
            kv_free_tokens: 0,
            ..Default::default()
        };
        for s in self.per_server_stats() {
            agg.running_ranks.extend(&s.running_ranks);
            agg.queued_ranks.extend(&s.queued_ranks);
            agg.adapters = agg.adapters.union(&s.adapters);
            agg.max_prompt_tokens = agg.max_prompt_tokens.max(s.max_prompt_tokens);
            agg.kv_free_tokens = agg.kv_free_tokens.max(s.kv_free_tokens);
            agg.tpot_slo = match (agg.tpot_slo, s.tpot_slo) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            agg.preemptions += s.preemptions;
            agg.pool_pages += s.pool_pages;
            agg.kv_held_pages += s.kv_held_pages;
            agg.adapter_held_pages += s.adapter_held_pages;
            agg.adapter_evictions += s.adapter_evictions;
            agg.event_overflows += s.event_overflows;
        }
        // The cluster's own client-facing channels are a second place a
        // stalled consumer can fall behind its stream.
        agg.event_overflows += self
            .live
            .values()
            .map(|route| route.chan.lock().unwrap().overflows())
            .sum::<usize>();
        agg
    }

    /// Cluster-level install: place the adapter on the serving backend
    /// with the smallest local adapter set (the least slot pressure) —
    /// ties go to the lowest index, `AdapterSet::Any` backends (which
    /// serve everything already) last. Use [`ClusterFront::install_on`]
    /// to target a specific backend.
    fn install_adapter(&mut self, spec: &LoraSpec) -> Result<()> {
        anyhow::ensure!(!self.backends.is_empty(), "cluster has no backends");
        let target = (0..self.backends.len())
            .filter(|&s| self.accepting(s))
            .min_by_key(|&s| match self.backends[s].stats().adapters {
                AdapterSet::Only(ids) => ids.len(),
                AdapterSet::Any => usize::MAX,
            });
        let Some(target) = target else {
            anyhow::bail!("cluster has no healthy backends");
        };
        self.install_on(target, spec)
    }

    /// Cluster-level uninstall: retire the adapter from every backend
    /// hosting it. Retirement is per-server atomic — each server either
    /// uninstalls (and loses its placement) or refuses because requests
    /// are in flight there; on any refusal the call errs and the caller
    /// retries, with already-retired servers staying retired.
    fn uninstall_adapter(&mut self, adapter: u64) -> Result<()> {
        let hosts: Vec<usize> = (0..self.backends.len())
            .filter(|&s| self.safe_stats(s).can_serve(adapter))
            .collect();
        anyhow::ensure!(!hosts.is_empty(), "adapter {adapter} not installed");
        let mut refused = Vec::new();
        for s in hosts {
            if let Err(e) = self.uninstall_on(s, adapter) {
                refused.push(format!("server {s}: {e}"));
            }
        }
        anyhow::ensure!(
            refused.is_empty(),
            "adapter {adapter} still hosted: {}",
            refused.join("; ")
        );
        Ok(())
    }

    /// Pre-warm the adapter on every serving backend hosting it; true
    /// when at least one backend warmed it.
    fn prewarm_adapter(&mut self, adapter: u64) -> Result<bool> {
        let mut any = false;
        let mut hosted = false;
        for s in 0..self.backends.len() {
            if self.health[s].poisoned {
                continue;
            }
            if self.backends[s].stats().can_serve(adapter) {
                hosted = true;
                any |= self.backends[s].prewarm_adapter(adapter)?;
            }
        }
        anyhow::ensure!(hosted, "adapter {adapter} not installed");
        Ok(any)
    }

    /// Aggregate cold-start counters across backends that report them
    /// (poisoned backends are skipped).
    fn cold_start_stats(&self) -> Option<ColdStartStats> {
        let mut total = ColdStartStats::default();
        let mut any = false;
        for s in 0..self.backends.len() {
            if self.health[s].poisoned {
                continue;
            }
            if let Some(st) = self.backends[s].cold_start_stats() {
                any = true;
                total.cold_admits += st.cold_admits;
                total.warm_admits += st.warm_admits;
                total.cpu_assisted += st.cpu_assisted;
                total.handoffs += st.handoffs;
                total.deferred_collisions += st.deferred_collisions;
                total.assist_decode_s += st.assist_decode_s;
            }
        }
        any.then_some(total)
    }

    /// Aggregate install-provenance counters across backends (poisoned
    /// backends are skipped). The migration acceptance check — zero
    /// synthetic re-seeds on a streamed-install target — reads this.
    fn install_source_stats(&self) -> InstallSourceStats {
        let mut total = InstallSourceStats::default();
        for s in 0..self.backends.len() {
            if self.health[s].poisoned {
                continue;
            }
            total = total.merge(self.backends[s].install_source_stats());
        }
        total
    }
}

/// Shared synthetic-workload driver: N native-runtime engines with a
/// heterogeneous-rank adapter population under one routing policy. Used
/// by `caraserve cluster`, `benches/cluster_slo.rs`, and the
/// multi-engine integration tests.
pub mod synthetic {
    use std::sync::Arc;
    use std::time::Instant;

    use anyhow::Result;

    use super::{ClusterFront, Health, RetryPolicy, ServingFront};
    use crate::config::GpuSpec;
    use crate::coordinator::{Coordinator, CoordinatorConfig};
    use crate::model::{LlamaConfig, LoraSpec};
    use crate::perfmodel::{profiler, KernelKind};
    use crate::runtime::{NativeConfig, NativeRuntime};
    use crate::scheduler::registry::{AdapterMeta, GlobalRegistry};
    use crate::scheduler::{policy_by_name, Policy, RankAwareConfig};
    use crate::server::api::{LifecycleState, Priority, RequestHandle, ServeRequest};
    use crate::server::engine::{ColdStartMode, EngineConfig, InferenceServer};
    use crate::server::metrics::ColdStartStats;
    use crate::sim::GpuModel;
    use crate::testkit::faults::{ChaosFront, FaultPlan};
    use crate::util::rng::{Rng, Zipf};
    use crate::util::stats::Summary;

    /// The heterogeneous rank population (Fig 5 / §7.5 style).
    pub const RANKS: [usize; 4] = [8, 16, 32, 64];

    /// Rank of adapter `a` in the synthetic population.
    pub fn rank_of(adapter: u64) -> usize {
        RANKS[(adapter % RANKS.len() as u64) as usize]
    }

    /// Is adapter `a` hosted on server `s`? Each adapter lives on two of
    /// the N servers (all of them when N ≤ 2), so `can_serve` routing is
    /// exercised for real on larger clusters.
    pub fn hosts(instances: usize, adapter: u64, server: usize) -> bool {
        instances <= 2
            || server == (adapter % instances as u64) as usize
            || server == ((adapter + 1) % instances as u64) as usize
    }

    /// Knobs for one synthetic cluster run.
    #[derive(Debug, Clone)]
    pub struct SyntheticConfig {
        /// Native engines in the cluster.
        pub instances: usize,
        /// Requests to submit.
        pub requests: usize,
        /// Adapter population (8 device slots per engine ⇒ more adapters
        /// than slots keeps cold starts live).
        pub adapters: usize,
        /// Workload seed (adapter choice, lengths, SLO tiers).
        pub seed: u64,
        /// Forward-pass threads per engine.
        pub threads: usize,
        /// Shared-memory CPU-LoRA workers per engine (0 = none).
        pub cpu_workers: usize,
        /// Cold-start mode for every engine.
        pub cold_start: ColdStartMode,
        /// KV pool pages per engine.
        pub kv_pages: usize,
        /// Cluster iterations driven between arrivals (open-loop-ish
        /// pacing: smaller ⇒ deeper queues ⇒ more routing pressure).
        pub polls_per_arrival: usize,
        /// Adapter-popularity skew. `0.0` keeps the legacy mix (60% of
        /// traffic on the hottest quarter); any positive value draws
        /// adapters from a Zipf distribution with this exponent
        /// (`--skew 1.0` ≈ classic power law; larger ⇒ hotter head),
        /// the regime where coordinator placement + migration pays off.
        pub skew: f64,
    }

    impl Default for SyntheticConfig {
        fn default() -> Self {
            SyntheticConfig {
                instances: 2,
                requests: 48,
                adapters: 24,
                seed: 1,
                threads: 1,
                cpu_workers: 0,
                cold_start: ColdStartMode::CaraServe,
                kv_pages: 256,
                polls_per_arrival: 2,
                skew: 0.0,
            }
        }
    }

    /// Per-policy results of one synthetic run.
    #[derive(Debug, Clone)]
    pub struct RunReport {
        pub policy: String,
        pub requests: usize,
        pub finished: usize,
        pub rejected: usize,
        /// TTFT summary (seconds).
        pub ttft: Option<Summary>,
        /// Decode-only TPOT summary (seconds).
        pub tpot: Option<Summary>,
        /// Fraction of SLO-carrying requests meeting both targets.
        pub slo_attainment: Option<f64>,
        /// Requests routed per server.
        pub routed: Vec<usize>,
        /// Routed rank-sum per server (the rank balance).
        pub routed_rank_sum: Vec<usize>,
        /// Aggregated cold-start counters.
        pub cold: ColdStartStats,
        /// Total decode-growth preemptions across servers.
        pub preemptions: usize,
        /// Total unified-pool adapter evictions across servers (0 on
        /// runtimes without paged adapter residency).
        pub adapter_evictions: usize,
        /// Wall-clock of the whole run (seconds).
        pub wall_s: f64,
        /// Per-request token streams in submission order (empty for
        /// rejected requests) — what bitwise-equivalence tests compare
        /// across placements and migrations.
        pub streams: Vec<Vec<i32>>,
    }

    /// Fit §5 performance models (BGMV, Llama2-7B/A10 profile) and build
    /// the named policy. The absolute latency scale is the profiled GPU
    /// model's, not the tiny native runtime's — only the *relative*
    /// cross-server cost ordering steers routing, and that is
    /// rank-faithful on both.
    pub fn policy(name: &str, seed: u64) -> Result<Box<dyn Policy>> {
        let gm = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let plan = profiler::ProfilePlan::default();
        let dec = profiler::calibrate(KernelKind::Bgmv, &plan, |ranks| {
            gm.decode_iter(&vec![160; ranks.len()])
                + gm.lora_decode_overhead(KernelKind::Bgmv, ranks)
        })
        .expect("decode perf-model calibration");
        let pre = profiler::calibrate(KernelKind::Bgmv, &plan, |ranks| {
            gm.prefill(ranks.len() * 28)
        })
        .expect("prefill perf-model calibration");
        let slo = 1.5 * gm.decode_iter(&[160]);
        policy_by_name(
            name,
            pre,
            dec,
            RankAwareConfig {
                slo,
                ..Default::default()
            },
            seed,
        )
    }

    /// One bare native engine per the config's knobs, with no adapters
    /// installed yet.
    fn engine(cfg: &SyntheticConfig) -> Result<InferenceServer> {
        let native = NativeRuntime::new(NativeConfig {
            threads: cfg.threads.max(1),
            ..NativeConfig::tiny()
        });
        let mut server = InferenceServer::new(
            native,
            EngineConfig {
                cold_start: cfg.cold_start,
                kv_pages: cfg.kv_pages,
                ..Default::default()
            },
        )?;
        if cfg.cpu_workers > 0
            && cfg.cold_start == ColdStartMode::CaraServe
            && server.runtime.supports_cpu_assist()
        {
            server.enable_cpu_assist(cfg.cpu_workers)?;
        }
        Ok(server)
    }

    /// Build the cluster: N native engines with *static* partial adapter
    /// placement (the pre-coordinator baseline: `hosts` assigns each
    /// adapter to servers by id, blind to demand), a shared registry
    /// carrying every adapter's rank, and the given policy in front.
    pub fn build(cfg: &SyntheticConfig, policy: Box<dyn Policy>) -> Result<ClusterFront> {
        let registry = Arc::new(GlobalRegistry::new());
        let mut backends: Vec<Box<dyn ServingFront>> = Vec::with_capacity(cfg.instances);
        for s in 0..cfg.instances {
            let mut server = engine(cfg)?;
            for a in 0..cfg.adapters as u64 {
                if hosts(cfg.instances, a, s) {
                    server.install_adapter(&LoraSpec::standard(a, rank_of(a), "tiny"))?;
                }
            }
            backends.push(Box::new(server));
        }
        for a in 0..cfg.adapters as u64 {
            registry.register(AdapterMeta {
                id: a,
                rank: rank_of(a),
                base_model: "tiny".into(),
                weights_path: String::new(),
            });
            for s in 0..cfg.instances {
                if hosts(cfg.instances, a, s) {
                    registry.place(a, s);
                }
            }
        }
        Ok(ClusterFront::new(backends, policy, registry))
    }

    /// Build the coordinated cluster: the same N native engines, but
    /// with **no** static placement — every adapter is registered in the
    /// shared registry with a historical demand prior (the workload's
    /// own adapter histogram, what the §3 coordinator would have
    /// observed), and the [`Coordinator`] computes placements from
    /// popularity × rank × slot pressure, installs them, and pre-warms
    /// the hot head before the first request arrives.
    pub fn build_coordinated(
        cfg: &SyntheticConfig,
        policy: Box<dyn Policy>,
        ccfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        let registry = Arc::new(GlobalRegistry::new());
        let mut backends: Vec<Box<dyn ServingFront>> = Vec::with_capacity(cfg.instances);
        for _ in 0..cfg.instances {
            backends.push(Box::new(engine(cfg)?));
        }
        for a in 0..cfg.adapters as u64 {
            registry.register(AdapterMeta {
                id: a,
                rank: rank_of(a),
                base_model: "tiny".into(),
                weights_path: String::new(),
            });
        }
        // Demand prior: the workload generator is deterministic, so its
        // adapter histogram doubles as the coordinator's request log.
        for req in workload(cfg) {
            registry.record_request(req.adapter);
        }
        let mut coord =
            Coordinator::new(ClusterFront::new(backends, policy, registry), ccfg);
        coord.place_and_prewarm()?;
        Ok(coord)
    }

    /// The heterogeneous workload: skewed adapter popularity (Zipf with
    /// exponent `cfg.skew` when positive; otherwise the legacy mix of
    /// 60% of traffic on the hottest quarter — both keep warm hits and
    /// cold starts live), mixed prompt/output lengths, and three SLO
    /// tiers spanning interactive to batch. Deterministic per seed, so
    /// the same config always yields the same request list.
    pub fn workload(cfg: &SyntheticConfig) -> Vec<ServeRequest> {
        let mut rng = Rng::new(cfg.seed);
        let hot = (cfg.adapters / 4).max(1);
        let zipf = (cfg.skew > 0.0).then(|| Zipf::new(cfg.adapters, cfg.skew));
        (0..cfg.requests)
            .map(|_| {
                let adapter = match &zipf {
                    Some(z) => z.sample(&mut rng) as u64,
                    None if rng.chance(0.6) => rng.range(0, hot) as u64,
                    None => rng.range(0, cfg.adapters) as u64,
                };
                let prompt: Vec<i32> = (0..rng.range(8, 32))
                    .map(|_| rng.range(0, 1024) as i32)
                    .collect();
                let req = ServeRequest::new(adapter, prompt)
                    .max_new_tokens(rng.range(8, 24));
                match rng.range(0, 3) {
                    0 => req.slo(150.0, 40.0).priority(Priority::Interactive),
                    1 => req.slo(300.0, 80.0),
                    _ => req.slo(600.0, 160.0).priority(Priority::Batch),
                }
            })
            .collect()
    }

    /// Submit the workload with the config's pacing and drive the front
    /// to idle; returns the handles (submission order) and wall time.
    fn drive<F: ServingFront>(
        front: &mut F,
        reqs: Vec<ServeRequest>,
        polls_per_arrival: usize,
    ) -> Result<(Vec<RequestHandle>, f64)> {
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(reqs.len());
        for req in reqs {
            handles.push(front.submit(req));
            for _ in 0..polls_per_arrival {
                front.poll()?;
            }
        }
        front.run_until_idle()?;
        Ok((handles, t0.elapsed().as_secs_f64()))
    }

    /// Reconcile the handles and assemble the per-policy report from
    /// the cluster's metrics.
    fn report(
        policy_name: &str,
        cluster: &ClusterFront,
        handles: &[RequestHandle],
        wall_s: f64,
    ) -> Result<RunReport> {
        let total = handles.len();
        let finished = handles
            .iter()
            .filter(|h| h.state() == LifecycleState::Finished)
            .count();
        let rejected = handles
            .iter()
            .filter(|h| h.state() == LifecycleState::Rejected)
            .count();
        // One reconciliation for every caller (CLI, bench, tests): the
        // harness never cancels, so each submission must end Finished or
        // Rejected — anything else is request loss.
        anyhow::ensure!(
            finished + rejected == total,
            "request loss: {finished} finished + {rejected} rejected != {total} submitted"
        );
        let per_server = cluster.per_server_stats();
        Ok(RunReport {
            policy: policy_name.to_string(),
            requests: total,
            finished,
            rejected,
            ttft: cluster.metrics().summary("ttft"),
            tpot: cluster.metrics().summary("tpot"),
            slo_attainment: cluster.metrics().slo_attainment(),
            routed: cluster.routed().to_vec(),
            routed_rank_sum: cluster.routed_rank_sum().to_vec(),
            cold: cluster.cold_start_stats().unwrap_or_default(),
            preemptions: per_server.iter().map(|s| s.preemptions).sum(),
            adapter_evictions: per_server.iter().map(|s| s.adapter_evictions).sum(),
            wall_s,
            streams: handles.iter().map(|h| h.tokens()).collect(),
        })
    }

    /// Drive one policy over the synthetic workload end to end with the
    /// static placement baseline and report cluster metrics.
    pub fn run(policy_name: &str, cfg: &SyntheticConfig) -> Result<RunReport> {
        let mut cluster = build(cfg, policy(policy_name, cfg.seed)?)?;
        let (handles, wall_s) = drive(&mut cluster, workload(cfg), cfg.polls_per_arrival)?;
        report(policy_name, &cluster, &handles, wall_s)
    }

    /// Drive one policy over the same workload with the coordinator in
    /// front: registry-driven placement, pre-warming, and live
    /// migration. Returns the report plus the coordinator itself so
    /// callers can inspect [`crate::coordinator::CoordinatorStats`] and
    /// the final registry placements.
    pub fn run_coordinated(
        policy_name: &str,
        cfg: &SyntheticConfig,
        ccfg: CoordinatorConfig,
    ) -> Result<(RunReport, Coordinator)> {
        let mut coord = build_coordinated(cfg, policy(policy_name, cfg.seed)?, ccfg)?;
        let (handles, wall_s) = drive(&mut coord, workload(cfg), cfg.polls_per_arrival)?;
        let rep = report(policy_name, coord.cluster(), &handles, wall_s)?;
        Ok((rep, coord))
    }

    /// Chaos knobs for one synthetic run: per-victim fault plans plus
    /// the cluster's retry/failover policy.
    #[derive(Debug, Clone, Default)]
    pub struct ChaosConfig {
        /// `(backend index, fault plan)` — victims get a
        /// [`ChaosFront`] wrapper executing the plan.
        pub faults: Vec<(usize, FaultPlan)>,
        /// Health/retry/degradation knobs for the routing front.
        pub retry: Option<RetryPolicy>,
    }

    /// Build the static-placement cluster with chaos victims wrapped in
    /// [`ChaosFront`] decorators.
    pub fn build_chaos(
        cfg: &SyntheticConfig,
        policy: Box<dyn Policy>,
        chaos: &ChaosConfig,
    ) -> Result<ClusterFront> {
        for (v, _) in &chaos.faults {
            anyhow::ensure!(
                *v < cfg.instances,
                "fault victim {v} out of range ({} instances)",
                cfg.instances
            );
        }
        let registry = Arc::new(GlobalRegistry::new());
        let mut backends: Vec<Box<dyn ServingFront>> = Vec::with_capacity(cfg.instances);
        for s in 0..cfg.instances {
            let mut server = engine(cfg)?;
            for a in 0..cfg.adapters as u64 {
                if hosts(cfg.instances, a, s) {
                    server.install_adapter(&LoraSpec::standard(a, rank_of(a), "tiny"))?;
                }
            }
            let boxed: Box<dyn ServingFront> = Box::new(server);
            let boxed = match chaos.faults.iter().find(|(v, _)| *v == s) {
                Some((_, plan)) => Box::new(ChaosFront::new(boxed, plan.clone())),
                None => boxed,
            };
            backends.push(boxed);
        }
        for a in 0..cfg.adapters as u64 {
            registry.register(AdapterMeta {
                id: a,
                rank: rank_of(a),
                base_model: "tiny".into(),
                weights_path: String::new(),
            });
            for s in 0..cfg.instances {
                if hosts(cfg.instances, a, s) {
                    registry.place(a, s);
                }
            }
        }
        let cluster = ClusterFront::new(backends, policy, registry);
        Ok(match &chaos.retry {
            Some(r) => cluster.with_retry(r.clone()),
            None => cluster,
        })
    }

    /// Results of one chaos run, reconciled against the no-fault oracle.
    #[derive(Debug, Clone)]
    pub struct ChaosReport {
        /// The chaos run's ordinary per-policy report.
        pub base: RunReport,
        /// Finished requests whose stream is bitwise equal to the
        /// no-fault oracle's (resumed/failed-over requests included).
        pub stable: usize,
        /// Finished requests whose stream diverged from the oracle —
        /// must be 0; any other value is a failover-correctness bug.
        pub diverged: usize,
        /// Requests terminated by the fault (typed `BackendFailed` /
        /// `Overloaded` rejections).
        pub failed: usize,
        /// Successful failover re-placements.
        pub failovers: usize,
        /// Requests shed by the degradation gate.
        pub shed: usize,
        /// Final per-backend health.
        pub health: Vec<Health>,
    }

    /// Drive one policy over the synthetic workload with faults
    /// injected, and reconcile every finished stream against the
    /// no-fault oracle run (same config, no chaos). The oracle
    /// comparison is the §failover acceptance criterion: a backend
    /// death mid-decode must leave every completed stream bitwise
    /// identical.
    pub fn run_chaos(
        policy_name: &str,
        cfg: &SyntheticConfig,
        chaos: &ChaosConfig,
    ) -> Result<(ChaosReport, RunReport)> {
        let oracle = run(policy_name, cfg)?;
        let mut cluster = build_chaos(cfg, policy(policy_name, cfg.seed)?, chaos)?;
        let (handles, wall_s) = drive(&mut cluster, workload(cfg), cfg.polls_per_arrival)?;
        let base = report(policy_name, &cluster, &handles, wall_s)?;
        let (mut stable, mut diverged, mut failed) = (0, 0, 0);
        for (i, h) in handles.iter().enumerate() {
            match h.state() {
                LifecycleState::Finished if !oracle.streams[i].is_empty() => {
                    if oracle.streams[i] == h.tokens() {
                        stable += 1;
                    } else {
                        diverged += 1;
                    }
                }
                // The oracle itself rejected this request (e.g. a KV
                // bound): nothing to compare.
                LifecycleState::Finished => stable += 1,
                _ => failed += 1,
            }
        }
        let report = ChaosReport {
            stable,
            diverged,
            failed,
            failovers: cluster.failovers(),
            shed: cluster.shed_count(),
            health: cluster.health(),
            base,
        };
        Ok((report, oracle))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::scheduler::baselines::MostIdle;
    use crate::scheduler::registry::AdapterMeta;
    use crate::server::api::{FinishReason, LifecycleState};
    use crate::sim::{GpuModel, ServingMode, SimFront, SimInstance};
    use crate::testkit::faults::{ChaosFront, FaultPlan};

    fn sim_backend(max_prompt: usize, adapters: &[(u64, usize)]) -> SimFront {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        let mut front = SimFront::new(inst, max_prompt);
        for &(id, rank) in adapters {
            front.register_adapter(id, rank);
        }
        front
    }

    fn registry_of(adapters: &[(u64, usize)]) -> Arc<GlobalRegistry> {
        let reg = GlobalRegistry::new();
        for &(id, rank) in adapters {
            reg.register(AdapterMeta {
                id,
                rank,
                base_model: "sim".into(),
                weights_path: String::new(),
            });
        }
        Arc::new(reg)
    }

    fn cluster_of(backends: Vec<Box<dyn ServingFront>>, adapters: &[(u64, usize)]) -> ClusterFront {
        ClusterFront::new(backends, Box::new(MostIdle), registry_of(adapters))
    }

    #[test]
    fn cluster_of_one_matches_bare_backend() {
        let adapters: Vec<(u64, usize)> = (0..4).map(|id| (id, 64)).collect();
        let reqs = || {
            (0..6).map(|i| {
                ServeRequest::new(i % 4, vec![1; 8 + i as usize]).max_new_tokens(3 + i as usize)
            })
        };
        let mut bare = sim_backend(64, &adapters);
        let bare_handles: Vec<_> = reqs().map(|r| bare.submit(r)).collect();
        bare.run_until_idle().unwrap();

        let mut cluster = cluster_of(
            vec![Box::new(sim_backend(64, &adapters))],
            &adapters,
        );
        let cluster_handles: Vec<_> = reqs().map(|r| cluster.submit(r)).collect();
        cluster.run_until_idle().unwrap();

        for (b, c) in bare_handles.iter().zip(&cluster_handles) {
            assert_eq!(c.state(), LifecycleState::Finished);
            assert_eq!(b.tokens(), c.tokens(), "cluster-of-1 changed the stream");
            let events = c.drain_events();
            assert_eq!(events[0], RequestEvent::Admitted);
            assert_eq!(events[1], RequestEvent::Routed { server: 0 });
            assert!(matches!(events[2], RequestEvent::FirstToken(_)));
            assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
            assert_eq!(
                events.last(),
                Some(&RequestEvent::Finished(FinishReason::Length))
            );
        }
        assert_eq!(cluster.metrics().records().len(), 6);
    }

    #[test]
    fn routes_by_adapter_placement() {
        // Adapter 7 lives only on backend 1; eligibility must steer there
        // even though backend 0 is equally idle.
        let a0: Vec<(u64, usize)> = vec![(1, 8)];
        let a1: Vec<(u64, usize)> = vec![(1, 8), (7, 64)];
        let all: Vec<(u64, usize)> = vec![(1, 8), (7, 64)];
        let mut cluster = cluster_of(
            vec![Box::new(sim_backend(64, &a0)), Box::new(sim_backend(64, &a1))],
            &all,
        );
        let h = cluster.submit(ServeRequest::new(7, vec![1; 8]).max_new_tokens(2));
        cluster.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        assert!(h
            .drain_events()
            .contains(&RequestEvent::Routed { server: 1 }));
        assert_eq!(cluster.routed(), &[0, 1]);
        assert_eq!(cluster.routed_rank_sum(), &[0, 64]);
    }

    #[test]
    fn reroutes_on_backend_rejection() {
        // Backend 0 claims eligibility but its KV bound refuses the
        // request at submit; the front must re-route to backend 1, not
        // surface Rejected.
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        let tight = sim_backend(64, &adapters).with_kv_capacity(16);
        let roomy = sim_backend(64, &adapters).with_kv_capacity(60);
        let mut cluster =
            cluster_of(vec![Box::new(tight), Box::new(roomy)], &adapters);
        // 8 prompt + 40 output > 16 + 1 on backend 0; fits on backend 1.
        let h = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(40));
        cluster.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        assert_eq!(h.tokens().len(), 40);
        let events = h.drain_events();
        assert!(events.contains(&RequestEvent::Routed { server: 1 }));
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);

        // When every backend refuses, the client sees one terminal
        // Rejected carrying the last refusal.
        let h = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(100));
        assert_eq!(h.state(), LifecycleState::Rejected);
        match h.drain_events().as_slice() {
            [RequestEvent::Rejected(RejectReason::NoEligibleServer { last: Some(last) })] => {
                // The boxed refusal is the last backend's typed reason.
                assert!(
                    matches!(**last, RejectReason::KvCapacity { .. }),
                    "{last:?}"
                );
            }
            other => panic!("expected typed NoEligibleServer, got {other:?}"),
        }
    }

    #[test]
    fn unregistered_adapter_rejected_at_the_front() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        let mut cluster =
            cluster_of(vec![Box::new(sim_backend(64, &adapters))], &adapters);
        let h = cluster.submit(ServeRequest::new(99, vec![1; 8]));
        assert_eq!(h.state(), LifecycleState::Rejected);
        assert!(!cluster.poll().unwrap());
    }

    #[test]
    fn cancel_fans_out_to_the_owning_backend() {
        let adapters: Vec<(u64, usize)> = (0..2).map(|id| (id, 32)).collect();
        let mut cluster = cluster_of(
            vec![
                Box::new(sim_backend(64, &adapters)),
                Box::new(sim_backend(64, &adapters)),
            ],
            &adapters,
        );
        // Queued cancel through the front.
        let queued = cluster.submit(ServeRequest::new(0, vec![1; 8]).max_new_tokens(30));
        assert!(cluster.cancel(queued.id()));
        // Mid-decode cancel through the client handle.
        let running = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(30));
        for _ in 0..3 {
            cluster.poll().unwrap();
        }
        running.cancel();
        cluster.run_until_idle().unwrap();
        assert_eq!(queued.state(), LifecycleState::Cancelled);
        assert_eq!(running.state(), LifecycleState::Cancelled);
        assert!(running.tokens().len() < 30);
        assert!(!cluster.cancel(queued.id()), "dead ids report false");
        assert!(!cluster.cancel(12345));
    }

    #[test]
    fn probation_rejoin_reinstalls_lost_placements() {
        let adapters: Vec<(u64, usize)> = (0..3).map(|id| (id, 16)).collect();
        let mut cluster = cluster_of(
            vec![
                Box::new(sim_backend(64, &adapters)),
                Box::new(sim_backend(64, &adapters)),
            ],
            &adapters,
        );
        for &(id, _) in &adapters {
            cluster.registry.place(id, 0);
            cluster.registry.place(id, 1);
        }
        // Backend 0 "reboots" without its state: wipe its local adapter
        // set directly, bypassing the registry, exactly as a process
        // restart would.
        for &(id, _) in &adapters {
            cluster.backends[0].uninstall_adapter(id).unwrap();
        }
        assert!(!cluster.backends[0].stats().can_serve(1));
        cluster.health[0].state = Health::Probation;
        cluster.record_poll_ok(0);
        assert_eq!(
            cluster.health_of(0),
            Health::Healthy,
            "readmitted only after placements are restored"
        );
        assert_eq!(cluster.rejoin_reinstalls(), 3);
        assert!(cluster.backends[0].stats().can_serve(0));
        assert!(cluster.backends[0].stats().can_serve(2));
        // Rejoin *with* state: everything resident, nothing re-installed.
        cluster.health[1].state = Health::Probation;
        cluster.record_poll_ok(1);
        assert_eq!(cluster.health_of(1), Health::Healthy);
        assert_eq!(cluster.rejoin_reinstalls(), 3);
    }

    #[test]
    fn install_on_updates_backend_and_registry_together() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        let mut cluster = cluster_of(
            vec![Box::new(sim_backend(64, &adapters)), Box::new(sim_backend(64, &adapters))],
            &adapters,
        );
        // Adapter 9 is unknown everywhere: a submit rejects at the front.
        assert_eq!(
            cluster.submit(ServeRequest::new(9, vec![1; 4])).state(),
            LifecycleState::Rejected
        );
        cluster.install_on(1, &LoraSpec::standard(9, 16, "sim")).unwrap();
        assert_eq!(cluster.registry().servers_for(9), vec![1]);
        assert_eq!(cluster.registry().rank_of(9), Some(16));
        // Routing now steers to the only hosting backend.
        let h = cluster.submit(ServeRequest::new(9, vec![1; 4]).max_new_tokens(2));
        cluster.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        assert!(h.drain_events().contains(&RequestEvent::Routed { server: 1 }));
        // Out-of-range targets are an error, not a panic.
        assert!(cluster.install_on(5, &LoraSpec::standard(9, 16, "sim")).is_err());
        assert!(cluster.prewarm_on(5, 9).is_err());
        assert!(cluster.uninstall_on(5, 9).is_err());
    }

    #[test]
    fn uninstall_refuses_while_requests_are_in_flight() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8), (2, 8)];
        let mut cluster = cluster_of(
            vec![Box::new(sim_backend(64, &adapters))],
            &adapters,
        );
        cluster.registry().place(1, 0);
        cluster.registry().place(2, 0);
        let h = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(6));
        // Queued on adapter 1: the per-server retire must refuse and
        // leave both the placement and the backend untouched.
        let err = cluster.uninstall_on(0, 1).unwrap_err();
        assert!(err.to_string().contains("busy"), "{err}");
        assert_eq!(cluster.registry().servers_for(1), vec![0]);
        assert!(cluster.stats().can_serve(1));
        // Adapter 2 is idle: retire succeeds and prunes its placement.
        cluster.uninstall_on(0, 2).unwrap();
        assert!(cluster.registry().servers_for(2).is_empty());
        assert!(!cluster.stats().can_serve(2));
        // After draining, the refused retire goes through; the stream
        // completed untouched.
        cluster.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        assert_eq!(h.tokens(), vec![0, 1, 2, 3, 4, 5]);
        ServingFront::uninstall_adapter(&mut cluster, 1).unwrap();
        assert!(cluster.registry().servers_for(1).is_empty());
        assert_eq!(
            cluster.submit(ServeRequest::new(1, vec![1; 4])).state(),
            LifecycleState::Rejected
        );
    }

    #[test]
    fn cluster_level_install_picks_least_loaded_backend() {
        // Backend 0 hosts two adapters, backend 1 one: a cluster-level
        // install lands on backend 1.
        let a0: Vec<(u64, usize)> = vec![(1, 8), (2, 8)];
        let a1: Vec<(u64, usize)> = vec![(1, 8)];
        let all: Vec<(u64, usize)> = vec![(1, 8), (2, 8)];
        let mut cluster = cluster_of(
            vec![Box::new(sim_backend(64, &a0)), Box::new(sim_backend(64, &a1))],
            &all,
        );
        ServingFront::install_adapter(&mut cluster, &LoraSpec::standard(7, 32, "sim")).unwrap();
        assert_eq!(cluster.registry().servers_for(7), vec![1]);
        assert!(cluster.per_server_stats()[1].can_serve(7));
        assert!(!cluster.per_server_stats()[0].can_serve(7));
    }

    #[test]
    fn stats_aggregate_across_backends() {
        let a0: Vec<(u64, usize)> = vec![(1, 8)];
        let a1: Vec<(u64, usize)> = vec![(2, 64)];
        let all: Vec<(u64, usize)> = vec![(1, 8), (2, 64)];
        let mut cluster = cluster_of(
            vec![Box::new(sim_backend(32, &a0)), Box::new(sim_backend(64, &a1))],
            &all,
        );
        let _h1 = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(4).slo(200.0, 50.0));
        let _h2 = cluster.submit(ServeRequest::new(2, vec![1; 8]).max_new_tokens(4).slo(200.0, 30.0));
        let s = cluster.stats();
        assert_eq!(s.total_requests(), 2);
        assert!(s.can_serve(1) && s.can_serve(2) && !s.can_serve(3));
        assert_eq!(s.max_prompt_tokens, 64);
        assert!((s.tpot_slo.unwrap() - 0.030).abs() < 1e-12);
        cluster.run_until_idle().unwrap();
        assert_eq!(cluster.stats().total_requests(), 0);
        // Both sim backends report cold-start counters; the aggregate
        // sees both cold admits.
        let cs = cluster.cold_start_stats().unwrap();
        assert_eq!(cs.cold_admits, 2);
    }

    fn chaos_sim(plan: &str, adapters: &[(u64, usize)]) -> Box<dyn ServingFront> {
        Box::new(ChaosFront::new(
            Box::new(sim_backend(64, adapters)),
            FaultPlan::parse(plan).unwrap(),
        ))
    }

    #[test]
    fn panic_is_contained_and_stream_survives_failover() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        // No-fault oracle: the stream the client must see either way.
        let mut oracle = cluster_of(
            vec![
                Box::new(sim_backend(64, &adapters)),
                Box::new(sim_backend(64, &adapters)),
            ],
            &adapters,
        );
        let oh = oracle.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(10));
        oracle.run_until_idle().unwrap();
        assert_eq!(oh.state(), LifecycleState::Finished);

        // Same request; the owning backend panics on its 2nd decode
        // poll. The panic must not escape, and the stream must match
        // the oracle bitwise after failing over to backend 1.
        let mut cluster = cluster_of(
            vec![
                chaos_sim("panic@decode:2", &adapters),
                Box::new(sim_backend(64, &adapters)),
            ],
            &adapters,
        );
        let h = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(10));
        cluster.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        assert_eq!(h.tokens(), oh.tokens(), "failover changed the stream");
        assert_eq!(cluster.health_of(0), Health::Down);
        assert_eq!(cluster.health_of(1), Health::Healthy);
        assert_eq!(cluster.failovers(), 1);
        let events = h.drain_events();
        assert!(events.contains(&RequestEvent::Routed { server: 0 }));
        assert!(events.contains(&RequestEvent::Rerouted { from: 0, to: 1 }));
        // Exactly one FirstToken (the continuation's first token is
        // relayed as a plain Token) and exactly one terminal event.
        assert_eq!(
            events
                .iter()
                .filter(|e| matches!(e, RequestEvent::FirstToken(_)))
                .count(),
            1
        );
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
    }

    #[test]
    fn transient_errors_recover_through_probation() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        let mut cluster = cluster_of(
            vec![chaos_sim(
                "error@poll:1,error@poll:2,error@poll:3",
                &adapters,
            )],
            &adapters,
        )
        .with_retry(RetryPolicy {
            down_after: 3,
            backoff_base: 2,
            ..Default::default()
        });
        cluster.poll().unwrap();
        assert_eq!(cluster.health_of(0), Health::Suspect);
        cluster.poll().unwrap();
        assert_eq!(cluster.health_of(0), Health::Suspect);
        cluster.poll().unwrap();
        assert_eq!(cluster.health_of(0), Health::Down, "3rd consecutive error");
        cluster.poll().unwrap();
        assert_eq!(cluster.health_of(0), Health::Down, "backoff not elapsed");
        // Tick 5 ≥ probe_at (3 + backoff 2): probe runs clean → Healthy.
        cluster.poll().unwrap();
        assert_eq!(cluster.health_of(0), Health::Healthy);
        // And it serves again.
        let h = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(3));
        cluster.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
    }

    #[test]
    fn all_backends_down_degrades_with_typed_overload() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        let mut cluster = cluster_of(
            vec![
                chaos_sim("die@poll:1", &adapters),
                chaos_sim("die@poll:1", &adapters),
            ],
            &adapters,
        )
        .with_retry(RetryPolicy {
            down_after: 1,
            ..Default::default()
        });
        let h1 = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(5));
        // One poll kills both backends; the in-flight request has no
        // survivor to resume on → typed BackendFailed terminal.
        cluster.run_until_idle().unwrap();
        assert_eq!(cluster.health(), vec![Health::Down, Health::Down]);
        assert_eq!(h1.state(), LifecycleState::Rejected);
        let events = h1.drain_events();
        assert!(events
            .iter()
            .any(|e| matches!(e, RequestEvent::Rejected(RejectReason::BackendFailed { server: 0 }))));
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
        // With nothing serving, new submissions shed with a typed
        // Overloaded instead of queueing into a dead cluster.
        let h2 = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(5));
        assert_eq!(h2.state(), LifecycleState::Rejected);
        match h2.drain_events().as_slice() {
            [RequestEvent::Rejected(RejectReason::Overloaded { healthy: 0, .. })] => {}
            other => panic!("expected typed Overloaded, got {other:?}"),
        }
        assert_eq!(cluster.shed_count(), 1);
    }

    #[test]
    fn stall_watchdog_takes_wedged_backend_down_and_reroutes() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        // Backend 0 wedges from its 1st poll: claims progress forever,
        // makes none. Only the per-request stall watchdog can catch it.
        let mut cluster = cluster_of(
            vec![
                chaos_sim("stall@poll:1", &adapters),
                Box::new(sim_backend(64, &adapters)),
            ],
            &adapters,
        )
        .with_retry(RetryPolicy {
            stall_polls: 8,
            ..Default::default()
        });
        let h = cluster.submit(ServeRequest::new(1, vec![1; 8]).max_new_tokens(6));
        cluster.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        assert_eq!(h.tokens(), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(cluster.health_of(0), Health::Down, "wedged backend stays down");
        assert!(h
            .drain_events()
            .contains(&RequestEvent::Rerouted { from: 0, to: 1 }));
    }

    #[test]
    fn unregistered_adapter_gets_typed_reason() {
        let adapters: Vec<(u64, usize)> = vec![(1, 8)];
        let mut cluster =
            cluster_of(vec![Box::new(sim_backend(64, &adapters))], &adapters);
        let h = cluster.submit(ServeRequest::new(99, vec![1; 8]));
        match h.drain_events().as_slice() {
            [RequestEvent::Rejected(RejectReason::AdapterNotRegistered { adapter: 99 })] => {}
            other => panic!("expected typed AdapterNotRegistered, got {other:?}"),
        }
    }
}
