//! Iteration-level continuous batching policy (Fig 2 / Orca-style).
//!
//! Pure decision logic, separated from execution so the policy is unit-
//! and property-testable: given the queue and the running set, decide
//! whether the next iteration is a prefill (admit new requests — they
//! preempt decoding) or a decode, and which requests participate.
//! Carries the lifecycle API's [`ActiveRequest`]s: priority classes
//! order the queue, and cancellation removes entries from either side.

use std::collections::VecDeque;

use super::api::{ActiveRequest, Priority, SamplingParams, SloSpec};

/// A queued request with arrival metadata.
#[derive(Debug, Clone)]
pub struct QueuedReq {
    pub req: ActiveRequest,
    pub arrival: std::time::Instant,
}

/// A running (decoding) request.
#[derive(Debug, Clone)]
pub struct RunningReq {
    pub id: u64,
    pub adapter: u64,
    /// The original user prompt — kept so a decode-growth preemption can
    /// re-queue the request with a rebuildable context.
    pub prompt: Vec<i32>,
    /// Context length (prompt + generated so far).
    pub ctx: usize,
    /// Tokens generated so far.
    pub generated: usize,
    /// Sampling configuration (budget, stop tokens, top-k seed).
    pub sampling: SamplingParams,
    /// Priority class (preserved across preemption/re-queue).
    pub priority: Priority,
    /// Latency SLO, if the request carries one.
    pub slo: Option<SloSpec>,
    /// Last emitted token (input to the next decode step).
    pub last_token: i32,
    /// Set when a stop token was emitted (finishes ahead of the budget).
    pub stopped: bool,
}

impl RunningReq {
    /// Is this request done after `generated` tokens?
    pub fn finished(&self) -> bool {
        self.stopped || self.generated >= self.sampling.max_new_tokens
    }
}

/// What the engine should run next.
#[derive(Debug, Clone, PartialEq)]
pub enum NextAction {
    /// Admit these queue positions (front-first) into a prefill pass.
    Prefill { admit: usize },
    /// Run one decode iteration over the running batch.
    Decode,
    /// Nothing to do.
    Idle,
}

/// The batching policy.
pub struct Batcher {
    /// Max running requests (decode bucket capacity).
    pub max_batch: usize,
    /// Max requests admitted per prefill pass (prefill bucket capacity).
    pub max_prefill_batch: usize,
    /// Queue of waiting requests, ordered by (priority desc, arrival).
    pub queue: VecDeque<QueuedReq>,
    /// Running batch.
    pub running: Vec<RunningReq>,
}

impl Batcher {
    /// New policy with the given bucket capacities.
    pub fn new(max_batch: usize, max_prefill_batch: usize) -> Batcher {
        Batcher {
            max_batch,
            max_prefill_batch,
            queue: VecDeque::new(),
            running: Vec::new(),
        }
    }

    /// Enqueue an arrival: after every queued request of equal-or-higher
    /// priority, ahead of lower ones (FIFO within a class).
    pub fn enqueue(&mut self, req: ActiveRequest) {
        let pos = super::api::priority_insert_pos(
            self.queue.iter().map(|q| q.req.priority),
            req.priority,
        );
        self.queue.insert(
            pos,
            QueuedReq {
                req,
                arrival: std::time::Instant::now(),
            },
        );
    }

    /// Remove a queued request by id (cancellation before prefill).
    pub fn remove_queued(&mut self, id: u64) -> Option<QueuedReq> {
        let pos = self.queue.iter().position(|q| q.req.id == id)?;
        self.queue.remove(pos)
    }

    /// Remove a running request by id (cancellation mid-decode).
    pub fn remove_running(&mut self, id: u64) -> Option<RunningReq> {
        let pos = self.running.iter().position(|r| r.id == id)?;
        Some(self.running.remove(pos))
    }

    /// Decide the next iteration (Fig 2: arrivals preempt decode).
    /// `can_admit(context_len)` is the KV manager's admission check —
    /// sized by the full prefill context, which for a re-queued
    /// (preempted) request includes its already-generated tokens.
    pub fn next_action(&self, can_admit: impl Fn(usize) -> bool) -> NextAction {
        self.next_action_by(|q| can_admit(q.req.context_len()))
    }

    /// [`next_action`](Self::next_action) with the whole queued request
    /// visible to the admission predicate. The unified-pool engine needs
    /// this: admitting a request may also page in its adapter's weights,
    /// so eligibility depends on `(adapter, context_len)` jointly, not on
    /// context length alone. Same FIFO discipline — the scan stops at the
    /// first inadmissible request so the head is never starved.
    pub fn next_action_by(&self, can_admit: impl Fn(&QueuedReq) -> bool) -> NextAction {
        if !self.queue.is_empty() && self.running.len() < self.max_batch {
            // Admit from the front while capacity and pool pages allow.
            let room = (self.max_batch - self.running.len()).min(self.max_prefill_batch);
            let mut admit = 0;
            for q in self.queue.iter().take(room) {
                if can_admit(q) {
                    admit += 1;
                } else {
                    break; // FIFO: don't starve the head of the queue
                }
            }
            if admit > 0 {
                return NextAction::Prefill { admit };
            }
        }
        if !self.running.is_empty() {
            NextAction::Decode
        } else {
            NextAction::Idle
        }
    }

    /// Pop the first `admit` queued requests (after a Prefill decision).
    pub fn take_admits(&mut self, admit: usize) -> Vec<QueuedReq> {
        (0..admit)
            .map(|_| self.queue.pop_front().expect("admit > queue len"))
            .collect()
    }

    /// Move a prefilled request into the running set.
    pub fn start_running(&mut self, r: RunningReq) {
        assert!(
            self.running.len() < self.max_batch,
            "running batch overflow"
        );
        self.running.push(r);
    }

    /// Remove finished requests, returning them.
    pub fn reap_finished(&mut self) -> Vec<RunningReq> {
        let (done, keep): (Vec<_>, Vec<_>) =
            self.running.drain(..).partition(|r| r.finished());
        self.running = keep;
        done
    }

    /// Total load (queue + running) — the scheduler's GetStats view.
    pub fn load(&self) -> usize {
        self.queue.len() + self.running.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::api::Priority;

    fn req(id: u64, prompt: usize) -> ActiveRequest {
        ActiveRequest {
            id,
            adapter: id,
            prompt: vec![1; prompt],
            sampling: SamplingParams {
                max_new_tokens: 4,
                ..Default::default()
            },
            priority: Priority::Standard,
            slo: None,
            resume: None,
        }
    }

    fn running(id: u64, ctx: usize, generated: usize, max: usize) -> RunningReq {
        RunningReq {
            id,
            adapter: id,
            prompt: vec![1; ctx.saturating_sub(generated.saturating_sub(1))],
            ctx,
            generated,
            sampling: SamplingParams {
                max_new_tokens: max,
                ..Default::default()
            },
            priority: Priority::Standard,
            slo: None,
            last_token: 0,
            stopped: false,
        }
    }

    #[test]
    fn idle_when_empty() {
        let b = Batcher::new(8, 4);
        assert_eq!(b.next_action(|_| true), NextAction::Idle);
    }

    #[test]
    fn prefill_preempts_decode() {
        let mut b = Batcher::new(8, 4);
        b.start_running(running(1, 10, 1, 5));
        assert_eq!(b.next_action(|_| true), NextAction::Decode);
        b.enqueue(req(2, 16));
        assert_eq!(b.next_action(|_| true), NextAction::Prefill { admit: 1 });
    }

    #[test]
    fn admits_bounded_by_room_and_prefill_bucket() {
        let mut b = Batcher::new(4, 2);
        for i in 0..5 {
            b.enqueue(req(i, 8));
        }
        // Prefill bucket limits to 2.
        assert_eq!(b.next_action(|_| true), NextAction::Prefill { admit: 2 });
        // Fill running to 3: room = 1.
        for i in 10..13 {
            b.start_running(running(i, 8, 0, 4));
        }
        assert_eq!(b.next_action(|_| true), NextAction::Prefill { admit: 1 });
    }

    #[test]
    fn full_batch_decodes_despite_queue() {
        let mut b = Batcher::new(2, 2);
        b.enqueue(req(1, 8));
        for i in 10..12 {
            b.start_running(running(i, 8, 0, 4));
        }
        assert_eq!(b.next_action(|_| true), NextAction::Decode);
    }

    #[test]
    fn kv_pressure_blocks_admission_fifo() {
        let mut b = Batcher::new(8, 4);
        b.enqueue(req(1, 100)); // too big for KV
        b.enqueue(req(2, 4)); // would fit, but FIFO blocks behind head
        let action = b.next_action(|p| p <= 50);
        assert_eq!(action, NextAction::Idle);
        // With a running batch it decodes instead of idling.
        b.start_running(running(9, 4, 0, 4));
        assert_eq!(b.next_action(|p| p <= 50), NextAction::Decode);
    }

    #[test]
    fn admission_sizes_by_resume_context() {
        use crate::server::api::ResumeState;
        let mut b = Batcher::new(8, 4);
        let mut r = req(1, 40);
        // 21 generated tokens → context = 40 + 20 = 60 (last token is the
        // next decode input, not part of the rebuilt prefix).
        r.resume = Some(ResumeState {
            tokens: vec![7; 21],
        });
        b.enqueue(r);
        assert_eq!(b.next_action(|c| c <= 50), NextAction::Idle);
        assert_eq!(b.next_action(|c| c <= 60), NextAction::Prefill { admit: 1 });
    }

    #[test]
    fn next_action_by_sees_the_whole_request() {
        let mut b = Batcher::new(8, 4);
        b.enqueue(req(1, 8)); // adapter 1
        b.enqueue(req(2, 8)); // adapter 2
        // Adapter-aware predicate: only adapter 1 is admissible; FIFO
        // still stops the scan at the first refusal.
        assert_eq!(
            b.next_action_by(|q| q.req.adapter == 1),
            NextAction::Prefill { admit: 1 }
        );
        assert_eq!(b.next_action_by(|q| q.req.adapter == 2), NextAction::Idle);
        // Delegation: next_action is next_action_by over context_len.
        assert_eq!(b.next_action(|c| c >= 8), NextAction::Prefill { admit: 2 });
    }

    #[test]
    fn reap_finished_partitions() {
        let mut b = Batcher::new(8, 4);
        for (id, gen) in [(1u64, 4usize), (2, 2), (3, 4)] {
            b.start_running(running(id, 10, gen, 4));
        }
        let done = b.reap_finished();
        assert_eq!(done.len(), 2);
        assert_eq!(b.running.len(), 1);
        assert_eq!(b.running[0].id, 2);
    }

    #[test]
    fn stopped_requests_reap_before_budget() {
        let mut b = Batcher::new(8, 4);
        let mut r = running(1, 10, 1, 8);
        r.stopped = true;
        b.start_running(r);
        assert_eq!(b.reap_finished().len(), 1);
    }

    #[test]
    fn take_admits_fifo_order() {
        let mut b = Batcher::new(8, 4);
        for i in 0..3 {
            b.enqueue(req(i, 8));
        }
        let admits = b.take_admits(2);
        assert_eq!(admits[0].req.id, 0);
        assert_eq!(admits[1].req.id, 1);
        assert_eq!(b.queue.len(), 1);
    }

    #[test]
    fn priority_orders_queue_fifo_within_class() {
        let mut b = Batcher::new(8, 4);
        let mut std1 = req(1, 8);
        std1.priority = Priority::Standard;
        let mut batch2 = req(2, 8);
        batch2.priority = Priority::Batch;
        let mut hot3 = req(3, 8);
        hot3.priority = Priority::Interactive;
        let mut hot4 = req(4, 8);
        hot4.priority = Priority::Interactive;
        for r in [std1, batch2, hot3, hot4] {
            b.enqueue(r);
        }
        let order: Vec<u64> = b.queue.iter().map(|q| q.req.id).collect();
        assert_eq!(order, vec![3, 4, 1, 2]);
    }

    #[test]
    fn remove_queued_and_running_by_id() {
        let mut b = Batcher::new(8, 4);
        b.enqueue(req(1, 8));
        b.enqueue(req(2, 8));
        assert_eq!(b.remove_queued(1).unwrap().req.id, 1);
        assert!(b.remove_queued(1).is_none());
        assert_eq!(b.queue.len(), 1);

        b.start_running(running(5, 8, 1, 4));
        assert_eq!(b.remove_running(5).unwrap().id, 5);
        assert!(b.remove_running(5).is_none());
        assert_eq!(b.load(), 1);
    }
}
