//! Iteration-level continuous batching policy (Fig 2 / Orca-style).
//!
//! Pure decision logic, separated from execution so the policy is unit-
//! and property-testable: given the queue and the running set, decide
//! whether the next iteration is a prefill (admit new requests — they
//! preempt decoding) or a decode, and which requests participate.

use std::collections::VecDeque;

use super::api::InferenceRequest;

/// A queued request with arrival metadata.
#[derive(Debug, Clone)]
pub struct QueuedReq {
    pub req: InferenceRequest,
    pub arrival: std::time::Instant,
}

/// A running (decoding) request.
#[derive(Debug, Clone)]
pub struct RunningReq {
    pub id: u64,
    pub adapter: u64,
    /// Context length (prompt + generated so far).
    pub ctx: usize,
    /// Tokens generated so far.
    pub generated: usize,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// Last emitted token (input to the next decode step).
    pub last_token: i32,
}

impl RunningReq {
    /// Is this request done after `generated` tokens?
    pub fn finished(&self) -> bool {
        self.generated >= self.max_new_tokens
    }
}

/// What the engine should run next.
#[derive(Debug, Clone, PartialEq)]
pub enum NextAction {
    /// Admit these queue positions (front-first) into a prefill pass.
    Prefill { admit: usize },
    /// Run one decode iteration over the running batch.
    Decode,
    /// Nothing to do.
    Idle,
}

/// The batching policy.
pub struct Batcher {
    /// Max running requests (decode bucket capacity).
    pub max_batch: usize,
    /// Max requests admitted per prefill pass (prefill bucket capacity).
    pub max_prefill_batch: usize,
    /// Queue of waiting requests.
    pub queue: VecDeque<QueuedReq>,
    /// Running batch.
    pub running: Vec<RunningReq>,
}

impl Batcher {
    /// New policy with the given bucket capacities.
    pub fn new(max_batch: usize, max_prefill_batch: usize) -> Batcher {
        Batcher {
            max_batch,
            max_prefill_batch,
            queue: VecDeque::new(),
            running: Vec::new(),
        }
    }

    /// Enqueue an arrival.
    pub fn enqueue(&mut self, req: InferenceRequest) {
        self.queue.push_back(QueuedReq {
            req,
            arrival: std::time::Instant::now(),
        });
    }

    /// Decide the next iteration (Fig 2: arrivals preempt decode).
    /// `can_admit(prompt_len)` is the KV manager's admission check.
    pub fn next_action(&self, can_admit: impl Fn(usize) -> bool) -> NextAction {
        if !self.queue.is_empty() && self.running.len() < self.max_batch {
            // Admit from the front while capacity and KV pages allow.
            let room = (self.max_batch - self.running.len()).min(self.max_prefill_batch);
            let mut admit = 0;
            for q in self.queue.iter().take(room) {
                if can_admit(q.req.prompt.len()) {
                    admit += 1;
                } else {
                    break; // FIFO: don't starve the head of the queue
                }
            }
            if admit > 0 {
                return NextAction::Prefill { admit };
            }
        }
        if !self.running.is_empty() {
            NextAction::Decode
        } else {
            NextAction::Idle
        }
    }

    /// Pop the first `admit` queued requests (after a Prefill decision).
    pub fn take_admits(&mut self, admit: usize) -> Vec<QueuedReq> {
        (0..admit)
            .map(|_| self.queue.pop_front().expect("admit > queue len"))
            .collect()
    }

    /// Move a prefilled request into the running set.
    pub fn start_running(&mut self, r: RunningReq) {
        assert!(
            self.running.len() < self.max_batch,
            "running batch overflow"
        );
        self.running.push(r);
    }

    /// Remove finished requests, returning them.
    pub fn reap_finished(&mut self) -> Vec<RunningReq> {
        let (done, keep): (Vec<_>, Vec<_>) =
            self.running.drain(..).partition(|r| r.finished());
        self.running = keep;
        done
    }

    /// Total load (queue + running) — the scheduler's GetStats view.
    pub fn load(&self) -> usize {
        self.queue.len() + self.running.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize) -> InferenceRequest {
        InferenceRequest {
            id,
            adapter: id,
            prompt: vec![1; prompt],
            max_new_tokens: 4,
        }
    }

    #[test]
    fn idle_when_empty() {
        let b = Batcher::new(8, 4);
        assert_eq!(b.next_action(|_| true), NextAction::Idle);
    }

    #[test]
    fn prefill_preempts_decode() {
        let mut b = Batcher::new(8, 4);
        b.start_running(RunningReq {
            id: 1,
            adapter: 1,
            ctx: 10,
            generated: 1,
            max_new_tokens: 5,
            last_token: 0,
        });
        assert_eq!(b.next_action(|_| true), NextAction::Decode);
        b.enqueue(req(2, 16));
        assert_eq!(b.next_action(|_| true), NextAction::Prefill { admit: 1 });
    }

    #[test]
    fn admits_bounded_by_room_and_prefill_bucket() {
        let mut b = Batcher::new(4, 2);
        for i in 0..5 {
            b.enqueue(req(i, 8));
        }
        // Prefill bucket limits to 2.
        assert_eq!(b.next_action(|_| true), NextAction::Prefill { admit: 2 });
        // Fill running to 3: room = 1.
        for i in 10..13 {
            b.start_running(RunningReq {
                id: i,
                adapter: i,
                ctx: 8,
                generated: 0,
                max_new_tokens: 4,
                last_token: 0,
            });
        }
        assert_eq!(b.next_action(|_| true), NextAction::Prefill { admit: 1 });
    }

    #[test]
    fn full_batch_decodes_despite_queue() {
        let mut b = Batcher::new(2, 2);
        b.enqueue(req(1, 8));
        for i in 10..12 {
            b.start_running(RunningReq {
                id: i,
                adapter: i,
                ctx: 8,
                generated: 0,
                max_new_tokens: 4,
                last_token: 0,
            });
        }
        assert_eq!(b.next_action(|_| true), NextAction::Decode);
    }

    #[test]
    fn kv_pressure_blocks_admission_fifo() {
        let mut b = Batcher::new(8, 4);
        b.enqueue(req(1, 100)); // too big for KV
        b.enqueue(req(2, 4)); // would fit, but FIFO blocks behind head
        let action = b.next_action(|p| p <= 50);
        assert_eq!(action, NextAction::Idle);
        // With a running batch it decodes instead of idling.
        b.start_running(RunningReq {
            id: 9,
            adapter: 9,
            ctx: 4,
            generated: 0,
            max_new_tokens: 4,
            last_token: 0,
        });
        assert_eq!(b.next_action(|p| p <= 50), NextAction::Decode);
    }

    #[test]
    fn reap_finished_partitions() {
        let mut b = Batcher::new(8, 4);
        for (id, gen) in [(1u64, 4usize), (2, 2), (3, 4)] {
            b.start_running(RunningReq {
                id,
                adapter: id,
                ctx: 10,
                generated: gen,
                max_new_tokens: 4,
                last_token: 0,
            });
        }
        let done = b.reap_finished();
        assert_eq!(done.len(), 2);
        assert_eq!(b.running.len(), 1);
        assert_eq!(b.running[0].id, 2);
    }

    #[test]
    fn take_admits_fifo_order() {
        let mut b = Batcher::new(8, 4);
        for i in 0..3 {
            b.enqueue(req(i, 8));
        }
        let admits = b.take_admits(2);
        assert_eq!(admits[0].req.id, 0);
        assert_eq!(admits[1].req.id, 1);
        assert_eq!(b.queue.len(), 1);
    }
}
