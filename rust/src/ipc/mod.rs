//! Inter-process communication substrate for the CPU-assisted LoRA
//! engine (paper §4.2, Figs 8 & 17).
//!
//! The paper runs CPU-LoRA workers as isolated processes and feeds them
//! through **shared memory** (vs. the domain-socket IPC of existing
//! frameworks). We keep the data plane byte-for-byte process-ready:
//!
//! - [`shm`] — a real `mmap(MAP_SHARED | MAP_ANONYMOUS)` region carved
//!   into fixed slots, each with a seqlock-style state word; works
//!   unchanged across `fork()`.
//! - [`socket`] — the Unix-domain-socket baseline used by Fig 17, with
//!   caller-supplied receive deadlines and a typed
//!   [`socket::SocketError::TimedOut`] for stalled-peer detection;
//!   since PR 9 it also carries length-prefixed *byte* frames (the
//!   [`crate::remote`] wire protocol's transport).
//! - [`signal`] — futex-backed doorbells: the "asynchronous signaling"
//!   half of the paper's fused memcpy+signal operator.

pub mod shm;
pub mod signal;
pub mod socket;

pub use shm::{ShmRegion, SlotChannel};
pub use signal::Doorbell;
pub use socket::{SocketChannel, SocketError};
