//! Futex-backed doorbells: cheap one-to-one wake-ups between the base
//! process and CPU-LoRA workers.
//!
//! This is the signaling half of the paper's fused async-memcpy+signal
//! operator (§4.2, Fig 8): the producer rings the doorbell *after* the
//! payload write is visible (release ordering); the consumer waits
//! without spinning the core. On Linux the wait parks on `futex(2)`,
//! which works across processes when the atomic lives in MAP_SHARED
//! memory — matching the paper's process-isolated workers.

use std::sync::atomic::{AtomicU32, Ordering};

/// A monotonically increasing event counter the consumer can wait on.
#[repr(C)]
pub struct Doorbell {
    seq: AtomicU32,
}

impl Default for Doorbell {
    fn default() -> Self {
        Self::new()
    }
}

impl Doorbell {
    /// New doorbell with sequence 0.
    pub const fn new() -> Self {
        Self {
            seq: AtomicU32::new(0),
        }
    }

    /// Current sequence value (acquire).
    pub fn load(&self) -> u32 {
        self.seq.load(Ordering::Acquire)
    }

    /// Ring: bump the sequence (release) and wake all waiters.
    pub fn ring(&self) {
        // ORDERING: the release RMW publishes every store the ringer
        // made before ringing (payload bytes, length word) to the
        // waiter's acquire load of `seq` — the slot protocol's only
        // synchronization edge. Audit (PR 6): no Relaxed anywhere on
        // the doorbell/slot-header path.
        self.seq.fetch_add(1, Ordering::Release);
        futex_wake_all(&self.seq);
    }

    /// Wait until the sequence moves past `seen` (as returned by
    /// [`Doorbell::load`] before the caller started waiting). Spins
    /// briefly (the common sub-microsecond case), then parks on futex.
    pub fn wait_past(&self, seen: u32) -> u32 {
        // ORDERING: acquire loads pair with `ring`'s release RMW, so a
        // caller that observes the bumped sequence also observes the
        // message written before the ring.
        // Short spin: LoRA layer sync is typically < 1 µs away.
        for _ in 0..1024 {
            let cur = self.seq.load(Ordering::Acquire);
            if cur != seen {
                return cur;
            }
            std::hint::spin_loop();
        }
        loop {
            let cur = self.seq.load(Ordering::Acquire);
            if cur != seen {
                return cur;
            }
            futex_wait(&self.seq, seen);
        }
    }
}

#[cfg(target_os = "linux")]
fn futex_wait(atom: &AtomicU32, expected: u32) {
    // SAFETY: FUTEX_WAIT reads the aligned u32 behind `atom` (valid for
    // the whole call) and compares it with `expected`; a null timeout
    // means wait indefinitely. Spurious wakeups are fine — the caller
    // re-checks in a loop.
    unsafe {
        libc::syscall(
            libc::SYS_futex,
            atom.as_ptr(),
            libc::FUTEX_WAIT,
            expected,
            std::ptr::null::<libc::timespec>(),
        );
    }
}

#[cfg(target_os = "linux")]
fn futex_wake_all(atom: &AtomicU32) {
    // SAFETY: FUTEX_WAKE only takes the address as a key to find
    // waiters; `atom` is a live aligned u32 for the whole call.
    unsafe {
        libc::syscall(libc::SYS_futex, atom.as_ptr(), libc::FUTEX_WAKE, i32::MAX);
    }
}

#[cfg(not(target_os = "linux"))]
fn futex_wait(_atom: &AtomicU32, _expected: u32) {
    std::thread::yield_now();
}

#[cfg(not(target_os = "linux"))]
fn futex_wake_all(_atom: &AtomicU32) {}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ring_wakes_waiter() {
        let bell = Arc::new(Doorbell::new());
        let bell2 = bell.clone();
        let seen = bell.load();
        let h = std::thread::spawn(move || bell2.wait_past(seen));
        std::thread::sleep(std::time::Duration::from_millis(10));
        bell.ring();
        let got = h.join().unwrap();
        assert_eq!(got, seen + 1);
    }

    #[test]
    fn wait_returns_immediately_if_already_past() {
        let bell = Doorbell::new();
        let seen = bell.load();
        bell.ring();
        assert_eq!(bell.wait_past(seen), seen + 1);
    }

    #[test]
    fn many_rings_counted() {
        let bell = Doorbell::new();
        for _ in 0..10 {
            bell.ring();
        }
        assert_eq!(bell.load(), 10);
    }

    #[test]
    fn ping_pong_between_threads() {
        let a = Arc::new(Doorbell::new());
        let b = Arc::new(Doorbell::new());
        let (a2, b2) = (a.clone(), b.clone());
        let rounds = 1_000;
        let h = std::thread::spawn(move || {
            let mut seen_a = 0;
            for _ in 0..rounds {
                seen_a = a2.wait_past(seen_a);
                b2.ring();
            }
        });
        let mut seen_b = 0;
        for _ in 0..rounds {
            a.ring();
            seen_b = b.wait_past(seen_b);
        }
        h.join().unwrap();
        assert_eq!(a.load(), rounds);
        assert_eq!(b.load(), rounds);
    }
}
