//! Unix-domain-socket IPC baseline (Fig 17's comparator).
//!
//! Mirrors the message-passing IPC of existing LLM frameworks: each
//! message is length-prefixed and the f32 payload is serialized through
//! the kernel socket buffer — i.e. two copies plus syscalls per hop,
//! which is exactly the overhead the shared-memory plane avoids.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;

/// One end of a framed f32 message channel over a Unix socket pair.
pub struct SocketChannel {
    stream: UnixStream,
}

impl SocketChannel {
    /// Create a connected pair (base-process end, worker end).
    pub fn pair() -> std::io::Result<(SocketChannel, SocketChannel)> {
        let (a, b) = UnixStream::pair()?;
        Ok((SocketChannel { stream: a }, SocketChannel { stream: b }))
    }

    /// Send one framed message: u32 length (f32 count) + payload bytes.
    pub fn send(&mut self, payload: &[f32]) -> std::io::Result<()> {
        let len = payload.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        // Serialize: this byte-copy is the cost sockets pay and shm avoids.
        let bytes: Vec<u8> = payload.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.stream.write_all(&bytes)
    }

    /// Receive one framed message into `out`.
    pub fn recv(&mut self, out: &mut Vec<f32>) -> std::io::Result<()> {
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        let mut bytes = vec![0u8; len * 4];
        self.stream.read_exact(&mut bytes)?;
        out.clear();
        out.reserve(len);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[1.0, -2.5, 3.25]).unwrap();
        let mut got = Vec::new();
        b.recv(&mut got).unwrap();
        assert_eq!(got, vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn echo_across_threads() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        let h = std::thread::spawn(move || {
            let mut buf = Vec::new();
            for _ in 0..100 {
                b.recv(&mut buf).unwrap();
                let doubled: Vec<f32> = buf.iter().map(|v| v * 2.0).collect();
                b.send(&doubled).unwrap();
            }
        });
        let mut resp = Vec::new();
        for i in 0..100 {
            a.send(&[i as f32; 16]).unwrap();
            a.recv(&mut resp).unwrap();
            assert!(resp.iter().all(|&v| v == i as f32 * 2.0));
        }
        h.join().unwrap();
    }

    #[test]
    fn empty_message_ok() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[]).unwrap();
        let mut got = vec![1.0];
        b.recv(&mut got).unwrap();
        assert!(got.is_empty());
    }
}
