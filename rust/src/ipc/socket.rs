//! Unix-domain-socket IPC: the Fig 17 f32 baseline plus the framed
//! byte transport the distributed tier ([`crate::remote`]) runs on.
//!
//! The f32 API mirrors the message-passing IPC of existing LLM
//! frameworks: each message is length-prefixed and the f32 payload is
//! serialized through the kernel socket buffer — i.e. two copies plus
//! syscalls per hop, which is exactly the overhead the shared-memory
//! plane avoids.
//!
//! The byte-frame API ([`SocketChannel::send_bytes`] /
//! [`SocketChannel::recv_bytes`] / [`SocketChannel::recv_bytes_deadline`])
//! generalizes the same length-prefixed framing to opaque payloads and
//! adds **partial-frame resync**: a deadline that expires mid-frame
//! keeps the bytes already received in an internal staging buffer, so
//! the next receive resumes the same frame instead of desynchronizing
//! the stream. `remote::wire` layers its versioned frame codec on top.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::{Duration, Instant};

/// Upper bound on one byte frame's payload. A declared length beyond
/// this is a protocol violation (or a desynchronized stream) and
/// surfaces as a typed I/O error instead of an allocation attempt.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Failure of a deadline-bounded receive ([`SocketChannel::recv_deadline`]).
///
/// `TimedOut` is a *typed* variant — callers (the engine's worker-pool
/// supervisor, the failover machinery) branch on it to declare a peer
/// stalled, which an opaque `io::Error` string would make fragile.
#[derive(Debug)]
pub enum SocketError {
    /// The peer produced no complete frame within the deadline. A frame
    /// half-received when the deadline expires also lands here: a peer
    /// that wedges mid-payload is exactly as stalled as one that never
    /// wrote, and the caller's recovery (declare it dead, fail over) is
    /// the same.
    TimedOut {
        /// How long the receive actually waited.
        waited: Duration,
    },
    /// Any other I/O failure (peer closed, kernel error).
    Io(std::io::Error),
}

impl std::fmt::Display for SocketError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SocketError::TimedOut { waited } => {
                write!(f, "socket receive timed out after {waited:?}")
            }
            SocketError::Io(e) => write!(f, "socket i/o error: {e}"),
        }
    }
}

impl std::error::Error for SocketError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SocketError::TimedOut { .. } => None,
            SocketError::Io(e) => Some(e),
        }
    }
}

/// One end of a framed message channel over a Unix stream socket:
/// f32 messages (the Fig 17 baseline) or opaque byte frames (the
/// distributed serving transport).
pub struct SocketChannel {
    stream: UnixStream,
    /// Bytes received toward the byte frame currently being read. A
    /// deadline expiring mid-frame leaves its progress here so the next
    /// `recv_bytes*` call resumes the same frame (resync, not desync).
    staged: Vec<u8>,
}

impl SocketChannel {
    /// Create a connected pair (base-process end, worker end).
    pub fn pair() -> std::io::Result<(SocketChannel, SocketChannel)> {
        let (a, b) = UnixStream::pair()?;
        Ok((SocketChannel::from_stream(a), SocketChannel::from_stream(b)))
    }

    /// Wrap an already-connected stream (listener `accept` side).
    pub fn from_stream(stream: UnixStream) -> SocketChannel {
        SocketChannel {
            stream,
            staged: Vec::new(),
        }
    }

    /// Connect to a listening Unix socket at `path`.
    pub fn connect<P: AsRef<Path>>(path: P) -> std::io::Result<SocketChannel> {
        Ok(SocketChannel::from_stream(UnixStream::connect(path)?))
    }

    /// Send one framed message: u32 length (f32 count) + payload bytes.
    pub fn send(&mut self, payload: &[f32]) -> std::io::Result<()> {
        let len = payload.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        // Serialize: this byte-copy is the cost sockets pay and shm avoids.
        let bytes: Vec<u8> = payload.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.stream.write_all(&bytes)
    }

    /// Receive one framed message into `out`.
    pub fn recv(&mut self, out: &mut Vec<f32>) -> std::io::Result<()> {
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        let mut bytes = vec![0u8; len * 4];
        self.stream.read_exact(&mut bytes)?;
        out.clear();
        out.reserve(len);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(())
    }

    /// Receive one framed message, giving up after `deadline` — the
    /// caller supplies the budget (derived from its SLO or retry
    /// policy), the channel enforces it. On [`SocketError::TimedOut`]
    /// the channel stays usable: blocking mode is restored and a frame
    /// the peer sends later is received normally (any half-read frame
    /// bytes are consumed by the failed call, so only use the channel
    /// again if the protocol re-synchronizes — in practice a stalled
    /// worker is torn down, which is the point of the typed error).
    pub fn recv_deadline(
        &mut self,
        out: &mut Vec<f32>,
        deadline: Duration,
    ) -> Result<(), SocketError> {
        let start = Instant::now();
        let res = self.recv_deadline_inner(out, start, deadline);
        // Restore blocking mode whatever happened, so plain `recv` on
        // this channel keeps its blocking contract.
        let _ = self.stream.set_read_timeout(None);
        res
    }

    fn recv_deadline_inner(
        &mut self,
        out: &mut Vec<f32>,
        start: Instant,
        deadline: Duration,
    ) -> Result<(), SocketError> {
        let mut len_buf = [0u8; 4];
        self.read_exact_by(&mut len_buf, start, deadline)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        let mut bytes = vec![0u8; len * 4];
        self.read_exact_by(&mut bytes, start, deadline)?;
        out.clear();
        out.reserve(len);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(())
    }

    /// `read_exact` against an absolute deadline: each kernel wait gets
    /// the *remaining* budget (a peer trickling bytes can't reset the
    /// clock by staying barely alive), and short reads accumulate until
    /// the buffer fills or time runs out.
    fn read_exact_by(
        &mut self,
        buf: &mut [u8],
        start: Instant,
        deadline: Duration,
    ) -> Result<(), SocketError> {
        let mut filled = 0;
        while filled < buf.len() {
            let left = deadline
                .checked_sub(start.elapsed())
                .filter(|d| !d.is_zero())
                .ok_or(SocketError::TimedOut {
                    waited: start.elapsed(),
                })?;
            self.stream
                .set_read_timeout(Some(left))
                .map_err(SocketError::Io)?;
            match self.stream.read(&mut buf[filled..]) {
                Ok(0) => {
                    return Err(SocketError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "peer closed mid-frame",
                    )))
                }
                Ok(n) => filled += n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Err(SocketError::TimedOut {
                        waited: start.elapsed(),
                    })
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(SocketError::Io(e)),
            }
        }
        Ok(())
    }

    /// Send one opaque byte frame: u32 little-endian payload length +
    /// payload. Frames above [`MAX_FRAME_BYTES`] are refused before any
    /// bytes hit the wire (a half-sent oversized frame would poison the
    /// stream for both peers).
    pub fn send_bytes(&mut self, payload: &[u8]) -> std::io::Result<()> {
        if payload.len() > MAX_FRAME_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("frame of {} bytes exceeds MAX_FRAME_BYTES", payload.len()),
            ));
        }
        let len = payload.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(payload)
    }

    /// Receive one byte frame, blocking until it is complete. Resumes a
    /// frame a previous timed-out [`SocketChannel::recv_bytes_deadline`]
    /// left half-read.
    pub fn recv_bytes(&mut self) -> Result<Vec<u8>, SocketError> {
        loop {
            if let Some(frame) = self.take_staged_frame()? {
                return Ok(frame);
            }
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => return Err(SocketError::Io(eof_error(&self.staged))),
                Ok(n) => self.staged.extend_from_slice(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(SocketError::Io(e)),
            }
        }
    }

    /// Receive one byte frame, giving up after `deadline`. Unlike the
    /// f32 [`SocketChannel::recv_deadline`], a timeout mid-frame keeps
    /// the bytes already received staged, so a later receive **resumes
    /// the same frame** — the channel re-synchronizes instead of
    /// shifting the stream by half a frame. Blocking mode is restored
    /// on every exit path.
    pub fn recv_bytes_deadline(&mut self, deadline: Duration) -> Result<Vec<u8>, SocketError> {
        let start = Instant::now();
        let res = self.recv_bytes_by(start, deadline);
        // Restore blocking mode whatever happened, so plain receives on
        // this channel keep their blocking contract.
        let _ = self.stream.set_read_timeout(None);
        res
    }

    fn recv_bytes_by(&mut self, start: Instant, deadline: Duration) -> Result<Vec<u8>, SocketError> {
        loop {
            if let Some(frame) = self.take_staged_frame()? {
                return Ok(frame);
            }
            let left = deadline
                .checked_sub(start.elapsed())
                .filter(|d| !d.is_zero())
                .ok_or(SocketError::TimedOut {
                    waited: start.elapsed(),
                })?;
            self.stream
                .set_read_timeout(Some(left))
                .map_err(SocketError::Io)?;
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => return Err(SocketError::Io(eof_error(&self.staged))),
                Ok(n) => self.staged.extend_from_slice(&buf[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Err(SocketError::TimedOut {
                        waited: start.elapsed(),
                    })
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(SocketError::Io(e)),
            }
        }
    }

    /// Pop one complete frame off the staging buffer, if present.
    /// `Err` on a declared length above [`MAX_FRAME_BYTES`] — the
    /// stream is desynchronized or the peer is violating the protocol,
    /// and either way the connection is unusable.
    fn take_staged_frame(&mut self) -> Result<Option<Vec<u8>>, SocketError> {
        if self.staged.len() < 4 {
            return Ok(None);
        }
        let len =
            u32::from_le_bytes([self.staged[0], self.staged[1], self.staged[2], self.staged[3]])
                as usize;
        if len > MAX_FRAME_BYTES {
            return Err(SocketError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("declared frame length {len} exceeds MAX_FRAME_BYTES"),
            )));
        }
        if self.staged.len() < 4 + len {
            return Ok(None);
        }
        let frame = self.staged[4..4 + len].to_vec();
        self.staged.drain(..4 + len);
        Ok(Some(frame))
    }
}

/// Peer-closed error, distinguishing a clean close (between frames)
/// from a mid-frame one.
fn eof_error(staged: &[u8]) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::UnexpectedEof,
        if staged.is_empty() {
            "peer closed"
        } else {
            "peer closed mid-frame"
        },
    )
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[1.0, -2.5, 3.25]).unwrap();
        let mut got = Vec::new();
        b.recv(&mut got).unwrap();
        assert_eq!(got, vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn echo_across_threads() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        let h = std::thread::spawn(move || {
            let mut buf = Vec::new();
            for _ in 0..100 {
                b.recv(&mut buf).unwrap();
                let doubled: Vec<f32> = buf.iter().map(|v| v * 2.0).collect();
                b.send(&doubled).unwrap();
            }
        });
        let mut resp = Vec::new();
        for i in 0..100 {
            a.send(&[i as f32; 16]).unwrap();
            a.recv(&mut resp).unwrap();
            assert!(resp.iter().all(|&v| v == i as f32 * 2.0));
        }
        h.join().unwrap();
    }

    #[test]
    fn empty_message_ok() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[]).unwrap();
        let mut got = vec![1.0];
        b.recv(&mut got).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn recv_deadline_times_out_on_a_stalled_peer() {
        let (_a, mut b) = SocketChannel::pair().unwrap();
        let mut got = Vec::new();
        let start = std::time::Instant::now();
        match b.recv_deadline(&mut got, Duration::from_millis(30)) {
            Err(SocketError::TimedOut { waited }) => {
                // ≥ the deadline minus kernel timer granularity.
                assert!(waited >= Duration::from_millis(20), "{waited:?}");
            }
            other => panic!("expected TimedOut, got {other:?}"),
        }
        // The wait is bounded by the deadline, not the peer's mood.
        assert!(start.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn recv_deadline_times_out_mid_frame() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        // Peer writes the length header and half the payload, then
        // wedges — the budget covers the whole frame, so this is a
        // timeout, not a success with a short buffer.
        a.stream.write_all(&4u32.to_le_bytes()).unwrap();
        a.stream.write_all(&[0u8; 8]).unwrap();
        let mut got = Vec::new();
        assert!(matches!(
            b.recv_deadline(&mut got, Duration::from_millis(30)),
            Err(SocketError::TimedOut { .. })
        ));
    }

    #[test]
    fn recv_deadline_receives_a_prompt_frame_and_restores_blocking() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[1.0, 2.0]).unwrap();
        let mut got = Vec::new();
        b.recv_deadline(&mut got, Duration::from_secs(5)).unwrap();
        assert_eq!(got, vec![1.0, 2.0]);
        // The channel stays usable with the blocking API afterwards.
        a.send(&[3.0]).unwrap();
        b.recv(&mut got).unwrap();
        assert_eq!(got, vec![3.0]);
    }

    #[test]
    fn byte_frames_roundtrip() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send_bytes(&[1, 2, 3, 255]).unwrap();
        a.send_bytes(&[]).unwrap();
        a.send_bytes(&[9; 10_000]).unwrap();
        assert_eq!(b.recv_bytes().unwrap(), vec![1, 2, 3, 255]);
        assert_eq!(b.recv_bytes().unwrap(), Vec::<u8>::new());
        assert_eq!(b.recv_bytes().unwrap(), vec![9; 10_000]);
    }

    #[test]
    fn byte_frame_deadline_resyncs_on_partial_frame() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        // Peer writes the header and half the payload, then stalls past
        // the deadline...
        a.stream.write_all(&8u32.to_le_bytes()).unwrap();
        a.stream.write_all(&[1, 2, 3, 4]).unwrap();
        assert!(matches!(
            b.recv_bytes_deadline(Duration::from_millis(30)),
            Err(SocketError::TimedOut { .. })
        ));
        // ...then completes the frame: the staged half is kept, so the
        // next receive returns the *whole* frame, and the stream stays
        // aligned for the frame after it.
        a.stream.write_all(&[5, 6, 7, 8]).unwrap();
        assert_eq!(
            b.recv_bytes_deadline(Duration::from_secs(5)).unwrap(),
            vec![1, 2, 3, 4, 5, 6, 7, 8]
        );
        a.send_bytes(&[42]).unwrap();
        assert_eq!(b.recv_bytes().unwrap(), vec![42]);
    }

    #[test]
    fn oversized_declared_length_is_a_typed_error() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.stream
            .write_all(&(u32::MAX).to_le_bytes())
            .unwrap();
        match b.recv_bytes() {
            Err(SocketError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
            }
            other => panic!("expected Io(InvalidData), got {other:?}"),
        }
        assert!(a.send_bytes(&vec![0u8; MAX_FRAME_BYTES + 1]).is_err());
    }

    #[test]
    fn byte_frames_interleave_with_f32_frames() {
        // Both APIs share the length-prefixed framing, so a connection
        // can carry either — what matters is both ends agreeing per
        // frame, which the remote protocol fixes by construction.
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[1.5]).unwrap();
        let mut f = Vec::new();
        b.recv(&mut f).unwrap();
        assert_eq!(f, vec![1.5]);
        a.send_bytes(b"hello").unwrap();
        assert_eq!(b.recv_bytes().unwrap(), b"hello".to_vec());
    }

    #[test]
    fn recv_deadline_reports_peer_close_as_io_not_timeout() {
        let (a, mut b) = SocketChannel::pair().unwrap();
        drop(a);
        let mut got = Vec::new();
        match b.recv_deadline(&mut got, Duration::from_secs(5)) {
            Err(SocketError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
            }
            other => panic!("expected Io(UnexpectedEof), got {other:?}"),
        }
    }
}
