//! Unix-domain-socket IPC baseline (Fig 17's comparator).
//!
//! Mirrors the message-passing IPC of existing LLM frameworks: each
//! message is length-prefixed and the f32 payload is serialized through
//! the kernel socket buffer — i.e. two copies plus syscalls per hop,
//! which is exactly the overhead the shared-memory plane avoids.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::time::{Duration, Instant};

/// Failure of a deadline-bounded receive ([`SocketChannel::recv_deadline`]).
///
/// `TimedOut` is a *typed* variant — callers (the engine's worker-pool
/// supervisor, the failover machinery) branch on it to declare a peer
/// stalled, which an opaque `io::Error` string would make fragile.
#[derive(Debug)]
pub enum SocketError {
    /// The peer produced no complete frame within the deadline. A frame
    /// half-received when the deadline expires also lands here: a peer
    /// that wedges mid-payload is exactly as stalled as one that never
    /// wrote, and the caller's recovery (declare it dead, fail over) is
    /// the same.
    TimedOut {
        /// How long the receive actually waited.
        waited: Duration,
    },
    /// Any other I/O failure (peer closed, kernel error).
    Io(std::io::Error),
}

impl std::fmt::Display for SocketError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SocketError::TimedOut { waited } => {
                write!(f, "socket receive timed out after {waited:?}")
            }
            SocketError::Io(e) => write!(f, "socket i/o error: {e}"),
        }
    }
}

impl std::error::Error for SocketError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SocketError::TimedOut { .. } => None,
            SocketError::Io(e) => Some(e),
        }
    }
}

/// One end of a framed f32 message channel over a Unix socket pair.
pub struct SocketChannel {
    stream: UnixStream,
}

impl SocketChannel {
    /// Create a connected pair (base-process end, worker end).
    pub fn pair() -> std::io::Result<(SocketChannel, SocketChannel)> {
        let (a, b) = UnixStream::pair()?;
        Ok((SocketChannel { stream: a }, SocketChannel { stream: b }))
    }

    /// Send one framed message: u32 length (f32 count) + payload bytes.
    pub fn send(&mut self, payload: &[f32]) -> std::io::Result<()> {
        let len = payload.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        // Serialize: this byte-copy is the cost sockets pay and shm avoids.
        let bytes: Vec<u8> = payload.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.stream.write_all(&bytes)
    }

    /// Receive one framed message into `out`.
    pub fn recv(&mut self, out: &mut Vec<f32>) -> std::io::Result<()> {
        let mut len_buf = [0u8; 4];
        self.stream.read_exact(&mut len_buf)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        let mut bytes = vec![0u8; len * 4];
        self.stream.read_exact(&mut bytes)?;
        out.clear();
        out.reserve(len);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(())
    }

    /// Receive one framed message, giving up after `deadline` — the
    /// caller supplies the budget (derived from its SLO or retry
    /// policy), the channel enforces it. On [`SocketError::TimedOut`]
    /// the channel stays usable: blocking mode is restored and a frame
    /// the peer sends later is received normally (any half-read frame
    /// bytes are consumed by the failed call, so only use the channel
    /// again if the protocol re-synchronizes — in practice a stalled
    /// worker is torn down, which is the point of the typed error).
    pub fn recv_deadline(
        &mut self,
        out: &mut Vec<f32>,
        deadline: Duration,
    ) -> Result<(), SocketError> {
        let start = Instant::now();
        let res = self.recv_deadline_inner(out, start, deadline);
        // Restore blocking mode whatever happened, so plain `recv` on
        // this channel keeps its blocking contract.
        let _ = self.stream.set_read_timeout(None);
        res
    }

    fn recv_deadline_inner(
        &mut self,
        out: &mut Vec<f32>,
        start: Instant,
        deadline: Duration,
    ) -> Result<(), SocketError> {
        let mut len_buf = [0u8; 4];
        self.read_exact_by(&mut len_buf, start, deadline)?;
        let len = u32::from_le_bytes(len_buf) as usize;
        let mut bytes = vec![0u8; len * 4];
        self.read_exact_by(&mut bytes, start, deadline)?;
        out.clear();
        out.reserve(len);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(())
    }

    /// `read_exact` against an absolute deadline: each kernel wait gets
    /// the *remaining* budget (a peer trickling bytes can't reset the
    /// clock by staying barely alive), and short reads accumulate until
    /// the buffer fills or time runs out.
    fn read_exact_by(
        &mut self,
        buf: &mut [u8],
        start: Instant,
        deadline: Duration,
    ) -> Result<(), SocketError> {
        let mut filled = 0;
        while filled < buf.len() {
            let left = deadline
                .checked_sub(start.elapsed())
                .filter(|d| !d.is_zero())
                .ok_or(SocketError::TimedOut {
                    waited: start.elapsed(),
                })?;
            self.stream
                .set_read_timeout(Some(left))
                .map_err(SocketError::Io)?;
            match self.stream.read(&mut buf[filled..]) {
                Ok(0) => {
                    return Err(SocketError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "peer closed mid-frame",
                    )))
                }
                Ok(n) => filled += n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Err(SocketError::TimedOut {
                        waited: start.elapsed(),
                    })
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(SocketError::Io(e)),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[1.0, -2.5, 3.25]).unwrap();
        let mut got = Vec::new();
        b.recv(&mut got).unwrap();
        assert_eq!(got, vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn echo_across_threads() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        let h = std::thread::spawn(move || {
            let mut buf = Vec::new();
            for _ in 0..100 {
                b.recv(&mut buf).unwrap();
                let doubled: Vec<f32> = buf.iter().map(|v| v * 2.0).collect();
                b.send(&doubled).unwrap();
            }
        });
        let mut resp = Vec::new();
        for i in 0..100 {
            a.send(&[i as f32; 16]).unwrap();
            a.recv(&mut resp).unwrap();
            assert!(resp.iter().all(|&v| v == i as f32 * 2.0));
        }
        h.join().unwrap();
    }

    #[test]
    fn empty_message_ok() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[]).unwrap();
        let mut got = vec![1.0];
        b.recv(&mut got).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn recv_deadline_times_out_on_a_stalled_peer() {
        let (_a, mut b) = SocketChannel::pair().unwrap();
        let mut got = Vec::new();
        let start = std::time::Instant::now();
        match b.recv_deadline(&mut got, Duration::from_millis(30)) {
            Err(SocketError::TimedOut { waited }) => {
                // ≥ the deadline minus kernel timer granularity.
                assert!(waited >= Duration::from_millis(20), "{waited:?}");
            }
            other => panic!("expected TimedOut, got {other:?}"),
        }
        // The wait is bounded by the deadline, not the peer's mood.
        assert!(start.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn recv_deadline_times_out_mid_frame() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        // Peer writes the length header and half the payload, then
        // wedges — the budget covers the whole frame, so this is a
        // timeout, not a success with a short buffer.
        a.stream.write_all(&4u32.to_le_bytes()).unwrap();
        a.stream.write_all(&[0u8; 8]).unwrap();
        let mut got = Vec::new();
        assert!(matches!(
            b.recv_deadline(&mut got, Duration::from_millis(30)),
            Err(SocketError::TimedOut { .. })
        ));
    }

    #[test]
    fn recv_deadline_receives_a_prompt_frame_and_restores_blocking() {
        let (mut a, mut b) = SocketChannel::pair().unwrap();
        a.send(&[1.0, 2.0]).unwrap();
        let mut got = Vec::new();
        b.recv_deadline(&mut got, Duration::from_secs(5)).unwrap();
        assert_eq!(got, vec![1.0, 2.0]);
        // The channel stays usable with the blocking API afterwards.
        a.send(&[3.0]).unwrap();
        b.recv(&mut got).unwrap();
        assert_eq!(got, vec![3.0]);
    }

    #[test]
    fn recv_deadline_reports_peer_close_as_io_not_timeout() {
        let (a, mut b) = SocketChannel::pair().unwrap();
        drop(a);
        let mut got = Vec::new();
        match b.recv_deadline(&mut got, Duration::from_secs(5)) {
            Err(SocketError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
            }
            other => panic!("expected Io(UnexpectedEof), got {other:?}"),
        }
    }
}
