//! Shared-memory data plane (paper §4.2 "Shared memory data transfer").
//!
//! A [`ShmRegion`] is a real `mmap(MAP_SHARED | MAP_ANONYMOUS)` mapping —
//! visible across `fork()`, i.e. genuinely usable by the paper's isolated
//! CPU-LoRA *processes*; in this repo the workers are threads (1-core
//! testbed) but the data plane makes no such assumption.
//!
//! The region is carved into [`SlotChannel`]s: single-producer/
//! single-consumer f32 slots with a doorbell pair. The base process
//! writes the input activation x into the request slot and rings the
//! request bell; the worker computes xAB into the response slot and
//! rings the response bell. No serialization, no copies beyond the
//! activation itself — the property Fig 17 measures against sockets.

use std::sync::atomic::{AtomicU32, Ordering};

use super::signal::Doorbell;

/// Error type for shm operations.
#[derive(Debug)]
pub enum ShmError {
    Mmap(std::io::Error),
    TooSmall { need: usize, have: usize },
}

impl std::fmt::Display for ShmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShmError::Mmap(e) => write!(f, "mmap failed: {e}"),
            ShmError::TooSmall { need, have } => {
                write!(f, "region too small: need {need} bytes, have {have}")
            }
        }
    }
}

impl std::error::Error for ShmError {}

/// A shared anonymous mapping. Dropped ⇒ unmapped.
pub struct ShmRegion {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the region is plain `mmap`ed memory with no thread affinity;
// synchronization of the *contents* is the user's business
// (SlotChannel provides it via atomics with acquire/release pairs).
unsafe impl Send for ShmRegion {}
// SAFETY: `&ShmRegion` only exposes the base pointer and length;
// concurrent readers of those immutable fields are safe.
unsafe impl Sync for ShmRegion {}

impl ShmRegion {
    /// Map `len` bytes of MAP_SHARED|MAP_ANONYMOUS memory, zeroed.
    pub fn new(len: usize) -> Result<ShmRegion, ShmError> {
        // SAFETY: anonymous mapping (no fd, offset 0); the kernel picks
        // the address (null hint) and zeroes the pages. The only error
        // surface is the MAP_FAILED return, checked below.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED | libc::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(ShmError::Mmap(std::io::Error::last_os_error()));
        }
        Ok(ShmRegion {
            ptr: ptr as *mut u8,
            len,
        })
    }

    /// Region length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if zero-length.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Raw base pointer.
    pub fn as_ptr(&self) -> *mut u8 {
        self.ptr
    }
}

impl Drop for ShmRegion {
    fn drop(&mut self) {
        // SAFETY: `ptr`/`len` are exactly the successful mmap's return
        // and request; the mapping is unmapped once (Drop runs once).
        unsafe {
            libc::munmap(self.ptr as *mut libc::c_void, self.len);
        }
    }
}

/// Header of one SPSC slot, laid out at the front of its shm segment.
///
/// Each direction owns its own length word: a request published while
/// the previous response is still being read (or a shutdown poison
/// message racing an in-flight job) must not clobber the other
/// direction's length. A single shared `len` did exactly that.
#[repr(C)]
struct SlotHeader {
    /// Payload length (f32s) of the current *request* message.
    req_len: AtomicU32,
    /// Payload length (f32s) of the current *response* message.
    resp_len: AtomicU32,
    /// Producer→consumer doorbell.
    req: Doorbell,
    /// Consumer→producer doorbell.
    resp: Doorbell,
}

/// A single-producer single-consumer f32 message slot inside a
/// [`ShmRegion`]: one in-flight request + one in-flight response
/// (exactly the per-layer LoRA exchange pattern: x in, xAB out).
pub struct SlotChannel {
    header: *mut SlotHeader,
    req_buf: *mut f32,
    resp_buf: *mut f32,
    capacity: usize,
}

// SAFETY: the raw pointers target the owning ShmRegion's mapping,
// which outlives the channel by construction at every use site (the
// pool keeps the region alive); moving the channel moves only the
// pointers.
unsafe impl Send for SlotChannel {}
// SAFETY: shared access is the point — one producer and one consumer
// thread. The header fields are atomics, and buffer reads/writes are
// ordered by the doorbell acquire/release protocol (see send/recv).
unsafe impl Sync for SlotChannel {}

impl SlotChannel {
    /// Bytes needed for one slot with `capacity` f32s each way.
    pub fn bytes_needed(capacity: usize) -> usize {
        std::mem::size_of::<SlotHeader>() + 2 * capacity * 4
    }

    /// Carve a slot out of `region` at byte offset `offset`.
    ///
    /// # Safety contract (checked)
    /// The range must lie inside the region; alignment of the region base
    /// (page-aligned) plus 4-byte multiples keeps atomics aligned.
    pub fn at(
        region: &ShmRegion,
        offset: usize,
        capacity: usize,
    ) -> Result<SlotChannel, ShmError> {
        let need = offset + Self::bytes_needed(capacity);
        if need > region.len() {
            return Err(ShmError::TooSmall {
                need,
                have: region.len(),
            });
        }
        assert_eq!(offset % 8, 0, "slot offset must be 8-byte aligned");
        // SAFETY: the bounds check above guarantees header + both
        // buffers lie inside the region; the page-aligned base plus the
        // 8-byte-aligned offset keeps the AtomicU32 header fields and
        // f32 buffers aligned.
        unsafe {
            let base = region.as_ptr().add(offset);
            let header = base as *mut SlotHeader;
            let req_buf = base.add(std::mem::size_of::<SlotHeader>()) as *mut f32;
            let resp_buf = req_buf.add(capacity);
            Ok(SlotChannel {
                header,
                req_buf,
                resp_buf,
                capacity,
            })
        }
    }

    /// Capacity in f32s per direction.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn header(&self) -> &SlotHeader {
        // SAFETY: `header` points into the live region (see `at`), is
        // properly aligned, and SlotHeader is all atomics — shared
        // references from both sides are sound.
        unsafe { &*self.header }
    }

    /// Producer: publish a request payload and ring the request bell.
    /// Returns the doorbell sequence to pass to [`Self::recv_response`].
    pub fn send_request(&self, payload: &[f32]) -> u32 {
        assert!(payload.len() <= self.capacity, "payload exceeds slot");
        // SAFETY: `payload.len() <= capacity` (asserted) keeps the copy
        // inside the request buffer; SPSC discipline means no concurrent
        // writer, and the consumer only reads after the release-store +
        // ring below publish the bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(payload.as_ptr(), self.req_buf, payload.len());
        }
        self.header()
            .req_len
            .store(payload.len() as u32, Ordering::Release);
        let resp_seen = self.header().resp.load();
        self.header().req.ring();
        resp_seen
    }

    /// Consumer: wait for a request past `seen`, copy it out.
    /// Returns the new doorbell sequence.
    pub fn recv_request(&self, seen: u32, out: &mut Vec<f32>) -> u32 {
        let new_seen = self.header().req.wait_past(seen);
        // Clamp defensively: a corrupted length must never read past the
        // slot (the consumer validates semantics on top of this).
        let len =
            (self.header().req_len.load(Ordering::Acquire) as usize).min(self.capacity);
        out.clear();
        out.reserve(len);
        // SAFETY: `len` is clamped to capacity, so the slice stays in
        // the request buffer; the doorbell wait above acquire-pairs with
        // the producer's release ring, making the payload bytes visible.
        unsafe {
            let src = std::slice::from_raw_parts(self.req_buf, len);
            out.extend_from_slice(src);
        }
        new_seen
    }

    /// Consumer: publish the response and ring the response bell.
    pub fn send_response(&self, payload: &[f32]) {
        assert!(payload.len() <= self.capacity, "payload exceeds slot");
        // SAFETY: same argument as `send_request`, response direction:
        // length-checked copy into the response buffer, published to the
        // single reader by the release-store + ring below.
        unsafe {
            std::ptr::copy_nonoverlapping(
                payload.as_ptr(),
                self.resp_buf,
                payload.len(),
            );
        }
        self.header()
            .resp_len
            .store(payload.len() as u32, Ordering::Release);
        self.header().resp.ring();
    }

    /// Producer: wait for the response rung after `resp_seen` and copy it
    /// into `out` (resized to the message length).
    pub fn recv_response(&self, resp_seen: u32, out: &mut Vec<f32>) {
        self.header().resp.wait_past(resp_seen);
        let len =
            (self.header().resp_len.load(Ordering::Acquire) as usize).min(self.capacity);
        out.clear();
        // SAFETY: same argument as `recv_request`, response direction:
        // clamped length, and the doorbell wait acquire-pairs with the
        // consumer's release ring before the bytes are read.
        unsafe {
            let src = std::slice::from_raw_parts(self.resp_buf, len);
            out.extend_from_slice(src);
        }
    }

    /// Current request doorbell sequence (consumer bootstrap).
    pub fn request_seq(&self) -> u32 {
        self.header().req.load()
    }

    /// Current response doorbell sequence — how many responses the
    /// consumer has published. Lets a pool owner drain in-flight work
    /// (wait until responses catch up with submissions) before tearing
    /// a slot down.
    pub fn response_seq(&self) -> u32 {
        self.header().resp.load()
    }
}

/// Convenience: allocate a region holding `n` slots of `capacity` f32s
/// and return the region with its carved channels.
pub fn slot_channels(
    n: usize,
    capacity: usize,
) -> Result<(ShmRegion, Vec<SlotChannel>), ShmError> {
    // 8-byte align each slot.
    let stride = (SlotChannel::bytes_needed(capacity) + 7) & !7;
    let region = ShmRegion::new(stride * n.max(1))?;
    let mut slots = Vec::with_capacity(n);
    for i in 0..n {
        slots.push(SlotChannel::at(&region, i * stride, capacity)?);
    }
    Ok((region, slots))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn region_maps_and_zeroes() {
        let r = ShmRegion::new(4096).unwrap();
        assert_eq!(r.len(), 4096);
        // SAFETY: reading the freshly mapped region within its length.
        let s = unsafe { std::slice::from_raw_parts(r.as_ptr(), 4096) };
        assert!(s.iter().all(|&b| b == 0));
    }

    #[test]
    fn roundtrip_single_thread() {
        let (_region, slots) = slot_channels(1, 64).unwrap();
        let ch = &slots[0];
        let resp_seen = ch.send_request(&[1.0, 2.0, 3.0]);
        let mut got = Vec::new();
        let _ = ch.recv_request(0, &mut got);
        assert_eq!(got, vec![1.0, 2.0, 3.0]);
        ch.send_response(&[9.0, 8.0]);
        let mut resp = Vec::new();
        ch.recv_response(resp_seen, &mut resp);
        assert_eq!(resp, vec![9.0, 8.0]);
    }

    #[test]
    fn roundtrip_across_threads_many_messages() {
        let (region, mut slots) = slot_channels(1, 256).unwrap();
        let region = Arc::new(region);
        let ch = Arc::new(slots.remove(0));
        let ch2 = ch.clone();
        let _keep = region.clone();
        let rounds = 500usize;
        let worker = std::thread::spawn(move || {
            // Start from 0 (fresh region): reading request_seq() here
            // would race with an early send_request from the main thread.
            let mut seen = 0u32;
            let mut buf = Vec::new();
            for _ in 0..rounds {
                seen = ch2.recv_request(seen, &mut buf);
                // Echo doubled.
                let doubled: Vec<f32> = buf.iter().map(|v| v * 2.0).collect();
                ch2.send_response(&doubled);
            }
        });
        let mut resp = Vec::new();
        for i in 0..rounds {
            let payload: Vec<f32> = (0..16).map(|k| (i * 16 + k) as f32).collect();
            let resp_seen = ch.send_request(&payload);
            ch.recv_response(resp_seen, &mut resp);
            assert_eq!(resp.len(), 16);
            for (k, v) in resp.iter().enumerate() {
                assert_eq!(*v, (i * 16 + k) as f32 * 2.0);
            }
        }
        worker.join().unwrap();
    }

    #[test]
    fn multiple_slots_are_independent() {
        let (_region, slots) = slot_channels(4, 8).unwrap();
        for (i, ch) in slots.iter().enumerate() {
            ch.send_request(&[i as f32]);
        }
        for (i, ch) in slots.iter().enumerate() {
            let mut got = Vec::new();
            ch.recv_request(0, &mut got);
            assert_eq!(got, vec![i as f32]);
        }
    }

    #[test]
    fn capacity_checked() {
        let (_region, slots) = slot_channels(1, 2).unwrap();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            slots[0].send_request(&[0.0; 3]);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn too_small_region_rejected() {
        let r = ShmRegion::new(16).unwrap();
        assert!(SlotChannel::at(&r, 0, 1024).is_err());
    }

    /// Regression for the shared-`len` race: requests and responses of
    /// *different* lengths must never clobber each other's length word.
    /// Each round sends a request of one length and expects a response of
    /// an unrelated length, over many threads' worth of rounds.
    #[test]
    fn asymmetric_lengths_survive_sustained_ping_pong() {
        let capacity = 128usize;
        let (region, mut slots) = slot_channels(1, capacity).unwrap();
        let region = Arc::new(region);
        let ch = Arc::new(slots.remove(0));
        let (ch2, keep) = (ch.clone(), region.clone());
        let rounds = 3_000usize;
        let worker = std::thread::spawn(move || {
            let _k = keep;
            let mut seen = 0u32;
            let mut buf = Vec::new();
            for _ in 0..rounds {
                seen = ch2.recv_request(seen, &mut buf);
                // Respond with a *different* length: the request length
                // encoded as a run of its own value.
                let n = buf.len();
                let resp_len = (n * 7 + 3) % 128 + 1;
                let resp: Vec<f32> = vec![n as f32; resp_len];
                ch2.send_response(&resp);
            }
        });
        let mut resp = Vec::new();
        let mut rng = crate::util::rng::Rng::new(42);
        for i in 0..rounds {
            let n = rng.range(1, capacity + 1);
            let payload: Vec<f32> = vec![0.25; n];
            let token = ch.send_request(&payload);
            ch.recv_response(token, &mut resp);
            let want_len = (n * 7 + 3) % 128 + 1;
            assert_eq!(resp.len(), want_len, "round {i}: resp length clobbered");
            assert!(
                resp.iter().all(|&v| v == n as f32),
                "round {i}: resp content clobbered"
            );
        }
        worker.join().unwrap();
    }

    /// The two length words are genuinely independent: publishing a new
    /// request must leave a still-unread response intact.
    #[test]
    fn request_publish_does_not_clobber_pending_response() {
        let (_region, slots) = slot_channels(1, 64).unwrap();
        let ch = &slots[0];
        // Round 1: request → response (left unread for now).
        let token = ch.send_request(&[1.0, 2.0]);
        let mut got = Vec::new();
        ch.recv_request(0, &mut got);
        ch.send_response(&[7.0, 8.0, 9.0]);
        // Producer publishes the *next* request before reading the
        // response (the overlap the old shared `len` corrupted).
        ch.send_request(&[5.0; 17]);
        let mut resp = Vec::new();
        ch.recv_response(token, &mut resp);
        assert_eq!(resp, vec![7.0, 8.0, 9.0]);
    }
}
