//! [`SimFront`]: the discrete-event simulator behind the streaming
//! [`ServingFront`] surface.
//!
//! Wraps one [`SimInstance`] with the same request-lifecycle API the
//! PJRT engine exposes: `submit` returns a [`RequestHandle`], `poll`
//! advances one simulated iteration and translates its
//! [`IterOutcome`] into per-request events, cancellation and stop
//! tokens are honored at iteration boundaries, and `stats` produces the
//! scheduler's [`ServerStats`] view. This lets schedulers, drivers, and
//! the lifecycle test-suite run identical code against the simulator
//! and the real engine.
//!
//! The simulator models latency, not content, so the token *values* are
//! synthesized deterministically: request `r`'s `n`-th output token is
//! `n` (0, 1, 2, …). A stop token `k` therefore terminates a stream
//! after `k + 1` tokens — enough to exercise the stop-token lifecycle
//! path end to end.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::instance::{IterOutcome, SimInstance, SimReq};
use super::workload::WorkloadRequest;
use crate::scheduler::registry::{AdapterMeta, GlobalRegistry};
use crate::scheduler::ServerStats;
use crate::server::api::{
    EventChannel, FinishReason, Priority, RejectReason, RequestEvent, RequestHandle,
    SamplingParams, ServeRequest, ServingFront, SloSpec,
};

/// Book-keeping for one live simulated request.
struct LiveReq {
    channel: Arc<Mutex<EventChannel>>,
    sampling: SamplingParams,
    priority: Priority,
    slo: Option<SloSpec>,
    /// Tokens emitted so far (also the value of the next token).
    emitted: usize,
}

/// A simulated inference server exposing the [`ServingFront`] API.
pub struct SimFront {
    inst: SimInstance,
    /// Adapter metadata (rank) — requests against unregistered adapters
    /// are rejected, mirroring the engine's installed-adapter check.
    registry: GlobalRegistry,
    /// Simulated clock (seconds).
    clock: f64,
    next_id: u64,
    live: HashMap<u64, LiveReq>,
    /// Largest prompt accepted (mirrors the engine's bucket bound).
    max_prompt: usize,
    /// Per-request token capacity (mirrors the engine's KV bound
    /// `prompt + output ≤ capacity + 1`); unbounded by default.
    kv_capacity: usize,
    /// Event-buffer overflows from retired requests (mirrors the
    /// engine's monotone `event_overflows` accounting).
    retired_overflows: usize,
}

impl SimFront {
    /// Wrap an instance. `max_prompt` bounds accepted prompt lengths.
    pub fn new(inst: SimInstance, max_prompt: usize) -> SimFront {
        SimFront {
            inst,
            registry: GlobalRegistry::new(),
            clock: 0.0,
            next_id: 0,
            live: HashMap::new(),
            max_prompt,
            kv_capacity: usize::MAX,
            retired_overflows: 0,
        }
    }

    /// Mirror the engine's per-request KV bound: requests with
    /// `prompt + max_new_tokens > capacity + 1` are rejected, so drivers
    /// tuned against the simulator see the engine's admission behavior.
    pub fn with_kv_capacity(mut self, capacity: usize) -> SimFront {
        self.kv_capacity = capacity;
        self
    }

    /// Register an adapter (id + rank) so requests against it are
    /// admitted — the simulator's convenience form of the trait-level
    /// [`ServingFront::install_adapter`] (no weights to install).
    pub fn register_adapter(&mut self, id: u64, rank: usize) {
        self.registry.register(AdapterMeta {
            id,
            rank,
            base_model: self.inst.model.cfg.name.clone(),
            weights_path: String::new(),
        });
    }

    /// The simulated clock (seconds since construction).
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// The wrapped instance (completed `SimReq`s, iteration log, …).
    pub fn instance(&self) -> &SimInstance {
        &self.inst
    }

    fn validate(&self, req: &ServeRequest) -> Result<usize, RejectReason> {
        crate::server::api::validate_shape(req, self.max_prompt, self.kv_capacity)?;
        self.registry.rank_of(req.adapter).ok_or(
            RejectReason::AdapterNotInstalled {
                adapter: req.adapter,
            },
        )
    }

    fn emit(&self, id: u64, event: RequestEvent) {
        if let Some(req) = self.live.get(&id) {
            req.channel.lock().unwrap().push(event);
        }
    }

    /// Honor pending cancellations at the iteration boundary: remove the
    /// request from the instance's queue or running batch and emit the
    /// terminal `Cancelled` event.
    fn reap_cancelled(&mut self) {
        let cancelled: Vec<u64> = self
            .live
            .iter()
            .filter(|(_, r)| {
                let c = r.channel.lock().unwrap();
                c.cancel_requested() && !c.is_terminal()
            })
            .map(|(&id, _)| id)
            .collect();
        for id in cancelled {
            let in_queue = self.inst.queue.iter().position(|r| r.req.id == id);
            if let Some(pos) = in_queue {
                let _ = self.inst.queue.remove(pos);
            } else if let Some(pos) = self.inst.running.iter().position(|r| r.req.id == id) {
                self.inst.running.remove(pos);
            } else {
                continue; // mid-iteration; retry at the next boundary
            }
            self.emit(id, RequestEvent::Cancelled);
            self.retire(id);
        }
    }

    /// Drop a terminal request, folding its event-buffer overflow count
    /// into the front's running total.
    fn retire(&mut self, id: u64) {
        if let Some(req) = self.live.remove(&id) {
            self.retired_overflows += req.channel.lock().unwrap().overflows();
        }
    }

    /// Translate one iteration's outcome into request events, applying
    /// stop tokens.
    fn apply_outcome(&mut self, outcome: IterOutcome) {
        let now = self.clock;
        for &id in &outcome.emitted {
            let Some(req) = self.live.get_mut(&id) else {
                continue;
            };
            let token = req.emitted as i32;
            req.emitted += 1;
            let first = outcome.first_tokens.contains(&id);
            let stop = req.sampling.stop_tokens.contains(&token);
            let budget_done = outcome.finished.contains(&id);
            {
                let mut chan = req.channel.lock().unwrap();
                chan.push(if first {
                    RequestEvent::FirstToken(token)
                } else {
                    RequestEvent::Token(token)
                });
                if stop || budget_done {
                    chan.push(RequestEvent::Finished(if stop {
                        FinishReason::Stop
                    } else {
                        FinishReason::Length
                    }));
                }
            }
            if stop && !budget_done {
                // Terminated ahead of budget: retire from the running
                // batch and stamp completion for the instance's records.
                if let Some(pos) = self.inst.running.iter().position(|r| r.req.id == id) {
                    let mut sr = self.inst.running.remove(pos);
                    sr.finish = Some(now);
                    self.inst.done.push(sr);
                }
            }
            if stop || budget_done {
                self.retire(id);
            }
        }
    }
}

impl ServingFront for SimFront {
    fn submit(&mut self, req: ServeRequest) -> RequestHandle {
        let id = self.next_id;
        self.next_id += 1;
        let (handle, channel) = RequestHandle::new(id);
        let rank = match self.validate(&req) {
            Ok(rank) => rank,
            Err(reason) => {
                channel.lock().unwrap().push(RequestEvent::Rejected(reason));
                return handle;
            }
        };
        channel.lock().unwrap().push(RequestEvent::Admitted);
        // A failover resubmission resumes mid-stream: the rebuilt
        // context (prompt + replayed tokens minus the next decode
        // input) is re-prefilled, only the *remaining* budget is
        // decoded, and the synthesized token counter starts where the
        // dead backend stopped — so the deterministic 0,1,2,… stream
        // continues bitwise across the failover.
        let replayed = req.resume.as_ref().map_or(0, |rs| rs.tokens.len());
        // Priority insertion via the same helper as the engine's batcher
        // (unknown ids — never live here — rank highest, i.e. stay put).
        let pos = crate::server::api::priority_insert_pos(
            self.inst.queue.iter().map(|q| {
                self.live
                    .get(&q.req.id)
                    .map_or(Priority::Interactive, |l| l.priority)
            }),
            req.priority,
        );
        self.inst.queue.insert(
            pos,
            SimReq::new(WorkloadRequest {
                id,
                arrival: self.clock,
                adapter: req.adapter,
                rank,
                prompt_len: req.prompt.len() + replayed.saturating_sub(1),
                output_len: req.sampling.max_new_tokens.saturating_sub(replayed).max(1),
            }),
        );
        self.live.insert(
            id,
            LiveReq {
                channel,
                sampling: req.sampling,
                priority: req.priority,
                slo: req.slo,
                emitted: replayed,
            },
        );
        handle
    }

    fn poll(&mut self) -> anyhow::Result<bool> {
        self.reap_cancelled();
        if !self.inst.has_work() {
            return Ok(false);
        }
        let duration = self.inst.start_iteration(self.clock);
        self.clock += duration;
        let outcome = self.inst.finish_iteration(self.clock);
        self.apply_outcome(outcome);
        Ok(true)
    }

    fn cancel(&mut self, id: u64) -> bool {
        match self.live.get(&id) {
            Some(req) => req.channel.lock().unwrap().try_request_cancel(),
            None => false,
        }
    }

    /// Register the adapter's metadata (the simulator models latency,
    /// not weights) so requests against it are admitted.
    fn install_adapter(&mut self, spec: &crate::model::LoraSpec) -> anyhow::Result<()> {
        self.register_adapter(spec.id, spec.rank);
        Ok(())
    }

    /// Drop the adapter's registration. Refuses while simulated requests
    /// on it are queued or running, mirroring the engine's uninstall
    /// guard so coordinator logic tested on the simulator transfers.
    fn uninstall_adapter(&mut self, adapter: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.registry.rank_of(adapter).is_some(),
            "adapter {adapter} not installed"
        );
        let queued = self.inst.queue.iter();
        let running = self.inst.running.iter();
        let busy = queued.chain(running).filter(|r| r.req.adapter == adapter).count();
        anyhow::ensure!(busy == 0, "adapter {adapter} busy: {busy} in-flight requests");
        self.registry.unregister(adapter);
        // Mirror the engine's slot eviction: a later re-install must
        // cold-start again, not inherit stale residency.
        self.inst.cache.remove(adapter);
        Ok(())
    }

    /// Insert the adapter into the simulated device cache so its first
    /// request admits warm (zero modeled cold-start exposure).
    fn prewarm_adapter(&mut self, adapter: u64) -> anyhow::Result<bool> {
        anyhow::ensure!(
            self.registry.rank_of(adapter).is_some(),
            "adapter {adapter} not installed"
        );
        self.inst.cache.insert(adapter);
        Ok(true)
    }

    fn stats(&self) -> ServerStats {
        ServerStats {
            running_ranks: self.inst.running_ranks(),
            queued_ranks: self.inst.queued_ranks(),
            // Real eligibility data: the registered adapter set and the
            // prompt bound this front actually enforces at submit.
            adapters: crate::scheduler::AdapterSet::only(self.registry.ids()),
            max_prompt_tokens: self.max_prompt,
            tpot_slo: crate::server::api::tightest_tpot_slo(
                self.live.values().map(|r| &r.slo),
            ),
            event_overflows: self.retired_overflows
                + self
                    .live
                    .values()
                    .map(|r| r.channel.lock().unwrap().overflows())
                    .sum::<usize>(),
            ..Default::default()
        }
    }

    /// Cold-start counters in the engine's
    /// [`crate::server::metrics::ColdStartStats`] shape, so drivers read
    /// the same surface from simulator and engine (contract
    /// compatibility). A request counts cold when its serving exposed
    /// any cold-start time; under `ServingMode::CaraServe` cold admits
    /// are CPU-assisted by construction (the simulator's
    /// `overlapped_prefill` models exactly that path). Handoffs and
    /// collision deferrals are engine-side mechanics the event simulator
    /// doesn't model; they stay zero here.
    fn cold_start_stats(&self) -> Option<crate::server::metrics::ColdStartStats> {
        let assisted = self.inst.mode == crate::sim::ServingMode::CaraServe;
        let mut stats = crate::server::metrics::ColdStartStats::default();
        for r in self.inst.done.iter().chain(self.inst.running.iter()) {
            if r.first_token.is_none() {
                continue; // not admitted yet
            }
            if r.cold_start > 0.0 {
                stats.cold_admits += 1;
                if assisted {
                    stats.cpu_assisted += 1;
                }
            } else {
                stats.warm_admits += 1;
            }
        }
        Some(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;
    use crate::server::api::{LifecycleState, Priority};
    use crate::sim::{GpuModel, ServingMode};

    fn front() -> SimFront {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::CaraServe, 32, 8, 64);
        let mut front = SimFront::new(inst, 512);
        for id in 0..8 {
            front.register_adapter(id, 64);
        }
        front
    }

    fn request(adapter: u64, prompt: usize, max_new: usize) -> ServeRequest {
        ServeRequest::new(adapter, vec![1; prompt]).max_new_tokens(max_new)
    }

    #[test]
    fn full_lifecycle_event_ordering() {
        let mut f = front();
        let h = f.submit(request(1, 32, 4));
        f.run_until_idle().unwrap();
        let events = h.drain_events();
        assert_eq!(events[0], RequestEvent::Admitted);
        assert_eq!(events[1], RequestEvent::FirstToken(0));
        assert_eq!(events[2], RequestEvent::Token(1));
        assert_eq!(events[3], RequestEvent::Token(2));
        assert_eq!(events[4], RequestEvent::Token(3));
        assert_eq!(events[5], RequestEvent::Finished(FinishReason::Length));
        assert_eq!(events.len(), 6);
        assert_eq!(h.tokens(), vec![0, 1, 2, 3]);
        assert_eq!(h.state(), LifecycleState::Finished);
    }

    #[test]
    fn unregistered_adapter_rejected() {
        let mut f = front();
        let h = f.submit(request(999, 16, 2));
        assert_eq!(h.state(), LifecycleState::Rejected);
        // No work admitted; polling stays idle.
        assert!(!f.poll().unwrap());
    }

    #[test]
    fn oversized_prompt_rejected() {
        let mut f = front();
        let h = f.submit(request(1, 513, 2));
        assert_eq!(h.state(), LifecycleState::Rejected);
        let h2 = f.submit(ServeRequest::new(1, vec![]));
        assert_eq!(h2.state(), LifecycleState::Rejected);
    }

    #[test]
    fn kv_capacity_bound_mirrors_engine() {
        let mut f = front().with_kv_capacity(128);
        // 32 + 97 = 129 > 128 + 1 → rejected, like the engine's bound.
        let h = f.submit(request(1, 32, 98));
        assert_eq!(h.state(), LifecycleState::Rejected);
        let h2 = f.submit(request(1, 32, 97));
        assert_eq!(h2.state(), LifecycleState::Queued);
        f.run_until_idle().unwrap();
        assert_eq!(h2.state(), LifecycleState::Finished);
    }

    #[test]
    fn cancel_while_queued_never_runs() {
        let mut f = front();
        let h = f.submit(request(1, 32, 8));
        assert!(f.cancel(h.id()));
        f.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Cancelled);
        assert!(h.tokens().is_empty());
        // Cancelling again (or an unknown id) reports dead.
        assert!(!f.cancel(h.id()));
        assert!(!f.cancel(12345));
    }

    #[test]
    fn cancel_mid_decode_stops_stream() {
        let mut f = front();
        let h = f.submit(request(1, 32, 50));
        // Prefill + a couple of decode steps.
        for _ in 0..3 {
            assert!(f.poll().unwrap());
        }
        assert_eq!(h.state(), LifecycleState::Running);
        assert!(f.cancel(h.id()));
        f.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Cancelled);
        let n = h.tokens().len();
        assert!((1..50).contains(&n), "tokens after cancel: {n}");
        let events = h.drain_events();
        assert_eq!(events.last(), Some(&RequestEvent::Cancelled));
        assert_eq!(
            events.iter().filter(|e| e.is_terminal()).count(),
            1,
            "exactly one terminal event"
        );
    }

    #[test]
    fn stop_token_terminates_early_with_stop_reason() {
        let mut f = front();
        // Synthesized stream is 0, 1, 2, …; stop at 2 → 3 tokens.
        let h = f.submit(request(1, 32, 50).stop_token(2));
        f.run_until_idle().unwrap();
        assert_eq!(h.tokens(), vec![0, 1, 2]);
        let events = h.drain_events();
        assert_eq!(
            events.last(),
            Some(&RequestEvent::Finished(FinishReason::Stop))
        );
    }

    #[test]
    fn stop_on_first_token_finishes_at_prefill() {
        let mut f = front();
        let h = f.submit(request(1, 32, 50).stop_token(0));
        f.run_until_idle().unwrap();
        assert_eq!(h.tokens(), vec![0]);
        assert_eq!(h.state(), LifecycleState::Finished);
    }

    #[test]
    fn resume_submission_continues_deterministic_stream() {
        use crate::server::api::ResumeState;
        let mut f = front();
        let mut req = request(1, 32, 8);
        req.resume = Some(ResumeState {
            tokens: vec![0, 1, 2],
        });
        let h = f.submit(req);
        f.run_until_idle().unwrap();
        // Tokens 0..=2 were already delivered by the previous backend;
        // only the continuation 3..=7 lands on this fresh handle.
        assert_eq!(h.tokens(), vec![3, 4, 5, 6, 7]);
        assert_eq!(h.state(), LifecycleState::Finished);
    }

    #[test]
    fn stats_reports_ranks_and_tightest_slo() {
        let mut f = front();
        f.register_adapter(7, 16);
        let _h1 = f.submit(request(1, 32, 8).slo(500.0, 80.0));
        let _h2 = f.submit(
            ServeRequest::new(7, vec![1; 16])
                .max_new_tokens(8)
                .priority(Priority::Interactive)
                .slo(200.0, 40.0),
        );
        let s = f.stats();
        assert_eq!(s.queued_ranks.len(), 2);
        assert!(s.queued_ranks.contains(&64) && s.queued_ranks.contains(&16));
        assert!(s.can_serve(7) && !s.can_serve(999));
        assert_eq!(s.max_prompt_tokens, 512);
        assert!((s.tpot_slo.unwrap() - 0.040).abs() < 1e-12);
        // After prefill both are running.
        f.poll().unwrap();
        let s = f.stats();
        assert_eq!(s.running_ranks.len(), 2);
        assert!(s.queued_ranks.is_empty());
    }

    #[test]
    fn cold_start_stats_mirror_engine_semantics() {
        // CaraServe mode: a fresh adapter's first request is a cold,
        // CPU-assisted admit; a repeat on the (now resident) adapter is
        // warm.
        let mut f = front();
        let h1 = f.submit(request(1, 32, 2));
        f.run_until_idle().unwrap();
        let h2 = f.submit(request(1, 32, 2));
        f.run_until_idle().unwrap();
        assert_eq!(h1.state(), LifecycleState::Finished);
        assert_eq!(h2.state(), LifecycleState::Finished);
        let s = f.cold_start_stats().unwrap();
        assert_eq!(s.cold_admits, 1);
        assert_eq!(s.cpu_assisted, 1);
        assert_eq!(s.warm_admits, 1);

        // Cached oracle: never cold, never assisted.
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let inst = SimInstance::new(0, model, ServingMode::Cached, 32, 8, 64);
        let mut oracle = SimFront::new(inst, 512);
        oracle.register_adapter(1, 64);
        oracle.submit(request(1, 32, 2));
        oracle.run_until_idle().unwrap();
        let s = oracle.cold_start_stats().unwrap();
        assert_eq!(s.cold_admits, 0);
        assert_eq!(s.cpu_assisted, 0);
        assert_eq!(s.warm_admits, 1);
    }

    #[test]
    fn runtime_install_uninstall_and_prewarm() {
        let mut f = front();
        // Trait-level install mirrors register_adapter.
        f.install_adapter(&crate::model::LoraSpec::standard(40, 16, "sim"))
            .unwrap();
        let h = f.submit(request(40, 16, 30));
        // Busy: uninstall refuses while the request is queued/running.
        assert!(f.uninstall_adapter(40).unwrap_err().to_string().contains("busy"));
        f.run_until_idle().unwrap();
        assert_eq!(h.state(), LifecycleState::Finished);
        f.uninstall_adapter(40).unwrap();
        assert_eq!(f.submit(request(40, 16, 2)).state(), LifecycleState::Rejected);
        assert!(f.uninstall_adapter(40).is_err());
        assert!(f.prewarm_adapter(40).is_err());
        // Uninstall evicted the device cache: a re-installed adapter
        // cold-starts again instead of inheriting stale residency.
        f.install_adapter(&crate::model::LoraSpec::standard(40, 16, "sim"))
            .unwrap();
        f.submit(request(40, 16, 2));
        f.run_until_idle().unwrap();
        assert_eq!(f.cold_start_stats().unwrap().cold_admits, 2);

        // Prewarm: the first request on a warmed adapter admits warm
        // (fresh front so the counter only sees this request).
        let mut w = front();
        assert!(w.prewarm_adapter(1).unwrap());
        w.submit(request(1, 16, 2));
        w.run_until_idle().unwrap();
        let s = w.cold_start_stats().unwrap();
        assert_eq!(s.cold_admits, 0, "{s:?}");
        assert_eq!(s.warm_admits, 1);
    }

    #[test]
    fn simulated_clock_advances_only_with_work() {
        let mut f = front();
        assert_eq!(f.clock(), 0.0);
        assert!(!f.poll().unwrap());
        assert_eq!(f.clock(), 0.0);
        let _h = f.submit(request(1, 64, 3));
        f.run_until_idle().unwrap();
        assert!(f.clock() > 0.0);
    }
}
