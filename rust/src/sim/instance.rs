//! A simulated LLM inference server with continuous batching (Fig 2)
//! and the four serving modes of §7.1: CACHED (oracle), ONDMD
//! (on-demand loading), S-LoRA (on-demand + MBGMV), and CARASERVE
//! (CPU-assisted overlap).

use std::collections::VecDeque;

use super::gpu::GpuModel;
use super::workload::WorkloadRequest;
use crate::model::LoraSpec;
use crate::perfmodel::KernelKind;

/// Serving backend mode (the baselines of §7.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingMode {
    /// All adapters pre-cached in unlimited GPU memory (upper bound).
    Cached,
    /// Load on demand; cold-start blocks prefill (Punica-style, BGMV).
    OnDemand,
    /// Load on demand with the MBGMV kernel (S-LoRA).
    SLora,
    /// CPU-assisted overlap of loading and prefill (this paper).
    CaraServe,
}

impl ServingMode {
    /// The GPU LoRA kernel each mode uses (§7.1: all baselines except
    /// S-LoRA use BGMV for a fair single-GPU comparison).
    pub fn kernel(&self) -> KernelKind {
        match self {
            ServingMode::SLora => KernelKind::Mbgmv,
            _ => KernelKind::Bgmv,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            ServingMode::Cached => "cached",
            ServingMode::OnDemand => "ondmd",
            ServingMode::SLora => "s-lora",
            ServingMode::CaraServe => "caraserve",
        }
    }
}

/// Per-request bookkeeping inside an instance.
#[derive(Debug, Clone)]
pub struct SimReq {
    pub req: WorkloadRequest,
    /// Context length so far (tokens in KV cache).
    pub ctx: usize,
    /// Output tokens generated so far.
    pub generated: usize,
    /// Time of first emitted token (set at prefill-iteration end).
    pub first_token: Option<f64>,
    /// Completion time.
    pub finish: Option<f64>,
    /// Cold-start seconds this request was exposed to.
    pub cold_start: f64,
    /// Per-token emission times (for time-per-token CDFs).
    pub token_times: Vec<f64>,
}

impl SimReq {
    pub(crate) fn new(req: WorkloadRequest) -> SimReq {
        SimReq {
            req,
            ctx: 0,
            generated: 0,
            first_token: None,
            finish: None,
            cold_start: 0.0,
            token_times: Vec::new(),
        }
    }
}

/// Device adapter cache with LRU eviction (capacity in adapter count;
/// the paper's systems bound adapter memory on the GPU).
///
/// Stamp-based LRU: `touch`/`contains` are O(1); the O(n) victim scan
/// only runs on a cold insert at capacity (was an O(n)-per-touch
/// VecDeque scan before the §Perf pass).
#[derive(Debug, Clone)]
pub struct AdapterCache {
    capacity: usize,
    clock: u64,
    /// adapter id → last-use stamp.
    stamps: std::collections::HashMap<u64, u64>,
}

impl AdapterCache {
    /// Cache holding up to `capacity` adapters (usize::MAX ⇒ unlimited).
    pub fn new(capacity: usize) -> AdapterCache {
        AdapterCache {
            capacity,
            clock: 0,
            stamps: std::collections::HashMap::new(),
        }
    }

    /// Is the adapter resident? (Non-mutating.)
    pub fn contains(&self, id: u64) -> bool {
        self.stamps.contains_key(&id)
    }

    /// Is the adapter resident? (Touches LRU position on hit.)
    pub fn touch(&mut self, id: u64) -> bool {
        self.clock += 1;
        let clock = self.clock;
        if let Some(stamp) = self.stamps.get_mut(&id) {
            *stamp = clock;
            true
        } else {
            false
        }
    }

    /// Insert after a load; evicts the least-recently used if full.
    pub fn insert(&mut self, id: u64) {
        if self.touch(id) {
            return;
        }
        if self.stamps.len() >= self.capacity {
            if let Some((&victim, _)) =
                self.stamps.iter().min_by_key(|&(_, &stamp)| stamp)
            {
                self.stamps.remove(&victim);
            }
        }
        self.clock += 1;
        self.stamps.insert(id, self.clock);
    }

    /// Evict an adapter (runtime uninstall). Returns true if it was
    /// resident.
    pub fn remove(&mut self, id: u64) -> bool {
        self.stamps.remove(&id).is_some()
    }

    /// Number of resident adapters.
    pub fn len(&self) -> usize {
        self.stamps.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.stamps.is_empty()
    }
}

/// One iteration's record (Fig 11's per-iteration latency data).
#[derive(Debug, Clone, Copy)]
pub struct IterRecord {
    pub is_prefill: bool,
    pub duration: f64,
}

/// Per-request outcomes of one completed iteration — what streaming
/// front-ends ([`crate::sim::front::SimFront`]) translate into
/// [`crate::server::RequestEvent`]s.
#[derive(Debug, Clone, Default)]
pub struct IterOutcome {
    /// Requests that emitted their *first* token this iteration.
    pub first_tokens: Vec<u64>,
    /// Requests that emitted a token this iteration (includes firsts).
    pub emitted: Vec<u64>,
    /// Requests that exhausted their output budget and completed.
    pub finished: Vec<u64>,
}

/// A simulated inference server.
pub struct SimInstance {
    pub id: usize,
    pub model: GpuModel,
    pub mode: ServingMode,
    /// Max requests in the running batch.
    pub max_batch: usize,
    /// Host cores available to CPU-LoRA (CaraServe mode).
    pub cpu_cores: usize,
    /// Device adapter cache.
    pub cache: AdapterCache,
    /// Queue of routed-but-not-prefilled requests.
    pub queue: VecDeque<SimReq>,
    /// Running (decoding) batch.
    pub running: Vec<SimReq>,
    /// Completed requests.
    pub done: Vec<SimReq>,
    /// Iteration log.
    pub iters: Vec<IterRecord>,
    /// Whether an iteration is in flight.
    pub busy: bool,
    /// Requests admitted by the in-flight prefill iteration.
    pending_prefill: Vec<SimReq>,
    /// Duration of the in-flight iteration.
    pending_duration: f64,
    /// Cold-start seconds the in-flight prefill iteration exposes to the
    /// *blocked* running requests (Fig 2: every arrival's adapter load
    /// delays all in-flight decoding — the cumulative effect Fig 3-Left
    /// measures).
    pending_cold_exposure: f64,
}

impl SimInstance {
    /// New instance in the given mode.
    pub fn new(
        id: usize,
        model: GpuModel,
        mode: ServingMode,
        max_batch: usize,
        cpu_cores: usize,
        cache_capacity: usize,
    ) -> SimInstance {
        let capacity = if mode == ServingMode::Cached {
            usize::MAX
        } else {
            cache_capacity
        };
        SimInstance {
            id,
            model,
            mode,
            max_batch,
            cpu_cores,
            cache: AdapterCache::new(capacity),
            queue: VecDeque::new(),
            running: Vec::new(),
            done: Vec::new(),
            iters: Vec::new(),
            busy: false,
            pending_prefill: Vec::new(),
            pending_duration: 0.0,
            pending_cold_exposure: 0.0,
        }
    }

    /// Enqueue an arrival (already routed to this instance).
    pub fn enqueue(&mut self, req: WorkloadRequest) {
        self.queue.push_back(SimReq::new(req));
    }

    /// Ranks of the running batch (scheduler stats).
    pub fn running_ranks(&self) -> Vec<usize> {
        self.running.iter().map(|r| r.req.rank).collect()
    }

    /// Ranks of the queued requests (scheduler stats).
    pub fn queued_ranks(&self) -> Vec<usize> {
        self.queue.iter().map(|r| r.req.rank).collect()
    }

    /// Is there work to start?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.running.is_empty()
    }

    /// Begin the next iteration at time `now`; returns its duration.
    /// New arrivals preempt decoding (Fig 2): if the queue is non-empty
    /// and the batch has room, a prefill iteration runs; otherwise a
    /// decode iteration.
    pub fn start_iteration(&mut self, now: f64) -> f64 {
        assert!(!self.busy, "iteration already in flight");
        assert!(self.has_work(), "no work");
        self.busy = true;
        if !self.queue.is_empty() && self.running.len() < self.max_batch {
            self.start_prefill(now)
        } else {
            self.start_decode()
        }
    }

    fn start_prefill(&mut self, _now: f64) -> f64 {
        let room = self.max_batch - self.running.len();
        let admit = room.min(self.queue.len());
        let mut duration = 0.0;
        let mut cold_exposure = 0.0;
        let mut pending: Vec<SimReq> = Vec::with_capacity(admit);
        // Count the cold admits first so CaraServe splits its host cores.
        let cold_admits = self
            .queue
            .iter()
            .take(admit)
            .filter(|r| {
                self.mode != ServingMode::Cached
                    && !self.cache.contains(r.req.adapter)
            })
            .count()
            .max(1);
        for _ in 0..admit {
            let mut sr = self.queue.pop_front().unwrap();
            let spec =
                LoraSpec::standard(sr.req.adapter, sr.req.rank, &self.model.cfg.name);
            let resident = self.cache.touch(sr.req.adapter);
            let load = if resident || self.mode == ServingMode::Cached {
                0.0
            } else {
                self.model.adapter_load(&spec)
            };
            let gpu_pre = self.model.prefill(sr.req.prompt_len);
            let (cost, cold) = match self.mode {
                ServingMode::Cached => (gpu_pre, 0.0),
                ServingMode::OnDemand | ServingMode::SLora => (load + gpu_pre, load),
                ServingMode::CaraServe => {
                    if load == 0.0 {
                        (gpu_pre, 0.0)
                    } else {
                        let cores = (self.cpu_cores / cold_admits).max(1);
                        self.model.overlapped_prefill(
                            sr.req.prompt_len,
                            sr.req.rank,
                            cores,
                            load,
                        )
                    }
                }
            };
            self.cache.insert(sr.req.adapter);
            sr.cold_start += cold;
            cold_exposure += cold;
            duration += cost;
            pending.push(sr);
        }
        // Stash admits; their state is applied at iteration end.
        self.pending_prefill = pending;
        self.pending_cold_exposure = cold_exposure;
        self.iters.push(IterRecord {
            is_prefill: true,
            duration,
        });
        self.pending_duration = duration;
        duration
    }

    fn start_decode(&mut self) -> f64 {
        let ctx: Vec<usize> = self.running.iter().map(|r| r.ctx).collect();
        let ranks = self.running_ranks();
        let duration = self.model.decode_iter(&ctx)
            + self
                .model
                .lora_decode_overhead(self.mode.kernel(), &ranks);
        self.iters.push(IterRecord {
            is_prefill: false,
            duration,
        });
        self.pending_duration = duration;
        duration
    }

    /// Complete the in-flight iteration at time `now` (= start + the
    /// duration returned by [`Self::start_iteration`]). Returns the
    /// per-request outcomes so streaming front-ends can emit events;
    /// batch drivers are free to ignore them.
    pub fn finish_iteration(&mut self, now: f64) -> IterOutcome {
        assert!(self.busy, "no iteration in flight");
        self.busy = false;
        let mut outcome = IterOutcome::default();
        if !self.pending_prefill.is_empty() {
            // The blocked in-flight requests absorbed this iteration's
            // cold-start time too (Fig 2's cumulative delay).
            for r in self.running.iter_mut() {
                r.cold_start += self.pending_cold_exposure;
            }
            self.pending_cold_exposure = 0.0;
            // Prefill completion: admitted requests emit their first token.
            for mut sr in std::mem::take(&mut self.pending_prefill) {
                sr.first_token = Some(now);
                sr.token_times.push(now);
                sr.ctx = sr.req.prompt_len;
                sr.generated = 1;
                outcome.first_tokens.push(sr.req.id);
                outcome.emitted.push(sr.req.id);
                if sr.generated >= sr.req.output_len {
                    outcome.finished.push(sr.req.id);
                    sr.finish = Some(now);
                    self.done.push(sr);
                } else {
                    self.running.push(sr);
                }
            }
        } else {
            // Decode completion: everyone emits one token.
            let mut still_running = Vec::with_capacity(self.running.len());
            for mut sr in self.running.drain(..) {
                sr.generated += 1;
                sr.ctx += 1;
                sr.token_times.push(now);
                outcome.emitted.push(sr.req.id);
                if sr.generated >= sr.req.output_len {
                    outcome.finished.push(sr.req.id);
                    sr.finish = Some(now);
                    self.done.push(sr);
                } else {
                    still_running.push(sr);
                }
            }
            self.running = still_running;
        }
        outcome
    }

    /// Duration of the iteration currently in flight.
    pub fn pending_duration(&self) -> f64 {
        self.pending_duration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::model::LlamaConfig;

    fn instance(mode: ServingMode) -> SimInstance {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        SimInstance::new(0, model, mode, 32, 8, 64)
    }

    fn req(id: u64, adapter: u64, prompt: usize, output: usize) -> WorkloadRequest {
        WorkloadRequest {
            id,
            arrival: 0.0,
            adapter,
            rank: 64,
            prompt_len: prompt,
            output_len: output,
        }
    }

    fn run_to_completion(inst: &mut SimInstance) -> f64 {
        let mut t = 0.0;
        let mut guard = 0;
        while inst.has_work() {
            let d = inst.start_iteration(t);
            t += d;
            inst.finish_iteration(t);
            guard += 1;
            assert!(guard < 100_000, "non-terminating sim");
        }
        t
    }

    #[test]
    fn single_request_lifecycle() {
        let mut inst = instance(ServingMode::Cached);
        inst.enqueue(req(1, 1, 64, 5));
        let end = run_to_completion(&mut inst);
        assert_eq!(inst.done.len(), 1);
        let r = &inst.done[0];
        assert_eq!(r.generated, 5);
        assert_eq!(r.token_times.len(), 5);
        assert!(r.first_token.unwrap() > 0.0);
        assert!((r.finish.unwrap() - end).abs() < 1e-12);
        // Cached mode: zero cold start.
        assert_eq!(r.cold_start, 0.0);
        // 1 prefill + 4 decode iterations.
        assert_eq!(inst.iters.iter().filter(|i| i.is_prefill).count(), 1);
        assert_eq!(inst.iters.iter().filter(|i| !i.is_prefill).count(), 4);
    }

    #[test]
    fn ondemand_pays_cold_start_caraserve_hides_most() {
        let mut on = instance(ServingMode::OnDemand);
        on.enqueue(req(1, 1, 64, 5));
        run_to_completion(&mut on);
        let cold_on = on.done[0].cold_start;
        assert!(cold_on > 5e-3, "ondemand cold={cold_on}");

        let mut cara = instance(ServingMode::CaraServe);
        cara.enqueue(req(1, 1, 64, 5));
        run_to_completion(&mut cara);
        let cold_cara = cara.done[0].cold_start;
        assert!(
            cold_cara < cold_on * 0.7,
            "cara={cold_cara} on={cold_on}"
        );
    }

    #[test]
    fn warm_adapter_has_no_cold_start() {
        let mut inst = instance(ServingMode::OnDemand);
        inst.enqueue(req(1, 7, 32, 2));
        run_to_completion(&mut inst);
        // Same adapter again: now resident.
        inst.enqueue(req(2, 7, 32, 2));
        run_to_completion(&mut inst);
        assert_eq!(inst.done[1].cold_start, 0.0);
    }

    #[test]
    fn lru_eviction_causes_recold() {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut inst = SimInstance::new(0, model, ServingMode::OnDemand, 32, 8, 2);
        for (i, ad) in [(1u64, 1u64), (2, 2), (3, 3)] {
            inst.enqueue(req(i, ad, 16, 1));
            run_to_completion(&mut inst);
        }
        // Adapter 1 was evicted by 3 (capacity 2) → cold again.
        inst.enqueue(req(4, 1, 16, 1));
        run_to_completion(&mut inst);
        assert!(inst.done[3].cold_start > 0.0);
    }

    #[test]
    fn new_arrival_preempts_decode() {
        let mut inst = instance(ServingMode::Cached);
        inst.enqueue(req(1, 1, 64, 50));
        let d1 = inst.start_iteration(0.0);
        inst.finish_iteration(d1);
        // Request 1 decoding; request 2 arrives.
        inst.enqueue(req(2, 2, 64, 50));
        let d2 = inst.start_iteration(d1);
        // Must be a prefill iteration (preempts decode).
        assert!(inst.iters.last().unwrap().is_prefill);
        inst.finish_iteration(d1 + d2);
        assert_eq!(inst.running.len(), 2);
    }

    #[test]
    fn batch_respects_max_batch() {
        let model = GpuModel::new(LlamaConfig::llama2_7b(), GpuSpec::a10(), 1);
        let mut inst = SimInstance::new(0, model, ServingMode::Cached, 2, 8, 64);
        for i in 0..5 {
            inst.enqueue(req(i, i as u64, 16, 10));
        }
        let d = inst.start_iteration(0.0);
        inst.finish_iteration(d);
        assert_eq!(inst.running.len(), 2);
        assert_eq!(inst.queue.len(), 3);
    }

    #[test]
    fn slora_uses_mbgmv_kernel() {
        assert_eq!(ServingMode::SLora.kernel(), KernelKind::Mbgmv);
        assert_eq!(ServingMode::CaraServe.kernel(), KernelKind::Bgmv);
    }

    #[test]
    fn iter_outcome_reports_token_emissions() {
        let mut inst = instance(ServingMode::Cached);
        inst.enqueue(req(1, 1, 64, 3));
        let d = inst.start_iteration(0.0);
        let out = inst.finish_iteration(d);
        assert_eq!(out.first_tokens, vec![1]);
        assert_eq!(out.emitted, vec![1]);
        assert!(out.finished.is_empty());
        let mut t = d;
        let mut finished = Vec::new();
        while inst.has_work() {
            let d = inst.start_iteration(t);
            t += d;
            let out = inst.finish_iteration(t);
            assert!(out.first_tokens.is_empty());
            finished.extend(out.finished);
        }
        assert_eq!(finished, vec![1]);
    }

    #[test]
    fn adapter_cache_lru_semantics() {
        let mut c = AdapterCache::new(2);
        c.insert(1);
        c.insert(2);
        assert!(c.touch(1)); // 1 now MRU
        c.insert(3); // evicts 2
        assert!(c.touch(1));
        assert!(!c.touch(2));
        assert!(c.touch(3));
        assert_eq!(c.len(), 2);
    }
}
